"""Version-robust shims over the moving parts of the jax API.

The repo targets the jax version baked into the container (0.4.x today) but
is written against the current-API names; every call site imports these
symbols from here instead of guessing which jax exposes them:

  shard_map : ``jax.shard_map`` (new) or ``jax.experimental.shard_map``
              (0.4.x).  The old implementation's replication checker predates
              the vma system the bodies are written for, so the fallback
              disables ``check_rep``.
  pvary     : ``jax.lax.pvary`` where it exists; identity on 0.4.x (which
              has no varying-manual-axes tracking to satisfy).
  make_mesh : ``jax.make_mesh`` with ``axis_types=Auto`` where supported;
              plain ``jax.make_mesh(shape, axes)`` on 0.4.x (Auto is the
              only behaviour the old version has).
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pvary", "make_mesh", "axis_size", "set_mesh"]


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    def set_mesh(mesh):
        # on 0.4.x the Mesh object IS the context manager
        return mesh


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        kwargs.setdefault("check_rep", False)
        kwargs.pop("check_vma", None)
        return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)


if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:
    def pvary(x, axis_name):
        return x


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        # psum of a Python constant folds to the axis size statically
        return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names):
    """An Auto-typed mesh on any jax version."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    except (ImportError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
