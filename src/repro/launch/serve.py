"""Serving launcher: continuous-batching engine over paged FP8 KV and
W8-resident weights.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_moe_235b \
      --reduced --requests 16 [--bf16-kv] [--no-w8]

Drives a synthetic trace through serve/engine.py: FCFS admission against a
token budget, interleaved bucketed prefill + masked full-batch decode in one
jitted step, youngest-first eviction under page pressure.  The old
fixed-batch shared-position loop lives on in serve/serve_step.py for the
dry-run shape cells.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.sharding import make_plan
from repro.models.lm import ParallelPlan, init_params
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_235b")
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=128)
    ap.add_argument("--max-pages", type=int, default=8)
    ap.add_argument("--token-budget", type=int, default=512)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: max prompt tokens per tick")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache: shared page-aligned prompt "
                         "prefixes are quantized+prefilled once and reused "
                         "across requests (refcounted FP8 KV pages)")
    ap.add_argument("--disagg", action="store_true",
                    help="prefill/decode disaggregation: a two-tier fleet "
                         "(prefill replicas park finished prefills; KV pages "
                         "migrate bit-for-bit to decode replicas under a "
                         "transfer-bytes budget)")
    ap.add_argument("--prefill-replicas", type=int, default=1)
    ap.add_argument("--decode-replicas", type=int, default=1)
    ap.add_argument("--transfer-budget", type=int, default=1 << 20,
                    metavar="BYTES",
                    help="KV migration wire bytes per router drain cycle")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--bf16-kv", action="store_true")
    ap.add_argument("--no-w8", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-jsonl", default=None, metavar="PATH",
                    help="write structured serve telemetry (per-tick "
                         "records, request_done events with TTFT/TBT, "
                         "KV-pool occupancy) as JSONL for "
                         "`python -m repro.obs.report`")
    ap.add_argument("--obs-prom", default=None, metavar="PATH",
                    help="write a Prometheus text-exposition snapshot of "
                         "the serve metrics registry at exit")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh()
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    else:
        mesh = make_production_mesh()
        plan = make_plan(cfg, mesh)

    recipe = get_recipe(args.recipe)
    params = init_params(cfg, jax.random.key(0))
    fp8 = recipe.name == "fp8_flow"
    ecfg = ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        n_pages=args.n_pages, max_pages_per_req=args.max_pages,
        token_budget=args.token_budget, prefill_buckets=(16, 32, 64),
        prefill_chunk=args.prefill_chunk,
        fp8_kv=fp8 and not args.bf16_kv,
        w8_weights=fp8 and not args.no_w8,
        prefix_cache=args.prefix_cache, seed=args.seed)
    from repro.obs.sink import JsonlSink, Telemetry, null_telemetry
    if args.obs_jsonl is not None or args.obs_prom is not None:
        sinks = (JsonlSink(args.obs_jsonl),) if args.obs_jsonl else ()
        tel = Telemetry(sinks=sinks)
    else:
        tel = null_telemetry()
    if args.disagg:
        import dataclasses as _dc
        from repro.serve.router import DisaggConfig, DisaggRouter
        pes = [ServeEngine(cfg, recipe, plan, params,
                           _dc.replace(ecfg, role="prefill", seed=ecfg.seed),
                           telemetry=tel)
               for _ in range(args.prefill_replicas)]
        des = [ServeEngine(cfg, recipe, plan, params,
                           _dc.replace(ecfg, role="decode", seed=ecfg.seed),
                           telemetry=tel)
               for _ in range(args.decode_replicas)]
        runner = DisaggRouter(
            pes, des, dcfg=DisaggConfig(
                transfer_budget_bytes=args.transfer_budget), telemetry=tel)
        engine = pes[0]
        print(f"[serve] disaggregated fleet: {len(pes)} prefill + "
              f"{len(des)} decode replicas, transfer budget "
              f"{args.transfer_budget / 2**20:.2f} MiB/cycle")
    else:
        engine = ServeEngine(cfg, recipe, plan, params, ecfg, telemetry=tel)
        runner = engine
    print(f"[serve] {args.arch} recipe={recipe.name} "
          f"kv={'fp8' if ecfg.fp8_kv else 'bf16'} "
          f"w8={ecfg.w8_weights} pool={engine.kv_bytes()/2**20:.1f} MiB")

    r = np.random.default_rng(args.seed)
    reqs = [Request(prompt=list(r.integers(1, cfg.vocab,
                                           int(r.integers(3, 17)))),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    results = runner.run(reqs, realtime=False)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v["tokens"]) for v in results.values())
    print(f"[serve] {len(results)}/{args.requests} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok / dt:.1f} tok/s), "
          f"max concurrent {engine.max_concurrent}")
    s = results.stats
    print(f"[serve] ticks={s['ticks']} admitted={s['admitted']} "
          f"evicted={s['evicted']} finished={s['finished']} "
          f"prefill_chunks={s['prefill_chunks']} "
          f"decode_tokens={s['decode_tokens']}")
    if args.disagg:
        d = s["disagg"]
        print(f"[serve] disagg: migrations={d['migrations']} "
              f"wire={d['kv_transfer_bytes'] / 2**20:.2f} MiB "
              f"shipped_pages={d['shipped_pages']} "
              f"deduped_pages={d['deduped_pages']} "
              f"requeued={d['requeued_evictions']} "
              f"deferrals={d['budget_deferrals']}")
    if args.prefix_cache:
        total_prompt = sum(len(q.prompt) for q in reqs)
        print(f"[serve] prefix cache: hits={s['prefix_hits']}/"
              f"{s['prefix_lookups']} hit_tokens={s['prefix_hit_tokens']}"
              f"/{total_prompt} shared_pages={s['shared_pages']} "
              f"cache_evictions={s['cache_evictions']}")
    if args.obs_prom is not None:
        tel.write_prometheus(args.obs_prom)
        print(f"[serve] wrote metrics snapshot to {args.obs_prom}")
    if args.obs_jsonl is not None:
        tel.emit_registry()
        tel.close()
        print(f"[serve] wrote telemetry to {args.obs_jsonl} "
              f"(report: python -m repro.obs.report {args.obs_jsonl})")


if __name__ == "__main__":
    main()
