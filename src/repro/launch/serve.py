"""Serving launcher: batched decode against a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_moe_235b \
      --reduced --tokens 16 [--fp8-kv]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.sharding import make_plan
from repro.models.lm import ParallelPlan, init_cache, init_params
from repro.serve.serve_step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_235b")
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--fp8-kv", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh()
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    else:
        mesh = make_production_mesh()
        plan = make_plan(cfg, mesh)

    recipe = get_recipe(args.recipe)
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, args.batch, args.max_len, fp8_kv=args.fp8_kv)
    step = jax.jit(make_serve_step(cfg, recipe, plan))
    toks = jnp.ones((args.batch, 1), jnp.int32)
    with mesh:
        t0 = time.perf_counter()
        for t in range(args.tokens):
            toks, cache = step(params, cache, toks, jnp.int32(t))
        jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.tokens} tokens x {args.batch} requests in "
          f"{dt:.2f}s ({args.tokens * args.batch / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
