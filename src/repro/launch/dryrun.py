"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory_analysis / cost_analysis / collective-bytes for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_moe_235b \
      --shape train_4k [--multi-pod] [--recipe fp8_flow] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every defined cell
"""
# The production mesh needs 512 placeholder devices; jax locks the device
# count at first init, so this MUST precede every other import.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.compat import set_mesh  # noqa: E402

from repro.configs import ARCH_IDS, get_arch  # noqa: E402
from repro.configs.base import SHAPES, applicable_shapes  # noqa: E402
from repro.core.recipes import get_recipe  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import sharding  # noqa: E402
from repro.models.lm import init_cache, init_params, ParallelPlan  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.roofline import analysis  # noqa: E402


def opt_config_for(cfg) -> adamw.AdamWConfig:
    """>=100B params: bf16 moments, no separate master (memory plan §4)."""
    big = cfg.n_params() > 100e9
    return adamw.AdamWConfig(
        moment_dtype=jnp.bfloat16 if big else jnp.float32,
        master_weights=not big)


def _env_overrides(cfg):
    """Perf-iteration knobs (EXPERIMENTS.md §Perf): capacity factor and FP8
    KV cache, switchable per dry-run via env."""
    import dataclasses
    cf = os.environ.get("REPRO_CF")
    if cf:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cf))
    return cfg


def fp8_kv() -> bool:
    return os.environ.get("REPRO_FP8_KV", "0") == "1"


def w8_serve() -> bool:
    return os.environ.get("REPRO_W8", "0") == "1"


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    cfg = _env_overrides(get_arch(arch))
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        A = cfg.grad_accum
        mb = B // A
        S_tok = S - (cfg.frontend_len if cfg.frontend != "none" else 0)
        batch = {
            "tokens": sds((A, mb, S_tok), i32),
            "targets": sds((A, mb, S_tok), i32),
            "mask": sds((A, mb, S_tok), f32),
        }
        if cfg.frontend != "none":
            batch["prefix"] = sds((A, mb, cfg.frontend_len, cfg.d_model), bf16)
        if cfg.encdec:
            batch["enc_input"] = sds((A, mb, S, cfg.d_model), bf16)
        if A == 1:
            batch = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                     for k, v in batch.items()}
        return {"batch": batch}

    if shape.kind == "prefill":
        S_tok = S - (cfg.frontend_len if cfg.frontend != "none" else 0)
        batch = {"tokens": sds((B, S_tok), i32)}
        if cfg.frontend != "none":
            batch["prefix"] = sds((B, cfg.frontend_len, cfg.d_model), bf16)
        if cfg.encdec:
            batch["enc_input"] = sds((B, S, cfg.d_model), bf16)
        return {"batch": batch}

    # decode
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S, fp8_kv=fp8_kv()))
    return {
        "cache": cache,
        "tokens": sds((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               recipe_name: str = "fp8_flow"):
    """Returns (jitted_fn, example_args_with_shardings, meta)."""
    cfg = _env_overrides(get_arch(arch))
    shape = SHAPES[shape_name]
    recipe = get_recipe(recipe_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = sharding.make_plan(cfg, mesh)
    n_chips = 512 if multi_pod else 256

    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0)))
    if shape.kind == "decode" and w8_serve():
        # W8-resident serving: pre-quantized FP8 weights, no FSDP gathers
        import dataclasses as _dc
        from repro.serve.w8 import quantize_params_for_serving
        params_shapes = jax.eval_shape(quantize_params_for_serving,
                                       params_shapes)
        cfg_specs = _dc.replace(cfg, fsdp=False)
        plan = _dc.replace(plan, fsdp_axis=None)
        params_sh = sharding.tree_specs(cfg_specs, mesh, params_shapes)
    else:
        params_sh = sharding.tree_specs(cfg, mesh, params_shapes)
    ins = input_specs(arch, shape_name)

    if shape.kind == "train":
        opt = opt_config_for(cfg)
        opt_shapes = jax.eval_shape(
            lambda ps: adamw.init_state(opt, ps), params_shapes)
        opt_sh = sharding.opt_state_specs(cfg, mesh, params_sh, opt_shapes)
        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        state_sh = {"params": params_sh, "opt": opt_sh}
        batch_sh = sharding.batch_specs(mesh, ins["batch"], plan.dp_axes)
        from repro.train.train_step import make_train_step
        step = make_train_step(cfg, recipe, plan, opt,
                               grad_accum=cfg.grad_accum)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        args = (state_shapes, ins["batch"])
        tokens = shape.global_batch * shape.seq_len
        mf = analysis.model_flops_train(cfg, tokens)
    elif shape.kind == "prefill":
        batch_sh = sharding.batch_specs(mesh, ins["batch"], plan.dp_axes)
        from repro.serve.serve_step import make_prefill
        step = make_prefill(cfg, recipe, plan)
        fn = jax.jit(step, in_shardings=(params_sh, batch_sh))
        args = (params_shapes, ins["batch"])
        tokens = shape.global_batch * shape.seq_len
        mf = analysis.model_flops_decode(cfg, tokens)
    else:
        cache_sh = sharding.cache_specs(cfg, mesh, ins["cache"], plan.dp_axes)
        tok_sh = sharding.batch_specs(mesh, {"tokens": ins["tokens"]},
                                      plan.dp_axes)["tokens"]
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.serve.serve_step import make_serve_step
        step = make_serve_step(cfg, recipe, plan)
        fn = jax.jit(step, in_shardings=(params_sh, cache_sh, tok_sh,
                                         NamedSharding(mesh, P())),
                     donate_argnums=(1,))
        args = (params_shapes, ins["cache"], ins["tokens"], ins["pos"])
        mf = analysis.model_flops_decode(cfg, shape.global_batch)

    meta = {"arch": arch, "shape": shape_name, "recipe": recipe_name,
            "multi_pod": multi_pod, "n_chips": n_chips,
            "model_flops_global": mf, "mesh": dict(mesh.shape)}
    return fn, args, meta, mesh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             recipe_name: str = "fp8_flow", verbose: bool = True,
             probe: bool = True):
    t0 = time.time()
    fn, args, meta, mesh = build_cell(arch, shape_name, multi_pod=multi_pod,
                                      recipe_name=recipe_name)
    with set_mesh(mesh):
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    if probe:
        # trip-count-correct roofline via component probes (probe.py)
        from repro.roofline import probe as probe_mod
        cfg = _env_overrides(get_arch(arch))
        shape = SHAPES[shape_name]
        plan = sharding.make_plan(cfg, mesh)
        params_shapes = jax.eval_shape(
            lambda: init_params(cfg, jax.random.key(0)))
        if shape.kind == "decode" and w8_serve():
            import dataclasses as _dc
            from repro.serve.w8 import quantize_params_for_serving
            params_shapes = jax.eval_shape(quantize_params_for_serving,
                                           params_shapes)
            plan = _dc.replace(plan, fsdp_axis=None)
            cfg = _dc.replace(cfg, fsdp=False)
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            cost = probe_mod.probe_train(cfg, get_recipe(recipe_name), plan,
                                         mesh, params_shapes,
                                         B // cfg.grad_accum, S)
        else:
            cost = probe_mod.probe_infer(cfg, get_recipe(recipe_name), plan,
                                         mesh, params_shapes, B, S,
                                         decode=shape.kind == "decode")
        roof = analysis.Roofline(
            flops=cost["flops"], hbm_bytes=cost["hbm_bytes"],
            coll_bytes=cost["coll_bytes"], coll_by_kind=cost["coll_by_kind"],
            model_flops=meta["model_flops_global"] / meta["n_chips"],
            n_chips=meta["n_chips"])
    else:
        roof = analysis.analyze(
            compiled, model_flops_global=meta["model_flops_global"],
            n_chips=meta["n_chips"])
    rec = dict(meta)
    rec.update({
        "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "roofline": roof.to_dict(),
    })
    if verbose:
        m = rec["memory"]
        r = rec["roofline"]
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'} ({recipe_name}): "
              f"args={m['argument_bytes']/2**30:.2f}GiB "
              f"temp={m['temp_bytes']/2**30:.2f}GiB "
              f"peak~{m['peak_bytes_est']/2**30:.2f}GiB | "
              f"t_comp={r['t_compute']*1e3:.1f}ms "
              f"t_mem={r['t_memory']*1e3:.1f}ms "
              f"t_coll={r['t_collective']*1e3:.1f}ms "
              f"bottleneck={r['bottleneck']} mfu={r['mfu']:.2%} "
              f"({rec['compile_s']}s compile)")
    return rec


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        for shape_name in applicable_shapes(cfg):
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    records = []
    for arch, shape_name in cells:
        for mp in meshes:
            try:
                records.append(run_cell(arch, shape_name, multi_pod=mp,
                                        recipe_name=args.recipe))
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                records.append({"arch": arch, "shape": shape_name,
                                "multi_pod": mp, "recipe": args.recipe,
                                "ok": False, "error": f"{type(e).__name__}: {e}"})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {len(records)} records -> {args.out}")
    n_ok = sum(1 for r in records if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(records)} cells compiled")
    return 0 if n_ok == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
