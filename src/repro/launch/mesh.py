"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: a leading pod=2 axis = 512 chips; the pod axis carries pure
    data parallelism (gradient all-reduce over the slow inter-pod links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for single-process tests."""
    return make_mesh(shape, axes)
