"""Sharding plans: arch -> ParallelPlan + pytree PartitionSpecs.

Rules (DESIGN.md §4):
  TP ('model')    attention heads / d_ff / vocab, when divisible
  EP ('model')    MoE experts when n_experts % tp == 0 (else TP-in-expert)
  DP ('data' [+ 'pod'])  batch/tokens; optimizer state ZeRO-1 over 'data'
  FSDP ('data')   d_model dim of the huge expert/MLP weights (>=200B archs),
                  gathered inside the shard_map blocks
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.lm import ParallelPlan, mlp_tp_ok


def make_plan(cfg: ArchConfig, mesh) -> ParallelPlan:
    import os
    axes = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    tp = mesh.shape["model"]
    moe_mode = "ep" if (cfg.moe and cfg.n_experts % tp == 0
                        and cfg.n_experts >= tp) else "tp"
    return ParallelPlan(
        mesh=mesh, dp_axes=dp_axes, tp_axis="model", moe_mode=moe_mode,
        fsdp_axis="data" if cfg.fsdp else None,
        shard_map_mlp=True,
        moe_tp_combine=os.environ.get("REPRO_MOE_TP_COMBINE", "local_first"),
        mlp_tp=os.environ.get("REPRO_MLP_TP", "0") == "1",
    )


def _tp_ok(n, tp):
    return n % tp == 0


def _attn_param_bytes(cfg: ArchConfig) -> int:
    hd = cfg.head_dim
    per = cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * hd \
        + cfg.n_heads * hd * cfg.d_model
    return cfg.n_layers * per * 2


def param_specs(cfg: ArchConfig, mesh) -> Any:
    """PartitionSpec pytree matching init_params' structure (by leaf path)."""
    tp = mesh.shape["model"]
    fs = "data" if cfg.fsdp else None
    # attention weights: replicate when small (<1 GiB total — psum'd Wgrad is
    # cheaper than per-layer gathers), else FSDP over data x model jointly
    attn_shard = _attn_param_bytes(cfg) > 2 ** 30
    emb_tp = _tp_ok(cfg.vocab_padded, tp)

    def leaf_spec(path: str, ndim: int) -> P:
        # `path` is the dotted key path WITHOUT the stacking dim; specs below
        # are written for the stacked array (leading None for the layer dim
        # when ndim exceeds the per-layer rank).
        name = path.split(".")[-1]
        lead = (None,) * (ndim - _per_layer_rank(name))

        def spec(*s):
            return P(*(lead + s))

        if name == "embed":
            return P("model" if emb_tp else None, None)
        if name == "lm_head":
            return P(None, "model" if emb_tp else None)
        # attention is sequence-parallel (CP) — heads never TP-shard; the
        # projection weights FSDP over data x model jointly (gathered per
        # layer inside the scan) when big, replicated when small
        if name in ("wq", "wk", "wv", "wo"):
            return spec(("data", "model") if attn_shard else None, None)
        # dense MLP weights: DP-mode baseline (no TP) — replicated when
        # small, FSDP over 'data' when cfg.fsdp (gathered inside shard_map)
        if name == "w13":                     # (D, g, F)
            return spec(fs, None, None)
        if name == "w2":                      # (F, D)
            return spec(None, fs)
        if name == "ws13":                    # shared expert (D, g, Fs)
            return spec(fs, None, None)
        if name == "ws2":
            return spec(None, fs)
        if name == "we13":                    # (E, D, g, Fe)
            if cfg.n_experts % tp == 0 and cfg.n_experts >= tp:
                return spec("model", fs, None, None)          # EP
            return spec(None, fs, None, "model")              # TP-in-expert
        if name == "we2":                     # (E, Fe, D)
            if cfg.n_experts % tp == 0 and cfg.n_experts >= tp:
                return spec("model", None, fs)
            return spec(None, "model", fs)
        if name == "in_proj":                 # mamba (D, k) — replicated TP
            return spec("data" if fs else None, None)
        if name == "out_proj":
            return spec(None, "data" if fs else None)
        return P(*((None,) * ndim))           # norms, biases, router, conv

    return leaf_spec


_PER_LAYER_RANK = {
    "embed": 2, "lm_head": 2,
    "wq": 2, "wk": 2, "wv": 2, "wo": 2,
    "w13": 3, "w2": 2, "ws13": 3, "ws2": 2,
    "we13": 4, "we2": 3,
    "in_proj": 2, "out_proj": 2,
}


def _per_layer_rank(name):
    return _PER_LAYER_RANK.get(name, 0)


def tree_specs(cfg: ArchConfig, mesh, tree_shapes) -> Any:
    """Build the full PartitionSpec pytree for a params-shaped tree."""
    ls = param_specs(cfg, mesh)

    def to_spec(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        # QTensor leaves ('we13.data' / 'we13.scale') follow the parent rule
        if keys and str(keys[-1]) in ("data", "scale") and len(keys) > 1:
            keys = keys[:-1]
        name = ".".join(str(k) for k in keys)
        ndim = len(leaf.shape)
        base = ls(name, ndim)
        if len(base) < ndim:
            base = P(*(tuple(base) + (None,) * (ndim - len(base))))
        if len(base) > ndim:
            base = P(*tuple(base)[:ndim])
        # drop shardings that don't divide the dim evenly
        fixed = []
        for dim, ax in zip(leaf.shape, tuple(base)):
            if ax is None:
                fixed.append(None)
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else \
                int(jnp.prod(jnp.array([mesh.shape[a] for a in ax])))
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(to_spec, tree_shapes)


def opt_state_specs(cfg: ArchConfig, mesh, params_specs, opt_shapes) -> Any:
    """ZeRO-1: moments/master additionally sharded over 'data' on the first
    dim that divides evenly and is not already sharded.  Tolerates QTensor-
    encoded state leaves (AdamWConfig.state_policy): their flat (rows, TILE)
    payload+scale pair shards over 'data' on the row dim."""
    dsize = mesh.shape["data"]

    def zero1(sharding, leaf):
        spec = (list(sharding.spec)
                + [None] * (len(leaf.shape) - len(sharding.spec)))
        spec = spec[:len(leaf.shape)]   # QTensor flat leaves drop param rank
        if any(s == "data" or (isinstance(s, tuple) and "data" in s)
               for s in spec):
            return NamedSharding(mesh, P(*spec))
        for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
            if s is None and dim % dsize == 0 and dim >= dsize:
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    def build(path, leaf):
        # path like ('m'|'v'|'master', <params path...>[, 'data'|'scale'])
        # or ('step',)
        if not path or getattr(path[0], "key", None) == "step":
            return NamedSharding(mesh, P())
        sub_path = path[1:]
        ps = params_specs
        qtensor_attr = False
        for k in sub_path:
            if not isinstance(ps, (dict, list, tuple)):
                qtensor_attr = True   # rest of the path is QTensor attrs
                break
            key = getattr(k, "key", getattr(k, "idx", None))
            ps = ps[key]
        if qtensor_attr:
            # flat (rows, TILE) payload/scale pair: the param's spec does
            # not apply to these dims — zero1 row-shard both consistently
            ps = NamedSharding(mesh, P())
        return zero1(ps, leaf)

    return jax.tree_util.tree_map_with_path(build, opt_shapes)


def dist_state_specs(mesh, opt_state, axis: str = "data") -> Any:
    """NamedShardings for a DistPlan optimizer state (repro.dist): the flat
    ZeRO-1 bucket arrays — e4m3/f16 payloads AND their po2 row scales —
    shard over the DP axis on the row dim (scale-aware: slicing rows slices
    payload and scales consistently); 'step' and the sensitive-leaf state
    stay replicated.  Pass to checkpointing.restore to re-shard a ZeRO-1
    checkpoint onto a different DP mesh size."""
    dsize = mesh.shape[axis]

    def spec(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        nd = getattr(leaf, "ndim", 0)
        if "flat" in keys and nd >= 1 and leaf.shape[0] % dsize == 0 \
                and leaf.shape[0] >= dsize:
            return NamedSharding(mesh, P(axis, *([None] * (nd - 1))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec, opt_state)


def _axes_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        out = 1
        for a in ax:
            out *= mesh.shape[a]
        return out
    return mesh.shape[ax]


def _fit(mesh, spec: P, shape) -> NamedSharding:
    """Drop partitions that don't divide the dim (e.g. batch 1 over dp)."""
    spec = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, ax in zip(shape, spec):
        fixed.append(ax if ax is not None and dim % _axes_size(mesh, ax) == 0
                     else None)
    return NamedSharding(mesh, P(*fixed))


def batch_specs(mesh, batch_shapes, dp_axes) -> Any:
    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        # grad-accum leading dim is unsharded: batch dim is dim0 for ndim<=3
        # ({tokens,targets,mask}: (B,S) / (A,B,S); prefix: (B,P,D))
        keys = [getattr(k, "key", "") for k in path]
        name = keys[-1] if keys else ""
        if name in ("tokens", "targets", "mask"):
            sp = P(None, dp_axes, None) if nd == 3 else P(dp_axes, None)
            return _fit(mesh, sp, leaf.shape)
        if name in ("prefix", "enc_input"):
            sp = P(None, dp_axes, None, None) if nd == 4 \
                else P(dp_axes, None, None)
            return _fit(mesh, sp, leaf.shape)
        return _fit(mesh, P(*([dp_axes] + [None] * (nd - 1))), leaf.shape)

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_specs(cfg: ArchConfig, mesh, cache_shapes, dp_axes) -> Any:
    """KV caches: batch over dp; heads over model if divisible, else head_dim
    over model (dense-GQA kv counts are small); SSM state heads over model."""
    tp = mesh.shape["model"]

    def spec(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):               # (L, B, S, KV, hd)
            kv, hd = leaf.shape[3], leaf.shape[4]
            if kv % tp == 0:
                sp = P(None, dp_axes, None, "model", None)
            elif hd % tp == 0:
                sp = P(None, dp_axes, None, None, "model")
            else:
                sp = P(None, dp_axes, None, None, None)
            return _fit(mesh, sp, leaf.shape)
        if name == "state":                  # (L, B, H, P, N)
            h = leaf.shape[2]
            sp = P(None, dp_axes, "model" if h % tp == 0 else None,
                   None, None)
            return _fit(mesh, sp, leaf.shape)
        if name == "conv":                   # (L, B, conv-1, ch)
            return _fit(mesh, P(None, dp_axes, None, None), leaf.shape)
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
