"""Sequential, resumable dry-run sweep over every (arch x shape x mesh) cell.

Appends one JSON record per cell to the output file as it goes (crash-safe);
already-present cells are skipped, so the sweep can be re-launched after
fixes and only failed/missing cells re-run.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse   # noqa: E402
import gc         # noqa: E402
import json       # noqa: E402
import signal     # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402


class CellTimeout(Exception):
    pass


def _alarm(signum, frame):
    raise CellTimeout()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun/sweep.json")
    ap.add_argument("--timeout", type=int, default=900)
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import all_cells, run_cell

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            for rec in json.load(f):
                done[(rec["arch"], rec["shape"], rec["multi_pod"],
                      rec.get("recipe", "fp8_flow"))] = rec

    meshes = []
    if "single" in args.meshes:
        meshes.append(False)
    if "multi" in args.meshes:
        meshes.append(True)

    records = list(done.values())
    signal.signal(signal.SIGALRM, _alarm)
    cells = [c for c in all_cells()
             if args.only_arch is None or c[0] == args.only_arch]
    todo = [(a, s, mp) for a, s in cells for mp in meshes
            if (a, s, mp, args.recipe) not in done
            or not done[(a, s, mp, args.recipe)].get("ok")]
    print(f"[sweep] {len(todo)} cells to run "
          f"({len(done)} cached in {args.out})", flush=True)

    for i, (arch, shape, mp) in enumerate(todo):
        key = (arch, shape, mp, args.recipe)
        signal.alarm(args.timeout)
        try:
            rec = run_cell(arch, shape, multi_pod=mp,
                           recipe_name=args.recipe)
        except CellTimeout:
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "recipe": args.recipe, "ok": False,
                   "error": f"timeout>{args.timeout}s"}
            print(f"[sweep] TIMEOUT {arch} x {shape} mp={mp}", flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "recipe": args.recipe, "ok": False,
                   "error": f"{type(e).__name__}: {str(e)[:500]}"}
        finally:
            signal.alarm(0)
        records = [r for r in records
                   if (r["arch"], r["shape"], r["multi_pod"],
                       r.get("recipe", "fp8_flow")) != key]
        records.append(rec)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        jax.clear_caches()
        gc.collect()
        print(f"[sweep] {i + 1}/{len(todo)} done", flush=True)

    n_ok = sum(1 for r in records if r.get("ok"))
    print(f"[sweep] finished: {n_ok}/{len(records)} ok", flush=True)


if __name__ == "__main__":
    main()
