"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek_v2_lite \
      --recipe fp8_flow --steps 100 [--reduced] [--ckpt-dir DIR] \
      [--elastic] [--dist-wire fp8] [--dist-schedule stream] \
      [--remat-policy fp8_resident] [--grad-accum N]

On a real TPU fleet this process runs once per host under
`jax.distributed.initialize()`; on this container use --reduced for an
executable configuration (full configs are exercised via launch.dryrun).

--dist-wire {off,fp8,bf16,f32} selects the explicit DP communication plan
(repro.dist.DistPlan): quantized ZeRO-1 gradient reduce-scatter + FP8-split
optimizer state.  It replaces the old implicit pjit-psum reduction (and the
never-wired --compress-pod-grads flag).  The wire needs a DP-only mesh, so
with --reduced the test mesh spans every visible device on the data axis.

--dist-schedule {posthoc,stream} picks WHEN the wire runs: 'posthoc'
reduces every bucket after the full backward; 'stream' aligns buckets to
layer boundaries and issues each bucket's quantize + reduce-scatter from
inside the staged backward the moment its layer's grads exist, hiding the
DP wire behind the remaining backward compute.  --grad-accum N streams
too: microbatch grads accumulate locally and each bucket goes on the wire
once, from the last microbatch's backward.  When the configuration cannot
stream (encoder-decoder arch, buckets that do not align to layer
boundaries) the launcher warns and falls back to 'posthoc' instead of
miscompiling.

--remat-policy selects the activation-residency plan
(train/memory.py MemoryPlan): 'fp8_resident' keeps only the QTensor stage
outputs across the forward/backward boundary (the paper's memory claim),
'pair' checkpoints two-layer blocks (compile-time lever at depth).

--guard arms the numerics guardrails (train/guards.py): train_step emits an
in-step anomaly bitmask (nonfinite loss/grads, grad-norm spikes vs a
carried EMA, FP8 saturation/underflow-flush fractions, wire-guard trips)
and the loop runs the skip -> rollback -> bf16-demote recovery ladder.
"""
import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig
from repro.dist import DistPlan
from repro.dist.grad_comm import wire_grad_bytes
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.sharding import dist_state_specs, make_plan
from repro.models.lm import ParallelPlan
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import ElasticTrainer
from repro.train.guards import GuardPlan, GuardPolicy
from repro.train.loop import run as run_loop
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_v2_lite")
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--elastic", action="store_true")
    ap.add_argument("--dist-wire", default="off",
                    choices=["off", "fp8", "bf16", "f32"],
                    help="explicit DP gradient wire + ZeRO-1 (repro.dist)")
    ap.add_argument("--dist-schedule", default="posthoc",
                    choices=["posthoc", "stream"],
                    help="reduce buckets after the backward (posthoc) or "
                         "stream them out of the staged backward in reverse "
                         "layer order (stream)")
    ap.add_argument("--remat-policy", default=None,
                    choices=["none", "full", "fp8_resident", "pair"],
                    help="activation-residency plan (train/memory.py "
                         "MemoryPlan): none = save everything, full = bf16 "
                         "stage checkpointing, fp8_resident = keep only the "
                         "QTensor stage outputs across fwd/bwd, pair = "
                         "checkpoint-of-pairs (compile-time lever)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches per step; with --dist-schedule "
                         "stream the wire runs once, from inside the last "
                         "microbatch's backward")
    ap.add_argument("--guard", action="store_true",
                    help="arm the numerics guardrails (train/guards.py): "
                         "in-step anomaly bitmask + skip/rollback/demote "
                         "recovery ladder with a bf16 fallback step")
    ap.add_argument("--guard-spike-factor", type=float, default=4.0,
                    help="grad-norm spike threshold as a multiple of the "
                         "carried EMA")
    ap.add_argument("--guard-rollback-after", type=int, default=3,
                    help="consecutive anomalous steps before restoring the "
                         "last valid checkpoint")
    ap.add_argument("--guard-demote-steps", type=int, default=8,
                    help="length of the bf16 fallback window entered after "
                         "persistent anomalies")
    ap.add_argument("--obs-jsonl", default=None, metavar="PATH",
                    help="write structured telemetry (typed loop events, "
                         "per-step samples with per-site FP8 sat/flush, "
                         "cast-ledger snapshots) as JSONL; feed the file to "
                         "`python -m repro.obs.report`")
    ap.add_argument("--obs-prom", default=None, metavar="PATH",
                    help="write a Prometheus text-exposition snapshot of "
                         "the metrics registry at exit")
    args = ap.parse_args()

    from repro.obs.sink import JsonlSink, Telemetry, null_telemetry
    if args.obs_jsonl is not None or args.obs_prom is not None:
        sinks = (JsonlSink(args.obs_jsonl),) if args.obs_jsonl else ()
        tel = Telemetry(sinks=sinks)
    else:
        tel = null_telemetry()

    dist = DistPlan(wire=args.dist_wire, schedule=args.dist_schedule) \
        if args.dist_wire != "off" else None
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=args.remat_policy)
    if args.reduced:
        # DP size must divide DistPlan.shard_multiple for equal ZeRO shards
        ndev = max(d for d in range(1, jax.device_count() + 1)
                   if dist.shard_multiple % d == 0
                   and jax.device_count() % d == 0) \
            if dist is not None else 1
        if dist is not None and ndev < jax.device_count():
            print(f"[train] WARNING: DP size clamped to {ndev} of "
                  f"{jax.device_count()} devices (must divide "
                  f"DistPlan.shard_multiple={dist.shard_multiple}); "
                  f"the rest sit idle")
        mesh = make_test_mesh((ndev, 1))
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = make_plan(cfg, mesh)
    print(f"[train] {args.arch} ({cfg.n_params()/1e9:.2f}B params) "
          f"recipe={args.recipe} mesh={dict(mesh.shape)}")
    if dist is not None:
        n_dp = mesh.shape[dist.axis]
        n = cfg.n_params()
        print(f"[train] dist wire={dist.wire} schedule={dist.schedule} "
              f"zero1 over '{dist.axis}' "
              f"x{n_dp}: ~{wire_grad_bytes(n, n_dp, dist.wire)/2**20:.0f} "
              f"MiB grad bytes/step/device "
              f"(bf16 all-reduce: {wire_grad_bytes(n, n_dp, 'bf16', 'none')/2**20:.0f} MiB)")

    recipe = get_recipe(args.recipe)
    opt = AdamWConfig(lr=args.lr)
    guard = GuardPlan(spike_factor=args.guard_spike_factor) \
        if args.guard else None
    state = init_train_state(cfg, opt, jax.random.key(0), dist=dist,
                             guard=guard)
    if dist is not None:
        # static wire accounting: one layout event + a modelled bytes/step
        # counter the loop increments every step
        from repro.dist import build_layout
        layout = build_layout(state["params"], dist)
        n_dp = mesh.shape[dist.axis]
        gbytes = wire_grad_bytes(cfg.n_params(), n_dp, dist.wire)
        tel.record("wire_layout", wire=dist.wire, schedule=dist.schedule,
                   n_dp=n_dp, n_buckets=len(layout.buckets),
                   n_sensitive=len(layout.sensitive),
                   n_leaves=layout.n_leaves, fp8_elems=layout.fp8_elems,
                   wire_rows=layout.wire_rows,
                   grad_bytes_per_step=gbytes)
        tel.per_step_counters["wire_grad_bytes_total"] = gbytes
        tel.per_step_counters["wire_buckets_total"] = len(layout.buckets)
    if dist is not None and dist.schedule == "stream":
        # fast clear fallback: if the layout's buckets cannot align to layer
        # boundaries (or the config cannot stream), warn and run post-hoc —
        # the layered layout is kept, so the ZeRO-1 state stays valid.
        # (grad_accum no longer blocks streaming: microbatch grads
        # accumulate locally and wire once on the last microbatch.)
        from repro.dist import build_layout, streaming_fallback_reason
        reason = streaming_fallback_reason(
            cfg, build_layout(state["params"], dist),
            grad_accum=args.grad_accum)
        if reason:
            print(f"[train] WARNING: streaming wire unavailable ({reason}); "
                  f"falling back to the post-hoc schedule")
            dist = dataclasses.replace(dist, schedule="posthoc")
    step = jax.jit(make_train_step(cfg, recipe, plan, opt, dist=dist,
                                   grad_accum=args.grad_accum,
                                   total_steps=args.steps,
                                   warmup_steps=max(args.steps // 10, 1),
                                   guard=guard))
    policy = fallback = None
    if guard is not None:
        policy = GuardPolicy(rollback_after=args.guard_rollback_after,
                             demote_steps=args.guard_demote_steps)
        # graceful degradation target: same arch/plan/opt under the bf16
        # recipe (no quantize sites), still guard-instrumented so the
        # ladder keeps observing while demoted
        if recipe.name != "bf16":
            fallback = jax.jit(make_train_step(
                cfg, get_recipe("bf16"), plan, opt, dist=dist,
                grad_accum=args.grad_accum, total_steps=args.steps,
                warmup_steps=max(args.steps // 10, 1), guard=guard))
        print(f"[train] guardrails armed: spike_factor="
              f"{args.guard_spike_factor} rollback_after="
              f"{args.guard_rollback_after} demote_steps="
              f"{args.guard_demote_steps}")
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    elastic = ElasticTrainer(n_data_shards=mesh.shape["data"]) \
        if args.elastic else None
    restore_sh = None
    if dist is not None and args.ckpt_dir is not None:
        restore_sh = {"params": jax.tree.map(
                          lambda _: None, state["params"]),
                      "opt": dist_state_specs(mesh, state["opt"], dist.axis)}
    with mesh:
        state, hist = run_loop(step, state, data, n_steps=args.steps,
                               grad_accum=args.grad_accum,
                               ckpt_dir=args.ckpt_dir, elastic=elastic,
                               restore_shardings=restore_sh,
                               guard_policy=policy, fallback_step=fallback,
                               telemetry=tel)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}")
    dev_ms = [h["device_ms"] for h in hist]
    fetch_ms = [h["fetch_ms"] for h in hist]
    print(f"[train] timing: device {sum(dev_ms)/len(dev_ms):.1f}ms/step, "
          f"host fetch {sum(fetch_ms)/len(fetch_ms):.1f}ms/step "
          f"({len(hist)} steps)")
    if args.obs_prom is not None:
        tel.write_prometheus(args.obs_prom)
        print(f"[train] wrote metrics snapshot to {args.obs_prom}")
    if args.obs_jsonl is not None:
        tel.emit_registry()
        tel.close()
        print(f"[train] wrote telemetry to {args.obs_jsonl} "
              f"(report: python -m repro.obs.report {args.obs_jsonl})")


if __name__ == "__main__":
    main()
