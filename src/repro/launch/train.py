"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek_v2_lite \
      --recipe fp8_flow --steps 100 [--reduced] [--ckpt-dir DIR] \
      [--elastic] [--compress-pod-grads]

On a real TPU fleet this process runs once per host under
`jax.distributed.initialize()`; on this container use --reduced for an
executable configuration (full configs are exercised via launch.dryrun).
"""
import argparse

import jax

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.sharding import make_plan
from repro.models.lm import ParallelPlan
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import ElasticTrainer
from repro.train.loop import run as run_loop
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_v2_lite")
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--elastic", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh()
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = make_plan(cfg, mesh)
    print(f"[train] {args.arch} ({cfg.n_params()/1e9:.2f}B params) "
          f"recipe={args.recipe} mesh={dict(mesh.shape)}")

    recipe = get_recipe(args.recipe)
    opt = AdamWConfig(lr=args.lr)
    step = jax.jit(make_train_step(cfg, recipe, plan, opt,
                                   total_steps=args.steps,
                                   warmup_steps=max(args.steps // 10, 1)))
    state = init_train_state(cfg, opt, jax.random.key(0))
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    elastic = ElasticTrainer(n_data_shards=mesh.shape["data"]) \
        if args.elastic else None
    with mesh:
        state, hist = run_loop(step, state, data, n_steps=args.steps,
                               ckpt_dir=args.ckpt_dir, elastic=elastic)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
