"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms, all in seconds-per-step on the TPU v5e target:
  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the compiled module is
the per-device SPMD partition, so these are per-chip numbers).  Collective
bytes are NOT in cost_analysis — we parse the compiled HLO text and sum the
payload bytes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (all-reduce counts 2x: reduce + broadcast phases of a
ring).  Scale buffers of FP8 collectives are counted like any other payload
— the paper's 'doubled buffers' effect is visible in the term.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e per-chip constants (DESIGN.md §5)
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_FP8 = 394e12        # fp8-native MXU ceiling (v6e-class), reported
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link (≈ one active direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start", "ragged-all-to-all")

# matches e.g.:  %all-gather.3 = bf16[8,128]{1,0} all-gather(bf16[1,128] %x)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(k for k in _COLL_KINDS) + r")\(")
_TUPLE_RE = re.compile(
    r"=\s*\(\s*((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s*("
    + "|".join(k for k in _COLL_KINDS) + r")\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind payload bytes of every collective in the (per-device) HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        kind = None
        for k in _COLL_KINDS:
            if f" {k}(" in line:
                kind = k.replace("-start", "")
                break
        if kind is None:
            continue
        # output payload(s): every shape on the LHS of '='
        lhs = line.split("=", 1)
        if len(lhs) != 2:
            continue
        shapes = _SHAPE_RE.findall(lhs[1].split(kind + "(")[0])
        nbytes = sum(_nbytes(dt, dims) for dt, dims in shapes)
        factor = 2 if kind == "all-reduce" else 1   # reduce + broadcast
        out[kind] = out.get(kind, 0) + nbytes * factor
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: float            # per-chip collective payload bytes
    coll_by_kind: Dict[str, int]
    model_flops: float           # 6*N*D useful flops (per chip)
    n_chips: int

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self):
        """No-overlap model: the dominant term bounds the step."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self):
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu(self):
        """Model-flops utilization at the no-overlap step time."""
        if self.step_time == 0:
            return 0.0
        return self.model_flops / PEAK_FLOPS_BF16 / self.step_time

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops, "n_chips": self.n_chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "step_time": self.step_time, "mfu": self.mfu,
            "useful_fraction": self.useful_fraction,
        }


def analyze(compiled, *, model_flops_global: float, n_chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        flops=flops, hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())),
        coll_by_kind=coll,
        model_flops=model_flops_global / n_chips,
        n_chips=n_chips)


def model_flops_train(cfg, tokens: int) -> float:
    """6*N_active*D (the standard 'useful' training flops)."""
    return 6.0 * cfg.active_params() * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_params() * tokens
