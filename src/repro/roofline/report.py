"""Render the dry-run sweep JSON into the EXPERIMENTS.md tables."""
from __future__ import annotations

import json
import sys


def _fmt_t(s):
    return f"{s * 1e3:8.1f}"


def render(path: str, multi_pod: bool = False) -> str:
    with open(path) as f:
        recs = json.load(f)
    recs = [r for r in recs if r.get("ok") and r["multi_pod"] == multi_pod]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    mesh = "2x16x16 (512)" if multi_pod else "16x16 (256)"
    out = [f"Mesh {mesh} — per-chip roofline terms (ms/step), v5e constants.",
           "",
           "| arch | shape | peak GiB | t_comp | t_mem | t_coll | bottleneck "
           "| MFU | useful |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory"]["peak_bytes_est"] / 2 ** 30
        ro = r["roofline"]
        fit = "" if m <= 16.0 else " (!)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {m:.1f}{fit} "
            f"| {_fmt_t(ro['t_compute'])} | {_fmt_t(ro['t_memory'])} "
            f"| {_fmt_t(ro['t_collective'])} | {ro['bottleneck']} "
            f"| {ro['mfu']:.1%} | {ro['useful_fraction']:.2f} |")
    return "\n".join(out)


def summary(path: str) -> str:
    with open(path) as f:
        recs = json.load(f)
    ok = [r for r in recs if r.get("ok")]
    n_fit = sum(1 for r in ok
                if r["memory"]["peak_bytes_est"] / 2 ** 30 <= 16.0)
    bn = {}
    for r in ok:
        if not r["multi_pod"]:
            bn[r["roofline"]["bottleneck"]] = \
                bn.get(r["roofline"]["bottleneck"], 0) + 1
    return (f"{len(ok)}/{len(recs)} cells compiled; "
            f"{n_fit}/{len(ok)} within the 16 GiB v5e budget "
            f"(CPU-measured, unfused-temp pessimistic); "
            f"single-pod bottlenecks: {bn}")


if __name__ == "__main__":
    p = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun/sweep.json"
    print(summary(p))
    print()
    print(render(p, multi_pod=False))
    print()
    print(render(p, multi_pod=True))
