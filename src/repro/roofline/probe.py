"""Component cost probes — trip-count-correct roofline accounting.

``compiled.cost_analysis()`` on a whole train step counts each ``lax.scan``
(while-loop) body ONCE, so an L-layer model's FLOPs would be undercounted by
~L x.  Instead we lower each repeated component separately at the SAME
shardings as the full step and multiply by its trip count:

  train    = n_groups x group_grad  +  head_loss_grad  +  optimizer_update
  prefill  = n_groups x group_fwd   +  head_logits
  decode   = n_groups x group_decode + head_logits

Every number still comes from a compiled XLA artifact of this cell's exact
shapes/shardings — the full-step compile remains the fit/compile proof; the
probes provide the per-step cost integral.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.roofline import analysis
from repro.compat import set_mesh


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    coll = analysis.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(coll.values())),
        "coll_by_kind": coll,
    }


def _acc(total, part, mult):
    total["flops"] += part["flops"] * mult
    total["hbm_bytes"] += part["hbm_bytes"] * mult
    total["coll_bytes"] += part["coll_bytes"] * mult
    for k, v in part["coll_by_kind"].items():
        total["coll_by_kind"][k] = total["coll_by_kind"].get(k, 0) + v * mult
    return total


def _x_sharding(mesh, plan, B, S):
    import numpy as np
    dp_size = int(np.prod([mesh.shape[a] for a in plan.dp_axes])) \
        if plan.dp_axes else 1
    dp = plan.dp_axes if B % max(dp_size, 1) == 0 else None
    seq = plan.tp_axis if S % mesh.shape[plan.tp_axis] == 0 else None
    return NamedSharding(mesh, P(dp, seq, None))


def _group_slice_shapes(cfg, params_shapes, stack_key="layers"):
    glen = len(cfg.pattern)
    nd = cfg.n_dense_layers if cfg.moe else 0
    n = (cfg.n_layers - nd) if stack_key == "layers" else \
        (nd if stack_key == "dense_layers" else cfg.n_enc_layers)
    if n % glen:
        glen = 1
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((glen,) + a.shape[1:], a.dtype),
        params_shapes[stack_key]), glen, n // glen


def _group_specs(cfg, mesh, slice_shapes):
    from repro.launch.sharding import tree_specs
    return tree_specs(cfg, mesh, slice_shapes)


def probe_train(cfg, recipe, plan, mesh, params_shapes, B, S):
    """Costs for one train step (global batch B x S) on this mesh."""
    from repro.models.lm import _sub_layer, layer_kinds

    total = {"flops": 0.0, "hbm_bytes": 0.0, "coll_bytes": 0.0,
             "coll_by_kind": {}}
    D = cfg.d_model
    x_sds = jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)
    x_sh = _x_sharding(mesh, plan, B, S)
    positions = jnp.arange(S, dtype=jnp.int32)

    def make_group_fn(pattern, moe):
        def run(x, pslice):
            aux = jnp.float32(0.0)
            for i in range(len(pattern)):
                pi = jax.tree.map(lambda a: a[i], pslice)
                x, a, _, _, _ = _sub_layer(cfg, recipe, plan, pattern[i],
                                           moe, pi, x, positions)
                aux = aux + a
            return x, aux

        from repro.train.memory import MemoryPlan
        ckpt = MemoryPlan.from_config(cfg).wrap(run)

        def grad_fn(x, pslice):
            (y, aux), vjp = jax.vjp(ckpt, x, pslice)
            gx, gp = vjp((jnp.ones_like(y), jnp.float32(1.0)))
            return gx, gp
        return grad_fn

    # main stack
    slice_shapes, glen, ng = _group_slice_shapes(cfg, params_shapes, "layers")
    pattern = cfg.pattern if len(cfg.pattern) == glen else (cfg.pattern[0],)
    fn = jax.jit(make_group_fn(pattern, cfg.moe),
                 in_shardings=(x_sh, _group_specs(cfg, mesh, slice_shapes)))
    with set_mesh(mesh):
        comp = fn.lower(x_sds, slice_shapes).compile()
    _acc(total, _cost_of(comp), ng * cfg.grad_accum)

    # dense prologue stack
    nd = cfg.n_dense_layers if cfg.moe else 0
    if nd:
        sl, glen_d, ng_d = _group_slice_shapes(cfg, params_shapes,
                                               "dense_layers")
        fn = jax.jit(make_group_fn((cfg.pattern[0],) * glen_d, False),
                     in_shardings=(x_sh, _group_specs(cfg, mesh, sl)))
        with set_mesh(mesh):
            comp = fn.lower(x_sds, sl).compile()
        _acc(total, _cost_of(comp), ng_d * cfg.grad_accum)

    # encoder stack (seamless)
    if cfg.encdec:
        sl, glen_e, ng_e = _group_slice_shapes(cfg, params_shapes,
                                               "enc_layers")
        fn = jax.jit(make_group_fn(("global",) * glen_e, False),
                     in_shardings=(x_sh, _group_specs(cfg, mesh, sl)))
        with set_mesh(mesh):
            comp = fn.lower(x_sds, sl).compile()
        _acc(total, _cost_of(comp), ng_e * cfg.grad_accum)

    # embedding + head + CE (fwd+bwd)
    total = _probe_head(cfg, recipe, plan, mesh, params_shapes, B, S, total,
                        train=True, mult=cfg.grad_accum)
    # optimizer update
    total = _probe_opt(cfg, mesh, params_shapes, total)
    return total


def _probe_head(cfg, recipe, plan, mesh, params_shapes, B, S, total, *,
                train, mult=1):
    from repro.models.lm import _lm_logits, _xent, _embed_tokens
    from repro.launch.sharding import tree_specs

    D = cfg.d_model
    Vp = cfg.vocab_padded
    emb_sds = params_shapes["embed"]
    head_key = "embed" if cfg.tie_embeddings else "lm_head"
    head_sds = params_shapes[head_key]
    sub = {"embed": emb_sds, head_key: head_sds}
    sub_specs = tree_specs(cfg, mesh, sub)
    x_sh = _x_sharding(mesh, plan, B, S)
    tok_sh = NamedSharding(mesh, P(
        plan.dp_axes if B % max(1, _dpsize(mesh, plan)) == 0 else None, None))

    def f(x, params, tokens, targets):
        emb = _embed_tokens(cfg, params, tokens)
        x = x + emb                    # stands in for the residual stream
        logits = _lm_logits(cfg, params, x, plan)
        if train:
            mask = jnp.ones_like(targets, jnp.float32)
            return _xent(logits, targets, mask)
        return jnp.sum(logits[:, -1, :].astype(jnp.float32))

    def g(x, params, tokens, targets):
        if train:
            _, grads = jax.value_and_grad(f, argnums=(0, 1))(
                x, params, tokens, targets)
            return grads
        return f(x, params, tokens, targets)

    x_sds = jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)
    t_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
    fn = jax.jit(g, in_shardings=(x_sh, sub_specs, tok_sh, tok_sh))
    with set_mesh(mesh):
        comp = fn.lower(x_sds, sub, t_sds, t_sds).compile()
    return _acc(total, _cost_of(comp), mult)


def _dpsize(mesh, plan):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in plan.dp_axes])) \
        if plan.dp_axes else 1


def _probe_opt(cfg, mesh, params_shapes, total):
    from repro.launch.dryrun import opt_config_for
    from repro.launch.sharding import opt_state_specs, tree_specs
    from repro.optim import adamw

    opt = opt_config_for(cfg)
    opt_shapes = jax.eval_shape(lambda ps: adamw.init_state(opt, ps),
                                params_shapes)
    p_specs = tree_specs(cfg, mesh, params_shapes)
    o_specs = opt_state_specs(cfg, mesh, p_specs, opt_shapes)
    g_shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16), params_shapes)

    def f(params, grads, state):
        return adamw.apply_updates(opt, params, grads, state)[:2]

    fn = jax.jit(f, in_shardings=(p_specs, p_specs, o_specs))
    with set_mesh(mesh):
        comp = fn.lower(params_shapes, g_shapes, opt_shapes).compile()
    return _acc(total, _cost_of(comp), 1)


def probe_infer(cfg, recipe, plan, mesh, params_shapes, B, S, *, decode):
    """Costs for prefill (full fwd) or one decode token."""
    from repro.models.lm import _sub_layer, init_cache

    total = {"flops": 0.0, "hbm_bytes": 0.0, "coll_bytes": 0.0,
             "coll_by_kind": {}}
    D = cfg.d_model

    if not decode:
        x_sds = jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)
        x_sh = _x_sharding(mesh, plan, B, S)
        positions = jnp.arange(S, dtype=jnp.int32)

        def make_fwd(pattern, moe):
            def run(x, pslice):
                for i in range(len(pattern)):
                    pi = jax.tree.map(lambda a: a[i], pslice)
                    x, _, _, _, _ = _sub_layer(cfg, recipe, plan, pattern[i],
                                               moe, pi, x, positions)
                return x
            return run

        sl, glen, ng = _group_slice_shapes(cfg, params_shapes, "layers")
        pattern = cfg.pattern if len(cfg.pattern) == glen else (cfg.pattern[0],)
        fn = jax.jit(make_fwd(pattern, cfg.moe),
                     in_shardings=(x_sh, _group_specs(cfg, mesh, sl)))
        with set_mesh(mesh):
            comp = fn.lower(x_sds, sl).compile()
        _acc(total, _cost_of(comp), ng)
        nd = cfg.n_dense_layers if cfg.moe else 0
        if nd:
            sl, glen_d, ng_d = _group_slice_shapes(cfg, params_shapes,
                                                   "dense_layers")
            fn = jax.jit(make_fwd((cfg.pattern[0],) * glen_d, False),
                         in_shardings=(x_sh, _group_specs(cfg, mesh, sl)))
            with set_mesh(mesh):
                comp = fn.lower(x_sds, sl).compile()
            _acc(total, _cost_of(comp), ng_d)
        if cfg.encdec:
            sl, glen_e, ng_e = _group_slice_shapes(cfg, params_shapes,
                                                   "enc_layers")
            fn = jax.jit(make_fwd(("global",) * glen_e, False),
                         in_shardings=(x_sh, _group_specs(cfg, mesh, sl)))
            with set_mesh(mesh):
                comp = fn.lower(x_sds, sl).compile()
            _acc(total, _cost_of(comp), ng_e)
        return _probe_head(cfg, recipe, plan, mesh, params_shapes, B, S,
                           total, train=False)

    # decode: one layer group against its cache slice
    from repro.launch.sharding import cache_specs
    from repro.models.lm import decode_step
    # probing per-group decode requires the cache slice machinery; instead
    # lower the FULL decode step and multiply the while-body by the group
    # count analytically is incorrect; so probe one group explicitly:
    from repro.launch.dryrun import fp8_kv
    kinds = [cfg.pattern[i % len(cfg.pattern)] for i in range(cfg.n_layers)]
    sl, glen, ng = _group_slice_shapes(cfg, params_shapes, "layers")
    pattern = cfg.pattern if len(cfg.pattern) == glen else (cfg.pattern[0],)
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, B, S, fp8_kv=fp8_kv()))
    c_specs = cache_specs(cfg, mesh, cache_shapes, plan.dp_axes)

    def grp(x, pslice, kslice, vslice, st, cv, pos):
        from repro.models.lm import _moe_stage, _mlp_decode
        from repro.models.layers import apply_norm, attn_block
        from repro.models.ssm import mamba2_block
        positions = jnp.full((1,), pos, jnp.int32)
        for i in range(len(pattern)):
            pi = jax.tree.map(lambda a: a[i], pslice)
            kind = pattern[i]
            h = apply_norm(cfg.norm, x, pi, "ln1")
            if kind == "ssm":
                mix, _, _ = mamba2_block(cfg, pi, h, state=st[i],
                                         conv_state=cv[i], decode=True)
            elif kind == "hybrid":
                a_out, _ = attn_block(cfg, pi, h, positions=positions,
                                      cache=(kslice[i], vslice[i]),
                                      cache_pos=pos)
                s_out, _, _ = mamba2_block(cfg, pi, h, state=st[i],
                                           conv_state=cv[i], decode=True)
                mix = 0.5 * (a_out + s_out)
            else:
                window = cfg.window if kind == "local" else 0
                mix, _ = attn_block(cfg, pi, h, positions=positions,
                                    layer_window=window,
                                    cache=(kslice[i], vslice[i]),
                                    cache_pos=pos)
            x = x + mix
            if not (kind == "ssm" and not cfg.d_ff):
                h2 = apply_norm(cfg.norm, x, pi, "ln2")
                if cfg.moe:
                    mo, _ = _moe_stage(cfg, recipe, plan, pi, h2, decode=True)
                else:
                    mo = _mlp_decode(cfg, pi, h2)
                x = x + mo
        return x

    x_sds = jax.ShapeDtypeStruct((B, 1, D), jnp.bfloat16)
    x_sh = NamedSharding(mesh, P(
        plan.dp_axes if B % max(1, _dpsize(mesh, plan)) == 0 else None,
        None, None))
    main = cache_shapes.get("main_attn")
    mssm = cache_shapes.get("main_ssm")

    def sl_k(c):
        return jax.tree.map(lambda a: jax.ShapeDtypeStruct(
            (glen,) + a.shape[1:], a.dtype), c)

    args = [x_sds, sl]
    in_sh = [x_sh, _group_specs(cfg, mesh, sl)]
    kty = vty = sty = cty = None
    if main is not None:
        kty, vty = sl_k(main["k"]), sl_k(main["v"])
    else:
        kty = vty = jax.ShapeDtypeStruct((glen, 1, 1, 1, 1), jnp.bfloat16)
    if mssm is not None:
        sty, cty = sl_k(mssm["state"]), sl_k(mssm["conv"])
    else:
        sty = cty = jax.ShapeDtypeStruct((glen, 1, 1, 1, 1), jnp.float32)
    cspec = cache_specs(cfg, mesh, {"k": kty, "v": vty}, plan.dp_axes) \
        if main is not None else {
            "k": NamedSharding(mesh, P()), "v": NamedSharding(mesh, P())}
    sspec = cache_specs(cfg, mesh, {"state": sty, "conv": cty}, plan.dp_axes) \
        if mssm is not None else {
            "state": NamedSharding(mesh, P()), "conv": NamedSharding(mesh, P())}
    args += [kty, vty, sty, cty, jax.ShapeDtypeStruct((), jnp.int32)]
    in_sh += [cspec["k"], cspec["v"], sspec["state"], sspec["conv"],
              NamedSharding(mesh, P())]
    fn = jax.jit(grp, in_shardings=tuple(in_sh))
    with set_mesh(mesh):
        comp = fn.lower(*args).compile()
    _acc(total, _cost_of(comp), ng)
    return _probe_head(cfg, recipe, plan, mesh, params_shapes, B, 1, total,
                       train=False)
