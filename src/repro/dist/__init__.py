"""FP8-native distributed training state & wire.

Extends the paper's casting-free dataflow across the data-parallel axis:
quantized gradient reduction (e4m3 payload + po2 exponent scales in one
uint8 message per bucket), FP8-split optimizer state, and ZeRO-1
scale-aware sharding.  See plan.py for the entry-point `DistPlan`.
"""
from repro.dist.plan import (DistPlan, GradLayout, build_layout,  # noqa: F401
                             streaming_fallback_reason)
from repro.dist.opt_state import StatePolicy  # noqa: F401
