"""The quantized data-parallel gradient wire.

One bucket -> ONE uint8 message (reusing the single-message packing idiom of
the overlapped EP dispatch, core/moe.py): the e4m3 payload rows and their
int8 po2 exponents are bitcast-packed side by side, so the reduce-scatter
costs one collective launch and (1 + 1/TILE) bytes per gradient element
instead of 2 (bf16) or 4 (f32).

Reduction semantics (mode='zero1'):
  1. every replica quantizes its LOCAL gradient bucket with the globally
     agreed po2 scale (scale_sync.agreed_po2_scale — a pmax of per-row amax);
  2. the packed message reduce-scatters (all_to_all of the P row-blocks);
  3. each replica dequantizes the P received sub-shards EXACTLY (shared po2
     scales) and sums in f32, then divides by P (gradient mean);
  4. the owned f32 shard feeds the ZeRO-1 optimizer update directly —
     it is never re-quantized, so the DP axis adds exactly one quantization
     per replica and no double quantization error.

Sensitive leaves (plan.is_sensitive) take reduce_sensitive: a bf16-cast psum
(or f32 when wire='f32'), replicated result.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import casts
from repro.core.fp8 import E4M3, E4M3_MAX, TILE
from repro.dist import scale_sync
from repro.obs.trace import annotate

_E4M3_BYTES = 1
_EXP_BYTES = 1


def _u8(x):
    """Bitcast to uint8, flattening the introduced trailing byte axis."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint8)
    return u.reshape(*x.shape[:-1], -1)


def pack_bucket(payload: jax.Array, exp: jax.Array) -> jax.Array:
    """(rows, TILE) e4m3 + (rows, 1) int8 -> (rows, TILE+1) uint8."""
    return jnp.concatenate([_u8(payload), _u8(exp)], axis=-1)


def unpack_bucket(msg: jax.Array):
    """Inverse of pack_bucket (works on any leading batch dims)."""
    payload = jax.lax.bitcast_convert_type(msg[..., :TILE], E4M3)
    exp = jax.lax.bitcast_convert_type(msg[..., TILE:], jnp.int8)
    return payload, exp


def quantize_bucket(flat: jax.Array, axis_name):
    """Quantize a (rows, TILE) f32 bucket with the AGREED per-row po2 scale.
    Returns (payload e4m3, exp int8 (rows, 1)); both scale-identical across
    the DP axis.  Recorded as a fused cast (it is part of the comm kernel,
    not a counted Fig.-2 activation cast)."""
    casts.record("fused_quantize", "dp_wire", flat.size)
    scale = scale_sync.agreed_po2_scale(flat, axis_name)
    payload = jnp.clip(flat / scale, -E4M3_MAX, E4M3_MAX).astype(E4M3)
    from repro.core import quant
    if quant.stats_armed():
        quant._maybe_record_stats("dp_wire", flat / scale, payload, E4M3_MAX)
    from repro.runtime import fault_injection
    payload = fault_injection.apply("wire_payload", "dp_wire", payload)
    exp = fault_injection.apply("wire_exp", "dp_wire",
                                scale_sync.scale_to_exp_i8(scale))
    return payload, exp


def reduce_scatter_bucket(flat: jax.Array, axis_name, n_shards: int,
                          wire: str, guard=None):
    """(rows, TILE) local f32 grads -> (rows/n_shards, TILE) owned f32 MEAN.

    rows must divide n_shards (plan.py pads to shard_multiple).  With one
    shard the wire is exercised end-to-end minus the collective.

    guard (a train/guards.py GuardPlan) arms the WIRE GUARD: the received
    message's exponents/payload are checked before the dequant-sum
    (scale_sync.wire_anomaly, replica-uniform) and a poisoned bucket drops
    to the bf16-psum fallback computed from the LOCAL pre-quantize f32
    gradient — the step's update survives the fault in-step.  Returns
    (owned, bad) instead of plain `owned` when guarded."""
    rows = flat.shape[0]
    assert rows % n_shards == 0, (rows, n_shards)

    with annotate(f"wire/rs_bucket_{wire}"):
        return _reduce_scatter_bucket(flat, axis_name, n_shards, wire, guard)


def _reduce_scatter_bucket(flat, axis_name, n_shards, wire, guard):
    rows = flat.shape[0]
    if wire == "fp8":
        payload, exp = quantize_bucket(flat, axis_name)
        msg = pack_bucket(payload, exp).reshape(n_shards, rows // n_shards,
                                                TILE + _EXP_BYTES)
        if axis_name is not None and n_shards > 1:
            msg = jax.lax.all_to_all(msg, axis_name, split_axis=0,
                                     concat_axis=0, tiled=False)
        pay, exps = unpack_bucket(msg)
        if guard is not None:
            bad = scale_sync.wire_anomaly(exps, pay, axis_name,
                                          guard.wire_exp_limit)

            def fp8_sum(_):
                parts = pay.astype(jnp.float32) * \
                    scale_sync.exp_i8_to_scale(exps)
                return jnp.sum(parts, axis=0)

            def bf16_fallback(_):
                # existing bf16-psum wire, sliced to the owned row block
                g = flat.astype(jnp.bfloat16)
                rows_l = rows // n_shards
                if axis_name is not None and n_shards > 1:
                    g = jax.lax.psum(g, axis_name)
                    idx = jax.lax.axis_index(axis_name)
                else:
                    idx = 0
                return jax.lax.dynamic_slice_in_dim(
                    g.astype(jnp.float32), idx * rows_l, rows_l, 0)

            owned = jax.lax.cond(bad, bf16_fallback, fp8_sum, None)
            return owned / n_shards, bad
        parts = pay.astype(jnp.float32) * scale_sync.exp_i8_to_scale(exps)
        owned = jnp.sum(parts, axis=0)
    else:
        wdtype = jnp.bfloat16 if wire == "bf16" else jnp.float32
        msg = flat.astype(wdtype).reshape(n_shards, rows // n_shards, TILE)
        if axis_name is not None and n_shards > 1:
            msg = jax.lax.all_to_all(msg, axis_name, split_axis=0,
                                     concat_axis=0, tiled=False)
        owned = jnp.sum(msg.astype(jnp.float32), axis=0)
    if guard is not None:
        return owned / n_shards, jnp.bool_(False)
    return owned / n_shards


def all_gather_shard(shard: jax.Array, axis_name) -> jax.Array:
    """ZeRO-1 epilogue: gather the updated (rows/P, TILE) param shards back
    to the full (rows, TILE) bucket (param dtype, e.g. bf16)."""
    if axis_name is None:
        return shard
    return jax.lax.all_gather(shard, axis_name, axis=0, tiled=True)


def reduce_sensitive(g: jax.Array, axis_name, n_shards: int,
                     wire: str) -> jax.Array:
    """bf16-fallback reduction for sensitive leaves: cast to the fallback
    wire dtype, psum, mean.  f32 wire keeps full precision (baseline)."""
    with annotate("wire/sensitive_psum"):
        wdtype = jnp.float32 if wire == "f32" else jnp.bfloat16
        gw = g.astype(wdtype)
        if axis_name is not None and n_shards > 1:
            gw = jax.lax.psum(gw, axis_name)
        return gw.astype(jnp.float32) / n_shards


# ---------------------------------------------------------------------------
# Bytes-on-wire model (benchmarks/dp_comm_ab.py + tests).  Counts bytes a
# single device puts on the interconnect for the GRADIENT reduction, using
# the standard ring factors: all-reduce moves 2(P-1)/P of the buffer,
# reduce-scatter and all-gather (P-1)/P each.
# ---------------------------------------------------------------------------
def wire_grad_bytes(n_elems: int, n_shards: int, wire: str,
                    mode: str = "zero1") -> float:
    P = max(n_shards, 1)
    ring = (P - 1) / P
    rows = -(-n_elems // TILE)
    if mode == "zero1":
        if wire == "fp8":
            payload = rows * TILE * _E4M3_BYTES + rows * _EXP_BYTES
            # amax agreement: ring all-reduce (pmax) of per-row f32 amax
            agree = 2 * ring * rows * 4
            return ring * payload + agree
        width = 2 if wire == "bf16" else 4
        return ring * rows * TILE * width
    # legacy implicit psum: full all-reduce of the gradients
    width = 2 if wire == "bf16" else 4
    return 2 * ring * n_elems * width


def wire_param_bytes(n_elems: int, n_shards: int,
                     param_bytes: int = 2) -> float:
    """ZeRO-1 all-gather of updated params (bf16) — same for every wire."""
    P = max(n_shards, 1)
    return (P - 1) / P * n_elems * param_bytes


def stream_exposed_us(bucket_us, overlap_us) -> float:
    """Exposed (unhidden) DP-wire time under the STREAMING schedule.

    bucket_us[i]  modelled wire time of bucket i, in emission order (the
                  layered layout's reverse-layer order);
    overlap_us[i] backward compute available AFTER bucket i is issued and
                  BEFORE bucket i+1 is (i.e. the next layer's backward).

    Greedy hiding: whatever is in flight drains against the next compute
    window; the return value is the wire time still exposed when the
    backward runs out of compute — the post-hoc schedule by contrast
    exposes sum(bucket_us) in full (every byte after the last GEMM)."""
    inflight = 0.0
    for b_us, c_us in zip(bucket_us, overlap_us):
        inflight = max(0.0, inflight + float(b_us) - float(c_us))
    return inflight
