"""FP8-split AdamW optimizer state behind QTensor.

At scale the optimizer state dominates memory: f32 m + f32 v + f32 master =
12 bytes/param on top of 2-byte bf16 params.  FP8-LM shows the first moment
tolerates e4m3 and the master weights tolerate 16-bit-plus-scale; MOSS shows
po2 per-block scaling keeps that stable without amax history.  The policy
here:

  m       e4m3 payload + per-row po2 scale (QTensor, 1.03 B/param)
  v       bf16 (2 B/param; the sqrt compresses its dynamic range)
  master  float16 payload + per-row po2 scale (QTensor, ~2.03 B/param) —
          the po2 row scale restores the exponent range f16 lacks, so the
          payload spends its 10 mantissa bits near the row amax

=> ~5.1 B/param of state instead of 12.  Encodings are per-TILE-row flat
(rows, 128), which is exactly the ZeRO-1 shard layout: slicing rows slices
payload AND scales consistently (scale-aware sharding), so a shard is
self-describing and re-shardable across DP sizes.

Sensitive/small leaves (norms, biases, router — see plan.is_sensitive) keep
classic f32 state: their memory is negligible and their updates precision-
critical.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import casts
from repro.core.fp8 import E4M3, E4M3_MAX, TILE, po2_scale
from repro.core.quant import QTensor, _dequantize_nocount


@dataclasses.dataclass(frozen=True)
class StatePolicy:
    """Optimizer-state dtype policy (AdamWConfig.state_policy).

    Kinds: 'f32' | 'bf16' (plain arrays, leaf-shaped) and 'e4m3' | 'f16'
    (QTensor: flat (rows, TILE) payload + per-row po2 scale)."""
    m: str = "e4m3"
    v: str = "bf16"
    master: str = "f16"
    min_size: int = 2048

    def applies(self, leaf) -> bool:
        return getattr(leaf, "ndim", 0) >= 2 and leaf.size >= self.min_size


def _rows(x: jax.Array) -> jax.Array:
    """Flatten any tensor to zero-padded (rows, TILE)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, TILE)


def _row_scale(rows: jax.Array, fmt_max: float) -> jax.Array:
    amax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True).astype(jnp.float32)
    return po2_scale(amax, fmt_max)


def encode(kind: str, x: jax.Array) -> object:
    """f32 tensor (any shape) -> policy-encoded state leaf."""
    if kind == "f32":
        return x.astype(jnp.float32)
    if kind == "bf16":
        return x.astype(jnp.bfloat16)
    rows = _rows(x)
    if kind == "e4m3":
        casts.record("fused_quantize", "opt_state", rows.size)
        scale = _row_scale(rows, E4M3_MAX)
        data = jnp.clip(rows.astype(jnp.float32) / scale,
                        -E4M3_MAX, E4M3_MAX).astype(E4M3)
        return QTensor(data=data, scale=scale, tile=(1, TILE))
    if kind == "f16":
        # payload normalized to (-1, 1]: f16's 10 mantissa bits sit right at
        # the row amax; po2 division is exact, so bf16 -> f16 payload loses
        # nothing representable
        scale = _row_scale(rows, 1.0)
        if rows.dtype == jnp.bfloat16:
            data = (rows / scale.astype(jnp.bfloat16)).astype(jnp.float16)
        else:
            data = (rows.astype(jnp.float32) / scale).astype(jnp.float16)
        return QTensor(data=data, scale=scale, tile=(1, TILE))
    raise ValueError(f"unknown state encoding {kind}")


def decode(enc, like_shape, size: int) -> jax.Array:
    """Policy-encoded state leaf -> f32 tensor of like_shape."""
    if isinstance(enc, QTensor):
        flat = _dequantize_nocount(enc, jnp.float32).reshape(-1)
        return flat[:size].reshape(like_shape)
    return enc.astype(jnp.float32)


def encode_like(x32: jax.Array, template) -> object:
    """Re-encode an updated f32 value into the template's representation."""
    if isinstance(template, QTensor):
        kind = "e4m3" if template.data.dtype == jnp.dtype(E4M3) else "f16"
        return encode(kind, x32)
    return x32.astype(template.dtype)


def zeros_encoded(kind: str, like) -> object:
    """Zero state in the target encoding WITHOUT an f32 temporary."""
    if kind in ("f32", "bf16"):
        dt = jnp.float32 if kind == "f32" else jnp.bfloat16
        return jnp.zeros(like.shape, dt)
    n_rows = -(-like.size // TILE)
    dt = E4M3 if kind == "e4m3" else jnp.float16
    return QTensor(data=jnp.zeros((n_rows, TILE), dt),
                   scale=jnp.ones((n_rows, 1), jnp.float32), tile=(1, TILE))


# ---------------------------------------------------------------------------
# ZeRO-1 flat bucket state.  State arrays are (bucket.rows, TILE) GLOBAL
# (sharded over the DP axis on dim 0 by launch/sharding.dist_state_specs);
# inside the train step's shard_map each replica sees its owned row shard.
# ---------------------------------------------------------------------------
def init_dist_state(opt, params, layout, plan):
    """{'step', 'flat': (per-bucket {'m','v'[,'master']}), 'sens': classic}"""
    from repro.dist.plan import bucket_flat
    pol = plan.policy
    leaves = jax.tree.leaves(params)
    flat = []
    for b in layout.buckets:
        like = jax.ShapeDtypeStruct((b.rows, TILE), jnp.float32)
        st = {"m": zeros_encoded(pol.m, like),
              "v": zeros_encoded(pol.v, like)}
        if opt.master_weights:
            st["master"] = encode(pol.master, bucket_flat(b, leaves))
        flat.append(st)
    sens_tree = {p: leaves[i] for i, p in layout.sensitive}
    sens = {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              sens_tree),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              sens_tree)}
    if opt.master_weights:
        sens["master"] = jax.tree.map(lambda p: p.astype(jnp.float32),
                                      sens_tree)
    return {"step": jnp.zeros((), jnp.int32), "flat": tuple(flat),
            "sens": sens}


def flat_bucket_update(opt, pol, st, owned_g32, clip, lr, b1c, b2c,
                       param_shard32=None):
    """AdamW on one owned ZeRO-1 shard; returns (new bf16 param shard,
    new bucket state).  owned_g32: (rows/P, TILE) MEAN-reduced f32 grads."""
    from repro.optim.adamw import adamw_math
    shp = owned_g32.shape
    n = owned_g32.size
    m32 = decode(st["m"], shp, n)
    v32 = decode(st["v"], shp, n)
    if "master" in st:
        base = decode(st["master"], shp, n)
    else:
        assert param_shard32 is not None
        base = param_shard32
    new_master, m_new, v_new = adamw_math(opt, owned_g32 * clip, m32, v32,
                                          base, lr, b1c, b2c)
    new_st = {"m": encode_like(m_new, st["m"]),
              "v": encode_like(v_new, st["v"])}
    if "master" in st:
        new_st["master"] = encode_like(new_master, st["master"])
    return new_master.astype(jnp.bfloat16), new_st


def state_bytes_model(n_params: int, pol: StatePolicy,
                      master_weights: bool = True) -> float:
    """Bytes/param of optimizer state under the policy (memory accounting)."""
    per = {"f32": 4.0, "bf16": 2.0,
           "e4m3": 1.0 + 4.0 / TILE, "f16": 2.0 + 4.0 / TILE}
    total = per[pol.m] + per[pol.v]
    if master_weights:
        total += per[pol.master]
    return total * n_params
