"""DistPlan: the explicit, planned data-parallel communication subsystem.

Replaces the implicit pjit-psum-only gradient reduction with a measurable
plan: which leaves ride the FP8 wire, how they bucketize into fused
messages, and how the ZeRO-1 optimizer shards own the flat gradient space.

Layout model
------------
FP8-eligible leaves (large >=2-D weights) are flattened, padded to TILE
(128)-element rows, and packed contiguously into buckets of ~bucket_mb
payload each.  One bucket = ONE uint8 wire message (payload + exponent
scales bitcast-packed, grad_comm.py).  Bucket row counts are padded to
`shard_multiple` so any DP size that divides it can own an equal shard —
this is what lets a ZeRO-1 checkpoint restore onto a different DP mesh.

Sensitive leaves — norms, biases, router, embeddings, anything tiny or
1-D — fall back to a bf16 psum: their gradients are high-dynamic-range,
low-volume, and not worth a quantization error budget.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.fp8 import TILE

# Leaves that always take the bf16 fallback wire regardless of size: the
# embedding/unembedding (sparse, outlier-heavy rows), the router (tiny but
# routing-critical — FP8-LM keeps it high precision), and conv/qk-norm odds.
SENSITIVE_NAMES = frozenset({
    "embed", "lm_head", "w_router", "conv_w", "q_norm", "k_norm",
})


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Static description of the DP-axis communication plan.

    axis            mesh axis the reduction runs over
    mode            'none' (pjit implicit psum, legacy) | 'zero1'
    wire            'fp8' (e4m3 + po2 int8 exponents) | 'bf16' | 'f32'
                    — bf16/f32 run the SAME bucketized reduce-scatter with a
                    plain payload, giving a controlled parity baseline
    bucket_mb       payload target per fused wire message
    shard_multiple  bucket rows pad to this multiple so any DP size <= it
                    (dividing it) owns an equal ZeRO-1 shard
    min_fp8_size    leaves smaller than this stay on the bf16 fallback
    policy          optimizer-state dtype policy (dist.opt_state.StatePolicy)
    """
    axis: str = "data"
    mode: str = "zero1"
    wire: str = "fp8"
    bucket_mb: float = 4.0
    shard_multiple: int = 64
    min_fp8_size: int = 2048
    policy: object = None  # None -> StatePolicy() (set in __post_init__)

    def __post_init__(self):
        if self.mode not in ("none", "zero1"):
            raise ValueError(f"unknown dist mode {self.mode}")
        if self.wire not in ("fp8", "bf16", "f32"):
            raise ValueError(f"unknown wire format {self.wire}")
        if self.policy is None:
            from repro.dist.opt_state import StatePolicy
            object.__setattr__(self, "policy", StatePolicy())

    @property
    def active(self) -> bool:
        return self.mode != "none"


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One FP8-wire leaf's home in the flat gradient space."""
    index: int          # position in the params tree's flatten order
    path: str           # dotted key path (diagnostics / tests)
    offset_rows: int    # first TILE-row inside the bucket
    rows: int           # ceil(size / TILE)
    size: int           # true element count (tail of the last row is pad)


@dataclasses.dataclass(frozen=True)
class Bucket:
    rows: int                       # padded: rows % shard_multiple == 0
    slots: Tuple[LeafSlot, ...]


@dataclasses.dataclass(frozen=True)
class GradLayout:
    """Static bucketization of a params tree under a DistPlan."""
    buckets: Tuple[Bucket, ...]
    sensitive: Tuple[Tuple[int, str], ...]   # (flatten index, path)
    n_leaves: int

    @property
    def fp8_elems(self) -> int:
        return sum(s.size for b in self.buckets for s in b.slots)

    @property
    def wire_rows(self) -> int:
        return sum(b.rows for b in self.buckets)


def path_str(path) -> str:
    return ".".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def is_sensitive(path: str, leaf, plan: DistPlan) -> bool:
    name = path.split(".")[-1]
    if name in SENSITIVE_NAMES:
        return True
    if getattr(leaf, "ndim", 0) <= 1:
        return True
    if leaf.size < plan.min_fp8_size:
        return True
    return not jnp.issubdtype(leaf.dtype, jnp.floating)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def build_layout(params, plan: DistPlan) -> GradLayout:
    """Pure-static: consumes only shapes/paths (safe on tracers)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    buckets, slots, sensitive = [], [], []
    cur_rows = 0
    target_rows = max(int(plan.bucket_mb * 2 ** 20) // TILE, plan.shard_multiple)

    def close():
        nonlocal cur_rows, slots
        if slots:
            buckets.append(Bucket(rows=_round_up(cur_rows, plan.shard_multiple),
                                  slots=tuple(slots)))
        slots, cur_rows = [], 0

    for i, (path, leaf) in enumerate(flat):
        p = path_str(path)
        if is_sensitive(p, leaf, plan):
            sensitive.append((i, p))
            continue
        rows = -(-leaf.size // TILE)
        if cur_rows and cur_rows + rows > target_rows:
            close()
        slots.append(LeafSlot(index=i, path=p, offset_rows=cur_rows,
                              rows=rows, size=leaf.size))
        cur_rows += rows
    close()
    return GradLayout(buckets=tuple(buckets), sensitive=tuple(sensitive),
                      n_leaves=len(flat))


# ---------------------------------------------------------------------------
# Flat-space <-> tree movement (runs inside jit; layout is static).
# ---------------------------------------------------------------------------
def bucket_flat(bucket: Bucket, leaves, dtype=jnp.float32) -> jax.Array:
    """Gather a bucket's leaves into its (rows, TILE) flat block, zero-padded
    at each slot's row tail and at the bucket tail."""
    parts = []
    for s in bucket.slots:
        x = leaves[s.index].reshape(-1).astype(dtype)
        pad = s.rows * TILE - s.size
        if pad:
            x = jnp.pad(x, (0, pad))
        parts.append(x)
    flat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    tail = bucket.rows * TILE - flat.shape[0]
    if tail:
        flat = jnp.pad(flat, (0, tail))
    return flat.reshape(bucket.rows, TILE)


def bucket_scatter(bucket: Bucket, flat: jax.Array, like_leaves) -> dict:
    """Slice a bucket's (rows, TILE) flat block back into {index: leaf}."""
    v = flat.reshape(-1)
    out = {}
    for s in bucket.slots:
        ref = like_leaves[s.index]
        x = v[s.offset_rows * TILE:s.offset_rows * TILE + s.size]
        out[s.index] = x.reshape(ref.shape).astype(ref.dtype)
    return out
