"""DistPlan: the explicit, planned data-parallel communication subsystem.

Replaces the implicit pjit-psum-only gradient reduction with a measurable
plan: which leaves ride the FP8 wire, how they bucketize into fused
messages, and how the ZeRO-1 optimizer shards own the flat gradient space.

Layout model
------------
FP8-eligible leaves (large >=2-D weights) are flattened, padded to TILE
(128)-element rows, and packed contiguously into buckets of ~bucket_mb
payload each.  One bucket = ONE uint8 wire message (payload + exponent
scales bitcast-packed, grad_comm.py).  Bucket row counts are padded to
`shard_multiple` so any DP size that divides it can own an equal shard —
this is what lets a ZeRO-1 checkpoint restore onto a different DP mesh.

Sensitive leaves — norms, biases, router, embeddings, anything tiny or
1-D — fall back to a bf16 psum: their gradients are high-dynamic-range,
low-volume, and not worth a quantization error budget.

Layer-aligned (staged) layout
-----------------------------
With ``layered=True`` the stacked decoder stacks (``layers`` /
``dense_layers``, parameters stored (L, ...)) bucketize PER LAYER, in
REVERSE layer order — exactly the order the staged backward
(train_step._streamed_grads) emits per-layer gradient leaves.  A slot then
covers ``leaves[index][layer]`` instead of the whole stacked leaf.  This is
the layout the ``schedule='stream'`` wire requires: bucket i's pre-agreed-
scale quantize + reduce-scatter is issued from inside the backward as soon
as layer i's grads exist, hiding the DP wire behind the remaining backward
compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fp8 import TILE

# Parameter-tree roots whose leaves are stacked (L, ...) over a decoder
# stack and scanned/unrolled per layer (models/lm.py).  Only these can be
# layer-aligned; enc/cross stacks ride the legacy trailing buckets (the
# staged backward does not drive them — see streaming_fallback_reason).
STACKED_STACKS = ("layers", "dense_layers")

# Leaves that always take the bf16 fallback wire regardless of size: the
# embedding/unembedding (sparse, outlier-heavy rows), the router (tiny but
# routing-critical — FP8-LM keeps it high precision), and conv/qk-norm odds.
SENSITIVE_NAMES = frozenset({
    "embed", "lm_head", "w_router", "conv_w", "q_norm", "k_norm",
})


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Static description of the DP-axis communication plan.

    axis            mesh axis the reduction runs over
    mode            'none' (pjit implicit psum, legacy) | 'zero1'
    wire            'fp8' (e4m3 + po2 int8 exponents) | 'bf16' | 'f32'
                    — bf16/f32 run the SAME bucketized reduce-scatter with a
                    plain payload, giving a controlled parity baseline
    bucket_mb       payload target per fused wire message
    shard_multiple  bucket rows pad to this multiple so any DP size <= it
                    (dividing it) owns an equal ZeRO-1 shard
    min_fp8_size    leaves smaller than this stay on the bf16 fallback
    policy          optimizer-state dtype policy (dist.opt_state.StatePolicy)
    schedule        'posthoc' (reduce every bucket after the full backward)
                    | 'stream' (issue bucket i's quantize+reduce-scatter from
                    inside the staged backward as soon as layer i's grads
                    exist — requires layer-aligned buckets)
    layered         layer-aligned bucketization (see module docstring);
                    None defaults to (schedule == 'stream').  'posthoc' +
                    layered=True is the controlled A/B baseline: identical
                    buckets and quantization groups, only the issue order
                    differs.
    """
    axis: str = "data"
    mode: str = "zero1"
    wire: str = "fp8"
    bucket_mb: float = 4.0
    shard_multiple: int = 64
    min_fp8_size: int = 2048
    policy: object = None  # None -> StatePolicy() (set in __post_init__)
    schedule: str = "posthoc"
    layered: Optional[bool] = None

    def __post_init__(self):
        if self.mode not in ("none", "zero1"):
            raise ValueError(f"unknown dist mode {self.mode}")
        if self.wire not in ("fp8", "bf16", "f32"):
            raise ValueError(f"unknown wire format {self.wire}")
        if self.schedule not in ("posthoc", "stream"):
            raise ValueError(f"unknown wire schedule {self.schedule}")
        if self.layered is None:
            object.__setattr__(self, "layered", self.schedule == "stream")
        if self.schedule == "stream" and not self.layered:
            raise ValueError(
                "schedule='stream' needs layer-aligned buckets "
                "(layered=True): the streaming backward emits gradients one "
                "layer at a time, so a bucket spanning layers could only be "
                "sent after ALL of them — the post-hoc wire in disguise")
        if self.policy is None:
            from repro.dist.opt_state import StatePolicy
            object.__setattr__(self, "policy", StatePolicy())

    @property
    def active(self) -> bool:
        return self.mode != "none"


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One FP8-wire leaf's home in the flat gradient space."""
    index: int          # position in the params tree's flatten order
    path: str           # dotted key path (diagnostics / tests)
    offset_rows: int    # first TILE-row inside the bucket
    rows: int           # ceil(size / TILE)
    size: int           # true element count (tail of the last row is pad)
    layer: Optional[int] = None     # layered layout: slot covers
                                    # leaves[index][layer] (one layer's slice
                                    # of the stacked (L, ...) leaf)


@dataclasses.dataclass(frozen=True)
class Bucket:
    rows: int                       # padded: rows % shard_multiple == 0
    slots: Tuple[LeafSlot, ...]
    stack: Optional[str] = None     # layered layout: owning stack name
    layer: Optional[int] = None     # layered layout: layer index (all slots
                                    # share it — buckets never span layers)


@dataclasses.dataclass(frozen=True)
class SensitiveSlot:
    """One bf16-fallback leaf.  In the LAYERED layout, a sensitive leaf that
    lives in a stacked decoder stack carries its `stack` tag: the streaming
    backward (train_step._streamed_grads) then issues each LAYER's slice on
    the bf16 psum wire together with that layer's FP8 bucket(s) instead of
    batching the whole stacked leaf post-hoc.  Iterates as (index, path)
    so legacy `for i, p in layout.sensitive` call sites keep working."""
    index: int
    path: str
    stack: Optional[str] = None

    def __iter__(self):
        yield self.index
        yield self.path


@dataclasses.dataclass(frozen=True)
class GradLayout:
    """Static bucketization of a params tree under a DistPlan."""
    buckets: Tuple[Bucket, ...]
    sensitive: Tuple[SensitiveSlot, ...]
    n_leaves: int

    @property
    def fp8_elems(self) -> int:
        return sum(s.size for b in self.buckets for s in b.slots)

    @property
    def wire_rows(self) -> int:
        return sum(b.rows for b in self.buckets)


def path_str(path) -> str:
    return ".".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def is_sensitive(path: str, leaf, plan: DistPlan) -> bool:
    name = path.split(".")[-1]
    if name in SENSITIVE_NAMES:
        return True
    if getattr(leaf, "ndim", 0) <= 1:
        return True
    if leaf.size < plan.min_fp8_size:
        return True
    return not jnp.issubdtype(leaf.dtype, jnp.floating)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class _LayerSlice:
    """Shape/dtype view of one layer's slice of a stacked (L, ...) leaf —
    what is_sensitive must judge (per-layer size, per-layer rank)."""

    def __init__(self, leaf):
        self.shape = tuple(leaf.shape[1:])
        self.ndim = len(self.shape)
        self.size = math.prod(self.shape) if self.shape else 1
        self.dtype = leaf.dtype


def build_layout(params, plan: DistPlan) -> GradLayout:
    """Pure-static: consumes only shapes/paths (safe on tracers)."""
    if plan.layered:
        return _build_layout_layered(params, plan)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    buckets, slots, sensitive = [], [], []
    cur_rows = 0
    target_rows = max(int(plan.bucket_mb * 2 ** 20) // TILE, plan.shard_multiple)

    def close():
        nonlocal cur_rows, slots
        if slots:
            buckets.append(Bucket(rows=_round_up(cur_rows, plan.shard_multiple),
                                  slots=tuple(slots)))
        slots, cur_rows = [], 0

    for i, (path, leaf) in enumerate(flat):
        p = path_str(path)
        if is_sensitive(p, leaf, plan):
            sensitive.append(SensitiveSlot(i, p))
            continue
        rows = -(-leaf.size // TILE)
        if cur_rows and cur_rows + rows > target_rows:
            close()
        slots.append(LeafSlot(index=i, path=p, offset_rows=cur_rows,
                              rows=rows, size=leaf.size))
        cur_rows += rows
    close()
    return GradLayout(buckets=tuple(buckets), sensitive=tuple(sensitive),
                      n_leaves=len(flat))


def _build_layout_layered(params, plan: DistPlan) -> GradLayout:
    """Layer-aligned bucketization: one bucket chain per (stack, layer),
    emitted in the staged backward's order — main stack last-layer-first,
    then the dense prologue last-first, then any non-stacked FP8 leaves in
    legacy packing.  Buckets NEVER span a layer boundary, so each one can be
    put on the wire the moment its layer's backward completes."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    target_rows = max(int(plan.bucket_mb * 2 ** 20) // TILE,
                      plan.shard_multiple)
    buckets, sensitive = [], []
    stacks = {name: [] for name in STACKED_STACKS}
    other = []
    for i, (path, leaf) in enumerate(flat):
        p = path_str(path)
        root = p.split(".")[0]
        (stacks[root] if root in stacks else other).append((i, p, leaf))

    def pack(entries, stack=None, layer=None):
        slots, cur = [], 0
        for i, p, size in entries:
            rows = -(-size // TILE)
            if cur and cur + rows > target_rows:
                buckets.append(Bucket(
                    rows=_round_up(cur, plan.shard_multiple),
                    slots=tuple(slots), stack=stack, layer=layer))
                slots, cur = [], 0
            slots.append(LeafSlot(index=i, path=p, offset_rows=cur,
                                  rows=rows, size=size, layer=layer))
            cur += rows
        if slots:
            buckets.append(Bucket(rows=_round_up(cur, plan.shard_multiple),
                                  slots=tuple(slots), stack=stack,
                                  layer=layer))

    # backward emission order: main stack reversed, then the dense prologue
    # reversed (the staged backward walks layers last-to-first)
    for name in ("layers", "dense_layers"):
        group = stacks.get(name) or []
        eligible = []
        for i, p, leaf in group:
            view = _LayerSlice(leaf)
            if is_sensitive(p, view, plan):
                # stack tag: the streaming backward reduces this leaf one
                # LAYER slice at a time, with that layer's bucket(s)
                sensitive.append(SensitiveSlot(i, p, stack=name))
            else:
                eligible.append((i, p, view.size))
        if eligible:
            n_layers = group[0][2].shape[0]
            for l in range(n_layers - 1, -1, -1):
                pack(eligible, stack=name, layer=l)
    tail = []
    for i, p, leaf in other:
        if is_sensitive(p, leaf, plan):
            sensitive.append(SensitiveSlot(i, p))
        else:
            tail.append((i, p, leaf.size))
    pack(tail)
    return GradLayout(buckets=tuple(buckets), sensitive=tuple(sensitive),
                      n_leaves=len(flat))


def streaming_fallback_reason(cfg, layout: Optional[GradLayout] = None,
                              grad_accum: int = 1) -> Optional[str]:
    """Why the streaming wire schedule cannot run this configuration (None
    when it can).  Callers either raise (make_train_step — fast clear error)
    or fall back to the post-hoc schedule with a warning (launch/train.py)
    instead of miscompiling.

    ``grad_accum`` is part of the probe's contract (callers pass the step's
    setting) but no longer names a blocker: microbatch gradients accumulate
    locally and each bucket is wired once, from the last microbatch's
    backward (train_step._streamed_grads)."""
    if getattr(cfg, "encdec", False) or getattr(cfg, "frontend", "none") != "none":
        return ("the staged layer program drives plain decoder-only stacks; "
                "encoder-decoder / frontend architectures keep the post-hoc "
                "wire")
    # grad_accum > 1 streams too: microbatch grads accumulate LOCALLY and
    # each bucket's quantize + reduce-scatter is issued once, from inside
    # the LAST microbatch's backward (train_step._streamed_grads).
    if layout is not None:
        if not layout.buckets:
            return "no FP8-eligible leaves to bucket (nothing to stream)"
        off = [b for b in layout.buckets if b.layer is None]
        if off:
            return (f"{len(off)} bucket(s) hold non-stacked leaves and "
                    f"cannot align to layer boundaries "
                    f"(e.g. {off[0].slots[0].path})")
    return None


# ---------------------------------------------------------------------------
# Flat-space <-> tree movement (runs inside jit; layout is static).
# ---------------------------------------------------------------------------
def bucket_flat_parts(bucket: Bucket, get_leaf, dtype=jnp.float32) -> jax.Array:
    """Gather a bucket into its (rows, TILE) flat block, zero-padded at each
    slot's row tail and at the bucket tail.  `get_leaf(slot)` supplies each
    slot's (already layer-sliced, if applicable) array — the streaming
    backward feeds per-layer vjp outputs here directly."""
    parts = []
    for s in bucket.slots:
        x = get_leaf(s).reshape(-1).astype(dtype)
        pad = s.rows * TILE - s.size
        if pad:
            x = jnp.pad(x, (0, pad))
        parts.append(x)
    flat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    tail = bucket.rows * TILE - flat.shape[0]
    if tail:
        flat = jnp.pad(flat, (0, tail))
    return flat.reshape(bucket.rows, TILE)


def bucket_flat(bucket: Bucket, leaves, dtype=jnp.float32) -> jax.Array:
    """bucket_flat_parts over a full flattened-params leaf list (layered
    slots take the slot's layer slice of the stacked leaf)."""
    return bucket_flat_parts(
        bucket,
        lambda s: leaves[s.index] if s.layer is None
        else leaves[s.index][s.layer],
        dtype)


def bucket_scatter(bucket: Bucket, flat: jax.Array, like_leaves) -> dict:
    """Slice a bucket's (rows, TILE) flat block back into leaf pieces.

    Returns {index: leaf} for flat-layout slots and {(index, layer): slice}
    for layered slots — the caller stacks a layered leaf's L pieces back
    into its (L, ...) array (train_step does this once per step)."""
    v = flat.reshape(-1)
    out = {}
    for s in bucket.slots:
        ref = like_leaves[s.index]
        shape = ref.shape if s.layer is None else ref.shape[1:]
        x = v[s.offset_rows * TILE:s.offset_rows * TILE + s.size]
        key = s.index if s.layer is None else (s.index, s.layer)
        out[key] = x.reshape(shape).astype(ref.dtype)
    return out
