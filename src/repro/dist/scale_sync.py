"""Cross-replica scale agreement for the quantized DP wire.

The paper eliminates double quantization error inside the MoE block by
keeping one po2 scale valid across layout changes (§3.1).  The same
discipline applied to the data-parallel axis: before any replica quantizes
its gradient bucket, the per-tile amax is agreed by a pmax over the DP axis,
so every replica quantizes with the SAME po2 scale.  Summing e4m3 payloads
that share a scale dequantizes exactly (e4m3 -> f32 is exact, x * po2 is
exact), so the reduction adds one quantization error per replica and ZERO
re-quantization error — the reduced shard goes straight to the optimizer in
f32 (ZeRO-1 owns it; nothing is quantized twice).

Scales travel as int8 exponents (s = 2^e), 1 byte per 128-element tile —
the wire stays pure uint8 after bitcast packing (grad_comm.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8 import E4M3_MAX, po2_scale


def agree_amax(amax: jax.Array, axis_name) -> jax.Array:
    """pmax over the DP axis: all replicas see the global per-tile amax.
    axis_name=None (single-replica tests) is the identity."""
    if axis_name is None:
        return amax
    return jax.lax.pmax(amax, axis_name)


def agreed_po2_scale(x_rows: jax.Array, axis_name, fmt_max: float = E4M3_MAX
                     ) -> jax.Array:
    """Per-row agreed po2 scale for a (rows, TILE) flat gradient bucket.
    Identical on every replica along `axis_name` by construction."""
    amax = jnp.max(jnp.abs(x_rows), axis=-1, keepdims=True).astype(jnp.float32)
    return po2_scale(agree_amax(amax, axis_name), fmt_max)


def scale_to_exp_i8(scale: jax.Array) -> jax.Array:
    """po2 scale -> int8 exponent (s = 2^e).  frexp is exact: s = 0.5 * 2^(e+1)
    so e fits int8 for any scale produced by po2_scale (|e| <= 126)."""
    m, e = jnp.frexp(scale.astype(jnp.float32))
    del m  # always 0.5 for a po2 input
    return (e - 1).astype(jnp.int8)


def exp_i8_to_scale(exp: jax.Array) -> jax.Array:
    """int8 exponent -> f32 po2 scale.  ldexp, NOT exp2: XLA's f32 exp2 is
    not correctly rounded for |e| >= 13, which would silently break the
    exact-po2 contract the whole wire rests on."""
    return jnp.ldexp(jnp.float32(1.0), exp.astype(jnp.int32))


def scale_to_exp_i8_bits(scale: jax.Array) -> jax.Array:
    """Pure-bit spelling of ``scale_to_exp_i8``: a po2 scale s = 2^e has f32
    bits (e+127) << 23 (sign 0, mantissa 0), so the exponent is a shift and
    a bias subtract — NO floating-point arithmetic at all.  Value-identical
    to the frexp form for every exponent po2_scale can produce (|e| <= 126,
    property-tested); used on casting-free paths (the KV-page migration
    wire) whose jaxpr must contain zero float ops."""
    bits = jax.lax.bitcast_convert_type(scale, jnp.uint32)
    return ((bits >> 23).astype(jnp.int32) - 127).astype(jnp.int8)


def exp_i8_to_scale_bits(exp: jax.Array) -> jax.Array:
    """Inverse of ``scale_to_exp_i8_bits`` by bit construction — value-
    identical to ``exp_i8_to_scale`` (ldexp) but float-op-free."""
    bits = ((exp.astype(jnp.int32) + 127) << 23).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def wire_anomaly(exp: jax.Array, payload: jax.Array, axis_name,
                 exp_limit: int) -> jax.Array:
    """Wire guard predicate, evaluated on the RECEIVED message before the
    dequant-sum: True when any unpacked po2 exponent is absurd (|e| beyond
    `exp_limit` — healthy e4m3 gradient tiles keep agreed scales within a
    few tens of octaves of 1.0) or any e4m3 payload lane decodes nonfinite
    (e4m3fn's only nonfinite encoding is NaN, 0x7f/0xff).  pmax makes the
    scalar replica-uniform so it can steer a lax.cond under shard_map."""
    bad_exp = jnp.any(jnp.abs(exp.astype(jnp.int32)) > exp_limit)
    bad_pay = jnp.any(jnp.isnan(payload.astype(jnp.float32)))
    bad = jnp.logical_or(bad_exp, bad_pay)
    if axis_name is not None:
        bad = jax.lax.pmax(bad.astype(jnp.int32), axis_name) > 0
    return bad
