"""Pallas TPU kernel: row-wise per-128-tile FP8 quantization (po2 scales).

Grid: (M/ROWS, K/TILE).  Each step loads a (ROWS, TILE) bf16/f32 block into
VMEM, computes the per-row po2 scale for that 128-wide tile, and writes the
e4m3 payload + the scale column.  One HBM read + two writes; the amax
reduction and the exponent ceil run on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fp8 import E4M3, E4M3_MAX, TILE

ROWS = 128  # token rows per block


def kernel_po2_scale(amax):
    """Exact po2 scale from an f32 amax — the in-kernel twin of
    ``core.fp8.po2_scale``.

    XLA's f32 ``exp2`` is not correctly rounded for |exp| >= 13, so the
    original ``jnp.exp2(exp)`` epilogues could emit scales that are NOT exact
    powers of two at large/small amax — silently breaking the scaling-aware
    transpose contract (the same latent bug ``po2_scale`` fixed with ldexp).
    Here the scale is BIT-CONSTRUCTED from the integer exponent (exact for
    exp in [-126, 126], i.e. every clamped value), which also lowers to plain
    integer VPU ops on TPU."""
    safe = jnp.maximum(amax, jnp.float32(1e-38))
    exp = jnp.clip(jnp.ceil(jnp.log2(safe / E4M3_MAX)), -126.0, 126.0)
    bits = (exp.astype(jnp.int32) + 127) << 23
    s = jax.lax.bitcast_convert_type(bits, jnp.float32)
    return jnp.where(amax > 0, s, jnp.float32(1.0))


def _quantize_kernel(x_ref, data_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                     # (ROWS, TILE)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)     # (ROWS, 1)
    s = kernel_po2_scale(amax)
    y = jnp.clip(x / s, -E4M3_MAX, E4M3_MAX)
    data_ref[...] = y.astype(E4M3)
    scale_ref[...] = s


def quantize_rowwise_pallas(x: jax.Array, *, interpret: bool = True):
    """x: (M, K) -> (data (M, K) e4m3, scale (M, K/TILE) f32 po2)."""
    M, K = x.shape
    assert M % ROWS == 0 and K % TILE == 0, (M, K)
    out_shapes = (
        jax.ShapeDtypeStruct((M, K), E4M3),
        jax.ShapeDtypeStruct((M, K // TILE), jnp.float32),
    )
    return pl.pallas_call(
        _quantize_kernel,
        grid=(M // ROWS, K // TILE),
        in_specs=[pl.BlockSpec((ROWS, TILE), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((ROWS, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS, 1), lambda i, j: (i, j)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x)
