"""Pallas TPU kernel: row-wise per-128-tile FP8 quantization (po2 scales).

Grid: (M/ROWS, K/TILE).  Each step loads a (ROWS, TILE) bf16/f32 block into
VMEM, computes the per-row po2 scale for that 128-wide tile, and writes the
e4m3 payload + the scale column.  One HBM read + two writes; the amax
reduction and the exponent ceil run on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fp8 import E4M3, E4M3_MAX, TILE

ROWS = 128  # token rows per block


def _quantize_kernel(x_ref, data_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                     # (ROWS, TILE)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)     # (ROWS, 1)
    safe = jnp.maximum(amax, jnp.float32(1e-38))
    exp = jnp.clip(jnp.ceil(jnp.log2(safe / E4M3_MAX)), -126.0, 126.0)
    s = jnp.where(amax > 0, jnp.exp2(exp), jnp.float32(1.0))
    y = jnp.clip(x / s, -E4M3_MAX, E4M3_MAX)
    data_ref[...] = y.astype(E4M3)
    scale_ref[...] = s


def quantize_rowwise_pallas(x: jax.Array, *, interpret: bool = True):
    """x: (M, K) -> (data (M, K) e4m3, scale (M, K/TILE) f32 po2)."""
    M, K = x.shape
    assert M % ROWS == 0 and K % TILE == 0, (M, K)
    out_shapes = (
        jax.ShapeDtypeStruct((M, K), E4M3),
        jax.ShapeDtypeStruct((M, K // TILE), jnp.float32),
    )
    return pl.pallas_call(
        _quantize_kernel,
        grid=(M // ROWS, K // TILE),
        in_specs=[pl.BlockSpec((ROWS, TILE), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((ROWS, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS, 1), lambda i, j: (i, j)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x)
