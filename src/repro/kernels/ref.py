"""Pure-jnp oracles for every Pallas kernel (bit-exact reference semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8 import BLOCK, E4M3, E4M3_MAX, TILE


def quantize_rowwise_ref(x: jax.Array):
    """Oracle for kernels/quantize.py."""
    M, K = x.shape
    xf = x.astype(jnp.float32).reshape(M, K // TILE, TILE)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    safe = jnp.maximum(amax, jnp.float32(1e-38))
    exp = jnp.clip(jnp.ceil(jnp.log2(safe / E4M3_MAX)), -126.0, 126.0)
    s = jnp.where(amax > 0, jnp.exp2(exp), jnp.float32(1.0))
    y = jnp.clip(xf / s[..., None], -E4M3_MAX, E4M3_MAX).astype(E4M3)
    return y.reshape(M, K), s


def fp8_transpose_ref(data: jax.Array, scale: jax.Array):
    """Oracle for kernels/fp8_transpose.py — the po2-exact f32 formulation.

    Multiplying an e4m3 value by a power-of-two ratio <= 1 in f32 and casting
    back to e4m3 is mathematically identical to the integer exponent-rebase
    (mantissa untouched; RNE into the subnormal grid on underflow).
    """
    M, K = data.shape
    nb_m, nb_k = M // BLOCK, K // BLOCK
    s = scale.reshape(nb_m, BLOCK, nb_k)
    s_max = jnp.max(s, axis=1)                                   # (nb_m, nb_k)
    ratio = s / s_max[:, None, :]
    x = data.reshape(nb_m, BLOCK, nb_k, BLOCK).astype(jnp.float32)
    x = x * ratio[:, :, :, None]
    xt = jnp.transpose(x.astype(E4M3), (2, 3, 0, 1)).reshape(K, M)
    s_out = jnp.repeat(jnp.swapaxes(s_max, 0, 1), BLOCK, axis=0)  # (K, nb_m)
    return xt, s_out


def fused_swiglu_quant_ref(h: jax.Array):
    """Oracle for kernels/fused_swiglu_quant.py."""
    M, twoF = h.shape
    F = twoF // 2
    g = h[:, :F].astype(jnp.float32)
    u = h[:, F:].astype(jnp.float32)
    y = g * jax.lax.logistic(g) * u
    return quantize_rowwise_ref(y)


def grouped_gemm_fp8_ref(x, sx, w, sw, out_dtype=jnp.bfloat16):
    """Oracle for kernels/grouped_gemm_fp8.py — per-K-tile scaled accumulation
    in the same order as the kernel (K-major partial sums in f32)."""
    E, C, K = x.shape
    N = w.shape[-1]
    nk = K // TILE
    xf = x.astype(jnp.float32).reshape(E, C, nk, TILE)
    wf = w.astype(jnp.float32).reshape(E, nk, TILE, N)
    acc = jnp.zeros((E, C, N), jnp.float32)
    for k in range(nk):
        partial = jnp.einsum("ect,etn->ecn", xf[:, :, k], wf[:, k],
                             precision=jax.lax.Precision.HIGHEST)
        swk = jnp.repeat(sw[:, k], TILE, axis=-1)[:, None, :]     # (E,1,N)
        acc = acc + partial * sx[:, :, k][..., None] * swk
    return acc.astype(out_dtype)


def grouped_gemm_nt_fp8_ref(a, sa, b, sb, out_dtype=jnp.float32):
    """Oracle for kernels/grouped_gemm_nt_fp8.py (Wgrad NT form)."""
    E, M, C = a.shape
    N = b.shape[1]
    nk = C // TILE
    af = a.astype(jnp.float32).reshape(E, M, nk, TILE)
    bf = b.astype(jnp.float32).reshape(E, N, nk, TILE)
    acc = jnp.zeros((E, M, N), jnp.float32)
    for k in range(nk):
        partial = jnp.einsum("emt,ent->emn", af[:, :, k], bf[:, :, k],
                             precision=jax.lax.Precision.HIGHEST)
        acc = acc + partial * sa[:, :, k][..., None] * sb[:, :, k][:, None, :]
    return acc.astype(out_dtype)


def grouped_gemm_fp8_quant_out_ref(x, sx, w, sw):
    """Oracle for the quantizing-epilogue grouped GEMM."""
    out = grouped_gemm_fp8_ref(x, sx, w, sw, out_dtype=jnp.float32)
    E, C, N = out.shape
    flat = out.reshape(E * C, N)
    data, scale = quantize_rowwise_ref(flat)
    return data.reshape(E, C, N), scale.reshape(E, C, N // TILE)


def fused_permute_pad_ref(x, s, row_map, n_out):
    """Oracle for kernels/fused_permute_pad.py."""
    valid = (row_map >= 0)[:, None]
    src = jnp.maximum(row_map, 0)
    xo = jnp.where(valid, x[src], jnp.zeros((n_out, x.shape[1]), x.dtype))
    so = jnp.where(valid, s[src], jnp.ones((n_out, s.shape[1]), s.dtype))
    return xo, so
