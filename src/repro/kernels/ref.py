"""Pure-jnp oracles for every Pallas kernel (bit-exact reference semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8 import BLOCK, E4M3, E4M3_MAX, TILE


def po2_scale_ref(amax):
    """Exact po2 scale from amax — mirrors kernels/quantize.kernel_po2_scale.

    Uses ldexp of the integer exponent instead of f32 ``exp2`` (which XLA does
    not correctly round for |exp| >= 13), so the oracle emits bit-identical
    scales to the bit-constructing kernels."""
    safe = jnp.maximum(amax, jnp.float32(1e-38))
    exp = jnp.clip(jnp.ceil(jnp.log2(safe / E4M3_MAX)), -126.0, 126.0)
    s = jnp.ldexp(jnp.float32(1.0), exp.astype(jnp.int32)).astype(jnp.float32)
    return jnp.where(amax > 0, s, jnp.float32(1.0))


def quantize_rowwise_ref(x: jax.Array):
    """Oracle for kernels/quantize.py."""
    M, K = x.shape
    xf = x.astype(jnp.float32).reshape(M, K // TILE, TILE)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    s = po2_scale_ref(amax)
    y = jnp.clip(xf / s[..., None], -E4M3_MAX, E4M3_MAX).astype(E4M3)
    return y.reshape(M, K), s


def fp8_transpose_ref(data: jax.Array, scale: jax.Array):
    """Oracle for kernels/fp8_transpose.py — the po2-exact f32 formulation.

    Multiplying an e4m3 value by a power-of-two ratio <= 1 in f32 and casting
    back to e4m3 is mathematically identical to the integer exponent-rebase
    (mantissa untouched; RNE into the subnormal grid on underflow).
    """
    M, K = data.shape
    nb_m, nb_k = M // BLOCK, K // BLOCK
    s = scale.reshape(nb_m, BLOCK, nb_k)
    s_max = jnp.max(s, axis=1)                                   # (nb_m, nb_k)
    ratio = s / s_max[:, None, :]
    x = data.reshape(nb_m, BLOCK, nb_k, BLOCK).astype(jnp.float32)
    x = x * ratio[:, :, :, None]
    xt = jnp.transpose(x.astype(E4M3), (2, 3, 0, 1)).reshape(K, M)
    s_out = jnp.repeat(jnp.swapaxes(s_max, 0, 1), BLOCK, axis=0)  # (K, nb_m)
    return xt, s_out


def fused_swiglu_quant_ref(h: jax.Array):
    """Oracle for kernels/fused_swiglu_quant.py."""
    M, twoF = h.shape
    F = twoF // 2
    g = h[:, :F].astype(jnp.float32)
    u = h[:, F:].astype(jnp.float32)
    y = g * jax.lax.logistic(g) * u
    return quantize_rowwise_ref(y)


def grouped_gemm_fp8_ref(x, sx, w, sw, out_dtype=jnp.bfloat16):
    """Oracle for kernels/grouped_gemm_fp8.py — per-K-tile scaled accumulation
    in the same order as the kernel (K-major partial sums in f32)."""
    E, C, K = x.shape
    N = w.shape[-1]
    nk = K // TILE
    xf = x.astype(jnp.float32).reshape(E, C, nk, TILE)
    wf = w.astype(jnp.float32).reshape(E, nk, TILE, N)
    acc = jnp.zeros((E, C, N), jnp.float32)
    for k in range(nk):
        partial = jnp.einsum("ect,etn->ecn", xf[:, :, k], wf[:, k],
                             precision=jax.lax.Precision.HIGHEST)
        swk = jnp.repeat(sw[:, k], TILE, axis=-1)[:, None, :]     # (E,1,N)
        acc = acc + partial * sx[:, :, k][..., None] * swk
    return acc.astype(out_dtype)


def grouped_gemm_nt_fp8_ref(a, sa, b, sb, out_dtype=jnp.float32):
    """Oracle for kernels/grouped_gemm_nt_fp8.py (Wgrad NT form)."""
    E, M, C = a.shape
    N = b.shape[1]
    nk = C // TILE
    af = a.astype(jnp.float32).reshape(E, M, nk, TILE)
    bf = b.astype(jnp.float32).reshape(E, N, nk, TILE)
    acc = jnp.zeros((E, M, N), jnp.float32)
    for k in range(nk):
        partial = jnp.einsum("emt,ent->emn", af[:, :, k], bf[:, :, k],
                             precision=jax.lax.Precision.HIGHEST)
        acc = acc + partial * sa[:, :, k][..., None] * sb[:, :, k][:, None, :]
    return acc.astype(out_dtype)


def grouped_gemm_fp8_quant_out_ref(x, sx, w, sw):
    """Oracle for the quantizing-epilogue grouped GEMM."""
    out = grouped_gemm_fp8_ref(x, sx, w, sw, out_dtype=jnp.float32)
    E, C, N = out.shape
    flat = out.reshape(E * C, N)
    data, scale = quantize_rowwise_ref(flat)
    return data.reshape(E, C, N), scale.reshape(E, C, N // TILE)


# ---------------------------------------------------------------------------
# Masked grouped-GEMM oracles (tile-granular masking, BM/BK = TILE = 128).
#
# Masking is TILE-granular, exactly like the kernels: a 128-row M-tile is
# live iff its first row index is < masked_m[e].  Rows in dead tiles come out
# as hard zeros (scale 1.0 for quantized outputs) regardless of input
# content; rows in a partially-live tile are computed whole.
# ---------------------------------------------------------------------------
def _tile_live_rows(masked_m, C):
    """(E,) counts -> (E, C) bool: row r live iff its tile start < count."""
    starts = (jnp.arange(C) // TILE) * TILE                       # (C,)
    return starts[None, :] < masked_m[:, None]


def masked_grouped_gemm_fp8_ref(x, sx, w, sw, masked_m,
                                out_dtype=jnp.bfloat16):
    """Oracle for the masked grouped GEMM (NN form)."""
    out = grouped_gemm_fp8_ref(x, sx, w, sw, out_dtype=jnp.float32)
    live = _tile_live_rows(masked_m, out.shape[1])
    return jnp.where(live[..., None], out, 0.0).astype(out_dtype)


def masked_grouped_gemm_fp8_quant_out_ref(x, sx, w, sw, masked_m):
    """Oracle for the masked quantizing-epilogue grouped GEMM: dead tiles
    emit payload 0 and scale 1.0 (what quantizing an all-zero row yields)."""
    out = grouped_gemm_fp8_ref(x, sx, w, sw, out_dtype=jnp.float32)
    E, C, N = out.shape
    live = _tile_live_rows(masked_m, C)
    out = jnp.where(live[..., None], out, 0.0)
    data, scale = quantize_rowwise_ref(out.reshape(E * C, N))
    return data.reshape(E, C, N), scale.reshape(E, C, N // TILE)


def masked_grouped_gemm_nt_fp8_ref(a, sa, b, sb, masked_m,
                                   out_dtype=jnp.float32):
    """Oracle for the masked NT grouped GEMM: contraction tiles beyond the
    live-token count are dropped (not merely zero-multiplied)."""
    E, M, C = a.shape
    N = b.shape[1]
    nk = C // TILE
    af = a.astype(jnp.float32).reshape(E, M, nk, TILE)
    bf = b.astype(jnp.float32).reshape(E, N, nk, TILE)
    acc = jnp.zeros((E, M, N), jnp.float32)
    for k in range(nk):
        partial = jnp.einsum("emt,ent->emn", af[:, :, k], bf[:, :, k],
                             precision=jax.lax.Precision.HIGHEST)
        partial = partial * sa[:, :, k][..., None] * sb[:, :, k][:, None, :]
        klive = (k * TILE < masked_m)[:, None, None]
        acc = acc + jnp.where(klive, partial, 0.0)
    return acc.astype(out_dtype)


def masked_grouped_gemm_swiglu_quant_ref(x, sx, w13, sw13, masked_m):
    """Oracle for the masked GEMM-1 with fused SwiGLU+quant epilogue.

    w13: (E, K, 2F) = [gate | up] halves.  Each half accumulates k-major in
    f32 (same order as the kernel), rounds through bf16 (matching the unfused
    pipeline's h bf16 island), then SwiGLU + row-wise e4m3 quantization.
    Dead tiles zero before the activation, so they quantize to payload 0 /
    scale 1.0 — the padded-pipeline bits for zero rows."""
    E, K, twoF = w13.shape
    F = twoF // 2
    w4 = w13.reshape(E, K, 2, F)
    sw4 = sw13.reshape(E, K // TILE, 2, F // TILE)
    g = grouped_gemm_fp8_ref(x, sx, w4[:, :, 0, :], sw4[:, :, 0, :],
                             out_dtype=jnp.float32)
    u = grouped_gemm_fp8_ref(x, sx, w4[:, :, 1, :], sw4[:, :, 1, :],
                             out_dtype=jnp.float32)
    C = g.shape[1]
    live = _tile_live_rows(masked_m, C)[..., None]
    g = jnp.where(live, g, 0.0).astype(jnp.bfloat16).astype(jnp.float32)
    u = jnp.where(live, u, 0.0).astype(jnp.bfloat16).astype(jnp.float32)
    y = (g * jax.lax.logistic(g)) * u
    data, scale = quantize_rowwise_ref(y.reshape(E * C, F))
    return data.reshape(E, C, F), scale.reshape(E, C, F // TILE)


def fused_permute_pad_ref(x, s, row_map, n_out):
    """Oracle for kernels/fused_permute_pad.py."""
    valid = (row_map >= 0)[:, None]
    src = jnp.maximum(row_map, 0)
    xo = jnp.where(valid, x[src], jnp.zeros((n_out, x.shape[1]), x.dtype))
    so = jnp.where(valid, s[src], jnp.ones((n_out, s.shape[1]), s.dtype))
    return xo, so
