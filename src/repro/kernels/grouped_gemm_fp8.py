"""Pallas TPU kernel: FP8 grouped GEMM with per-tile scaling (DeepGEMM-on-TPU).

out[e] = (x[e] . sx[e]) @ (w[e] . sw[e])   for every expert e, where
  x  : (E, C, K)  e4m3, row-wise (1,TILE) scales sx (E, C, K/TILE)
  w  : (E, K, N)  e4m3, (TILE,TILE) block scales  sw (E, K/TILE, N/TILE)
  out: (E, C, N)  bf16

Grid: (E, C/BM, N/BN, K/BK) with BK == TILE so each K-step contributes one
scale product; partials accumulate in an f32 VMEM scratch (MXU contract:
fp8 x fp8 -> f32).  The expert dimension rides the grid, so ragged groups
cost only their padded tiles — padding rows are zero and contribute nothing.

Block shapes are 128-aligned for the MXU; x/w blocks stream HBM->VMEM once
per (m,n,k) tile visit with the accumulator resident across the K loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8 import TILE

BM = 128
BN = 128
BK = TILE  # must equal the scale tile


def _gg_kernel(x_ref, sx_ref, w_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                   # (BM, BK) fp8 payload
    w = w_ref[0].astype(jnp.float32)                   # (BK, BN)
    partial = jax.lax.dot(x, w,
                          precision=jax.lax.Precision.HIGHEST)  # f32 accum
    sx = sx_ref[0]                                     # (BM, 1) act scales
    sw = sw_ref[0, 0, 0]                               # scalar weight scale
    acc_ref[...] += partial * (sx * sw)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def _gg_quant_kernel(x_ref, sx_ref, w_ref, sw_ref, o_ref, os_ref, acc_ref,
                     *, nk: int):
    """Same as _gg_kernel but the epilogue quantizes the (BM, BN=TILE) output
    tile to e4m3 + a po2 scale column — the 'fused epilogue quantization' that
    keeps Dgrad outputs in FP8 without an explicit cast kernel (§3.2)."""
    from repro.core.fp8 import E4M3, E4M3_MAX

    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    partial = jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)
    sx = sx_ref[0]
    sw = sw_ref[0, 0, 0]
    acc_ref[...] += partial * (sx * sw)

    @pl.when(k == nk - 1)
    def _done():
        acc = acc_ref[...]
        amax = jnp.max(jnp.abs(acc), axis=-1, keepdims=True)
        safe = jnp.maximum(amax, jnp.float32(1e-38))
        exp = jnp.clip(jnp.ceil(jnp.log2(safe / E4M3_MAX)), -126.0, 126.0)
        s = jnp.where(amax > 0, jnp.exp2(exp), jnp.float32(1.0))
        o_ref[0, ...] = jnp.clip(acc / s, -E4M3_MAX, E4M3_MAX).astype(E4M3)
        os_ref[0, ...] = s


def grouped_gemm_fp8_pallas(x, sx, w, sw, *, out_dtype=jnp.bfloat16,
                            quant_out: bool = False, interpret: bool = True):
    E, C, K = x.shape
    _, _, N = w.shape
    assert C % BM == 0 and N % BN == 0 and K % BK == 0, (C, K, N)
    nk = K // BK
    grid = (E, C // BM, N // BN, nk)
    in_specs = [
        pl.BlockSpec((1, BM, BK), lambda e, m, n, k: (e, m, k)),
        pl.BlockSpec((1, BM, 1), lambda e, m, n, k: (e, m, k)),
        pl.BlockSpec((1, BK, BN), lambda e, m, n, k: (e, k, n)),
        pl.BlockSpec((1, 1, 1), lambda e, m, n, k: (e, k, n)),
    ]
    if not quant_out:
        return pl.pallas_call(
            functools.partial(_gg_kernel, nk=nk),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, BM, BN), lambda e, m, n, k: (e, m, n)),
            out_shape=jax.ShapeDtypeStruct((E, C, N), out_dtype),
            scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
            interpret=interpret,
        )(x, sx, w, sw)

    from repro.core.fp8 import E4M3
    return pl.pallas_call(
        functools.partial(_gg_quant_kernel, nk=nk),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, BM, BN), lambda e, m, n, k: (e, m, n)),
            pl.BlockSpec((1, BM, 1), lambda e, m, n, k: (e, m, n)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((E, C, N), E4M3),
            jax.ShapeDtypeStruct((E, C, N // BN), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(x, sx, w, sw)
