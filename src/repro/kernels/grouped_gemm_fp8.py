"""Pallas TPU kernels: FP8 grouped GEMM with per-tile scaling (DeepGEMM-on-TPU).

out[e] = (x[e] . sx[e]) @ (w[e] . sw[e])   for every expert e, where
  x  : (E, C, K)  e4m3, row-wise (1,TILE) scales sx (E, C, K/TILE)
  w  : (E, K, N)  e4m3, (TILE,TILE) block scales  sw (E, K/TILE, N/TILE)
  out: (E, C, N)  bf16

Grid: (E, C/BM, N/BN, K/BK) with BK == TILE so each K-step contributes one
scale product; partials accumulate in an f32 VMEM scratch (MXU contract:
fp8 x fp8 -> f32).  Block shapes are 128-aligned for the MXU; x/w blocks
stream HBM->VMEM once per (m,n,k) tile visit with the accumulator resident
across the K loop.

Two layouts:

* PADDED (the seed): every expert is padded to the full capacity C; padding
  rows are zero and contribute nothing, but their tiles still ride through
  the MXU.
* MASKED (DeepGEMM/LightLLM ``masked_group_gemm`` layout): a per-expert
  ``masked_m`` count vector (int32 (E,), scalar-prefetched into SMEM) tells
  each M-tile whether ANY of its rows are live; tiles with
  ``m * BM >= masked_m[e]`` skip the dot+scale work entirely via ``pl.when``
  and write zeros in the epilogue, so expert-load imbalance becomes a
  compute no-op instead of padded-tile MXU work.  ``expected_m`` is a STATIC
  tuning hint (the per-expert load the caller expects, e.g.
  ``ceil(T * top_k / E)``): it sizes the FLOPs/bytes model in
  ``benchmarks/masked_moe_ab.py`` and lets the ``ops.py`` wrappers fall back
  to the padded kernel when ``expected_m >= C`` (masking would only add
  scalar-prefetch overhead).  Masking is TILE-GRANULAR: rows beyond
  ``masked_m[e]`` inside a partially-live tile are computed from whatever
  payload is there, so callers that need row-exact zeros must zero-pad the
  dead rows (the fused permute+pad dispatch layout guarantees this).

The masked GEMM-1 variant fuses the inter-GEMM SwiGLU + row-wise e4m3
re-quantize into the ``k == nk-1`` epilogue (paper §3.3.2 taken into the
kernel layer): gate/up column tiles accumulate in two scratches, the
epilogue rounds both through bf16 (bit-identical to the unfused
bf16-island h), applies silu(gate)*up and quantizes per (row, TILE)-tile —
the expert intermediate never materializes in bf16 in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8 import TILE
from repro.kernels.quantize import kernel_po2_scale

BM = 128
BN = 128
BK = TILE  # must equal the scale tile


def _gg_kernel(x_ref, sx_ref, w_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                   # (BM, BK) fp8 payload
    w = w_ref[0].astype(jnp.float32)                   # (BK, BN)
    partial = jax.lax.dot(x, w,
                          precision=jax.lax.Precision.HIGHEST)  # f32 accum
    sx = sx_ref[0]                                     # (BM, 1) act scales
    sw = sw_ref[0, 0, 0]                               # scalar weight scale
    acc_ref[...] += partial * (sx * sw)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def _quant_epilogue(acc, o_ref, os_ref):
    """Row-wise e4m3 + po2-scale quantization of a (BM, BN=TILE) f32 tile."""
    from repro.core.fp8 import E4M3, E4M3_MAX
    amax = jnp.max(jnp.abs(acc), axis=-1, keepdims=True)
    s = kernel_po2_scale(amax)
    o_ref[0, ...] = jnp.clip(acc / s, -E4M3_MAX, E4M3_MAX).astype(E4M3)
    os_ref[0, ...] = s


def _gg_quant_kernel(x_ref, sx_ref, w_ref, sw_ref, o_ref, os_ref, acc_ref,
                     *, nk: int):
    """Same as _gg_kernel but the epilogue quantizes the (BM, BN=TILE) output
    tile to e4m3 + a po2 scale column — the 'fused epilogue quantization' that
    keeps Dgrad outputs in FP8 without an explicit cast kernel (§3.2)."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    partial = jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)
    sx = sx_ref[0]
    sw = sw_ref[0, 0, 0]
    acc_ref[...] += partial * (sx * sw)

    @pl.when(k == nk - 1)
    def _done():
        _quant_epilogue(acc_ref[...], o_ref, os_ref)


def _assert_quant_out_tiling():
    """The quantizing epilogues compute ONE scale per (row, BN-tile) and the
    wrappers expose it as a row-wise (..., 1, TILE) QTensor whose scale shape
    is N // TILE.  That is only correct while BN == TILE — if the block
    shapes ever diverge the scale metadata would be silently wrong, so the
    mismatch must fail at trace time."""
    assert BN == TILE, (
        f"quant-out epilogue requires BN == TILE (got BN={BN}, TILE={TILE}): "
        "the per-(row, BN-tile) scales are exposed as (1, TILE) row tiles")


def grouped_gemm_fp8_pallas(x, sx, w, sw, *, out_dtype=jnp.bfloat16,
                            quant_out: bool = False, interpret: bool = True):
    E, C, K = x.shape
    _, _, N = w.shape
    assert C % BM == 0 and N % BN == 0 and K % BK == 0, (C, K, N)
    nk = K // BK
    grid = (E, C // BM, N // BN, nk)
    in_specs = [
        pl.BlockSpec((1, BM, BK), lambda e, m, n, k: (e, m, k)),
        pl.BlockSpec((1, BM, 1), lambda e, m, n, k: (e, m, k)),
        pl.BlockSpec((1, BK, BN), lambda e, m, n, k: (e, k, n)),
        pl.BlockSpec((1, 1, 1), lambda e, m, n, k: (e, k, n)),
    ]
    if not quant_out:
        return pl.pallas_call(
            functools.partial(_gg_kernel, nk=nk),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, BM, BN), lambda e, m, n, k: (e, m, n)),
            out_shape=jax.ShapeDtypeStruct((E, C, N), out_dtype),
            scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
            interpret=interpret,
        )(x, sx, w, sw)

    from repro.core.fp8 import E4M3
    _assert_quant_out_tiling()
    return pl.pallas_call(
        functools.partial(_gg_quant_kernel, nk=nk),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, BM, BN), lambda e, m, n, k: (e, m, n)),
            pl.BlockSpec((1, BM, 1), lambda e, m, n, k: (e, m, n)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((E, C, N), E4M3),
            jax.ShapeDtypeStruct((E, C, N // BN), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(x, sx, w, sw)


# ---------------------------------------------------------------------------
# Masked layout.  masked_m rides scalar prefetch (SMEM) so the per-tile
# liveness predicate is available before the tile body runs.
# ---------------------------------------------------------------------------
def _gg_masked_kernel(mm_ref, x_ref, sx_ref, w_ref, sw_ref, o_ref, acc_ref,
                      *, nk: int):
    e = pl.program_id(0)
    m = pl.program_id(1)
    k = pl.program_id(3)
    live = m * BM < mm_ref[e]

    @pl.when(live & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _step():
        x = x_ref[0].astype(jnp.float32)
        w = w_ref[0].astype(jnp.float32)
        partial = jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)
        acc_ref[...] += partial * (sx_ref[0] * sw_ref[0, 0, 0])

    @pl.when(k == nk - 1)
    def _done():
        # dead tiles write zeros — bitwise what the padded kernel produces
        # for zero-padded rows, so masked == padded on the whole buffer
        # whenever rows beyond masked_m are zero (the dispatch layout).
        o_ref[0, ...] = jnp.where(live, acc_ref[...], 0.0).astype(o_ref.dtype)


def _gg_masked_quant_kernel(mm_ref, x_ref, sx_ref, w_ref, sw_ref, o_ref,
                            os_ref, acc_ref, *, nk: int):
    e = pl.program_id(0)
    m = pl.program_id(1)
    k = pl.program_id(3)
    live = m * BM < mm_ref[e]

    @pl.when(live & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _step():
        x = x_ref[0].astype(jnp.float32)
        w = w_ref[0].astype(jnp.float32)
        partial = jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)
        acc_ref[...] += partial * (sx_ref[0] * sw_ref[0, 0, 0])

    @pl.when(k == nk - 1)
    def _done():
        # dead tiles: acc==0 -> amax==0 -> scale 1.0, payload 0 — the exact
        # bits the padded quantizing epilogue emits for zero rows.
        _quant_epilogue(jnp.where(live, acc_ref[...], 0.0), o_ref, os_ref)


def _gg_masked_swiglu_quant_kernel(mm_ref, x_ref, sx_ref, w_ref, sw_ref,
                                   o_ref, os_ref, accg_ref, accu_ref,
                                   *, nk: int):
    """Masked grouped GEMM-1 with the SwiGLU + row-wise re-quantize fused
    into the last K-step: w13 arrives reshaped (E, K, 2, F) so ONE operand
    block carries both the gate (half 0) and up (half 1) column tiles.  The
    epilogue rounds both accumulators through bf16 first — bit-identical to
    the unfused path's materialized bf16 island h — then quantizes
    silu(gate)*up per (row, TILE)-tile."""
    from repro.core.fp8 import E4M3, E4M3_MAX

    e = pl.program_id(0)
    m = pl.program_id(1)
    k = pl.program_id(3)
    live = m * BM < mm_ref[e]

    @pl.when(live & (k == 0))
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    @pl.when(live)
    def _step():
        x = x_ref[0].astype(jnp.float32)
        wg = w_ref[0, :, 0, :].astype(jnp.float32)     # (BK, BN) gate cols
        wu = w_ref[0, :, 1, :].astype(jnp.float32)     # (BK, BN) up cols
        sx = sx_ref[0]
        accg_ref[...] += jax.lax.dot(
            x, wg, precision=jax.lax.Precision.HIGHEST) * (sx * sw_ref[0, 0, 0, 0])
        accu_ref[...] += jax.lax.dot(
            x, wu, precision=jax.lax.Precision.HIGHEST) * (sx * sw_ref[0, 0, 1, 0])

    @pl.when(k == nk - 1)
    def _done():
        g = jnp.where(live, accg_ref[...], 0.0)
        u = jnp.where(live, accu_ref[...], 0.0)
        # bf16 round-trip = the paper's deliberate BF16 island, kept so the
        # fused epilogue is BITWISE the unfused h -> swiglu+quant kernel pair
        g = g.astype(jnp.bfloat16).astype(jnp.float32)
        u = u.astype(jnp.bfloat16).astype(jnp.float32)
        y = (g * jax.lax.logistic(g)) * u
        amax = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
        s = kernel_po2_scale(amax)
        o_ref[0, ...] = jnp.clip(y / s, -E4M3_MAX, E4M3_MAX).astype(E4M3)
        os_ref[0, ...] = s


def _masked_specs(extra=0):
    """in_specs shared by the masked kernels (index maps see the prefetched
    scalar ref as a trailing arg)."""
    return [
        pl.BlockSpec((1, BM, BK), lambda e, m, n, k, mm: (e, m, k)),
        pl.BlockSpec((1, BM, 1), lambda e, m, n, k, mm: (e, m, k)),
    ]


def masked_grouped_gemm_fp8_pallas(x, sx, w, sw, masked_m, *,
                                   out_dtype=jnp.bfloat16,
                                   quant_out: bool = False,
                                   interpret: bool = True):
    """Masked grouped GEMM: tiles with m*BM >= masked_m[e] are compute
    no-ops (zeros written in the epilogue)."""
    E, C, K = x.shape
    _, _, N = w.shape
    assert C % BM == 0 and N % BN == 0 and K % BK == 0, (C, K, N)
    assert masked_m.shape == (E,) and masked_m.dtype == jnp.int32, masked_m
    nk = K // BK
    grid = (E, C // BM, N // BN, nk)
    in_specs = _masked_specs() + [
        pl.BlockSpec((1, BK, BN), lambda e, m, n, k, mm: (e, k, n)),
        pl.BlockSpec((1, 1, 1), lambda e, m, n, k, mm: (e, k, n)),
    ]
    if not quant_out:
        gs = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((1, BM, BN),
                                   lambda e, m, n, k, mm: (e, m, n)),
            scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)])
        return pl.pallas_call(
            functools.partial(_gg_masked_kernel, nk=nk),
            grid_spec=gs,
            out_shape=jax.ShapeDtypeStruct((E, C, N), out_dtype),
            interpret=interpret,
        )(masked_m, x, sx, w, sw)

    from repro.core.fp8 import E4M3
    _assert_quant_out_tiling()
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, BM, BN), lambda e, m, n, k, mm: (e, m, n)),
            pl.BlockSpec((1, BM, 1), lambda e, m, n, k, mm: (e, m, n)),
        ),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)])
    return pl.pallas_call(
        functools.partial(_gg_masked_quant_kernel, nk=nk),
        grid_spec=gs,
        out_shape=(
            jax.ShapeDtypeStruct((E, C, N), E4M3),
            jax.ShapeDtypeStruct((E, C, N // BN), jnp.float32),
        ),
        interpret=interpret,
    )(masked_m, x, sx, w, sw)


def masked_grouped_gemm_swiglu_quant_pallas(x, sx, w13, sw13, masked_m, *,
                                            interpret: bool = True):
    """Masked grouped GEMM-1 with fused SwiGLU + e4m3 re-quantize epilogue.

    x    : (E, C, K) e4m3, row-wise scales sx (E, C, K/TILE)
    w13  : (E, K, 2F) e4m3 [gate | up], block scales sw13 (E, K/T, 2F/T)
    out  : (data (E, C, F) e4m3, scale (E, C, F/TILE) f32)

    The [gate | up] halves are exposed to the kernel through a zero-copy
    (E, K, 2, F) reshape, so ONE HBM operand (one BlockSpec) feeds both
    accumulators — no duplicate operand declaration.
    """
    from repro.core.fp8 import E4M3

    E, C, K = x.shape
    twoF = w13.shape[-1]
    F = twoF // 2
    assert C % BM == 0 and F % BN == 0 and K % BK == 0, (C, K, F)
    assert masked_m.shape == (E,) and masked_m.dtype == jnp.int32, masked_m
    _assert_quant_out_tiling()
    nk = K // BK
    w4 = w13.reshape(E, K, 2, F)
    sw4 = sw13.reshape(E, K // TILE, 2, F // TILE)
    grid = (E, C // BM, F // BN, nk)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid,
        in_specs=_masked_specs() + [
            pl.BlockSpec((1, BK, 2, BN), lambda e, m, n, k, mm: (e, k, 0, n)),
            pl.BlockSpec((1, 1, 2, 1), lambda e, m, n, k, mm: (e, k, 0, n)),
        ],
        out_specs=(
            pl.BlockSpec((1, BM, BN), lambda e, m, n, k, mm: (e, m, n)),
            pl.BlockSpec((1, BM, 1), lambda e, m, n, k, mm: (e, m, n)),
        ),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32),
                        pltpu.VMEM((BM, BN), jnp.float32)])
    return pl.pallas_call(
        functools.partial(_gg_masked_swiglu_quant_kernel, nk=nk),
        grid_spec=gs,
        out_shape=(
            jax.ShapeDtypeStruct((E, C, F), E4M3),
            jax.ShapeDtypeStruct((E, C, F // TILE), jnp.float32),
        ),
        interpret=interpret,
    )(masked_m, x, sx, w4, sw4)
