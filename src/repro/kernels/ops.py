"""Jit'd public wrappers for the Pallas kernel suite.

On this CPU container the kernels execute in interpret mode (the kernel body
runs in Python, validating TPU semantics); on a TPU runtime set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the backend default) to compile them
to Mosaic.  Every wrapper has a matching pure-jnp oracle in ``ref.py``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.core.fp8 import TILE
from repro.kernels.fp8_transpose import fp8_transpose_pallas
from repro.kernels.fused_permute_pad import fused_permute_pad_pallas
from repro.kernels.fused_swiglu_quant import fused_swiglu_quant_pallas
from repro.kernels.grouped_gemm_fp8 import grouped_gemm_fp8_pallas
from repro.kernels.grouped_gemm_nt_fp8 import grouped_gemm_nt_fp8_pallas
from repro.kernels.quantize import quantize_rowwise_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_rowwise(x: jax.Array, interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = quantize_rowwise_pallas(x, interpret=interpret)
    return QTensor(data=data, scale=scale, tile=(1, TILE))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fp8_transpose(q: QTensor, interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = fp8_transpose_pallas(q.data, q.scale, interpret=interpret)
    return QTensor(data=data, scale=scale, tile=(1, TILE))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_swiglu_quant(h: jax.Array, interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = fused_swiglu_quant_pallas(h, interpret=interpret)
    return QTensor(data=data, scale=scale, tile=(1, TILE))


@functools.partial(jax.jit, static_argnames=("n_out", "interpret"))
def fused_permute_pad(q: QTensor, row_map: jax.Array, n_out: int,
                      interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = fused_permute_pad_pallas(q.data, q.scale, row_map, n_out,
                                           interpret=interpret)
    return QTensor(data=data, scale=scale, tile=(1, TILE))


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_gemm_fp8(qx: QTensor, qw: QTensor, interpret: bool | None = None):
    """qx: (E, C, K) row-wise; qw: (E, K, N) block-wise -> (E, C, N) bf16."""
    interpret = _interpret_default() if interpret is None else interpret
    return grouped_gemm_fp8_pallas(qx.data, qx.scale, qw.data, qw.scale,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_gemm_fp8_quant_out(qx: QTensor, qw: QTensor,
                               interpret: bool | None = None) -> QTensor:
    """Grouped GEMM whose epilogue quantizes straight to e4m3 (Dgrad path)."""
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = grouped_gemm_fp8_pallas(qx.data, qx.scale, qw.data, qw.scale,
                                          quant_out=True, interpret=interpret)
    return QTensor(data=data, scale=scale, tile=(1, 1, TILE))


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_gemm_nt_fp8(qa: QTensor, qb: QTensor,
                        interpret: bool | None = None):
    """qa: (E, M, C), qb: (E, N, C) both row-wise over C -> (E, M, N) f32."""
    interpret = _interpret_default() if interpret is None else interpret
    return grouped_gemm_nt_fp8_pallas(qa.data, qa.scale, qb.data, qb.scale,
                                      interpret=interpret)
