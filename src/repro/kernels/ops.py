"""Jit'd public wrappers for the Pallas kernel suite.

On this CPU container the kernels execute in interpret mode (the kernel body
runs in Python, validating TPU semantics); on a TPU runtime set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the backend default) to compile them
to Mosaic.  Every wrapper has a matching pure-jnp oracle in ``ref.py``.

Alignment contract: the kernels require 128-aligned blocks, but MoE capacity
is only rounded to 8 on the decode path (``moe._round_up(..., 8)``), so the
row/capacity axes here are PADDED to the kernel block (payload 0, scale 1.0
— the bits quantizing a zero row produces) and outputs sliced back.  Model
axes (K, N, F) are true 128 multiples everywhere in the repo and stay
asserted.  The NT wrappers keep the hard assert on the contraction axis: a
row-tiled QTensor over that axis cannot even be constructed unless it is a
TILE multiple.

Masked variants take the per-expert live-row counts ``masked_m`` (int32
(E,)) from the dispatch plan plus a STATIC ``expected_m`` tuning hint; when
``expected_m >= capacity`` the wrapper falls back to the padded kernel
(masking would only add scalar-prefetch overhead at full load).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, row_tile
from repro.core.fp8 import TILE
from repro.kernels.fp8_transpose import fp8_transpose_pallas
from repro.kernels.fused_permute_pad import fused_permute_pad_pallas
from repro.kernels.fused_swiglu_quant import fused_swiglu_quant_pallas
from repro.kernels.grouped_gemm_fp8 import (
    BM,
    grouped_gemm_fp8_pallas,
    masked_grouped_gemm_fp8_pallas,
    masked_grouped_gemm_swiglu_quant_pallas,
)
from repro.kernels.grouped_gemm_nt_fp8 import (
    grouped_gemm_nt_fp8_pallas,
    masked_grouped_gemm_nt_fp8_pallas,
)
from repro.kernels.quantize import ROWS, quantize_rowwise_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad_rows(x: jax.Array, axis: int, n_to: int, value=0):
    """Zero-pad (or 1.0-pad, for scales) one axis up to n_to rows."""
    n = x.shape[axis]
    if n == n_to:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n_to - n)
    return jnp.pad(x, pad, constant_values=value)


def _pad_q_axis(q: QTensor, axis: int, block: int) -> QTensor:
    """Pad a QTensor's element-granular axis (tile[axis] == 1) to a block
    multiple: payload 0, scale 1.0 — exactly what quantizing a zero row
    emits, so padded rows are bitwise-inert through every kernel."""
    n = q.data.shape[axis]
    n_to = _round_up(n, block)
    if n_to == n:
        return q
    assert q.tile[axis] == 1, (q.tile, axis)
    return QTensor(_pad_rows(q.data, axis, n_to),
                   _pad_rows(q.scale, axis, n_to, value=1.0), q.tile)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_rowwise(x: jax.Array, interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    M = x.shape[0]
    data, scale = quantize_rowwise_pallas(
        _pad_rows(x, 0, _round_up(M, ROWS)), interpret=interpret)
    return QTensor(data=data[:M], scale=scale[:M], tile=row_tile(2))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fp8_transpose(q: QTensor, interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = fp8_transpose_pallas(q.data, q.scale, interpret=interpret)
    return QTensor(data=data, scale=scale, tile=row_tile(2))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_swiglu_quant(h: jax.Array, interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    M = h.shape[0]
    data, scale = fused_swiglu_quant_pallas(
        _pad_rows(h, 0, _round_up(M, ROWS)), interpret=interpret)
    return QTensor(data=data[:M], scale=scale[:M], tile=row_tile(2))


@functools.partial(jax.jit, static_argnames=("n_out", "interpret"))
def fused_permute_pad(q: QTensor, row_map: jax.Array, n_out: int,
                      interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    data, scale = fused_permute_pad_pallas(q.data, q.scale, row_map, n_out,
                                           interpret=interpret)
    return QTensor(data=data, scale=scale, tile=row_tile(2))


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_gemm_fp8(qx: QTensor, qw: QTensor, interpret: bool | None = None):
    """qx: (E, C, K) row-wise; qw: (E, K, N) block-wise -> (E, C, N) bf16."""
    interpret = _interpret_default() if interpret is None else interpret
    C = qx.data.shape[1]
    qx = _pad_q_axis(qx, 1, BM)
    out = grouped_gemm_fp8_pallas(qx.data, qx.scale, qw.data, qw.scale,
                                  interpret=interpret)
    return out[:, :C]


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_gemm_fp8_quant_out(qx: QTensor, qw: QTensor,
                               interpret: bool | None = None) -> QTensor:
    """Grouped GEMM whose epilogue quantizes straight to e4m3 (Dgrad path)."""
    interpret = _interpret_default() if interpret is None else interpret
    C = qx.data.shape[1]
    qx = _pad_q_axis(qx, 1, BM)
    data, scale = grouped_gemm_fp8_pallas(qx.data, qx.scale, qw.data, qw.scale,
                                          quant_out=True, interpret=interpret)
    return QTensor(data=data[:, :C], scale=scale[:, :C], tile=row_tile(3))


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_gemm_nt_fp8(qa: QTensor, qb: QTensor,
                        interpret: bool | None = None):
    """qa: (E, M, C), qb: (E, N, C) both row-wise over C -> (E, M, N) f32."""
    interpret = _interpret_default() if interpret is None else interpret
    return grouped_gemm_nt_fp8_pallas(qa.data, qa.scale, qb.data, qb.scale,
                                      interpret=interpret)


# ---------------------------------------------------------------------------
# Masked layout entry points.
# ---------------------------------------------------------------------------
def _use_padded(expected_m, C: int) -> bool:
    return expected_m is not None and expected_m >= C


@functools.partial(jax.jit, static_argnames=("expected_m", "interpret"))
def grouped_gemm_fp8_masked(qx: QTensor, qw: QTensor, masked_m: jax.Array,
                            expected_m: int | None = None,
                            interpret: bool | None = None):
    """Masked grouped GEMM: capacity tiles beyond masked_m[e] skip the MXU."""
    interpret = _interpret_default() if interpret is None else interpret
    C = qx.data.shape[1]
    if _use_padded(expected_m, C):
        return grouped_gemm_fp8(qx, qw, interpret=interpret)
    qx = _pad_q_axis(qx, 1, BM)
    out = masked_grouped_gemm_fp8_pallas(
        qx.data, qx.scale, qw.data, qw.scale, masked_m.astype(jnp.int32),
        interpret=interpret)
    return out[:, :C]


@functools.partial(jax.jit, static_argnames=("expected_m", "interpret"))
def grouped_gemm_fp8_masked_quant_out(qx: QTensor, qw: QTensor,
                                      masked_m: jax.Array,
                                      expected_m: int | None = None,
                                      interpret: bool | None = None) -> QTensor:
    interpret = _interpret_default() if interpret is None else interpret
    C = qx.data.shape[1]
    if _use_padded(expected_m, C):
        return grouped_gemm_fp8_quant_out(qx, qw, interpret=interpret)
    qx = _pad_q_axis(qx, 1, BM)
    data, scale = masked_grouped_gemm_fp8_pallas(
        qx.data, qx.scale, qw.data, qw.scale, masked_m.astype(jnp.int32),
        quant_out=True, interpret=interpret)
    return QTensor(data=data[:, :C], scale=scale[:, :C], tile=row_tile(3))


@functools.partial(jax.jit, static_argnames=("expected_m", "interpret"))
def grouped_gemm_nt_fp8_masked(qa: QTensor, qb: QTensor, masked_m: jax.Array,
                               expected_m: int | None = None,
                               interpret: bool | None = None):
    """Masked NT (Wgrad) form: contraction (token) tiles beyond masked_m[e]
    are skipped — bitwise-invisible because dead token columns are zero."""
    interpret = _interpret_default() if interpret is None else interpret
    C = qa.data.shape[2]
    if _use_padded(expected_m, C):
        return grouped_gemm_nt_fp8(qa, qb, interpret=interpret)
    return masked_grouped_gemm_nt_fp8_pallas(
        qa.data, qa.scale, qb.data, qb.scale, masked_m.astype(jnp.int32),
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("expected_m", "interpret"))
def grouped_gemm_swiglu_quant_masked(qx: QTensor, qw13: QTensor,
                                     masked_m: jax.Array,
                                     expected_m: int | None = None,
                                     interpret: bool | None = None) -> QTensor:
    """Masked grouped GEMM-1 with the fused SwiGLU + e4m3 re-quantize
    epilogue: (E, C, K) x (E, K, 2F) -> QTensor (E, C, F) row-tiled.  The
    bf16 island h never reaches HBM.  ``expected_m >= C`` does NOT fall back
    (the fusion is worth it at any load); masked_m = full C gives the padded
    bits anyway."""
    interpret = _interpret_default() if interpret is None else interpret
    C = qx.data.shape[1]
    qx = _pad_q_axis(qx, 1, BM)
    data, scale = masked_grouped_gemm_swiglu_quant_pallas(
        qx.data, qx.scale, qw13.data, qw13.scale, masked_m.astype(jnp.int32),
        interpret=interpret)
    return QTensor(data=data[:, :C], scale=scale[:, :C], tile=row_tile(3))
