"""Pallas TPU kernel: FP8 grouped GEMM, NT layout (Wgrad form).

out[e] = (a[e] . sa[e]) @ (b[e] . sb[e])^T  with contraction over the LAST
axis of both operands:
  a  : (E, M, C) e4m3, row-wise (1,TILE) scales sa (E, M, C/TILE)
  b  : (E, N, C) e4m3, row-wise (1,TILE) scales sb (E, N, C/TILE)
  out: (E, M, N) f32 (weight gradients accumulate in f32)

This is exactly the shape the scaling-aware direct transpose produces: Wgrad
consumes T(activations) and T(grad) — both row-tiled over the token axis —
with no dequantize/requantize anywhere (paper §3.1/§3.2).

The MASKED variant takes the per-expert live-token count vector ``masked_m``
(the same counts the masked forward GEMMs use): here the token axis is the
CONTRACTION axis, so K-steps with ``k * BK >= masked_m[e]`` are skipped —
their padded-token columns are all zero and contribute nothing, which makes
the skip bitwise-invisible (x + 0.0 == x in f32 for finite x) while saving
the full MXU visit.  Partially-live K-tiles are computed whole; callers must
zero-pad dead token columns (the direct transpose of the zero-padded
dispatch layout guarantees this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8 import TILE

BM = 128
BN = 128
BK = TILE


def _gg_nt_kernel(a_ref, sa_ref, b_ref, sb_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.float32)                   # (BM, BK)
    b = b_ref[0].astype(jnp.float32)                   # (BN, BK)
    partial = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST)           # (BM, BN) f32
    sa = sa_ref[0]                                     # (BM, 1)
    sb = sb_ref[0]                                     # (BN, 1)
    acc_ref[...] += partial * (sa * sb.T)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def grouped_gemm_nt_fp8_pallas(a, sa, b, sb, *, out_dtype=jnp.float32,
                               interpret: bool = True):
    E, M, C = a.shape
    _, N, _ = b.shape
    assert M % BM == 0 and N % BN == 0 and C % BK == 0, (M, N, C)
    nk = C // BK
    grid = (E, M // BM, N // BN, nk)
    kernel = functools.partial(_gg_nt_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BM, BK), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, BM, 1), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, BN, BK), lambda e, m, n, k: (e, n, k)),
            pl.BlockSpec((1, BN, 1), lambda e, m, n, k: (e, n, k)),
        ],
        out_specs=pl.BlockSpec((1, BM, BN), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(a, sa, b, sb)


# ---------------------------------------------------------------------------
# Masked layout: skip contraction steps over dead token tiles.
# ---------------------------------------------------------------------------
def _gg_nt_masked_kernel(mm_ref, a_ref, sa_ref, b_ref, sb_ref, o_ref, acc_ref,
                         *, nk: int):
    e = pl.program_id(0)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k * BK < mm_ref[e])
    def _step():
        a = a_ref[0].astype(jnp.float32)               # (BM, BK)
        b = b_ref[0].astype(jnp.float32)               # (BN, BK)
        partial = jax.lax.dot_general(
            a, b, (((1,), (1,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST)
        acc_ref[...] += partial * (sa_ref[0] * sb_ref[0].T)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def masked_grouped_gemm_nt_fp8_pallas(a, sa, b, sb, masked_m, *,
                                      out_dtype=jnp.float32,
                                      interpret: bool = True):
    """Masked NT grouped GEMM: the token (contraction) axis is masked —
    K-steps beyond expert e's live count contribute nothing and are skipped.
    Bitwise-equal to the padded kernel when dead token columns are zero."""
    E, M, C = a.shape
    _, N, _ = b.shape
    assert M % BM == 0 and N % BN == 0 and C % BK == 0, (M, N, C)
    assert masked_m.shape == (E,) and masked_m.dtype == jnp.int32, masked_m
    nk = C // BK
    grid = (E, M // BM, N // BN, nk)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid,
        in_specs=[
            pl.BlockSpec((1, BM, BK), lambda e, m, n, k, mm: (e, m, k)),
            pl.BlockSpec((1, BM, 1), lambda e, m, n, k, mm: (e, m, k)),
            pl.BlockSpec((1, BN, BK), lambda e, m, n, k, mm: (e, n, k)),
            pl.BlockSpec((1, BN, 1), lambda e, m, n, k, mm: (e, n, k)),
        ],
        out_specs=pl.BlockSpec((1, BM, BN), lambda e, m, n, k, mm: (e, m, n)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)])
    return pl.pallas_call(
        functools.partial(_gg_nt_masked_kernel, nk=nk),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((E, M, N), out_dtype),
        interpret=interpret,
    )(masked_m, a, sa, b, sb)
