"""Pallas TPU kernel: fused SwiGLU + row-wise FP8 quantization (paper §3.3.2).

Input is the grouped-GEMM-1 output h = [gate | up] (M, 2F) in bf16 (the
paper's deliberate BF16 island).  One kernel pass computes
silu(gate) * up and quantizes it straight to e4m3 + po2 scales — the
activation never round-trips through HBM in bf16, which is the fusion the
paper measures in Fig. 5.

Grid: (M/ROWS, F/TILE).  h is viewed as (M, 2, F) — a zero-copy reshape of
the contiguous [gate | up] layout — so a SINGLE HBM operand (one BlockSpec
fetching a (ROWS, 2, TILE) block) carries both the gate and up tiles of each
step; the compiled kernel declares the operand once instead of streaming the
same buffer through two input declarations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fp8 import E4M3, E4M3_MAX, TILE
from repro.kernels.quantize import kernel_po2_scale

ROWS = 128


def _swiglu_quant_kernel(h_ref, data_ref, scale_ref):
    g = h_ref[:, 0, :].astype(jnp.float32)
    u = h_ref[:, 1, :].astype(jnp.float32)
    y = (g * jax.lax.logistic(g)) * u                      # SwiGLU, f32
    amax = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
    s = kernel_po2_scale(amax)
    data_ref[...] = jnp.clip(y / s, -E4M3_MAX, E4M3_MAX).astype(E4M3)
    scale_ref[...] = s


def fused_swiglu_quant_pallas(h: jax.Array, *, interpret: bool = True):
    """h: (M, 2F) bf16 [gate | up] -> (data (M, F) e4m3, scale (M, F/TILE))."""
    M, twoF = h.shape
    F = twoF // 2
    assert M % ROWS == 0 and F % TILE == 0, (M, F)
    nb_f = F // TILE
    out_shapes = (
        jax.ShapeDtypeStruct((M, F), E4M3),
        jax.ShapeDtypeStruct((M, nb_f), jnp.float32),
    )
    return pl.pallas_call(
        _swiglu_quant_kernel,
        grid=(M // ROWS, nb_f),
        in_specs=[
            pl.BlockSpec((ROWS, 2, TILE), lambda i, j: (i, 0, j)),
        ],
        out_specs=(
            pl.BlockSpec((ROWS, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS, 1), lambda i, j: (i, j)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(h.reshape(M, 2, F))
