"""Pallas TPU kernel: scaling-aware FP8 direct transpose (paper Algorithm 1).

Grid: (M/128, K/128) — one 128x128 e4m3 block per step, resident in VMEM.
Per block:
  s_max  = max of the 128 row scales covering this block
  k_i    = log2(s_max / s_i)            (integer: scales are powers of two)
  out    = block^T with each element's exponent reduced by k_i, including
           correct round-to-nearest-even shifts into the subnormal range —
           pure integer ops on the bitcast uint8 encodings, no float math on
           the payload.  This is the TPU analogue of the paper's CUDA
           exponent-manipulation kernel: one VMEM round trip, VPU-only.

Encodings (e4m3fn): value = (-1)^s * 2^(E-7) * (1+M/8) for E>=1,
                    value = (-1)^s * 2^-6 * (M/8)      for E==0 (subnormal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fp8 import BLOCK, E4M3

_SIGN_MASK = 0x80
_EXP_SHIFT = 3
_EXP_MASK = 0xF
_MAN_MASK = 0x7


def _rshift_rne(v: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest-even right shift of a non-negative int32 array."""
    n = jnp.clip(n, 0, 15)
    floor = jnp.right_shift(v, n)
    rem = v - jnp.left_shift(floor, n)
    half = jnp.left_shift(jnp.int32(1), jnp.maximum(n - 1, 0))
    round_up = jnp.where(
        n > 0,
        (rem > half) | ((rem == half) & ((floor & 1) == 1)),
        False,
    )
    return floor + round_up.astype(jnp.int32)


def _rebase_exponent(enc_u8: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Divide encoded e4m3 values by 2^k (k >= 0 int32), re-encoding exactly."""
    enc = enc_u8.astype(jnp.int32)
    sign = enc & _SIGN_MASK
    e = jnp.right_shift(enc, _EXP_SHIFT) & _EXP_MASK
    m = enc & _MAN_MASK

    # normal input, stays normal: E' = E - k  (requires E - k >= 1)
    e_new = e - k
    normal_out = sign | jnp.left_shift(e_new & _EXP_MASK, _EXP_SHIFT) | m

    # normal input, falls into subnormal: shift (8+M) right by (1 - (E-k)), RNE
    shift = 1 - e_new
    m_sub = _rshift_rne(8 + m, shift)
    # a carry to 8 means it rounded up to the minimum normal (E'=1, M'=0)
    sub_from_normal = jnp.where(m_sub >= 8,
                                sign | (1 << _EXP_SHIFT),
                                sign | m_sub)

    # subnormal input: M' = rne(M >> k), stays subnormal
    sub_from_sub = sign | _rshift_rne(m, k)

    out = jnp.where(e == 0, sub_from_sub,
                    jnp.where(e_new >= 1, normal_out, sub_from_normal))
    return out.astype(jnp.uint8)


def _transpose_kernel(x_ref, s_ref, xo_ref, so_ref):
    """x_ref: (BLOCK, BLOCK) e4m3; s_ref: (BLOCK, 1) f32 row scales."""
    s = s_ref[...]                                     # (BLOCK, 1) po2
    s_max = jnp.max(s)
    # k = log2(s_max / s): extract exponents via frexp (s = 0.5 * 2^(e))
    _, e_s = jnp.frexp(s)
    _, e_max = jnp.frexp(s_max)
    k = (e_max - e_s).astype(jnp.int32)                # (BLOCK, 1), >= 0

    enc = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint8)
    rebased = _rebase_exponent(enc, k)                 # rows rebased onto s_max
    out = jax.lax.bitcast_convert_type(rebased, E4M3).T
    xo_ref[...] = out
    so_ref[...] = jnp.full((BLOCK, 1), s_max, jnp.float32)


def fp8_transpose_pallas(data: jax.Array, scale: jax.Array, *,
                         interpret: bool = True):
    """data: (M, K) e4m3 row-wise; scale: (M, K/BLOCK) f32 po2.

    Returns (data_t: (K, M) e4m3, scale_t: (K, M/BLOCK) f32) with the
    transposed tensor quantized at block-aligned scales.
    """
    M, K = data.shape
    assert M % BLOCK == 0 and K % BLOCK == 0, (M, K)
    nb_m, nb_k = M // BLOCK, K // BLOCK

    out_shapes = (
        jax.ShapeDtypeStruct((K, M), data.dtype),
        jax.ShapeDtypeStruct((K, nb_m), jnp.float32),
    )
    return pl.pallas_call(
        _transpose_kernel,
        grid=(nb_m, nb_k),
        in_specs=[
            pl.BlockSpec((BLOCK, BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK, 1), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK, BLOCK), lambda i, j: (j, i)),
            pl.BlockSpec((BLOCK, 1), lambda i, j: (j, i)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(data, scale)
