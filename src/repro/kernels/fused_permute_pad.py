"""Pallas TPU kernel: fused permute + padding for FP8 payload+scales (§3.3.1).

Reorders dispatched tokens so each expert's rows are contiguous AND pads each
expert group to a multiple of 128 rows (the TPU MXU alignment; the paper pads
to 16 for Hopper tensor cores) — in a single pass over HBM.  The row map is
scalar-prefetched into SMEM (`PrefetchScalarGridSpec`), so the BlockSpec index
map can route each output row to its source row with the DMA engine double-
buffering row fetches across grid steps; padding rows (map == -1) are written
as zeros by masking in-kernel.

The same kernel runs the backward unpermute+unpad with the inverse map.
Payload and its (1,TILE) scale column move together — data + scales in one
kernel, two fewer HBM round trips than separate permute/pad/scale-copy ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _permute_kernel(idx_ref, x_ref, s_ref, xo_ref, so_ref):
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    xo_ref[...] = jnp.where(valid, x_ref[...], jnp.zeros_like(x_ref))
    # padding scale is 1.0 so a downstream dequant of a zero payload stays 0
    so_ref[...] = jnp.where(valid, s_ref[...], jnp.ones_like(s_ref))


def fused_permute_pad_pallas(x, s, row_map, n_out, *, interpret: bool = True):
    """x: (T, D) payload; s: (T, Ds) scales; row_map: (n_out,) int32 source row
    for each output row (-1 = padding).  Returns ((n_out, D), (n_out, Ds))."""
    T, D = x.shape
    Ds = s.shape[1]

    def src_map(i, idx_ref):
        return (jnp.maximum(idx_ref[i], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out,),
        in_specs=[
            pl.BlockSpec((1, D), src_map),
            pl.BlockSpec((1, Ds), src_map),
        ],
        out_specs=(
            pl.BlockSpec((1, D), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((1, Ds), lambda i, idx_ref: (i, 0)),
        ),
    )
    return pl.pallas_call(
        _permute_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n_out, D), x.dtype),
            jax.ShapeDtypeStruct((n_out, Ds), s.dtype),
        ),
        interpret=interpret,
    )(row_map, x, s)
