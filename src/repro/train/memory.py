"""Quantization-aware rematerialization: the activation-residency plan.

``MemoryPlan`` is the SINGLE owner of ``jax.checkpoint`` for the whole stack
(models/lm.py's scan + unrolled drivers, the encoder-decoder scan, the
streaming per-layer backward in train/train_step.py, and the roofline
probe).  It replaces the bare ``cfg.remat: bool`` with a POLICY over what
stays resident across the forward/backward boundary, per decoder layer:

  none          no rematerialization: autodiff saves every backward
                residual.  In fp8_flow the grouped-FFN residuals are already
                QTensors (the recipe's own FP8 activation checkpointing),
                but the attention / norm / stage glue pins wide BF16 tensors
                per layer — the maximum-memory, minimum-recompute corner.
  full          BF16-boundary activation checkpointing — the classic
                selective-recompute baseline every bf16 training stack
                ships: the BF16 stage outputs (attn residual-out, the FFN
                input, the FFN's bf16 island ``h``, the expert output) are
                saved; within-stage values recompute.  The per-stage FP8
                QTensors the fp8_flow recipe already produced are DISCARDED
                and re-quantized inside the backward — the double work the
                paper's memory claim is about.
  fp8_resident  the paper policy: the ``checkpoint_name``-tagged QTensor
                stage outputs (``qx``/``qa`` from
                core/linear.py::ffn_fwd_fp8_core) are the ONLY saved
                activations; the backward recomputes the cheap BF16 glue
                (norms, attention, router, dispatch maps) from the
                layer-boundary residual and feeds every FFN backward GEMM
                from the FP8-resident saves.  Residency invariant: nothing
                wider than e4m3 + its po2 scales crosses the layer boundary
                except the residual stream itself
                (tests/test_remat.py asserts it on the saved-residual set).
  pair          checkpoint-of-pairs (the ROADMAP compile-time follow-on):
                plain input-only checkpoints over TWO-layer blocks — halves
                the trace sites at 61-layer DeepSeek depth, saves one bf16
                residual per two layers, recomputes everything (the
                smallest saved set / largest recompute corner).

Saved-bytes-per-MoE-layer model (benchmarks/remat_mem_ab.py measures the
real numbers off ``saved_residuals``; ``layer_saved_bytes_model`` below is
the analytic version; A = T*top_k*capacity_factor expert-slot rows):

  policy        saved activations / layer             bytes (bf16=2B, fp8=1B)
  none          everything autodiff needs             >= full + attn out/lse
  full          attn_out, ffn_in (T,D) bf16;          2(2TD + 2AF*g + AD)
                island h (A, g*F) bf16;
                expert out (A, D) bf16
  fp8_resident  qx (A, D) e4m3 + scales;              (1+4/TILE)(AD + AF)
                qa (A, F) e4m3 + scales
  pair          one bf16 residual per 2 layers        T*D (amortized)

The policies compute the SAME function — rematerialization is semantically
invisible — so loss curves agree to rounding (tests/test_remat.py).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax

from repro.core.quant import BF16_STAGE_NAMES, FP8_SAVE_NAMES

POLICIES = ("none", "full", "fp8_resident", "pair")


def _normalize(policy) -> str:
    """Accept the legacy bool spelling (config-sweep aliases): True -> the
    default 'full' remat, False -> 'none'."""
    if isinstance(policy, bool):
        return "full" if policy else "none"
    if policy not in POLICIES:
        raise ValueError(f"unknown remat policy {policy!r}; "
                         f"pick from {POLICIES}")
    return policy


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """Static activation-residency plan (hashable; safe to close over)."""
    policy: str = "full"

    def __post_init__(self):
        object.__setattr__(self, "policy", _normalize(self.policy))

    @classmethod
    def from_config(cls, cfg) -> "MemoryPlan":
        return cls(policy=getattr(cfg, "remat_policy", "full"))

    # -- structural knobs ---------------------------------------------------
    @property
    def remat(self) -> bool:
        """Whether any jax.checkpoint wrapper is applied at all."""
        return self.policy != "none"

    @property
    def block_size(self) -> int:
        """Layers per checkpoint block in the UNROLLED drivers (the staged
        layer program + the streaming backward): 2 under 'pair'."""
        return 2 if self.policy == "pair" else 1

    def group_factor(self, n_groups: int) -> int:
        """Pattern-group fold factor for the SCAN driver: under 'pair' two
        pattern groups fuse into one (checkpointed) scan body when the depth
        allows, halving the trace sites."""
        return 2 if self.policy == "pair" and n_groups % 2 == 0 else 1

    def layer_blocks(self, n_layers: int) -> Tuple[Tuple[int, ...], ...]:
        """Partition [0, n_layers) into checkpoint blocks in forward order
        (size block_size; a trailing odd layer gets its own block)."""
        bs = self.block_size
        return tuple(tuple(range(i, min(i + bs, n_layers)))
                     for i in range(0, n_layers, bs))

    def blocks_of(self, items: Sequence) -> Tuple[tuple, ...]:
        """layer_blocks applied to an explicit per-layer sequence."""
        return tuple(tuple(items[i] for i in blk)
                     for blk in self.layer_blocks(len(items)))

    # -- THE jax.checkpoint site --------------------------------------------
    def wrap(self, f):
        """Wrap a layer (or layer-block / scan-group) body according to the
        policy.  This is the only place in the repository where
        ``jax.checkpoint`` is invoked (tests/test_remat.py greps for it).

        The body runs under a ``remat/<policy>`` named scope (obs/trace.py)
        so profiler timelines and HLO dumps show each remat region — and its
        backward recompute — by name.  Trace-time metadata only: zero ops."""
        import functools

        from repro.obs.trace import annotate

        @functools.wraps(f)
        def named(*args, **kwargs):
            with annotate(f"remat/{self.policy}"):
                return f(*args, **kwargs)

        if self.policy == "none":
            return f            # no checkpoint -> no remat region to name
        if self.policy == "full":
            return jax.checkpoint(
                named, prevent_cse=False,
                policy=jax.checkpoint_policies.save_only_these_names(
                    *BF16_STAGE_NAMES))
        if self.policy == "fp8_resident":
            return jax.checkpoint(
                named, prevent_cse=False,
                policy=jax.checkpoint_policies.save_only_these_names(
                    *FP8_SAVE_NAMES))
        # 'pair': plain input-only checkpoint; the two-layer blocking is the
        # driver's job (block_size / group_factor above)
        return jax.checkpoint(named, prevent_cse=False)


def saved_residuals(f, *args, **kwargs):
    """Version-robust re-export of jax's saved-residual introspection: the
    list of (aval, source) pairs the backward of ``f`` would keep live —
    what the remat_mem benchmark and the residency tests account."""
    try:
        from jax.ad_checkpoint import saved_residuals as _sr
    except ImportError:                           # jax 0.4.x: private home
        from jax._src.ad_checkpoint import saved_residuals as _sr
    return _sr(f, *args, **kwargs)


def classify_residuals(res, residual_elems: int):
    """Split a saved_residuals list into the accounting buckets the bytes
    model reports: {'argument', 'fp8', 'scale', 'wide_bf16', 'small'} ->
    total bytes.  ``residual_elems`` is the element count of the residual
    stream (B*S*D) — the width bar of the fp8_resident invariant.  FP8
    payloads are saved as their uint8 BIT PATTERN (core.quant.tag_qtensor),
    so 1-byte dtypes count as 'fp8'."""
    import jax.numpy as jnp
    out = {"argument": 0, "fp8": 0, "scale": 0, "wide_bf16": 0, "small": 0}
    for aval, src in res:
        nbytes = aval.size * aval.dtype.itemsize
        if "from the argument" in str(src):
            out["argument"] += nbytes
        elif aval.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2,
                            jnp.uint8, jnp.int8):
            out["fp8"] += nbytes
        elif aval.size <= max(residual_elems // 16, 1):
            # per-tile scales / routing metadata / scalars
            out["scale" if aval.dtype == jnp.float32 else "small"] += nbytes
        elif aval.size > residual_elems and jnp.issubdtype(
                aval.dtype, jnp.floating) and aval.dtype.itemsize >= 2:
            out["wide_bf16"] += nbytes
        else:
            out["small"] += nbytes
    return out


def measure_layer_residuals(cfg, recipe, policy, *, batch: int = 4,
                            seq: int = 128):
    """Measure + classify the saved-residual set of one decoder layer (the
    first MoE layer, or the first layer of a dense arch) under ``policy``.
    THE shared harness behind tests/test_remat.py and
    benchmarks/remat_mem_ab.py — the residency gate and the bytes-model
    benchmark must account the same jaxpr.  Runs plan-less (mesh=None)."""
    import jax.numpy as jnp
    # deferred: models/lm.py imports this module at load time
    from repro.models.lm import (NO_PLAN, init_params, iter_layer_slices,
                                 layer_forward)
    params = init_params(cfg, jax.random.key(0))
    entries = [e for e in iter_layer_slices(cfg, params) if e[3]] or \
        list(iter_layer_slices(cfg, params))
    _, _, kind, moe, p_l = entries[0]
    D = cfg.d_model
    x = jnp.ones((batch, seq, D), jnp.bfloat16) * 0.1
    positions = jnp.arange(seq, dtype=jnp.int32)

    def f(p, xc, _k=kind):
        out, aux = layer_forward(cfg, recipe, NO_PLAN, _k, moe, p, xc,
                                 positions)
        return jnp.sum(out.astype(jnp.float32) ** 2) + aux

    res = saved_residuals(MemoryPlan(policy).wrap(f), p_l, x)
    return classify_residuals(res, batch * seq * D)


def layer_saved_bytes_model(cfg, T: int, policy: str) -> float:
    """Analytic saved-activation bytes per MoE layer under each policy (the
    README table; benchmarks/remat_mem_ab.py checks it against the measured
    saved_residuals).  T = tokens per device; excludes the layer-boundary
    residual stream itself (identical across policies)."""
    from repro.core.fp8 import TILE
    policy = _normalize(policy)
    D, F = cfg.d_model, (cfg.d_ff_expert if cfg.moe else cfg.d_ff)
    g = cfg.gate_factor
    A = int(T * cfg.top_k * cfg.capacity_factor) if cfg.moe else T
    if policy == "pair":
        return T * D * 2 / 2          # one bf16 residual per two layers
    if policy == "fp8_resident":
        per_fp8 = 1 + 4.0 / TILE      # e4m3 payload + f32 scale per TILE
        return (A * D + A * F) * per_fp8
    if policy == "full":
        return 2.0 * (T * D           # attn residual-out
                      + T * D         # ffn input (post-ln2)
                      + A * g * F     # the bf16 island h
                      + A * D)        # expert output (combine input)
    # 'none': full's saves plus the attention residuals autodiff keeps
    H, hd = cfg.n_heads, cfg.head_dim
    return layer_saved_bytes_model(cfg, T, "full") + 2.0 * T * H * hd
