"""Numerics guardrails: in-step FP8 anomaly detection + the recovery ladder.

Two halves, split along the jit boundary:

  in-jit   `evaluate` runs INSIDE train_step and folds the step's health
           into a uint32 anomaly bitmask + a tiny carried state (grad-norm
           EMA).  Everything it reads is already replica-uniform (pmean'd
           loss, psum'd grad norm, pmax'd saturation/flush/wire flags), so
           the flags replicate for free under shard_map and ride out with
           the metrics the loop ALREADY fetches every step — detection
           costs zero extra device syncs.
  on-host  `GuardPolicy.observe` turns the fetched bitmask into the
           recovery ladder: skip-step (discard the update — the previous
           state is still a live Python reference, nothing replays), then
           rollback to the last complete checkpoint after `rollback_after`
           consecutive strikes, then graceful degradation (demote fp8_flow
           to the bf16 recipe for `demote_steps` steps, then re-promote —
           the bf16 step has no quantize sites, so a persistent FP8-path
           fault is cured, not just retried), and finally a hard stop
           after `give_up_after` total strikes.  Every transition is
           logged as a structured event.

The backward-island quantize sites (q_bwd_*, dact_quant, dgrad_*) are NOT
stat-instrumented — their custom_vjp backward rules trace inside inner
backward traces where a collected scalar could not escape without leaking.
Backward saturation instead surfaces through the grad-norm spike and
nonfinite-grad bits, which see the same blow-up one reduction later.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax.numpy as jnp

# anomaly bitmask (uint32) --------------------------------------------------
NONFINITE_LOSS = 1     # loss is NaN/inf
NONFINITE_GRAD = 2     # global grad norm is NaN/inf
GNORM_SPIKE = 4        # grad norm > spike_factor x carried EMA (post-warmup)
FP8_SAT = 8            # forward quantize-site saturation fraction too high
FP8_FLUSH = 16         # forward quantize-site underflow-flush fraction high
WIRE_SCALE = 32        # wire guard fired: a bucket rode the bf16 fallback

HARD_FLAGS = NONFINITE_LOSS | NONFINITE_GRAD | GNORM_SPIKE

_FLAG_NAMES = ((NONFINITE_LOSS, "nonfinite_loss"),
               (NONFINITE_GRAD, "nonfinite_grad"),
               (GNORM_SPIKE, "gnorm_spike"),
               (FP8_SAT, "fp8_sat"),
               (FP8_FLUSH, "fp8_flush"),
               (WIRE_SCALE, "wire_scale"))


def flag_names(flags: int) -> str:
    names = [n for bit, n in _FLAG_NAMES if int(flags) & bit]
    return "|".join(names) or "none"


@dataclasses.dataclass(frozen=True)
class GuardPlan:
    """Static detection thresholds, closed over at trace time."""
    ema_beta: float = 0.95       # grad-norm EMA decay
    spike_factor: float = 4.0    # anomaly when gnorm > factor * EMA
    spike_warmup: int = 10       # healthy steps before the spike guard arms
    sat_frac_limit: float = 0.05   # fwd quantize |x/s| > fmax fraction
    flush_frac_limit: float = 0.5  # fwd quantize nonzero->zero fraction
    wire_exp_limit: int = 40     # |po2 exponent| beyond this is absurd
                                 # (e4m3 grads live within ~2^+-20 of scale 1)


def init_guard_state():
    """The tiny carried state: grad-norm EMA + healthy-step counter.
    Lives in `state['guard']`, replicated (P()) under shard_map."""
    return {"gnorm_ema": jnp.float32(0.0), "steps": jnp.int32(0)}


def evaluate(plan: GuardPlan, gstate, *, loss, gnorm, sat_frac=None,
             flush_frac=None, wire_bad=None):
    """In-jit anomaly fold.  All inputs must already be replica-uniform.
    Returns (flags uint32, new_gstate, guard_metrics)."""
    u32 = jnp.uint32

    def bit(cond, b):
        return jnp.where(cond, u32(b), u32(0))

    loss = jnp.asarray(loss, jnp.float32)
    gnorm = jnp.asarray(gnorm, jnp.float32)
    flags = bit(~jnp.isfinite(loss), NONFINITE_LOSS)
    flags = flags | bit(~jnp.isfinite(gnorm), NONFINITE_GRAD)
    warm = gstate["steps"] >= plan.spike_warmup
    ema = gstate["gnorm_ema"]
    spike = warm & jnp.isfinite(gnorm) & (ema > 0) & \
        (gnorm > plan.spike_factor * ema)
    flags = flags | bit(spike, GNORM_SPIKE)
    if sat_frac is not None:
        flags = flags | bit(jnp.asarray(sat_frac, jnp.float32)
                            > plan.sat_frac_limit, FP8_SAT)
    if flush_frac is not None:
        flags = flags | bit(jnp.asarray(flush_frac, jnp.float32)
                            > plan.flush_frac_limit, FP8_FLUSH)
    if wire_bad is not None:
        flags = flags | bit(wire_bad, WIRE_SCALE)

    # the EMA only learns from healthy steps, so one spike cannot drag the
    # baseline up and mask the next one
    ok = ((flags & u32(HARD_FLAGS)) == 0) & jnp.isfinite(gnorm)
    seeded = jnp.where(gstate["steps"] == 0, gnorm,
                       plan.ema_beta * ema + (1.0 - plan.ema_beta) * gnorm)
    new_state = {"gnorm_ema": jnp.where(ok, seeded, ema),
                 "steps": gstate["steps"] + jnp.where(ok, 1, 0).astype(
                     jnp.int32)}
    gmetrics = {"guard_flags": flags, "guard_gnorm_ema": new_state["gnorm_ema"]}
    return flags, new_state, gmetrics


# ---------------------------------------------------------------------------
# Host-side recovery ladder.
# ---------------------------------------------------------------------------
class GuardGiveUp(RuntimeError):
    """Raised when the anomaly budget is exhausted — the run is not
    recoverable by skipping/rolling back/demoting."""


@dataclasses.dataclass
class Verdict:
    skip: bool = False       # discard this step's update
    rollback: bool = False   # restore the last complete checkpoint
    demote: bool = False     # enter (or stay in) the bf16 fallback window


@dataclasses.dataclass
class GuardPolicy:
    """Recovery ladder driven by the per-step anomaly bitmask.

    Soft bits (FP8_SAT / FP8_FLUSH / WIRE_SCALE) are informational by
    default: the wire guard already recovered in-step (bf16 fallback), and
    saturation alone does not corrupt the update.  `skip_flags` widens the
    skip set if a deployment wants to act on them."""
    skip_flags: int = HARD_FLAGS
    rollback_after: int = 3      # consecutive strikes -> restore checkpoint
    demote_after: int = 5        # consecutive strikes -> bf16 fallback
    demote_steps: int = 8        # fallback window length (steps)
    give_up_after: int = 20      # total strikes -> GuardGiveUp

    consecutive: int = 0
    total: int = 0
    demoted_until: int = -1
    events: List[dict] = dataclasses.field(default_factory=list)
    # optional obs/sink.Telemetry: the loop wires its handle in so every
    # ladder transition also lands in the structured sinks + a counter
    telemetry: Optional[object] = None

    def _event(self, log_fn: Callable, step: int, event: str, flags: int,
               **extra):
        rec = {"step": step, "event": event, "flags": int(flags),
               "flag_names": flag_names(flags), **extra}
        self.events.append(rec)
        detail = " ".join(f"{k}={v}" for k, v in extra.items())
        msg = (f"[guard] step={step} event={event} "
               f"flags={rec['flag_names']}{(' ' + detail) if detail else ''}")
        if self.telemetry is not None:
            self.telemetry.record("guard", msg=msg, **rec)
            self.telemetry.counter("guard_events_total",
                                   labels={"event": event}).inc()
        log_fn(msg)

    def demoted(self, step: int) -> bool:
        return step < self.demoted_until

    def observe(self, step: int, flags: int, log_fn: Callable = print,
                can_rollback: bool = True) -> Verdict:
        flags = int(flags)
        v = Verdict()
        if flags and not (flags & self.skip_flags):
            # soft-only anomaly: log it, keep the update
            self._event(log_fn, step, "soft_anomaly", flags)
            return v
        if not flags:
            if self.consecutive:
                self._event(log_fn, step, "recovered", 0,
                            after_strikes=self.consecutive)
            self.consecutive = 0
            if self.demoted_until == step:  # fallback window just ended
                self._event(log_fn, step, "repromote", 0)
            return v

        self.consecutive += 1
        self.total += 1
        v.skip = True
        if self.total >= self.give_up_after:
            self._event(log_fn, step, "give_up", flags, total=self.total)
            raise GuardGiveUp(
                f"step {step}: {self.total} anomalous steps "
                f"(flags={flag_names(flags)}) — giving up")
        if self.consecutive >= self.demote_after:
            v.demote = True
            self.demoted_until = step + 1 + self.demote_steps
            self._event(log_fn, step, "demote", flags,
                        until=self.demoted_until)
        elif self.consecutive >= self.rollback_after and can_rollback:
            v.rollback = True
            self._event(log_fn, step, "rollback", flags,
                        consecutive=self.consecutive)
        else:
            self._event(log_fn, step, "skip", flags,
                        consecutive=self.consecutive)
        return v
