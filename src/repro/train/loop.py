"""The training loop: checkpoint/restart, health monitoring, elastic
re-meshing, async checkpointing — the control plane around train_step."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpointing
from repro.data.pipeline import DataConfig, make_batch
from repro.runtime.fault_tolerance import ElasticTrainer


def run(train_step: Callable, state, data_cfg: DataConfig, *,
        n_steps: int, ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, elastic: Optional[ElasticTrainer] = None,
        grad_accum: int = 1, fail_injector: Optional[Callable] = None,
        restore_shardings=None, log_fn=print):
    """Runs `n_steps`, restarting from the latest checkpoint if present.
    `fail_injector(step)` lets tests simulate host failures/stragglers.
    `restore_shardings` (optional pytree of NamedSharding matching `state`,
    e.g. launch/sharding.dist_state_specs for ZeRO-1 flat state) re-shards
    on restore — restart onto a different DP mesh size just works because
    the checkpoint holds the full logical arrays."""
    start = 0
    if ckpt_dir is not None:
        latest = checkpointing.latest_step(ckpt_dir)
        if latest is not None:
            state, start = checkpointing.restore(
                ckpt_dir, state, shardings=restore_shardings)
            start += 1
            log_fn(f"[loop] restored checkpoint step={start - 1}")

    history = []
    pending_save = None
    for step in range(start, n_steps):
        t0 = time.monotonic()
        batch = make_batch(data_cfg, step)
        if grad_accum > 1:
            batch = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        history.append({"step": step, "loss": loss, "dt": dt})

        if elastic is not None:
            if fail_injector is not None:
                fail_injector(step, elastic)
            elastic.step_report(0, dt)
            remesh, reassign = elastic.plan_step()
            if remesh:
                log_fn(f"[loop] host failure at step {step}: shrinking to "
                       f"{elastic.n_data_shards} data shards; restoring "
                       f"checkpoint and continuing")
                if ckpt_dir is not None and \
                        checkpointing.latest_step(ckpt_dir) is not None:
                    state, _ = checkpointing.restore(
                        ckpt_dir, state, shardings=restore_shardings)
            elif reassign:
                log_fn(f"[loop] stragglers reassigned: {reassign}")

        if step % log_every == 0:
            log_fn(f"[loop] step={step} loss={loss:.4f} "
                   f"gnorm={float(metrics.get('grad_norm', 0)):.3f} "
                   f"dt={dt*1e3:.0f}ms")
        if ckpt_dir is not None and step % ckpt_every == 0 and step > 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = checkpointing.save(ckpt_dir, step, state,
                                              async_=True)
    if pending_save is not None:
        pending_save.join()
    return state, history
