"""The training loop: checkpoint/restart, health monitoring, elastic
re-meshing, async checkpointing, and the numerics-guardrail recovery
ladder — the control plane around train_step.

Observability (obs/): the loop emits TYPED events through a Telemetry
handle — every former ``log_fn(f"[loop] ...")`` call site now writes a
structured record to the sinks AND renders the same human line, so logs
are unchanged while the JSONL artifact gains machine-readable history.
The per-step timing is split honestly: ``device_ms`` (dispatch + device
execution, measured to ``block_until_ready`` on the loss) vs ``fetch_ms``
(the blocking host transfer of the metrics dict) — the formerly-conflated
``dt`` (still reported) is their sum plus host-side loop work.  All device
telemetry rides the ONE existing per-step metrics fetch; the loop adds no
extra host syncs (tests/test_obs.py gates this on the jaxpr).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.checkpoint import checkpointing
from repro.core import casts
from repro.core import quant as quant_stats
from repro.data.pipeline import DataConfig, make_batch
from repro.obs.sink import null_telemetry
from repro.runtime import fault_injection
from repro.runtime.fault_tolerance import ElasticTrainer


def _restore_latest_valid(ckpt_dir, state, shardings, log_fn, tel=None):
    """Newest complete checkpoint that passes the integrity checks; corrupt
    steps (CheckpointCorruptError) are logged and skipped so one poisoned
    shard cannot wedge the rollback path.  Returns (state, step) or None."""
    for s in reversed(checkpointing.completed_steps(ckpt_dir)):
        try:
            st, _ = checkpointing.restore(ckpt_dir, state, step=s,
                                          shardings=shardings)
            return st, s
        except checkpointing.CheckpointCorruptError as e:
            msg = (f"[loop] checkpoint step_{s} failed integrity check "
                   f"({e}); falling back to an older step")
            if tel is not None:
                tel.record("ckpt_corrupt", ckpt_step=s, error=str(e),
                           msg=msg)
                tel.counter("ckpt_corrupt_total").inc()
            log_fn(msg)
    return None


def _ledger_snapshot(tel, fn, state, batch, step, demoted):
    """Cast-ledger snapshot of one step callable, taken abstractly.

    ``casts.record`` fires at Python trace time, so ``jax.eval_shape``
    under an active ledger tallies the full fwd+bwd cast census of this
    step function WITHOUT compiling or running anything.  Called once per
    distinct step callable ("per recompile": the fp8 step on first use,
    the bf16 fallback step on first demotion)."""
    try:
        with casts.ledger() as led:
            jax.eval_shape(fn, state, batch)
        tel.record(
            "cast_ledger", step=step, demoted=bool(demoted),
            fn=getattr(fn, "__name__", type(fn).__name__),
            activation_casts=led.activation_casts(),
            fused_casts=led.fused_casts(), total=led.total(),
            by_tag={f"{k}:{t}": n
                    for (k, t), n in sorted(led.by_tag().items())})
    except Exception as e:      # snapshot is best-effort; never break a step
        tel.record("cast_ledger_error", step=step, error=str(e))


def run(train_step: Callable, state, data_cfg: DataConfig, *,
        n_steps: int, ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, elastic: Optional[ElasticTrainer] = None,
        grad_accum: int = 1, fail_injector: Optional[Callable] = None,
        restore_shardings=None, log_fn=print, guard_policy=None,
        fallback_step: Optional[Callable] = None,
        fault_plan: Optional[fault_injection.FaultPlan] = None,
        telemetry=None):
    """Runs `n_steps`, restarting from the latest checkpoint if present.
    `fail_injector(step)` lets tests simulate host failures/stragglers.
    `restore_shardings` (optional pytree of NamedSharding matching `state`,
    e.g. launch/sharding.dist_state_specs for ZeRO-1 flat state) re-shards
    on restore — restart onto a different DP mesh size just works because
    the checkpoint holds the full logical arrays.

    guard_policy (train/guards.GuardPolicy) drives the recovery ladder off
    the 'guard_flags' metric a guarded train_step emits: skip-step (the
    previous state is still a live reference — discard the update, replay
    nothing), rollback to the last VALID checkpoint after K consecutive
    strikes (rewinding `step` so the data pipeline replays those batches),
    and demotion to `fallback_step` (a bf16-recipe step built with the
    same GuardPlan) for a bounded window before re-promoting.

    fault_plan (runtime/fault_injection.FaultPlan) schedules deterministic
    faults: numeric ones are baked into per-spec jit traces when
    `train_step` is a FaultStepper (`fault_plan.wrap(raw_step)`), host
    failures flip the HealthMonitor, and disk faults corrupt checkpoint
    shards on the way in.

    telemetry (obs/sink.Telemetry) collects typed events, per-step metric
    samples (riding the existing metrics fetch — zero extra host syncs),
    host-side span timings, and cast-ledger snapshots.  None -> a null
    handle: identical behavior, nothing kept."""
    tel = telemetry if telemetry is not None else null_telemetry()

    def _event(kind, msg, **fields):
        # typed record + the VERBATIM human line (tests grep these)
        tel.record(kind, msg=msg, **fields)
        log_fn(msg)

    start = 0
    if ckpt_dir is not None and checkpointing.latest_step(ckpt_dir) is not None:
        res = _restore_latest_valid(ckpt_dir, state, restore_shardings,
                                    log_fn, tel)
        if res is not None:
            state, rstep = res
            start = rstep + 1
            _event("ckpt_restore", f"[loop] restored checkpoint step={rstep}",
                   ckpt_step=rstep)

    if guard_policy is not None and getattr(guard_policy, "telemetry",
                                            None) is None:
        guard_policy.telemetry = tel

    history = []
    pending_save = None
    ledgered = set()        # id() of step callables already snapshot

    def _join_pending():
        nonlocal pending_save
        if pending_save is not None:
            pending_save.join()     # re-raises a failed background write
            pending_save = None

    step = start
    while step < n_steps:
        t0 = time.monotonic()
        if fault_plan is not None and ckpt_dir is not None:
            disk = fault_plan.disk_for(step)
            if disk is not None:
                _join_pending()
                poisoned = fault_injection.apply_disk_fault(disk, ckpt_dir)
                _event("disk_fault",
                       f"[loop] injected {disk.kind} at step {step} "
                       f"(checkpoint step_{poisoned})",
                       step=step, fault=disk.kind, ckpt_step=poisoned)
        batch = make_batch(data_cfg, step)
        if grad_accum > 1:
            batch = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)
        demoted = guard_policy is not None and fallback_step is not None \
            and guard_policy.demoted(step)
        fn = fallback_step if demoted else train_step
        if hasattr(fn, "for_step"):     # FaultStepper: per-spec jit cache
            fn = fn.for_step(step)
        if tel.enabled and id(fn) not in ledgered:
            ledgered.add(id(fn))
            _ledger_snapshot(tel, fn, state, batch, step, demoted)
        prev_state = state
        # the honest split of the old conflated `dt`: device span covers
        # dispatch + device execution (to data-ready), fetch span the
        # blocking device->host copy of the metrics dict — still the loop's
        # ONE per-step fetch (guard flags + quant stats ride along).
        with tel.span("device_step") as sp_dev:
            state, metrics = fn(state, batch)
            jax.block_until_ready(metrics)
        with tel.span("host_fetch") as sp_fetch:
            host_metrics = jax.device_get(metrics)
        loss = float(host_metrics["loss"])
        dt = time.monotonic() - t0
        history.append({"step": step, "loss": loss, "dt": dt,
                        "device_ms": sp_dev.ms, "fetch_ms": sp_fetch.ms})

        if tel.enabled:
            values = {"loss": loss}
            for k in ("grad_norm", "quant_sat_frac", "quant_flush_frac",
                      "guard_flags"):
                if k in host_metrics:
                    values[k] = float(host_metrics[k])
            extra = {}
            sv = host_metrics.get("quant_site_stats")
            if sv is not None:
                sites = {}
                for i, name in enumerate(quant_stats.STAT_SITES):
                    sat, flush = float(sv[i][0]), float(sv[i][1])
                    sites[name] = {"sat": sat, "flush": flush}
                    tel.gauge("quant_sat_frac",
                              labels={"site": name}).set(sat)
                    tel.gauge("quant_flush_frac",
                              labels={"site": name}).set(flush)
                extra["quant_sites"] = sites
            if demoted:
                extra["demoted"] = True
            tel.step(step, values,
                     spans={"device": sp_dev.ms, "fetch": sp_fetch.ms,
                            "total": dt * 1e3},
                     extra=extra)

        if guard_policy is not None:
            flags = int(host_metrics.get("guard_flags", 0))
            have_ckpt = ckpt_dir is not None and \
                bool(checkpointing.completed_steps(ckpt_dir))
            verdict = guard_policy.observe(step, flags, log_fn,
                                           can_rollback=have_ckpt)
            if verdict.skip:
                state = prev_state      # discard the poisoned update
                if verdict.rollback and have_ckpt:
                    _join_pending()
                    res = _restore_latest_valid(ckpt_dir, state,
                                                restore_shardings, log_fn,
                                                tel)
                    if res is not None:
                        state, rstep = res
                        _event("rollback",
                               f"[loop] rolled back to step {rstep}; "
                               f"replaying from step {rstep + 1}",
                               step=step, ckpt_step=rstep)
                        step = rstep + 1
                        continue
                step += 1
                continue

        if elastic is not None:
            if fault_plan is not None:
                hf = fault_plan.host_for(step)
                if hf is not None:
                    fault_injection.apply_host_fault(hf, elastic)
                    _event("host_fault",
                           f"[loop] injected host_failure "
                           f"host={hf.site or 0} at step {step}",
                           step=step, host=hf.site or 0)
            if fail_injector is not None:
                fail_injector(step, elastic)
            elastic.step_report(0, dt)
            remesh, reassign = elastic.plan_step()
            if remesh:
                _event("remesh",
                       f"[loop] host failure at step {step}: shrinking to "
                       f"{elastic.n_data_shards} data shards; restoring "
                       f"checkpoint and continuing",
                       step=step, n_data_shards=elastic.n_data_shards)
                if ckpt_dir is not None:
                    # join FIRST: an async save still in flight (e.g. from
                    # two steps ago) must land before we look for the
                    # newest checkpoint, or the rewind silently no-ops
                    _join_pending()
                if ckpt_dir is not None and \
                        checkpointing.latest_step(ckpt_dir) is not None:
                    res = _restore_latest_valid(ckpt_dir, state,
                                                restore_shardings, log_fn,
                                                tel)
                    if res is not None:
                        state, rstep = res
                        # rewind so the optimizer steps between the
                        # checkpoint and the failure are REPLAYED (the data
                        # pipeline is a pure function of step, so the
                        # survivors re-derive exactly those batches)
                        _event("rewind",
                               f"[loop] rewound to step {rstep + 1} after "
                               f"remesh (was {step + 1})",
                               step=step, resume_step=rstep + 1)
                        step = rstep + 1
                        continue
            elif reassign:
                _event("reassign",
                       f"[loop] stragglers reassigned: {reassign}",
                       step=step, assignments=list(reassign))

        if step % log_every == 0:
            _event("progress",
                   f"[loop] step={step} loss={loss:.4f} "
                   f"gnorm={float(host_metrics.get('grad_norm', 0)):.3f} "
                   f"dt={dt*1e3:.0f}ms",
                   step=step, loss=loss, dt_ms=dt * 1e3)
        if ckpt_dir is not None and step % ckpt_every == 0 and step > 0:
            _join_pending()
            pending_save = checkpointing.save(ckpt_dir, step, state,
                                              async_=True)
            tel.record("ckpt_save", step=step)
            tel.counter("ckpt_saves_total").inc()
        step += 1
    _join_pending()
    tel.flush()
    return state, history
