"""The training loop: checkpoint/restart, health monitoring, elastic
re-meshing, async checkpointing, and the numerics-guardrail recovery
ladder — the control plane around train_step."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.checkpoint import checkpointing
from repro.data.pipeline import DataConfig, make_batch
from repro.runtime import fault_injection
from repro.runtime.fault_tolerance import ElasticTrainer


def _restore_latest_valid(ckpt_dir, state, shardings, log_fn):
    """Newest complete checkpoint that passes the integrity checks; corrupt
    steps (CheckpointCorruptError) are logged and skipped so one poisoned
    shard cannot wedge the rollback path.  Returns (state, step) or None."""
    for s in reversed(checkpointing.completed_steps(ckpt_dir)):
        try:
            st, _ = checkpointing.restore(ckpt_dir, state, step=s,
                                          shardings=shardings)
            return st, s
        except checkpointing.CheckpointCorruptError as e:
            log_fn(f"[loop] checkpoint step_{s} failed integrity check "
                   f"({e}); falling back to an older step")
    return None


def run(train_step: Callable, state, data_cfg: DataConfig, *,
        n_steps: int, ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, elastic: Optional[ElasticTrainer] = None,
        grad_accum: int = 1, fail_injector: Optional[Callable] = None,
        restore_shardings=None, log_fn=print, guard_policy=None,
        fallback_step: Optional[Callable] = None,
        fault_plan: Optional[fault_injection.FaultPlan] = None):
    """Runs `n_steps`, restarting from the latest checkpoint if present.
    `fail_injector(step)` lets tests simulate host failures/stragglers.
    `restore_shardings` (optional pytree of NamedSharding matching `state`,
    e.g. launch/sharding.dist_state_specs for ZeRO-1 flat state) re-shards
    on restore — restart onto a different DP mesh size just works because
    the checkpoint holds the full logical arrays.

    guard_policy (train/guards.GuardPolicy) drives the recovery ladder off
    the 'guard_flags' metric a guarded train_step emits: skip-step (the
    previous state is still a live reference — discard the update, replay
    nothing), rollback to the last VALID checkpoint after K consecutive
    strikes (rewinding `step` so the data pipeline replays those batches),
    and demotion to `fallback_step` (a bf16-recipe step built with the
    same GuardPlan) for a bounded window before re-promoting.

    fault_plan (runtime/fault_injection.FaultPlan) schedules deterministic
    faults: numeric ones are baked into per-spec jit traces when
    `train_step` is a FaultStepper (`fault_plan.wrap(raw_step)`), host
    failures flip the HealthMonitor, and disk faults corrupt checkpoint
    shards on the way in."""
    start = 0
    if ckpt_dir is not None and checkpointing.latest_step(ckpt_dir) is not None:
        res = _restore_latest_valid(ckpt_dir, state, restore_shardings,
                                    log_fn)
        if res is not None:
            state, rstep = res
            start = rstep + 1
            log_fn(f"[loop] restored checkpoint step={rstep}")

    history = []
    pending_save = None

    def _join_pending():
        nonlocal pending_save
        if pending_save is not None:
            pending_save.join()     # re-raises a failed background write
            pending_save = None

    step = start
    while step < n_steps:
        t0 = time.monotonic()
        if fault_plan is not None and ckpt_dir is not None:
            disk = fault_plan.disk_for(step)
            if disk is not None:
                _join_pending()
                poisoned = fault_injection.apply_disk_fault(disk, ckpt_dir)
                log_fn(f"[loop] injected {disk.kind} at step {step} "
                       f"(checkpoint step_{poisoned})")
        batch = make_batch(data_cfg, step)
        if grad_accum > 1:
            batch = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)
        demoted = guard_policy is not None and fallback_step is not None \
            and guard_policy.demoted(step)
        fn = fallback_step if demoted else train_step
        if hasattr(fn, "for_step"):     # FaultStepper: per-spec jit cache
            fn = fn.for_step(step)
        prev_state = state
        state, metrics = fn(state, batch)
        loss = float(metrics["loss"])   # the loop's one per-step fetch —
        dt = time.monotonic() - t0      # guard flags ride the same metrics
        history.append({"step": step, "loss": loss, "dt": dt})

        if guard_policy is not None:
            flags = int(metrics.get("guard_flags", 0))
            have_ckpt = ckpt_dir is not None and \
                bool(checkpointing.completed_steps(ckpt_dir))
            verdict = guard_policy.observe(step, flags, log_fn,
                                           can_rollback=have_ckpt)
            if verdict.skip:
                state = prev_state      # discard the poisoned update
                if verdict.rollback and have_ckpt:
                    _join_pending()
                    res = _restore_latest_valid(ckpt_dir, state,
                                                restore_shardings, log_fn)
                    if res is not None:
                        state, rstep = res
                        log_fn(f"[loop] rolled back to step {rstep}; "
                               f"replaying from step {rstep + 1}")
                        step = rstep + 1
                        continue
                step += 1
                continue

        if elastic is not None:
            if fault_plan is not None:
                hf = fault_plan.host_for(step)
                if hf is not None:
                    fault_injection.apply_host_fault(hf, elastic)
                    log_fn(f"[loop] injected host_failure "
                           f"host={hf.site or 0} at step {step}")
            if fail_injector is not None:
                fail_injector(step, elastic)
            elastic.step_report(0, dt)
            remesh, reassign = elastic.plan_step()
            if remesh:
                log_fn(f"[loop] host failure at step {step}: shrinking to "
                       f"{elastic.n_data_shards} data shards; restoring "
                       f"checkpoint and continuing")
                if ckpt_dir is not None and \
                        checkpointing.latest_step(ckpt_dir) is not None:
                    _join_pending()
                    res = _restore_latest_valid(ckpt_dir, state,
                                                restore_shardings, log_fn)
                    if res is not None:
                        state, rstep = res
                        # rewind so the optimizer steps between the
                        # checkpoint and the failure are REPLAYED (the data
                        # pipeline is a pure function of step, so the
                        # survivors re-derive exactly those batches)
                        log_fn(f"[loop] rewound to step {rstep + 1} after "
                               f"remesh (was {step + 1})")
                        step = rstep + 1
                        continue
            elif reassign:
                log_fn(f"[loop] stragglers reassigned: {reassign}")

        if step % log_every == 0:
            log_fn(f"[loop] step={step} loss={loss:.4f} "
                   f"gnorm={float(metrics.get('grad_norm', 0)):.3f} "
                   f"dt={dt*1e3:.0f}ms")
        if ckpt_dir is not None and step % ckpt_every == 0 and step > 0:
            _join_pending()
            pending_save = checkpointing.save(ckpt_dir, step, state,
                                              async_=True)
        step += 1
    _join_pending()
    return state, history
