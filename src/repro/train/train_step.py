"""The jitted training step: loss -> grad -> (optional grad-accum) ->
gradient reduction -> AdamW update.

`make_train_step` closes over static config (arch, recipe, plan, optimizer)
and returns a function (state, batch) -> (state, metrics) suitable for
jax.jit with explicit in/out shardings (launch/sharding.py).

Two reduction regimes:
  dist=None      the legacy implicit path — the batch is sharded over the DP
                 axes and pjit inserts f32 psums for the gradients.
  dist=DistPlan  the explicit FP8-native wire (repro.dist): the whole step
                 runs inside ONE shard_map over the DP axis; gradients
                 reduce-scatter as e4m3 payload + po2 int8 exponents packed
                 into one uint8 message per bucket (pre-agreed scales, no
                 double quantization error), the ZeRO-1 owned shard updates
                 FP8-split optimizer state, and the updated bf16 param
                 shards all-gather back.  Sensitive leaves (norms, router,
                 embeddings) ride a bf16 psum.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.recipes import Recipe
from repro.models.lm import ParallelPlan, forward
from repro.optim import adamw, schedules


def make_train_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                    opt: adamw.AdamWConfig, *, grad_accum: int = 1,
                    dist: Optional[Any] = None,
                    total_steps: int = 100_000, warmup_steps: int = 100):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt': adamw state (or dist state when dist is set)}
    batch = {'tokens' (B, S), 'targets', 'mask', ...} with B the
    PER-MICROBATCH size when grad_accum > 1 — the step loops microbatches
    via lax.scan over the leading accum axis of the batch.

    dist: an active repro.dist.DistPlan routes the step through the
    quantized ZeRO-1 wire (see _make_dist_train_step)."""
    if dist is not None and dist.active:
        return _make_dist_train_step(cfg, recipe, plan, opt, dist,
                                     grad_accum=grad_accum,
                                     total_steps=total_steps,
                                     warmup_steps=warmup_steps)

    def loss_fn(params, mb):
        loss, metrics = forward(cfg, recipe, plan, params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        loss, metrics, grads = _local_grads(loss_fn, params, batch,
                                            grad_accum)
        lr_scale = schedules.warmup_cosine(
            state["opt"]["step"], total_steps=total_steps,
            warmup_steps=warmup_steps)
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            opt, params, grads, state["opt"], lr_scale=lr_scale)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def _local_grads(loss_fn, params, batch, grad_accum):
    """value+grad, optionally scanning a leading grad-accum batch axis."""
    if grad_accum > 1:
        def acc_body(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, jnp.float32(0.0)),
                                       batch)
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        return lsum / grad_accum, {}, grads
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch)
    return loss, metrics, grads


# ---------------------------------------------------------------------------
# The explicit FP8 wire + ZeRO-1 step (repro.dist).
# ---------------------------------------------------------------------------
def _make_dist_train_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                          opt: adamw.AdamWConfig, dist, *, grad_accum: int,
                          total_steps: int, warmup_steps: int):
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.dist import grad_comm
    from repro.dist import opt_state as ost
    from repro.dist.plan import bucket_flat, bucket_scatter, build_layout

    mesh = plan.mesh
    if mesh is None or dist.axis not in mesh.axis_names:
        raise ValueError(f"DistPlan needs a plan.mesh with axis "
                         f"'{dist.axis}'; got {mesh}")
    n_dp = mesh.shape[dist.axis]
    nontrivial = [a for a in mesh.axis_names
                  if a != dist.axis and mesh.shape[a] != 1]
    if nontrivial:
        raise ValueError(
            f"the DistPlan wire runs the forward replica-locally inside a "
            f"shard_map over '{dist.axis}'; model-parallel axes {nontrivial} "
            f"cannot nest another shard_map on jax {jax.__version__} — use "
            f"dist=None (implicit pjit psum) on model-parallel meshes")
    if dist.shard_multiple % n_dp != 0:
        raise ValueError(
            f"DP size {n_dp} does not divide DistPlan.shard_multiple="
            f"{dist.shard_multiple}: bucket rows pad to shard_multiple, so "
            f"ZeRO-1 shards would be unequal — set shard_multiple to a "
            f"multiple of the DP size (or size the data axis to a divisor)")
    # the forward must not open a nested shard_map: run it replica-local
    local_plan = dataclasses.replace(plan, mesh=None, dp_axes=(),
                                     fsdp_axis=None, shard_map_mlp=False,
                                     moe_overlap=None)
    pol = dist.policy
    axis = dist.axis

    def loss_fn(params, mb):
        loss, metrics = forward(cfg, recipe, local_plan, params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        layout = build_layout(params, dist)     # static (shapes only)
        treedef = jax.tree.structure(params)

        def body(params, opt_st, batch):
            loss, fwd_metrics, grads = _local_grads(loss_fn, params, batch,
                                                    grad_accum)
            pleaves = treedef.flatten_up_to(params)
            gleaves = treedef.flatten_up_to(grads)

            # quantized reduce-scatter: one fused uint8 message per bucket,
            # scales pre-agreed (scale_sync) so the sum never re-quantizes
            owned = [grad_comm.reduce_scatter_bucket(
                bucket_flat(b, gleaves), axis, n_dp, dist.wire)
                for b in layout.buckets]
            sens_g = {p: grad_comm.reduce_sensitive(gleaves[i], axis, n_dp,
                                                    dist.wire)
                      for i, p in layout.sensitive}

            # global grad norm in one fused f32 scalar pass: each replica
            # owns disjoint shards, so psum(sum owned^2) is the exact total
            parts = [jnp.sum(jnp.square(o)) for o in owned]
            sq_owned = jnp.sum(jnp.stack(parts)) if parts \
                else jnp.float32(0.0)
            sq_owned = jax.lax.psum(sq_owned, axis)
            sq_sens = [jnp.sum(jnp.square(g)) for g in sens_g.values()]
            gnorm = jnp.sqrt(sq_owned + (jnp.sum(jnp.stack(sq_sens))
                                         if sq_sens else jnp.float32(0.0)))
            clip = adamw.clip_factor(opt, gnorm)
            step = opt_st["step"] + 1
            b1c, b2c = adamw.bias_corrections(opt, step)
            lr = opt.lr * schedules.warmup_cosine(
                opt_st["step"], total_steps=total_steps,
                warmup_steps=warmup_steps)

            # ZeRO-1: update the owned shard, all-gather bf16 param shards
            new_leaves, new_flat = {}, []
            for b, o_g, st_b in zip(layout.buckets, owned, opt_st["flat"]):
                shard32 = None
                if "master" not in st_b:
                    rows_l = b.rows // n_dp
                    idx = jax.lax.axis_index(axis)
                    # flatten in the (bf16) param dtype, not f32: only the
                    # owned 1/P shard is widened (fp8-class leaves are all
                    # low-precision unless the user inits f32 params)
                    fdt = jnp.float32 if any(
                        pleaves[s.index].dtype == jnp.float32
                        for s in b.slots) else jnp.bfloat16
                    shard32 = jax.lax.dynamic_slice_in_dim(
                        bucket_flat(b, pleaves, fdt), idx * rows_l,
                        rows_l, 0).astype(jnp.float32)
                new_shard, new_st = ost.flat_bucket_update(
                    opt, pol, st_b, o_g, clip, lr, b1c, b2c, shard32)
                full = grad_comm.all_gather_shard(new_shard, axis)
                new_leaves.update(bucket_scatter(b, full, pleaves))
                new_flat.append(new_st)

            # sensitive leaves: replicated classic update (f32 state)
            sens_st = opt_st["sens"]
            new_sens = {"m": {}, "v": {}}
            if "master" in sens_st:
                new_sens["master"] = {}
            for i, pth in layout.sensitive:
                p = pleaves[i]
                g32 = sens_g[pth] * clip
                base = sens_st["master"][pth] if "master" in sens_st \
                    else p.astype(jnp.float32)
                new_master, m_new, v_new = adamw.adamw_math(
                    opt, g32, sens_st["m"][pth], sens_st["v"][pth], base,
                    lr, b1c, b2c)
                new_leaves[i] = new_master.astype(p.dtype)
                new_sens["m"][pth] = m_new
                new_sens["v"][pth] = v_new
                if "master" in sens_st:
                    new_sens["master"][pth] = new_master

            new_params = jax.tree.unflatten(
                treedef, [new_leaves[i] for i in range(len(pleaves))])
            new_opt = {"step": step, "flat": tuple(new_flat),
                       "sens": new_sens}
            metrics = {k: jax.lax.pmean(v, axis)
                       for k, v in dict(fwd_metrics).items()}
            metrics["loss"] = jax.lax.pmean(loss, axis)
            metrics["grad_norm"] = gnorm
            metrics["lr"] = lr
            return new_params, new_opt, metrics

        lead = 1 if grad_accum > 1 else 0
        batch_specs = jax.tree.map(
            lambda a: P(*((None,) * lead + (axis,))), batch)
        opt_in = {"step": P(),
                  "flat": tuple(P(axis, None) for _ in layout.buckets),
                  "sens": P()}
        sm = shard_map(body, mesh=mesh,
                       in_specs=(P(), opt_in, batch_specs),
                       out_specs=(P(), opt_in, P()))
        new_params, new_opt, metrics = sm(params, state["opt"], batch)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ArchConfig, opt: adamw.AdamWConfig, key,
                     dtype=jnp.bfloat16, dist=None) -> Dict[str, Any]:
    from repro.models.lm import init_params
    params = init_params(cfg, key, dtype)
    if dist is not None and dist.active:
        from repro.dist import opt_state as ost
        from repro.dist.plan import build_layout
        layout = build_layout(params, dist)
        return {"params": params,
                "opt": ost.init_dist_state(opt, params, layout, dist)}
    return {"params": params, "opt": adamw.init_state(opt, params)}
