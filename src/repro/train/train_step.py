"""The jitted training step: loss -> grad -> (optional grad-accum) ->
gradient reduction -> AdamW update.

`make_train_step` closes over static config (arch, recipe, plan, optimizer)
and returns a function (state, batch) -> (state, metrics) suitable for
jax.jit with explicit in/out shardings (launch/sharding.py).

Two reduction regimes:
  dist=None      the legacy implicit path — the batch is sharded over the DP
                 axes and pjit inserts f32 psums for the gradients.
  dist=DistPlan  the explicit FP8-native wire (repro.dist): the whole step
                 runs inside ONE shard_map over the DP axis; gradients
                 reduce-scatter as e4m3 payload + po2 int8 exponents packed
                 into one uint8 message per bucket (pre-agreed scales, no
                 double quantization error), the ZeRO-1 owned shard updates
                 FP8-split optimizer state, and the updated bf16 param
                 shards all-gather back.  Sensitive leaves (norms, router,
                 embeddings) ride a bf16 psum.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import quant
from repro.core.recipes import Recipe
from repro.models.lm import ParallelPlan, forward
from repro.obs.trace import annotate
from repro.optim import adamw, schedules
from repro.train import guards


def make_train_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                    opt: adamw.AdamWConfig, *, grad_accum: int = 1,
                    dist: Optional[Any] = None,
                    total_steps: int = 100_000, warmup_steps: int = 100,
                    guard: Optional[guards.GuardPlan] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt': adamw state (or dist state when dist is set)}
    batch = {'tokens' (B, S), 'targets', 'mask', ...} with B the
    PER-MICROBATCH size when grad_accum > 1 — the step loops microbatches
    via lax.scan over the leading accum axis of the batch.

    dist: an active repro.dist.DistPlan routes the step through the
    quantized ZeRO-1 wire (see _make_dist_train_step).

    guard: a train/guards.py GuardPlan arms in-step anomaly detection —
    the step carries state['guard'] (grad-norm EMA), collects FP8
    quantize-site stats, guards the DP wire, and emits a 'guard_flags'
    uint32 in the metrics.  guard=None leaves the traced step bitwise
    identical to an unguarded build (the detection code never traces)."""
    if dist is not None and dist.active:
        return _make_dist_train_step(cfg, recipe, plan, opt, dist,
                                     grad_accum=grad_accum,
                                     total_steps=total_steps,
                                     warmup_steps=warmup_steps, guard=guard)

    def loss_fn(params, mb):
        loss, metrics = forward(cfg, recipe, plan, params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        ctx = quant.collect_stats() if guard is not None \
            else contextlib.nullcontext()
        with ctx:
            loss, metrics, grads = _local_grads(loss_fn, params, batch,
                                                grad_accum)
        lr_scale = schedules.warmup_cosine(
            state["opt"]["step"], total_steps=total_steps,
            warmup_steps=warmup_steps)
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            opt, params, grads, state["opt"], lr_scale=lr_scale)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        new_state = {"params": new_params, "opt": new_opt}
        if guard is not None:
            flags, new_g, gm = guards.evaluate(
                guard, state["guard"], loss=loss,
                gnorm=opt_metrics["grad_norm"],
                sat_frac=metrics.get("quant_sat_frac"),
                flush_frac=metrics.get("quant_flush_frac"))
            new_state["guard"] = new_g
            metrics.update(gm)
        return new_state, metrics

    return train_step


def _local_grads(loss_fn, params, batch, grad_accum):
    """value+grad, optionally scanning a leading grad-accum batch axis.
    Forward metrics are accumulated across microbatches and averaged, the
    same way the loss is — they used to be silently dropped."""
    if grad_accum > 1:
        def acc_body(carry, mb):
            gsum, lsum = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss), metrics

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), ms = jax.lax.scan(acc_body, (g0, jnp.float32(0.0)),
                                        batch)
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        metrics = jax.tree.map(lambda a: jnp.mean(a, axis=0), ms)
        return lsum / grad_accum, metrics, grads
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch)
    return loss, metrics, grads


# ---------------------------------------------------------------------------
# The staged layer program's streaming step: manual per-layer forward (each
# layer's vjp saved), then a reverse-order backward that quantizes and
# reduce-scatters each layer's gradient bucket(s) AS SOON AS that layer's
# vjp has produced them — the DP wire rides behind the remaining backward
# compute instead of waiting for all of it (DistPlan schedule='stream').
# ---------------------------------------------------------------------------
def _streamed_grads(cfg, recipe, lplan, params, batch, layout, axis, n_dp,
                    wire, grad_accum: int = 1, guard=None):
    """Returns (loss, metrics, owned, sens_done, sens_raw, wire_bad):

    owned      aligns with layout.buckets (the layered, reverse-layer-order
               layout) and holds each bucket's already-reduced f32 shard;
    sens_done  maps a STACK-TAGGED sensitive leaf's path to its fully
               reduced, restacked f32 gradient — each layer's slice was
               issued on the bf16 fallback wire together with that layer's
               FP8 bucket(s), from inside the backward;
    sens_raw   maps the remaining (non-stacked: embeddings, final norms,
               head) sensitive leaves' flatten indices to their local
               gradients, reduced by the caller post-hoc as before.

    Rematerialization composes through the MemoryPlan (train/memory.py):
    each per-block jax.vjp wraps its layers per cfg.remat_policy ('pair'
    coarsens the streaming granularity to two-layer blocks).

    grad_accum > 1 streams too: the batch carries a leading microbatch
    axis; every microbatch's bucket flats and sensitive slices accumulate
    LOCALLY, and each quantize + reduce-scatter (and each bf16 psum) is
    issued exactly once, from inside the LAST microbatch's backward — the
    wire still hides behind backward compute, and the pre-agreed scales see
    the full accumulated gradient (no per-microbatch quantization)."""
    from repro.dist import grad_comm
    from repro.dist.plan import bucket_flat_parts, path_str
    from repro.models.layers import apply_norm
    from repro.models.lm import (AUX_LOSS_COEF, _embed_tokens, _lm_logits,
                                 _xent, iter_layer_slices, layer_forward)
    from repro.train.memory import MemoryPlan

    mem = MemoryPlan.from_config(cfg)

    # static maps: full-tree flatten index -> position in each stack's
    # per-layer subtree flatten order (subtree traversal is the same sorted
    # dict walk, so relative order matches)
    flatpaths = jax.tree_util.tree_flatten_with_path(params)[0]
    by_path = {path_str(pth): i for i, (pth, _) in enumerate(flatpaths)}
    stack_pos = {}
    for s in ("dense_layers", "layers"):
        idxs = [i for i, (pth, _) in enumerate(flatpaths)
                if path_str(pth).split(".")[0] == s]
        stack_pos[s] = {i: j for j, i in enumerate(idxs)}
    layer_buckets = {}
    for bi, b in enumerate(layout.buckets):
        layer_buckets.setdefault((b.stack, b.layer), []).append((bi, b))
    sens_stacked = {s.index: s for s in layout.sensitive
                    if s.stack is not None}
    sens_other_idx = {s.index for s in layout.sensitive if s.stack is None}
    entries = list(iter_layer_slices(cfg, params))
    blocks = mem.blocks_of(entries)

    owned = [None] * len(layout.buckets)
    flat_acc = [None] * len(layout.buckets)  # local microbatch accumulation
    sens_layer_acc = {}             # (index, layer) -> local grad sum
    sens_done_parts = {}            # index -> {layer: REDUCED grad slice}
    sens_raw = {}                   # index -> local (accumulated) gradient
    loss_sum = jnp.float32(0.0)
    aux_sum = jnp.float32(0.0)
    armed = quant.stats_armed()     # guard stats threaded through each vjp
    wire_bad = jnp.bool_(False) if guard is not None else None

    for m in range(grad_accum):
        mb = batch if grad_accum == 1 else \
            jax.tree.map(lambda a, _m=m: a[_m], batch)
        emit = m == grad_accum - 1
        inv = 1.0 if grad_accum == 1 else 1.0 / grad_accum
        tokens, targets = mb["tokens"], mb["targets"]
        mask = mb.get("mask", jnp.ones_like(tokens, jnp.float32))

        # ---- staged forward (unrolled; the two-layer carry window defers
        # each block's scalar epilogue past the next block's issue) --------
        x, emb_vjp = jax.vjp(
            lambda e: _embed_tokens(cfg, {"embed": e}, tokens),
            params["embed"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        recs = []                   # (block entries, vjp) in forward order
        aux_total = jnp.float32(0.0)
        pending = None
        for blk in blocks:
            ps = tuple(e[4] for e in blk)

            def f(ps_, xc, _km=tuple((e[2], e[3]) for e in blk)):
                a_blk = jnp.float32(0.0)
                for p, (kind, moe) in zip(ps_, _km):
                    xc, a = layer_forward(cfg, recipe, lplan, kind, moe, p,
                                          xc, positions)
                    a_blk = a_blk + a
                if armed:   # guard stats: drained in-block, threaded out
                    return xc, a_blk, quant.drain_stats()
                return xc, a_blk

            if armed:
                (x, a, sv), vjp_b = jax.vjp(mem.wrap(f), ps, x)
                quant.reinject_stats(sv)
            else:
                (x, a), vjp_b = jax.vjp(mem.wrap(f), ps, x)
            recs.append((blk, vjp_b))
            if pending is not None:
                aux_total = aux_total + pending
            pending = a
        if pending is not None:
            aux_total = aux_total + pending

        hp = {"final_norm_s": params["final_norm_s"]}
        if "final_norm_b" in params:
            hp["final_norm_b"] = params["final_norm_b"]
        hp["embed" if cfg.tie_embeddings else "lm_head"] = \
            params["embed"] if cfg.tie_embeddings else params["lm_head"]

        def head_f(hp_, xf, _targets=targets, _mask=mask):
            xn = apply_norm(cfg.norm, xf,
                            {"final_norm_s": hp_["final_norm_s"],
                             "final_norm_b": hp_.get("final_norm_b")},
                            "final_norm")
            return _xent(_lm_logits(cfg, hp_, xn, None), _targets, _mask)

        xent_loss, head_vjp = jax.vjp(head_f, hp, x)
        loss_sum = loss_sum + xent_loss + AUX_LOSS_COEF * aux_total
        aux_sum = aux_sum + aux_total

        # ---- streaming backward: reverse layer order, wire-on-the-way ----
        g_hp, g_x = head_vjp(jnp.float32(1.0))
        g_aux = jnp.float32(AUX_LOSS_COEF)      # d loss / d aux_l
        for blk, vjp_b in reversed(recs):
            if armed:   # zero cotangent for the threaded stats output
                g_ps, g_x = vjp_b((g_x, g_aux, quant.zero_stats()))
            else:
                g_ps, g_x = vjp_b((g_x, g_aux))
            for (stack, l, _k, _mo, _p), g_pl in zip(reversed(blk),
                                                     reversed(g_ps)):
                g_leaves = jax.tree.leaves(g_pl)
                pos = stack_pos[stack]
                for bi, b in layer_buckets.get((stack, l), ()):
                    flat = bucket_flat_parts(
                        b, lambda s: g_leaves[pos[s.index]])
                    if flat_acc[bi] is not None:
                        flat = flat + flat_acc[bi]
                    if emit:
                        # issued HERE, between layer l's and layer l-1's
                        # backward GEMMs: the pre-agreed-scale quantize +
                        # single-uint8-message RS (of the microbatch MEAN)
                        flat_m = flat * inv if grad_accum > 1 else flat
                        with annotate(f"wire/bucket{bi}_{stack}_l{l}"):
                            if guard is not None:
                                owned[bi], bad = \
                                    grad_comm.reduce_scatter_bucket(
                                        flat_m, axis, n_dp, wire,
                                        guard=guard)
                                wire_bad = jnp.logical_or(wire_bad, bad)
                            else:
                                owned[bi] = grad_comm.reduce_scatter_bucket(
                                    flat_m, axis, n_dp, wire)
                        flat_acc[bi] = None
                    else:
                        flat_acc[bi] = flat
                for i in pos:
                    g_s = g_leaves[pos[i]]
                    if i in sens_stacked:
                        key = (i, l)
                        if key in sens_layer_acc:
                            g_s = g_s + sens_layer_acc[key]
                        if emit:
                            # the layer's bf16 psum rides with its bucket(s)
                            with annotate(f"wire/sensitive_{stack}_l{l}"):
                                sens_done_parts.setdefault(i, {})[l] = \
                                    grad_comm.reduce_sensitive(
                                        g_s * inv if grad_accum > 1
                                        else g_s, axis, n_dp, wire)
                            sens_layer_acc.pop(key, None)
                        else:
                            sens_layer_acc[key] = g_s
                    elif i in sens_other_idx:   # non-layered fallback leaf
                        sens_raw[i] = g_s if i not in sens_raw \
                            else sens_raw[i] + g_s

        g_embed = emb_vjp(g_x)[0]
        if cfg.tie_embeddings:
            g_embed = g_embed + g_hp["embed"].astype(g_embed.dtype)
        ends = {"embed": g_embed,
                "final_norm_s": g_hp["final_norm_s"]}
        if "final_norm_b" in by_path:
            ends["final_norm_b"] = g_hp["final_norm_b"]
        if not cfg.tie_embeddings:
            ends["lm_head"] = g_hp["lm_head"]
        for name, g in ends.items():
            i = by_path[name]
            sens_raw[i] = g if i not in sens_raw else sens_raw[i] + g

    if grad_accum > 1:
        sens_raw = {i: g / grad_accum for i, g in sens_raw.items()}
    sens_done = {
        sens_stacked[i].path: jnp.stack([pieces[l]
                                         for l in range(len(pieces))])
        for i, pieces in sens_done_parts.items()}
    loss = loss_sum / grad_accum
    metrics = {"aux_loss": aux_sum / grad_accum, "loss": loss}
    if armed:
        # final drain: per-block reinjects + the dp_wire quantize records
        sv = quant.drain_stats()
        sm = quant.site_maxima(sv)
        metrics["quant_sat_frac"] = sm[0]
        metrics["quant_flush_frac"] = sm[1]
        metrics["quant_site_stats"] = sv
    return loss, metrics, owned, sens_done, sens_raw, wire_bad


# ---------------------------------------------------------------------------
# The explicit FP8 wire + ZeRO-1 step (repro.dist).
# ---------------------------------------------------------------------------
def _make_dist_train_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                          opt: adamw.AdamWConfig, dist, *, grad_accum: int,
                          total_steps: int, warmup_steps: int, guard=None):
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.dist import grad_comm
    from repro.dist import opt_state as ost
    from repro.dist.plan import (bucket_flat, bucket_scatter, build_layout,
                                 streaming_fallback_reason)

    mesh = plan.mesh
    if mesh is None or dist.axis not in mesh.axis_names:
        raise ValueError(f"DistPlan needs a plan.mesh with axis "
                         f"'{dist.axis}'; got {mesh}")
    if dist.schedule == "stream":
        reason = streaming_fallback_reason(cfg, grad_accum=grad_accum)
        if reason:
            raise ValueError(
                f"DistPlan schedule='stream' cannot run: {reason} — use "
                f"schedule='posthoc' (launch/train.py falls back "
                f"automatically)")
    n_dp = mesh.shape[dist.axis]
    nontrivial = [a for a in mesh.axis_names
                  if a != dist.axis and mesh.shape[a] != 1]
    if nontrivial:
        raise ValueError(
            f"the DistPlan wire runs the forward replica-locally inside a "
            f"shard_map over '{dist.axis}'; model-parallel axes {nontrivial} "
            f"cannot nest another shard_map on jax {jax.__version__} — use "
            f"dist=None (implicit pjit psum) on model-parallel meshes")
    if dist.shard_multiple % n_dp != 0:
        raise ValueError(
            f"DP size {n_dp} does not divide DistPlan.shard_multiple="
            f"{dist.shard_multiple}: bucket rows pad to shard_multiple, so "
            f"ZeRO-1 shards would be unequal — set shard_multiple to a "
            f"multiple of the DP size (or size the data axis to a divisor)")
    # the forward must not open a nested shard_map: run it replica-local
    local_plan = dataclasses.replace(plan, mesh=None, dp_axes=(),
                                     fsdp_axis=None, shard_map_mlp=False,
                                     moe_overlap=None)
    pol = dist.policy
    axis = dist.axis

    def loss_fn(params, mb):
        loss, metrics = forward(cfg, recipe, local_plan, params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        layout = build_layout(params, dist)     # static (shapes only)
        treedef = jax.tree.structure(params)
        if dist.schedule == "stream":
            reason = streaming_fallback_reason(cfg, layout, grad_accum)
            if reason:
                raise ValueError(
                    f"DistPlan schedule='stream' cannot run: {reason}")

        def body_impl(params, opt_st, batch, gstate):
            pleaves = treedef.flatten_up_to(params)
            wire_bad = None
            if dist.schedule == "stream":
                # staged layer program: per-layer backward, bucket i's
                # quantize + reduce-scatter issued the moment layer i's
                # grads exist (reverse layer order) — the DP wire hides
                # behind the remaining backward compute.  Stack-tagged
                # sensitive leaves stream per layer on the bf16 wire;
                # grad_accum > 1 accumulates locally and wires once on the
                # last microbatch.
                loss, fwd_metrics, owned, sens_done, sens_raw, wire_bad = \
                    _streamed_grads(cfg, recipe, local_plan, params, batch,
                                    layout, axis, n_dp, dist.wire,
                                    grad_accum=grad_accum, guard=guard)
            else:
                loss, fwd_metrics, grads = _local_grads(
                    loss_fn, params, batch, grad_accum)
                gleaves = treedef.flatten_up_to(grads)

                # quantized reduce-scatter: one fused uint8 message per
                # bucket, scales pre-agreed (scale_sync) so the sum never
                # re-quantizes
                if guard is not None:
                    pairs = [grad_comm.reduce_scatter_bucket(
                        bucket_flat(b, gleaves), axis, n_dp, dist.wire,
                        guard=guard) for b in layout.buckets]
                    owned = [o for o, _ in pairs]
                    wire_bad = jnp.bool_(False)
                    for _, bad in pairs:
                        wire_bad = jnp.logical_or(wire_bad, bad)
                    # wire-quantize stats recorded during the RS, after
                    # forward() drained its own: merge the site matrices
                    wire_sv = quant.drain_stats()
                    fwd_metrics = dict(fwd_metrics)
                    sites = jnp.maximum(
                        fwd_metrics["quant_site_stats"], wire_sv)
                    sm = quant.site_maxima(sites)
                    fwd_metrics["quant_site_stats"] = sites
                    fwd_metrics["quant_sat_frac"] = sm[0]
                    fwd_metrics["quant_flush_frac"] = sm[1]
                else:
                    owned = [grad_comm.reduce_scatter_bucket(
                        bucket_flat(b, gleaves), axis, n_dp, dist.wire)
                        for b in layout.buckets]
                sens_raw = {i: gleaves[i] for i, _ in layout.sensitive}
                sens_done = {}
            sens_g = {p: sens_done[p] if p in sens_done
                      else grad_comm.reduce_sensitive(sens_raw[i], axis,
                                                      n_dp, dist.wire)
                      for i, p in layout.sensitive}

            # global grad norm in one fused f32 scalar pass: each replica
            # owns disjoint shards, so psum(sum owned^2) is the exact total
            parts = [jnp.sum(jnp.square(o)) for o in owned]
            sq_owned = jnp.sum(jnp.stack(parts)) if parts \
                else jnp.float32(0.0)
            sq_owned = jax.lax.psum(sq_owned, axis)
            sq_sens = [jnp.sum(jnp.square(g)) for g in sens_g.values()]
            gnorm = jnp.sqrt(sq_owned + (jnp.sum(jnp.stack(sq_sens))
                                         if sq_sens else jnp.float32(0.0)))
            clip = adamw.clip_factor(opt, gnorm)
            step = opt_st["step"] + 1
            b1c, b2c = adamw.bias_corrections(opt, step)
            lr = opt.lr * schedules.warmup_cosine(
                opt_st["step"], total_steps=total_steps,
                warmup_steps=warmup_steps)

            # ZeRO-1: update the owned shard, all-gather bf16 param shards
            new_leaves, stacked_new, new_flat = {}, {}, []
            for b, o_g, st_b in zip(layout.buckets, owned, opt_st["flat"]):
                shard32 = None
                if "master" not in st_b:
                    rows_l = b.rows // n_dp
                    idx = jax.lax.axis_index(axis)
                    # flatten in the (bf16) param dtype, not f32: only the
                    # owned 1/P shard is widened (fp8-class leaves are all
                    # low-precision unless the user inits f32 params)
                    fdt = jnp.float32 if any(
                        pleaves[s.index].dtype == jnp.float32
                        for s in b.slots) else jnp.bfloat16
                    shard32 = jax.lax.dynamic_slice_in_dim(
                        bucket_flat(b, pleaves, fdt), idx * rows_l,
                        rows_l, 0).astype(jnp.float32)
                new_shard, new_st = ost.flat_bucket_update(
                    opt, pol, st_b, o_g, clip, lr, b1c, b2c, shard32)
                full = grad_comm.all_gather_shard(new_shard, axis)
                for key, piece in bucket_scatter(b, full, pleaves).items():
                    if isinstance(key, tuple):      # layered: (index, layer)
                        stacked_new.setdefault(key[0], {})[key[1]] = piece
                    else:
                        new_leaves[key] = piece
                new_flat.append(new_st)
            # layered buckets update one layer slice at a time; restack them
            for i, pieces in stacked_new.items():
                new_leaves[i] = jnp.stack(
                    [pieces[l] for l in range(pleaves[i].shape[0])])

            # sensitive leaves: replicated classic update (f32 state)
            sens_st = opt_st["sens"]
            new_sens = {"m": {}, "v": {}}
            if "master" in sens_st:
                new_sens["master"] = {}
            for i, pth in layout.sensitive:
                p = pleaves[i]
                g32 = sens_g[pth] * clip
                base = sens_st["master"][pth] if "master" in sens_st \
                    else p.astype(jnp.float32)
                new_master, m_new, v_new = adamw.adamw_math(
                    opt, g32, sens_st["m"][pth], sens_st["v"][pth], base,
                    lr, b1c, b2c)
                new_leaves[i] = new_master.astype(p.dtype)
                new_sens["m"][pth] = m_new
                new_sens["v"][pth] = v_new
                if "master" in sens_st:
                    new_sens["master"][pth] = new_master

            new_params = jax.tree.unflatten(
                treedef, [new_leaves[i] for i in range(len(pleaves))])
            new_opt = {"step": step, "flat": tuple(new_flat),
                       "sens": new_sens}
            # quant_* stats reduce by pmax (an anomaly ANYWHERE must trip
            # the replica-uniform flag); everything else stays pmean
            metrics = {k: jax.lax.pmax(v, axis) if k.startswith("quant_")
                       else jax.lax.pmean(v, axis)
                       for k, v in dict(fwd_metrics).items()}
            metrics["loss"] = jax.lax.pmean(loss, axis)
            metrics["grad_norm"] = gnorm
            metrics["lr"] = lr
            if guard is None:
                return new_params, new_opt, metrics
            # all evaluate() inputs are replica-uniform (pmean/psum/pmax
            # above; wire_anomaly pmaxes internally), so flags and the new
            # guard state replicate for free under out_specs P()
            flags, new_g, gm = guards.evaluate(
                guard, gstate, loss=metrics["loss"], gnorm=gnorm,
                sat_frac=metrics.get("quant_sat_frac"),
                flush_frac=metrics.get("quant_flush_frac"),
                wire_bad=wire_bad)
            metrics.update(gm)
            return new_params, new_opt, metrics, new_g

        lead = 1 if grad_accum > 1 else 0
        batch_specs = jax.tree.map(
            lambda a: P(*((None,) * lead + (axis,))), batch)
        opt_in = {"step": P(),
                  "flat": tuple(P(axis, None) for _ in layout.buckets),
                  "sens": P()}
        if guard is None:
            def body(params, opt_st, batch):
                return body_impl(params, opt_st, batch, None)

            sm = shard_map(body, mesh=mesh,
                           in_specs=(P(), opt_in, batch_specs),
                           out_specs=(P(), opt_in, P()))
            new_params, new_opt, metrics = sm(params, state["opt"], batch)
            return {"params": new_params, "opt": new_opt}, metrics

        def body(params, opt_st, batch, gstate):
            with quant.collect_stats():
                return body_impl(params, opt_st, batch, gstate)

        sm = shard_map(body, mesh=mesh,
                       in_specs=(P(), opt_in, batch_specs, P()),
                       out_specs=(P(), opt_in, P(), P()))
        new_params, new_opt, metrics, new_g = sm(
            params, state["opt"], batch, state["guard"])
        return {"params": new_params, "opt": new_opt, "guard": new_g}, \
            metrics

    return train_step


def init_train_state(cfg: ArchConfig, opt: adamw.AdamWConfig, key,
                     dtype=jnp.bfloat16, dist=None,
                     guard: Optional[guards.GuardPlan] = None
                     ) -> Dict[str, Any]:
    from repro.models.lm import init_params
    params = init_params(cfg, key, dtype)
    if dist is not None and dist.active:
        from repro.dist import opt_state as ost
        from repro.dist.plan import build_layout
        layout = build_layout(params, dist)
        state = {"params": params,
                 "opt": ost.init_dist_state(opt, params, layout, dist)}
    else:
        state = {"params": params, "opt": adamw.init_state(opt, params)}
    if guard is not None:
        state["guard"] = guards.init_guard_state()
    return state
