"""The jitted training step: loss -> grad -> (optional grad-accum) ->
(optional FP8-compressed pod reduction) -> AdamW update.

`make_train_step` closes over static config (arch, recipe, plan, optimizer)
and returns a function (state, batch) -> (state, metrics) suitable for
jax.jit with explicit in/out shardings (launch/sharding.py)."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.recipes import Recipe
from repro.models.lm import ParallelPlan, forward
from repro.optim import adamw, schedules


def make_train_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                    opt: adamw.AdamWConfig, *, grad_accum: int = 1,
                    compress_pod_grads: bool = False,
                    total_steps: int = 100_000, warmup_steps: int = 100):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt': adamw state}
    batch = {'tokens' (B, S), 'targets', 'mask', ...} with B the
    PER-MICROBATCH size when grad_accum > 1 — the step loops microbatches
    via lax.scan over the leading accum axis of the batch."""

    def loss_fn(params, mb):
        loss, metrics = forward(cfg, recipe, plan, params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        if grad_accum > 1:
            def acc_body(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, jnp.float32(0.0)),
                                           batch)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if compress_pod_grads and plan.mesh is not None and \
                "pod" in getattr(plan.mesh, "axis_names", ()):
            from repro.compat import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.runtime.compression import compressed_psum
            # grads arrive pod-sharded (per-pod partial sums when the batch
            # is pod-split); reduce them over the pod axis on an FP8 wire
            spec = P()  # grads replicated within pod after pjit's psums
            # NOTE: the pod reduction is modeled inside the loss psum by
            # pjit when batch is sharded over 'pod'; compressed_psum is the
            # explicit alternative exercised by runtime tests + benches.
            del spec

        lr_scale = schedules.warmup_cosine(
            state["opt"]["step"], total_steps=total_steps,
            warmup_steps=warmup_steps)
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            opt, params, grads, state["opt"], lr_scale=lr_scale)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ArchConfig, opt: adamw.AdamWConfig, key,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    from repro.models.lm import init_params
    params = init_params(cfg, key, dtype)
    return {"params": params, "opt": adamw.init_state(opt, params)}
