"""Deterministic sharded synthetic data pipeline.

Produces a reproducible token stream (hash-mixed counter -> vocab) so
convergence comparisons between recipes see IDENTICAL data order (the paper's
Fig. 6 controls for data ordering).  Sharding: each (host, data-shard) seeds
from (seed, step, shard) — no cross-host coordination needed, which is also
what makes elastic re-sharding (runtime/fault_tolerance.py) trivial: a shard
is a pure function of its index.

The stream has learnable structure (a noisy periodic grammar), so losses
decrease and BF16-vs-FP8 curves can separate if a recipe is broken.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _mix(a: jnp.ndarray) -> jnp.ndarray:
    """64-bit-ish integer hash (splitmix-style) on uint32."""
    a = a.astype(jnp.uint32)
    a = (a ^ (a >> 16)) * jnp.uint32(0x7feb352d)
    a = (a ^ (a >> 15)) * jnp.uint32(0x846ca68b)
    return a ^ (a >> 16)


def make_batch(cfg: DataConfig, step: int | jnp.ndarray):
    """Global batch for `step` — deterministic, no RNG state to checkpoint.

    Tokens follow a periodic template (period 17) hashed per sequence with
    20% hash-noise; targets are the next token.  Loss floor ~= H(noise) so
    curves decay visibly within a few hundred steps."""
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    step = jnp.asarray(step, jnp.uint32)
    seq_ids = jnp.arange(B, dtype=jnp.uint32) + step * jnp.uint32(B) \
        + jnp.uint32(cfg.seed) * jnp.uint32(0x9e3779b9)
    pos = jnp.arange(S + 1, dtype=jnp.uint32)
    base = _mix(seq_ids[:, None] * jnp.uint32(31)) % jnp.uint32(max(V // 4, 1))
    tmpl = (base + (pos[None, :] % jnp.uint32(17)) *
            _mix(seq_ids[:, None] + 7) % jnp.uint32(13)) % jnp.uint32(V)
    noise = _mix(seq_ids[:, None] ^ _mix(pos[None, :] + step))
    use_noise = (noise % jnp.uint32(5)) == 0          # 20% random tokens
    rnd = noise % jnp.uint32(V)
    toks = jnp.where(use_noise, rnd, tmpl).astype(jnp.int32)
    return {
        "tokens": toks[:, :S],
        "targets": toks[:, 1:],
        "mask": jnp.ones((B, S), jnp.float32),
    }


def make_batch_np(cfg: DataConfig, step: int):
    """NumPy twin for host-side prefetch (used by the training loop's
    double-buffered input thread)."""
    out = jax.device_get(make_batch(cfg, step))
    return {k: np.asarray(v) for k, v in out.items()}
