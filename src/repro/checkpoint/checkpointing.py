"""Checkpoint save/restore: atomic step directories, async writer thread,
integrity manifest — the restart half of fault tolerance.

Layout:
  <dir>/step_<N>/shard_<i>.npz     flattened leaf arrays
  <dir>/step_<N>/MANIFEST.json     treedef + shapes/dtypes + fingerprint
  <dir>/step_<N>/.COMPLETE         commit marker (atomic rename)

A crash mid-save leaves no .COMPLETE marker, so restore picks the newest
complete step — restart-safe by construction.  On a real multi-host cluster
each host writes its own process-local shards of the globally-sharded
arrays (jax.experimental.multihost_utils); on this single-process container
that degenerates to one shard, but the layout and protocol are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed an integrity check on restore: torn/unreadable
    shard bytes, a per-leaf shape/dtype mismatch against the MANIFEST, or a
    fingerprint mismatch.  Callers (train/loop.py's rollback ladder) catch
    this and fall back to an OLDER complete step instead of silently
    loading corrupt state into the optimizer."""


class AsyncSaveHandle:
    """Handle for an async save: join() re-raises any exception the writer
    thread hit, so a failed background write cannot masquerade as a
    durable checkpoint."""

    def __init__(self, fn):
        self._exc = None

        def _run():
            try:
                fn()
            except BaseException as e:   # noqa: BLE001 — re-raised on join
                self._exc = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def join(self):
        self._thread.join()
        if self._exc is not None:
            raise self._exc


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _fingerprint(arrays) -> float:
    return float(sum(float(np.sum(np.abs(a.astype(np.float64))))
                     for a in arrays if a.dtype.kind == "f"))


def save(ckpt_dir: str, step: int, tree: Any, *, async_: bool = False,
         max_keep: int = 3):
    """Atomic checkpoint write; optionally on a background thread."""
    def _write():
        tmp = os.path.join(ckpt_dir, f"_tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        leaves, treedef = _flatten(tree)
        arrays = {f"leaf_{i}": np.asarray(jax.device_get(x))
                  for i, x in enumerate(leaves)}
        # npz has no bf16/fp8 support: store raw bytes + dtype in manifest
        raw = {k: np.ascontiguousarray(a).view(np.uint8)
               for k, a in arrays.items()}
        np.savez(os.path.join(tmp, "shard_0.npz"), **raw)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.shape(a)) for a in arrays.values()],
            "dtypes": [str(a.dtype) for a in arrays.values()],
            "fingerprint": _fingerprint(arrays.values()),
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, ".COMPLETE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, max_keep)

    if async_:
        return AsyncSaveHandle(_write)
    _write()
    return None


def _gc(ckpt_dir: str, max_keep: int):
    steps = sorted(completed_steps(ckpt_dir))
    for s in steps[:-max_keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def completed_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, ".COMPLETE")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = completed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of `tree_like`.  `shardings` (optional
    matching pytree of NamedSharding) re-shards on load — this is what makes
    elastic restart onto a DIFFERENT mesh work: the npz holds the full
    logical array; device placement is decided at restore time."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    # an explicit step must ALSO be committed — a torn directory that never
    # got its .COMPLETE marker is not restorable just because it was named
    if not os.path.exists(os.path.join(path, ".COMPLETE")):
        raise CheckpointCorruptError(
            f"{path}: no .COMPLETE marker (torn or in-flight save)")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), "tree structure changed"
    import ml_dtypes  # noqa: F401 — registers bf16/fp8 numpy dtypes
    # integrity pass BEFORE any device_put: every leaf's raw bytes must
    # decode against the manifest's shape/dtype, and the float fingerprint
    # must reproduce bit-for-bit (same bytes, same summation order)
    arrays = []
    try:
        data = np.load(os.path.join(path, "shard_0.npz"))
        for i in range(len(leaves_like)):
            dtype = np.dtype(manifest["dtypes"][i])
            shape = tuple(manifest["shapes"][i])
            raw = data[f"leaf_{i}"]
            if raw.size * raw.itemsize != \
                    int(np.prod(shape, dtype=np.int64)) * dtype.itemsize:
                raise CheckpointCorruptError(
                    f"{path}: leaf_{i} holds {raw.size * raw.itemsize} "
                    f"bytes, manifest says {shape} {dtype}")
            arrays.append(raw.view(dtype).reshape(shape))
    except CheckpointCorruptError:
        raise
    except Exception as e:   # torn zip, bad CRC, missing member, ...
        raise CheckpointCorruptError(
            f"{path}: unreadable shard bytes ({type(e).__name__}: {e})"
        ) from e
    fp, want = _fingerprint(arrays), manifest["fingerprint"]
    if fp != want and not (np.isnan(fp) and np.isnan(want)):
        raise CheckpointCorruptError(
            f"{path}: fingerprint mismatch (manifest {want!r}, "
            f"recomputed {fp!r}) — shard bytes were altered after commit")
    new_leaves = []
    # None leaves mean "leave placement alone" — keep them as leaves so a
    # partially-specified shardings tree stays aligned with the state tree
    shard_leaves = jax.tree.flatten(
        shardings, is_leaf=lambda x: x is None)[0] \
        if shardings is not None else [None] * len(leaves_like)
    for arr, shd in zip(arrays, shard_leaves):
        if shd is not None:
            new_leaves.append(jax.device_put(arr, shd))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves), step
