"""The MoE block: router -> FP8 dispatch (all-to-all) -> fused permute+pad ->
grouped expert FFN -> combine.  DeepEP-style dataflow mapped onto
shard_map + jax.lax collectives (DESIGN.md §2, §4).

Token layout inside the shard_map body (one device's shard):
  x_loc : (T, D) bf16 tokens (T = local token count)
  EP    : size of the expert-parallel mesh axis ('model')
  E_loc : experts resident on this device (E_total / EP)

Dispatch uses fixed per-destination capacity C_send (static shapes for XLA),
dropping overflow assignments (standard capacity-factor routing; the drop
fraction is returned as a metric).  The send buffer is built by the fused
permute+pad operator directly in FP8 (fp8 recipes) so the all-to-all carries
1-byte payloads + po2 scales (the paper's 'doubled buffers' caveat — both are
counted by the collective roofline term).

Gradient flow (fp8_flow): the dispatch path is FP8 in BOTH directions — the
input-gradient cotangent is a QTensor whose payload rides the backward
all-to-all in e4m3 (paper Fig. 2d), produced by the Dgrad1 fused-quantizing
epilogue in linear.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import casts
from repro.core.fp8 import TILE
from repro.core.linear import (_q_row, _quant_weights, dequantize_exit,
                               expert_ffn, ffn_bwd_fp8_core, ffn_fwd_fp8_core,
                               quantize_entry)
from repro.core.quant import (QTensor, _dequantize_nocount, quantize_rowwise,
                              record_entry_stats,
                              tag_saveable)
from repro.core.recipes import Recipe
from repro.obs.trace import stage_annotation


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                      # per-expert hidden (F); w13 is (K, 2F)
    capacity_factor: float = 1.25
    ep_axis: str = "model"         # mesh axis carrying experts
    dp_axes: tuple = ("data",)     # token-sharded axes over which expert
                                   # weights are replicated (Wgrad psum set)
    act: str = "swiglu"
    router_dtype: str = "float32"
    # experts-per-device < 1 is impossible; if n_experts < EP the layer falls
    # back to TP-sharded experts (grok-1 case) — handled in models/lm.py by
    # calling moe_block_tp instead.


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Routing (BF16/FP32 — routers are numerically sensitive; all recipes agree).
# ---------------------------------------------------------------------------
def router_topk(x, w_router, top_k: int):
    """Returns (probs (T,k) f32, ids (T,k) i32, aux_loss scalar)."""
    logits = jnp.dot(x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)            # (T, E)
    p, ids = jax.lax.top_k(probs_full, top_k)               # (T, k)
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss
    E = w_router.shape[-1]
    me = jnp.mean(probs_full, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return p, ids, lb_loss + 1e-3 * z_loss


# ---------------------------------------------------------------------------
# Static routing plan: slot maps for send / expert-grouping / combine.
# All pure integer ops (argsort + cumsum); differentiation never touches them.
# ---------------------------------------------------------------------------
def _dispatch_plan(ids, top_k: int, EP: int, E_loc: int, C_send: int):
    """ids: (T, k) global expert ids.  Returns
    row_map_send : (EP*C_send,) source token row per send slot (-1 pad)
    slot_expert  : (EP*C_send,) LOCAL expert id on the dest rank (-1 pad)
    slot_assign  : (EP*C_send,) flat assignment index (for prob lookup; -1)
    drop_frac    : scalar f32
    """
    T = ids.shape[0]
    A = T * top_k
    flat_ids = ids.reshape(A)                      # global expert per assignment
    dest = flat_ids // E_loc                       # dest EP rank
    # stable sort by dest keeps token order within each destination
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    # position within destination group
    pos_all = jnp.arange(A) - jnp.searchsorted(sorted_dest, sorted_dest)
    keep = pos_all < C_send
    slot = sorted_dest * C_send + pos_all          # target send slot
    slot = jnp.where(keep, slot, EP * C_send)      # overflow -> scratch slot
    n_slots = EP * C_send
    init = jnp.full((n_slots + 1,), -1, jnp.int32)
    row_map_send = init.at[slot].set((order // top_k).astype(jnp.int32))[:-1]
    slot_expert = init.at[slot].set((flat_ids[order] % E_loc).astype(jnp.int32))[:-1]
    slot_assign = init.at[slot].set(order.astype(jnp.int32))[:-1]
    drop_frac = 1.0 - jnp.sum(keep.astype(jnp.float32)) / A
    return row_map_send, slot_expert, slot_assign, drop_frac


def _expert_plan(recv_expert, E_loc: int, C_exp: int):
    """recv_expert: (R,) local expert id per received row (-1 invalid).
    Returns row_map_exp (E_loc*C_exp,) source recv-row per expert slot (-1
    pad) and ret_map (R,) expert slot per recv row (-1 dropped)."""
    R = recv_expert.shape[0]
    e = jnp.where(recv_expert >= 0, recv_expert, E_loc)  # invalid -> bucket E
    order = jnp.argsort(e, stable=True)
    sorted_e = e[order]
    pos = jnp.arange(R) - jnp.searchsorted(sorted_e, sorted_e)
    keep = (pos < C_exp) & (sorted_e < E_loc)
    slot = jnp.where(keep, sorted_e * C_exp + pos, E_loc * C_exp)
    init = jnp.full((E_loc * C_exp + 1,), -1, jnp.int32)
    row_map_exp = init.at[slot].set(order.astype(jnp.int32))[:-1]
    ret_init = jnp.full((R + 1,), -1, jnp.int32)
    ret_map = ret_init.at[jnp.where(keep, order, R)].set(
        jnp.where(keep, slot, -1).astype(jnp.int32))[:-1]
    return row_map_exp, ret_map


def _expert_loads(row_map_exp, E_loc: int, C_exp: int):
    """Per-expert live-row counts from the expert plan — the ``masked_m``
    vector of the masked grouped-GEMM layout.  _expert_plan fills each
    expert's slots contiguously from 0, so the count IS the live prefix
    length (rows >= count are the zero-padded dead slots)."""
    return jnp.sum((row_map_exp.reshape(E_loc, C_exp) >= 0),
                   axis=1, dtype=jnp.int32)


def _masked_m_or_none(recipe: Recipe, row_map_exp, E_loc: int, C_exp: int):
    """masked_m for the grouped FFN when the recipe opts in (fp8_flow only —
    the masked kernels live on the FP8 pathway; other recipes ignore it)."""
    if recipe.masked_experts and recipe.name == "fp8_flow":
        return _expert_loads(row_map_exp, E_loc, C_exp)
    return None


# ---------------------------------------------------------------------------
# QTensor-aware permute with explicit VJP (casting-free routing of FP8
# cotangents through injective maps).
# ---------------------------------------------------------------------------
def _take_rows(x, row_map, fill=0.0):
    valid = (row_map >= 0)[:, None]
    rows = jnp.take(x, jnp.maximum(row_map, 0), axis=0)
    return jnp.where(valid, rows, jnp.asarray(fill, x.dtype))


def _permute_pad_fields(data, scale, row_map, use_pallas: bool):
    if use_pallas:
        from repro.kernels.fused_permute_pad import fused_permute_pad_pallas
        return fused_permute_pad_pallas(data, scale, row_map, row_map.shape[0])
    return _take_rows(data, row_map), _take_rows(scale, row_map, fill=1.0)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def permute_q(recipe: Recipe, q: QTensor, row_map, inv_map) -> QTensor:
    """Gather QTensor rows by row_map (fused permute+pad).  row_map must be
    injective on valid slots; backward gathers by inv_map — FP8 cotangents
    route without any dequantization."""
    d, s = _permute_pad_fields(q.data, q.scale, row_map, recipe.use_pallas)
    return QTensor(d, s, q.tile)


def _pq_fwd(recipe, q, row_map, inv_map):
    return permute_q(recipe, q, row_map, inv_map), (inv_map,)


def _pq_bwd(recipe, res, qg: QTensor):
    (inv_map,) = res
    d, s = _permute_pad_fields(qg.data, qg.scale, inv_map, recipe.use_pallas)
    return QTensor(d, s, qg.tile), None, None


permute_q.defvjp(_pq_fwd, _pq_bwd)


# ---------------------------------------------------------------------------
# Dispatch boundaries (entry quantize fused with the send permute).
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(0, 3))
def dispatch_quantize(recipe: Recipe, x, row_map, T: int) -> QTensor:
    """fp8_flow entry: ONE explicit quantize (the paper's entry-point cast),
    then the fused permute+pad into the padded send layout.
    Backward: FP8 gradient rows arrive from the backward all-to-all; they are
    dequantized inside the consuming scatter-add (fused) and summed per
    source token (the top-k reduction, kept in BF16 by design)."""
    q = quantize_rowwise(x, scale_mode=recipe.scale_mode, tag="q_entry")
    d, s = _permute_pad_fields(q.data, q.scale, row_map, recipe.use_pallas)
    return QTensor(d, s, q.tile)


def _dq_fwd(recipe, x, row_map, T):
    return dispatch_quantize(recipe, x, row_map, T), (row_map,
                                                      jnp.zeros((0,), x.dtype))


def _dq_bwd(recipe, T, res, qg: QTensor):
    row_map, wit = res
    casts.record("fused_dequantize", "dispatch_bwd", qg.data.size)
    g_rows = _dequantize_nocount(qg, jnp.bfloat16)
    seg = jnp.where(row_map >= 0, row_map, T)
    gx = jax.ops.segment_sum(g_rows.astype(jnp.float32), seg,
                             num_segments=T + 1)[:T]
    return gx.astype(wit.dtype), None


dispatch_quantize.defvjp(_dq_fwd, _dq_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4))
def fp8_dispatch_naive(recipe: Recipe, x, row_map, T: int, ep_axis: str):
    """naive_fp8 (Fig 2c): Q -> permute -> all-to-all(FP8) -> DQ, with a BF16
    backward all-to-all (DeepSeek keeps combine & all backward comm in BF16).
    Two explicit casts — exactly the Q/DQ-around-comm pair of Table 1."""
    y, _ = _fdn_fwd(recipe, x, row_map, T, ep_axis)
    return y


def _a2a(t, axis_name):
    if axis_name is None:           # local EP=1 path (no mesh axis mapped)
        return t
    EP = compat.axis_size(axis_name)
    shp = t.shape
    t = t.reshape(EP, shp[0] // EP, *shp[1:])
    t = jax.lax.all_to_all(t, axis_name, split_axis=0, concat_axis=0,
                           tiled=False)
    # tiled=False with split size 1: (EP, 1, C, ...) -> squeeze
    return t.reshape(shp)


def _fdn_fwd(recipe, x, row_map, T, ep_axis):
    q = quantize_rowwise(x, scale_mode=recipe.scale_mode, tag="q_entry")
    d, s = _permute_pad_fields(q.data, q.scale, row_map, recipe.use_pallas)
    d = _a2a(d, ep_axis)
    s = _a2a(s, ep_axis)
    x_recv = dequantize_exit(recipe, QTensor(d, s, q.tile))
    return x_recv, (row_map, jnp.zeros((0,), x.dtype))


def _fdn_bwd(recipe, T, ep_axis, res, g):
    row_map, wit = res
    g = _a2a(g.astype(jnp.bfloat16), ep_axis)                # BF16 backward comm
    seg = jnp.where(row_map >= 0, row_map, T)
    gx = jax.ops.segment_sum(g.astype(jnp.float32), seg, num_segments=T + 1)[:T]
    return gx.astype(wit.dtype), None


fp8_dispatch_naive.defvjp(_fdn_fwd, _fdn_bwd)


# ---------------------------------------------------------------------------
# The full MoE block (runs inside shard_map; ep_axis must be a mesh axis).
# ---------------------------------------------------------------------------
def moe_block(recipe: Recipe, cfg: MoEConfig, x, w_router, w13, w2):
    """x: (T, D) local tokens.  w13: (E_loc, D, 2F); w2: (E_loc, F, D);
    w_router: (D, E_total) replicated.  Returns (y (T, D), metrics dict).

    ep_axis=None runs the block fully locally (EP=1, every collective an
    identity) — used when the whole train step is already inside a
    data-parallel shard_map (repro.dist) and no expert axis exists."""
    T, D = x.shape
    EP = compat.axis_size(cfg.ep_axis) if cfg.ep_axis is not None else 1
    E_loc = cfg.n_experts // EP
    assert E_loc * EP == cfg.n_experts, (cfg.n_experts, EP)
    k = cfg.top_k
    C_send = _round_up(max(int(T * k / EP * cfg.capacity_factor), 8), 8)
    R = EP * C_send
    # fp8 recipes need 128-row alignment per expert group (transpose blocks
    # and MXU tiles); bf16 only needs sublane alignment.
    C_exp = _round_up(max(R // E_loc, 8), 128 if recipe.is_fp8 else 8)

    with stage_annotation("router"):
        p, ids, aux = router_topk(x, w_router, k)
        row_map_send, slot_expert, slot_assign, drop_frac = _dispatch_plan(
            ids, k, EP, E_loc, C_send)

    # ---- dispatch ----------------------------------------------------------
    with stage_annotation("dispatch"):
        if recipe.name == "fp8_flow":
            q_send = dispatch_quantize(recipe, x, row_map_send, T)
            record_entry_stats("q_entry_moe", x, scale_mode=recipe.scale_mode)
            d = _a2a(q_send.data, cfg.ep_axis)
            s = _a2a(q_send.scale, cfg.ep_axis)
            q_recv = QTensor(d, s, q_send.tile)
            recv_in = q_recv
        elif recipe.name == "naive_fp8":
            recv_in = fp8_dispatch_naive(recipe, x, row_map_send, T,
                                         cfg.ep_axis)
        else:  # bf16 / blockwise: BF16 dispatch
            x_send = _take_rows(x.astype(jnp.bfloat16), row_map_send)
            recv_in = _a2a(x_send, cfg.ep_axis)

        # metadata rides int32/f32 all-to-alls (ids are sent alongside
        # payloads; DeepEP packs them into the same message — we count their
        # bytes too)
        recv_expert = _a2a(slot_expert, cfg.ep_axis)
        p_flat = jnp.where(slot_assign >= 0,
                           p.reshape(-1)[jnp.maximum(slot_assign, 0)], 0.0)
        recv_p = _a2a(p_flat, cfg.ep_axis)

    # ---- expert grouping (fused permute+pad #2) ----------------------------
    with stage_annotation("expert"):
        row_map_exp, ret_map = _expert_plan(recv_expert, E_loc, C_exp)
        if recipe.name == "fp8_flow":
            q_exp = permute_q(recipe, recv_in, row_map_exp, ret_map)
            ffn_in = QTensor(q_exp.data.reshape(E_loc, C_exp, D),
                             q_exp.scale.reshape(E_loc, C_exp, D // TILE),
                             (1, 1, TILE))
        else:
            x_exp = _take_rows(recv_in, row_map_exp)
            ffn_in = x_exp.reshape(E_loc, C_exp, D)

        # ---- grouped expert FFN (the recipe heart) -------------------------
        masked_m = _masked_m_or_none(recipe, row_map_exp, E_loc, C_exp)
        y_exp = tag_saveable(
            expert_ffn(recipe, cfg.act, cfg.dp_axes, (), ffn_in, w13, w2,
                       masked_m),
            "stage_expert_out")

        # expert-side prob weighting (grad wrt p flows through this product)
        p_exp = _take_rows(recv_p[:, None], row_map_exp).reshape(E_loc, C_exp)
        y_exp = y_exp * p_exp[..., None].astype(y_exp.dtype)

    # ---- return + combine (BF16 by design: top-k reduction) ----------------
    with stage_annotation("combine"):
        y_ret = _take_rows(y_exp.reshape(E_loc * C_exp, D), ret_map)
        y_back = _a2a(y_ret, cfg.ep_axis)                    # (R, D) bf16
        seg = jnp.where(row_map_send >= 0, row_map_send, T)
        y = jax.ops.segment_sum(y_back.astype(jnp.float32), seg,
                                num_segments=T + 1)[:T]
    metrics = {"aux_loss": aux, "drop_frac": drop_frac}
    return y.astype(x.dtype), metrics


def moe_block_tp(recipe: Recipe, cfg: MoEConfig, x, w_router, w13, w2,
                 tp_axis: str = "model", combine_mode: str = "local_first"):
    """TP-sharded experts (n_experts < EP, e.g. grok-1's 8 experts on a
    16-wide model axis): every rank holds ALL experts with d_ff sharded.
    No dispatch all-to-all; tokens are grouped locally, each rank computes
    its F-slice, and the second GEMM's partial sums reduce over tp_axis.
    The FP8 pathway (quantize-once, direct-transpose Wgrad, fused ops) is
    unchanged — only the communication pattern differs (psum vs all-to-all).

    combine_mode (the §Perf hillclimb lever for the collective term):
      'psum_first'   paper-naive ordering: all-reduce the FULL (E, C_exp, D)
                     expert outputs, then combine locally.
      'local_first'  combine (segment-sum) the capacity-padded rows down to
                     (T, D) FIRST, then all-reduce only token rows —
                     E*C_exp/T = top_k*cf x fewer bytes on the wire.
      'reduce_scatter' local_first + psum_scatter: the output leaves seq-
                     sharded over tp_axis (Megatron-SP style), another tp x
                     fewer bytes; the caller re-gathers lazily (the residual
                     stream is SP-sharded anyway).
    """
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C_exp = _round_up(max(int(T * k / E * cfg.capacity_factor), 8),
                      128 if recipe.is_fp8 else 8)

    p, ids, aux = router_topk(x, w_router, k)
    # local grouping: assignments -> (E, C_exp) slots
    row_map, slot_expert, slot_assign, drop_frac = _dispatch_plan(
        ids, k, 1, E, E * C_exp)
    # _dispatch_plan with EP=1 gives one big group ordered by expert
    row_map_exp, ret_map = _expert_plan(slot_expert, E, C_exp)
    # compose maps: expert slot -> send slot -> token row
    tok_of_slot = jnp.where(row_map_exp >= 0,
                            row_map[jnp.maximum(row_map_exp, 0)], -1)

    if recipe.name == "fp8_flow":
        q_exp = dispatch_quantize(recipe, x, tok_of_slot, T)
        record_entry_stats("q_entry_moe", x, scale_mode=recipe.scale_mode)
        ffn_in = QTensor(q_exp.data.reshape(E, C_exp, D),
                         q_exp.scale.reshape(E, C_exp, D // TILE), (1, 1, TILE))
    else:
        ffn_in = _take_rows(x.astype(jnp.bfloat16), tok_of_slot)
        ffn_in = ffn_in.reshape(E, C_exp, D)

    masked_m = _masked_m_or_none(recipe, row_map_exp, E, C_exp)
    y_exp = tag_saveable(expert_ffn(recipe, cfg.act, cfg.dp_axes, (tp_axis,),
                                    ffn_in, w13, w2,
                                    masked_m),               # F-sliced partial
                         "stage_expert_out")
    if combine_mode == "psum_first":
        y_exp = jax.lax.psum(y_exp, tp_axis)                 # TP reduction

    p_of_slot = jnp.where(slot_assign >= 0,
                          p.reshape(-1)[jnp.maximum(slot_assign, 0)], 0.0)
    p_exp = _take_rows(p_of_slot[:, None], row_map_exp).reshape(E, C_exp)
    y_exp = y_exp * p_exp[..., None].astype(y_exp.dtype)

    seg = jnp.where(tok_of_slot >= 0, tok_of_slot, T)
    y = jax.ops.segment_sum(
        y_exp.reshape(E * C_exp, D).astype(jnp.float32), seg,
        num_segments=T + 1)[:T]
    if combine_mode == "local_first":
        y = jax.lax.psum(y.astype(jnp.bfloat16), tp_axis)
    elif combine_mode == "reduce_scatter":
        y = jax.lax.psum_scatter(y.astype(jnp.bfloat16), tp_axis,
                                 scatter_dimension=0, tiled=True)
    return y.astype(x.dtype), {"aux_loss": aux, "drop_frac": drop_frac}


# ---------------------------------------------------------------------------
# Decode-time EP MoE as a STAGED program (router -> dispatch -> expert FFN ->
# combine; the layer-stage names of models/lm.py map 1:1 onto these
# functions).  The combine is a psum over ep_axis (vLLM-style EP serving —
# no all-to-all for tiny batches), and the staged decomposition is what lets
# _decode_pipeline double-buffer it: chunk c's psum is on the wire while
# chunk c+1 runs its router/dispatch/expert stages.
# ---------------------------------------------------------------------------
def decode_stage_router(recipe: Recipe, cfg: MoEConfig, x, w_router, r,
                        E_loc: int):
    """Stage 'router' (decode, whole batch): top-k routing + the local-
    assignment map + the block's ONE entry quantize (fp8 recipes).  Routing
    the full batch here keeps aux_loss identical at any pipeline depth."""
    p, ids, aux = router_topk(x, w_router, cfg.top_k)
    local = (ids // E_loc) == r                     # (T, k) mine?
    local_e = jnp.where(local, ids % E_loc, -1).reshape(-1)   # (T*k,)
    if recipe.is_fp8:
        # W8A8 serving path: quantize activations once; weights quantized in
        # the grouped GEMM (forward-only, no backward dataflow concerns).
        # Chunks slice the QTensor — row scales are row-local, so pipeline
        # depth never re-quantizes.
        xq = quantize_rowwise(x, scale_mode=recipe.scale_mode, tag="q_entry")
    else:
        xq = x.astype(jnp.bfloat16)
    return p, aux, local_e, xq


def decode_stage_dispatch(recipe: Recipe, cfg: MoEConfig, xq, local_e_c,
                          tok0: int, E_loc: int, C_dec: int):
    """Stage 'dispatch' (decode, one chunk): expert-slot plan + the local
    gather into the (E_loc, C_dec, D) grouped layout.  Returns (ffn_in,
    row_map_exp, tok_of_slot [chunk-local], n_valid, n_kept)."""
    D = cfg.d_model
    row_map_exp, _ = _expert_plan(local_e_c, E_loc, C_dec)
    tok_loc = jnp.where(row_map_exp >= 0, row_map_exp // cfg.top_k, -1)
    tok_glob = jnp.where(tok_loc >= 0, tok_loc + tok0, -1)
    if recipe.is_fp8:
        d = _take_rows(xq.data, tok_glob)
        s = _take_rows(xq.scale, tok_glob, fill=1.0)
        ffn_in = QTensor(d.reshape(E_loc, C_dec, D),
                         s.reshape(E_loc, C_dec, D // TILE), (1, 1, TILE))
    else:
        ffn_in = _take_rows(xq, tok_glob).reshape(E_loc, C_dec, D)
    n_valid = jnp.sum((local_e_c >= 0).astype(jnp.float32))
    n_kept = jnp.sum((row_map_exp >= 0).astype(jnp.float32))
    return ffn_in, row_map_exp, tok_loc, n_valid, n_kept


def decode_stage_expert(recipe: Recipe, cfg: MoEConfig, ffn_in, w13, w2,
                        p_c, row_map_exp, tok_loc, Tc: int):
    """Stage 'expert FFN' (decode, one chunk): grouped FFN + prob weighting
    + the LOCAL half of the combine (per-token segment sum).  The returned
    (Tc, D) f32 partial still needs the cross-rank psum (stage 'combine')."""
    D = cfg.d_model
    grouped = ffn_in.data if isinstance(ffn_in, QTensor) else ffn_in
    E_loc, C_dec = grouped.shape[0], grouped.shape[1]
    masked_m = _masked_m_or_none(recipe, row_map_exp, E_loc, C_dec)
    y_exp = expert_ffn(recipe, cfg.act, (), (), ffn_in, w13, w2, masked_m)
    p_of_slot = jnp.where(
        row_map_exp >= 0,
        p_c.reshape(-1)[jnp.maximum(row_map_exp, 0)], 0.0)
    y_exp = y_exp * p_of_slot.reshape(E_loc, C_dec)[..., None].astype(
        y_exp.dtype)
    seg = jnp.where(tok_loc >= 0, tok_loc, Tc)
    return jax.ops.segment_sum(
        y_exp.reshape(E_loc * C_dec, D).astype(jnp.float32), seg,
        num_segments=Tc + 1)[:Tc]


def _decode_pipeline(recipe: Recipe, cfg: MoEConfig, x, w_router, w13, w2,
                     n_chunks: int):
    """The staged decode program at pipeline depth n_chunks: chunk c-1's
    combine psum is ISSUED before chunk c's dispatch/expert stages are
    traced, so the collective is on the wire while the independent FFN
    compute runs (decode tokens never interact below the combine — chunking
    the batch is exact, modulo per-chunk capacity C_dec under overflow)."""
    T, D = x.shape
    EP = compat.axis_size(cfg.ep_axis)
    E_loc = cfg.n_experts // EP
    r = jax.lax.axis_index(cfg.ep_axis)
    k = cfg.top_k
    # divisor-of-T clamping lives in ONE place (DispatchPlan), same as the
    # train-side moe_block_overlapped
    n = DispatchPlan(decode_chunks=n_chunks,
                     min_decode_tokens=1).decode_chunks_for(T)
    Tc = T // n
    C_dec = _round_up(max(int(2.0 * Tc * k / cfg.n_experts), 8), 8)

    with stage_annotation("router"):
        p, aux, local_e, xq = decode_stage_router(recipe, cfg, x, w_router,
                                                  r, E_loc)

    def partial(c):
        le = jax.lax.slice_in_dim(local_e, c * Tc * k, (c + 1) * Tc * k)
        with stage_annotation("dispatch"):
            ffn_in, rme, tok_loc, nv, nk = decode_stage_dispatch(
                recipe, cfg, xq, le, c * Tc, E_loc, C_dec)
        pc = jax.lax.slice_in_dim(p, c * Tc, (c + 1) * Tc)
        with stage_annotation("expert"):
            y_loc = decode_stage_expert(recipe, cfg, ffn_in, w13, w2, pc,
                                        rme, tok_loc, Tc)
        return y_loc, nv - nk

    ys = []
    pend_y, drops = partial(0)
    for c in range(1, n):
        # stage 'combine' of chunk c-1 rides the wire while chunk c's
        # dispatch + expert stages (traced next, independent of it) compute
        with stage_annotation("combine"):
            y_prev = jax.lax.psum(pend_y, cfg.ep_axis)
        pend_y, d_c = partial(c)
        ys.append(y_prev)
        drops = drops + d_c
    with stage_annotation("combine"):
        ys.append(jax.lax.psum(pend_y, cfg.ep_axis))
    # real drop accounting: each assignment is local to exactly one rank, so
    # the ones that did not get an expert slot (C_dec overflow) are the
    # drops; summed over the EP group against the global count T*k.
    drop_frac = jax.lax.psum(drops, cfg.ep_axis) / (T * k)
    y = jnp.concatenate(ys, axis=0) if n > 1 else ys[0]
    return y.astype(x.dtype), {"aux_loss": aux, "drop_frac": drop_frac}


def moe_block_decode(recipe: Recipe, cfg: MoEConfig, x, w_router, w13, w2):
    """Decode-time EP MoE: the token batch is small (<= a few hundred) and
    REPLICATED across the ep_axis; each rank computes only its resident
    experts' tokens and the combine is a psum over ep_axis (vLLM-style EP
    serving — no all-to-all for tiny batches).  Forward-only (serving).
    Single synchronous combine (= the staged pipeline at depth 1)."""
    return _decode_pipeline(recipe, cfg, x, w_router, w13, w2, n_chunks=1)


def moe_block_decode_overlapped(recipe: Recipe, cfg: MoEConfig, x, w_router,
                                w13, w2, n_chunks: int = 2):
    """Prefetching decode MoE: the staged pipeline at depth n_chunks — the
    next chunk's router output is consumed (dispatch gather + expert FFN)
    while the previous chunk's combine psum is in flight, converting the
    block's synchronous psum into a double-buffered chain.  Per-token math
    is identical to moe_block_decode when no capacity drops occur (C_dec is
    per-chunk, so drop SETS can differ under overflow, and the chunk-sized
    grouped-GEMM shape can wobble the bf16 output by 1 ulp)."""
    return _decode_pipeline(recipe, cfg, x, w_router, w13, w2,
                            n_chunks=n_chunks)


# ---------------------------------------------------------------------------
# Overlapped EP dispatch: chunked all-to-all / expert-FFN pipeline.
#
# The synchronous moe_block exposes its entire dispatch+combine communication
# on the critical path of every MoE layer.  moe_block_overlapped splits the
# token block into n_chunks micro-chunks and software-pipelines them: chunk
# i's dispatch all-to-all is issued BEFORE chunk i-1's grouped expert FFN, so
# XLA's latency-hiding scheduler can run the collective concurrently with the
# independent FFN compute (rtp-llm DeepEPLowLatencyRouter-style double
# buffering, mapped onto shard_map + lax collectives).
#
# Two further changes vs the synchronous block:
#   * the FP8 payload, its po2 scales, AND the routing metadata (local expert
#     ids + router probs) are PACKED INTO ONE uint8 message per chunk, so the
#     per-chunk dispatch costs 1 collective launch instead of 3;
#   * quantization stays block-level: ONE entry quantize over the full token
#     block (chunks slice the QTensor — row-tile scales are row-local, so no
#     chunk boundary ever re-quantizes) and ONE backward island quantize over
#     the full FFN-output cotangent.  The Fig.-2 cast count is therefore
#     unchanged: still 2 explicit casts for fp8_flow at any n_chunks.
#
# Numerics match moe_block up to f32 accumulation order PROVIDED no capacity
# drops occur (capacities C_send/C_exp are per-chunk, so drop SETS can differ
# between the chunked and monolithic blocks under overflow).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Static overlap configuration threaded through models/lm.py.

    n_chunks          pipeline depth per MoE layer (1 = fused-message only)
    min_chunk_tokens  never chunk below this many local tokens per chunk
                      (tiny chunks waste collective latency on padding)
    decode_chunks     pipeline depth for the decode-path EP MoE (the psum
                      chain of moe_block_decode_overlapped); 1 keeps the
                      synchronous combine
    min_decode_tokens decode batches are small — don't pipeline below this
    """
    n_chunks: int = 2
    min_chunk_tokens: int = 64
    decode_chunks: int = 2
    min_decode_tokens: int = 8

    def chunks_for(self, T: int) -> int:
        cap = max(1, min(self.n_chunks, T // max(self.min_chunk_tokens, 1)))
        return max(d for d in range(1, cap + 1) if T % d == 0)

    def decode_chunks_for(self, T: int) -> int:
        cap = max(1, min(self.decode_chunks,
                         T // max(self.min_decode_tokens, 1)))
        return max(d for d in range(1, cap + 1) if T % d == 0)


def _u8(x):
    """Bitcast to uint8 and flatten the trailing byte axis: (R, ...) -> (R, w)."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint8)
    return u.reshape(x.shape[0], -1)


def _pack_dispatch_msg(d, s, se, pf):
    """Fuse one chunk's dispatch into a single uint8 message:
    d (R, D) e4m3 payload | s (R, D/TILE) f32 scales | se (R,) i32 local
    expert ids | pf (R,) f32 router probs.  Width D + 4*D/TILE + 8 bytes."""
    return jnp.concatenate(
        [_u8(d), _u8(s), _u8(se[:, None]), _u8(pf[:, None])], axis=1)


def _unpack_dispatch_msg(msg, D: int):
    R = msg.shape[0]
    Ds = D // TILE
    d = jax.lax.bitcast_convert_type(msg[:, :D], jnp.float8_e4m3fn)
    o = D
    s = jax.lax.bitcast_convert_type(
        msg[:, o:o + 4 * Ds].reshape(R, Ds, 4), jnp.float32)
    o += 4 * Ds
    se = jax.lax.bitcast_convert_type(
        msg[:, o:o + 4].reshape(R, 1, 4), jnp.int32)[:, 0]
    pf = jax.lax.bitcast_convert_type(
        msg[:, o + 4:o + 8].reshape(R, 1, 4), jnp.float32)[:, 0]
    return d, s, se, pf


def _pack_bwd_msg(gd, gs, gp):
    """Backward fused message: FP8 input-gradient payload + scales + the
    per-row router-prob gradient ride ONE reverse collective."""
    return jnp.concatenate([_u8(gd), _u8(gs), _u8(gp[:, None])], axis=1)


def _unpack_bwd_msg(msg, D: int):
    R = msg.shape[0]
    Ds = D // TILE
    gd = jax.lax.bitcast_convert_type(msg[:, :D], jnp.float8_e4m3fn)
    gs = jax.lax.bitcast_convert_type(
        msg[:, D:D + 4 * Ds].reshape(R, Ds, 4), jnp.float32)
    gp = jax.lax.bitcast_convert_type(
        msg[:, D + 4 * Ds:].reshape(R, 1, 4), jnp.float32)[:, 0]
    return gd, gs, gp


def _chunk_geometry(recipe, cfg, T: int, n: int, EP: int, E_loc: int):
    Tc = T // n
    k = cfg.top_k
    C_send = _round_up(max(int(Tc * k / EP * cfg.capacity_factor), 8), 8)
    R = EP * C_send
    C_exp = _round_up(max(R // E_loc, 8), 128 if recipe.is_fp8 else 8)
    return Tc, C_send, R, C_exp


def moe_block_overlapped(recipe: Recipe, cfg: MoEConfig, x, w_router, w13, w2,
                         n_chunks: int = 2):
    """Drop-in replacement for moe_block with the chunked/overlapped dispatch
    pipeline.  Same signature + returns, plus the static n_chunks knob
    (clamped to a divisor of the local token count)."""
    T, D = x.shape
    n = DispatchPlan(n_chunks=n_chunks, min_chunk_tokens=1).chunks_for(T)
    p, ids, aux = router_topk(x, w_router, cfg.top_k)
    if recipe.name == "fp8_flow":
        record_entry_stats("q_entry_moe", x, scale_mode=recipe.scale_mode)
        y, drop = _overlap_core_flow(recipe, cfg, n, x, p, ids, w13, w2)
    else:
        y, drop = _overlap_chunks_autodiff(recipe, cfg, n, x, p, ids, w13, w2)
    return y, {"aux_loss": aux, "drop_frac": drop}


def _overlap_chunks_autodiff(recipe, cfg, n, x, p, ids, w13, w2):
    """bf16 / blockwise / naive_fp8: chunked pipeline built from the existing
    autodiff'd primitives.  Chunks are issued back-to-back so independent
    chunks can overlap, but each keeps its recipe's Q/DQ-at-the-boundary
    structure (the fused-message + hoisted-cast pipeline is fp8_flow-only:
    for the baselines, per-chunk casts ARE the cost the paper counts)."""
    T, D = x.shape
    EP = compat.axis_size(cfg.ep_axis)
    E_loc = cfg.n_experts // EP
    k = cfg.top_k
    Tc, C_send, R, C_exp = _chunk_geometry(recipe, cfg, T, n, EP, E_loc)
    ys, drops = [], []
    for c in range(n):
        xc = jax.lax.slice_in_dim(x, c * Tc, (c + 1) * Tc)
        pc = jax.lax.slice_in_dim(p, c * Tc, (c + 1) * Tc)
        idc = jax.lax.slice_in_dim(ids, c * Tc, (c + 1) * Tc)
        rms, se, sa, dc = _dispatch_plan(idc, k, EP, E_loc, C_send)
        if recipe.name == "naive_fp8":
            recv_in = fp8_dispatch_naive(recipe, xc, rms, Tc, cfg.ep_axis)
        else:
            recv_in = _a2a(_take_rows(xc.astype(jnp.bfloat16), rms),
                           cfg.ep_axis)
        recv_expert = _a2a(se, cfg.ep_axis)
        pf = jnp.where(sa >= 0, pc.reshape(-1)[jnp.maximum(sa, 0)], 0.0)
        recv_p = _a2a(pf, cfg.ep_axis)
        rme, ret = _expert_plan(recv_expert, E_loc, C_exp)
        x_exp = _take_rows(recv_in, rme).reshape(E_loc, C_exp, D)
        y_exp = tag_saveable(
            expert_ffn(recipe, cfg.act, cfg.dp_axes, (), x_exp, w13, w2),
            "stage_expert_out")
        p_exp = _take_rows(recv_p[:, None], rme).reshape(E_loc, C_exp)
        y_exp = y_exp * p_exp[..., None].astype(y_exp.dtype)
        y_ret = _take_rows(y_exp.reshape(E_loc * C_exp, D), ret)
        y_back = _a2a(y_ret, cfg.ep_axis)
        seg = jnp.where(rms >= 0, rms, Tc)
        ys.append(jax.ops.segment_sum(y_back.astype(jnp.float32), seg,
                                      num_segments=Tc + 1)[:Tc])
        drops.append(dc)
    y = jnp.concatenate(ys, axis=0).astype(x.dtype)
    return y, jnp.mean(jnp.stack(drops))


def _flow_fwd_impl(recipe, cfg, n, x, p, ids, w13, w2):
    T, D = x.shape
    EP = compat.axis_size(cfg.ep_axis)
    E_loc = cfg.n_experts // EP
    assert E_loc * EP == cfg.n_experts, (cfg.n_experts, EP)
    k = cfg.top_k
    Tc, C_send, R, C_exp = _chunk_geometry(recipe, cfg, T, n, EP, E_loc)

    qw13, qw2 = _quant_weights(recipe, w13, w2)

    # ONE entry quantize for the WHOLE block (the counted forward cast);
    # chunks slice the QTensor — row-tile scales are row-local, so chunk
    # boundaries never re-quantize.
    q = quantize_rowwise(x, scale_mode=recipe.scale_mode, tag="q_entry")

    plans = [_dispatch_plan(jax.lax.slice_in_dim(ids, c * Tc, (c + 1) * Tc),
                            k, EP, E_loc, C_send) for c in range(n)]

    def issue_dispatch(c):
        rms, se, sa, _ = plans[c]
        gmap = jnp.where(rms >= 0, rms + c * Tc, -1)
        d, s = _permute_pad_fields(q.data, q.scale, gmap, recipe.use_pallas)
        pc = jax.lax.slice_in_dim(p, c * Tc, (c + 1) * Tc)
        pf = jnp.where(sa >= 0, pc.reshape(-1)[jnp.maximum(sa, 0)], 0.0)
        return _a2a(_pack_dispatch_msg(d, s, se, pf), cfg.ep_axis)

    recv = issue_dispatch(0)
    ys, saved = [], []
    for c in range(n):
        # double buffer: chunk c+1's fused dispatch is ON THE WIRE while
        # chunk c runs its grouped FFN + combine below
        nxt = issue_dispatch(c + 1) if c + 1 < n else None
        d_r, s_r, e_r, p_r = _unpack_dispatch_msg(recv, D)
        rme, ret = _expert_plan(e_r, E_loc, C_exp)
        d_e, s_e = _permute_pad_fields(d_r, s_r, rme, recipe.use_pallas)
        qx_c = QTensor(d_e.reshape(E_loc, C_exp, D),
                       s_e.reshape(E_loc, C_exp, D // TILE), (1, 1, TILE))
        mm_c = _masked_m_or_none(recipe, rme, E_loc, C_exp)
        y_exp, (qx_c, qa_c, h_c) = ffn_fwd_fp8_core(recipe, cfg.act, qx_c,
                                                    qw13, qw2, mm_c)
        y_exp = tag_saveable(y_exp, "stage_expert_out")
        p_exp = _take_rows(p_r[:, None], rme).reshape(E_loc, C_exp)
        y_w = y_exp * p_exp[..., None].astype(y_exp.dtype)
        y_ret = _take_rows(y_w.reshape(E_loc * C_exp, D), ret)
        y_back = _a2a(y_ret, cfg.ep_axis)        # overlaps chunk c+1's FFN
        rms = plans[c][0]
        seg = jnp.where(rms >= 0, rms, Tc)
        ys.append(jax.ops.segment_sum(y_back.astype(jnp.float32), seg,
                                      num_segments=Tc + 1)[:Tc])
        saved.append((rms, plans[c][2], rme, ret, qx_c, qa_c, h_c, p_exp,
                      y_exp, mm_c))
        recv = nxt
    y = jnp.concatenate(ys, axis=0).astype(x.dtype)
    drop = jnp.mean(jnp.stack([pl[3] for pl in plans]))
    wit = (jnp.zeros((0,), x.dtype), jnp.zeros((0,), w13.dtype),
           jnp.zeros((0,), w2.dtype))
    return (y, drop), (tuple(saved), qw13, qw2, wit)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _overlap_core_flow(recipe: Recipe, cfg: MoEConfig, n: int, x, p, ids,
                       w13, w2):
    """fp8_flow overlapped core with a HAND-WRITTEN backward pipeline that
    mirrors the forward: chunked reverse combine (bf16), ONE hoisted island
    quantize, then per-chunk FFN backward with the FP8 input-gradient +
    prob-gradient riding one fused reverse collective per chunk."""
    (y, drop), _ = _flow_fwd_impl(recipe, cfg, n, x, p, ids, w13, w2)
    return y, drop


def _ocf_fwd(recipe, cfg, n, x, p, ids, w13, w2):
    return _flow_fwd_impl(recipe, cfg, n, x, p, ids, w13, w2)


def _ocf_bwd(recipe, cfg, n, res, ct):
    g_y, _g_drop = ct
    saved, qw13, qw2, (wx, wit13, wit2) = res
    T, D = g_y.shape
    Tc = T // n
    k = cfg.top_k
    E_loc, C_exp, _ = saved[0][4].data.shape
    S = E_loc * C_exp

    # ---- stage 1: per-chunk reverse combine (bf16 collectives pipeline) ----
    g_yexp, g_pexp = [], []
    for c in range(n):
        rms, sa, rme, ret, qx_c, qa_c, h_c, p_exp, y_exp, mm_c = saved[c]
        g_c = jax.lax.slice_in_dim(g_y, c * Tc, (c + 1) * Tc)
        g_back = _take_rows(g_c.astype(jnp.float32), rms)     # (R, D)
        g_ret = _a2a(g_back.astype(jnp.bfloat16), cfg.ep_axis)
        g_yw = _take_rows(g_ret, rme).reshape(E_loc, C_exp, D)
        g_yexp.append(g_yw * p_exp[..., None].astype(g_yw.dtype))
        g_pexp.append(jnp.sum(g_yw.astype(jnp.float32)
                              * y_exp.astype(jnp.float32), axis=-1))

    # ---- the ONE explicit backward cast (BF16 island -> FP8), hoisted out
    # of the chunk loop: quantize(concat) == concat(quantize) for row tiles,
    # so no chunk boundary re-quantizes and the Fig.-2 count stays at 2.
    qg_all = _q_row(recipe, jnp.concatenate(g_yexp, axis=1), "q_bwd_island")

    # ---- stage 2: per-chunk FFN backward + fused reverse dispatch,
    # software-pipelined (chunk c's reverse a2a flies while chunk c+1's FFN
    # backward computes; its unpack + segment-sums happen one step later).
    wg13 = jnp.zeros((), jnp.float32)
    wg2 = jnp.zeros((), jnp.float32)
    gx_chunks = [None] * n
    gp_chunks = [None] * n

    def land(c, msg):
        rms, sa = saved[c][0], saved[c][1]
        gd, gs, gp = _unpack_bwd_msg(msg, D)
        casts.record("fused_dequantize", "dispatch_bwd", gd.size)
        g_rows = _dequantize_nocount(QTensor(gd, gs, (1, TILE)), jnp.bfloat16)
        seg = jnp.where(rms >= 0, rms, Tc)
        gx_chunks[c] = jax.ops.segment_sum(
            g_rows.astype(jnp.float32), seg,
            num_segments=Tc + 1)[:Tc].astype(wx.dtype)
        segp = jnp.where(sa >= 0, sa, Tc * k)
        gp_chunks[c] = jax.ops.segment_sum(
            gp.astype(jnp.float32), segp,
            num_segments=Tc * k + 1)[:Tc * k].reshape(Tc, k)

    pending = None
    for c in range(n):
        rms, sa, rme, ret, qx_c, qa_c, h_c, p_exp, y_exp, mm_c = saved[c]
        qg_c = QTensor(
            jax.lax.slice_in_dim(qg_all.data, c * C_exp, (c + 1) * C_exp,
                                 axis=1),
            jax.lax.slice_in_dim(qg_all.scale, c * C_exp, (c + 1) * C_exp,
                                 axis=1), qg_all.tile)
        gxq, wg13_c, wg2_c = ffn_bwd_fp8_core(recipe, cfg.act, (), qx_c, qa_c,
                                              h_c, qw13, qw2, qg_c, mm_c)
        wg13 = wg13 + wg13_c
        wg2 = wg2 + wg2_c
        # inverse expert-grouping permute (FP8-exact), then ONE fused reverse
        # collective: e4m3 payload + po2 scales + router-prob grads together
        gd, gs = _permute_pad_fields(gxq.data.reshape(S, D),
                                     gxq.scale.reshape(S, D // TILE), ret,
                                     recipe.use_pallas)
        gp_r = _take_rows(g_pexp[c].reshape(S, 1), ret)[:, 0]
        msg = _a2a(_pack_bwd_msg(gd, gs, gp_r), cfg.ep_axis)
        if pending is not None:
            land(*pending)
        pending = (c, msg)
    land(*pending)

    g_x = jnp.concatenate(gx_chunks, axis=0)
    g_p = jnp.concatenate(gp_chunks, axis=0)
    wg_axes = cfg.dp_axes
    if wg_axes:
        wg13 = jax.lax.psum(wg13, wg_axes)
        wg2 = jax.lax.psum(wg2, wg_axes)
    return (g_x, g_p, None, wg13.astype(wit13.dtype), wg2.astype(wit2.dtype))


_overlap_core_flow.defvjp(_ocf_fwd, _ocf_bwd)
