"""Cast-operation accounting (paper Fig. 2: 12 casts -> 2).

A *cast* is an explicit, HBM-materialized quantize or dequantize of an
activation-path tensor.  The ledger records each call at trace time, so
tracing ``jax.grad(step)`` under an active ledger counts the casts of one
forward+backward pass — exactly the quantity Fig. 2 tallies per recipe.

Weight quantization is tagged separately (``q_w*``): the paper's count covers
the activation dataflow (weights are quantized once per step regardless of
recipe, and cached), so ``activation_casts()`` excludes weight tags while
``total()`` includes everything.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import List, Optional

_LEDGER: contextvars.ContextVar[Optional["CastLedger"]] = contextvars.ContextVar(
    "cast_ledger", default=None)


@dataclasses.dataclass
class CastEvent:
    kind: str   # 'quantize' | 'dequantize'
    tag: str
    numel: int


class CastLedger:
    def __init__(self):
        self.events: List[CastEvent] = []

    def activation_casts(self) -> int:
        """Explicit Q/DQ ops on the activation path (the Fig. 2 tally).

        Excludes weight quantization (``q_w*`` tags: once per step, cached,
        identical across recipes) and fused casts (``fused_*`` kinds: quantize/
        dequantize folded into a surrounding compute kernel's epilogue/prologue
        — no standalone HBM round trip, so the paper does not count them)."""
        return sum(1 for e in self.events
                   if e.kind in ("quantize", "dequantize")
                   and not e.tag.startswith("q_w"))

    def fused_casts(self) -> int:
        return sum(1 for e in self.events if e.kind.startswith("fused_"))

    def total(self) -> int:
        return len(self.events)

    def by_tag(self):
        out = {}
        for e in self.events:
            key = (e.kind, e.tag)
            out[key] = out.get(key, 0) + 1
        return out

    def summary(self) -> str:
        lines = [f"  {kind:<10s} {tag:<18s} x{n}" for (kind, tag), n in sorted(self.by_tag().items())]
        return "\n".join(lines) or "  (none)"


def record(kind: str, tag: str, numel: int) -> None:
    led = _LEDGER.get()
    if led is not None:
        led.events.append(CastEvent(kind, tag, int(numel)))


@contextlib.contextmanager
def ledger():
    led = CastLedger()
    tok = _LEDGER.set(led)
    try:
        yield led
    finally:
        _LEDGER.reset(tok)
