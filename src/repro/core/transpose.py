"""Scaling-aware FP8 transpose (paper §3.1, Algorithm 1).

Converts a row-wise quantized QTensor (tiles (1,128) along the contraction
axis) into the column-wise layout needed by Wgrad — WITHOUT dequantizing or
requantizing, hence without double quantization error.

Mechanism (requires power-of-two scales):
  per 128x128 block,  s_max = max of the 128 row scales in the block,
  every element is re-based onto s_max by subtracting
  k = log2(s_max / s_row) from its e4m3 exponent.  Because both scales are
  powers of two the mantissa is untouched: the dequantized VALUE is bit-exact,
  except when the re-based encoding underflows below the e4m3 subnormal grid —
  exactly the elements a correct requantization at scale s_max would also
  flush.  The transposed output carries one scale (s_max) per (row-tile,
  block) — coarser than fresh requantization but exact.

This module is the XLA-path implementation: multiply-by-2^(-k) in f32 and a
saturating cast, which is bit-identical to the exponent-bit manipulation
(property-tested against the Pallas bit-twiddle kernel in
``kernels/fp8_transpose.py``).

``transpose_naive`` is the baseline the paper replaces:
dequantize -> transpose -> requantize (fresh scales) — with 'linear' scales it
exhibits the double quantization error of Eq. (1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import casts
from repro.core.fp8 import BLOCK, E4M3, FMT_MAX, TILE
from repro.core.quant import QTensor, dequantize, quantize_rowwise


def _check_rowwise_2d(q: QTensor):
    if q.ndim < 2 or q.tile[-1] != TILE or any(t != 1 for t in q.tile[:-1]):
        raise ValueError(f"expected row-wise tiles (...,1,{TILE}), got {q.tile}")
    M, K = q.shape[-2:]
    if M % BLOCK or K % BLOCK:
        raise ValueError(f"dims ({M},{K}) must be multiples of {BLOCK}")


def transpose_direct(q: QTensor) -> QTensor:
    """(..., M, K) row-wise -> (..., K, M) row-wise, scales block-aligned.

    Counted as zero casts on the ledger — this is the point of the operator.
    """
    _check_rowwise_2d(q)
    # NOTE: deliberately no casts.record(...) here — the operator is casting-free.
    *lead, M, K = q.shape
    nb_m, nb_k = M // BLOCK, K // BLOCK

    # scales: (..., M, K/T) -> blocks (..., nb_m, BLOCK, nb_k)
    s = q.scale.reshape(*lead, nb_m, BLOCK, nb_k)
    s_max = jnp.max(s, axis=-2)                              # (..., nb_m, nb_k)
    ratio = s / s_max[..., None, :]                          # po2, <= 1

    # payload: (..., M, K) -> (..., nb_m, BLOCK, nb_k, BLOCK)
    x = q.data.reshape(*lead, nb_m, BLOCK, nb_k, BLOCK).astype(jnp.float32)
    # multiply by the po2 ratio: mantissa preserved, exponent shifted.
    x = x * ratio[..., :, :, None]
    fmax = FMT_MAX[q.dtype if q.dtype in FMT_MAX else E4M3]
    x = jnp.clip(x, -fmax, fmax).astype(q.dtype)

    # transpose blocks and within blocks: out[k, m] = x[m, k]
    nd = x.ndim
    perm = tuple(range(nd - 4)) + (nd - 2, nd - 1, nd - 4, nd - 3)
    xt = jnp.transpose(x, perm).reshape(*lead, K, M)

    # out scale: one per (output row, block of 128 output cols) = s_max[bm, bk]
    # broadcast s_max (..., nb_m, nb_k) -> (..., K, nb_m)
    s_out = jnp.transpose(s_max, tuple(range(s_max.ndim - 2)) + (s_max.ndim - 1, s_max.ndim - 2))
    s_out = jnp.repeat(s_out, BLOCK, axis=-2)                # (..., K, nb_m)
    tile = (1,) * len(lead) + (1, TILE)
    return QTensor(data=xt, scale=s_out, tile=tile)


def transpose_naive(q: QTensor, scale_mode: str = "po2") -> QTensor:
    """Baseline: dequantize -> transpose -> requantize (2 counted casts)."""
    xf = dequantize(q, jnp.float32, tag="dq_transpose")
    xt = jnp.swapaxes(xf, -1, -2)
    return quantize_rowwise(xt, fmt=q.dtype, scale_mode=scale_mode, tag="q_transpose")


def double_quant_error(x: jax.Array, scale_mode: str = "linear") -> jax.Array:
    """Paper Eq. (1): E = Q_col(D(Q_row(X))) - Q_col(X), dequantized to f32.

    With scale_mode='linear' (conventional recipe) this is generically nonzero;
    with 'po2' scales the rounding grid is preserved and E vanishes except for
    subnormal-underflow elements.
    """
    from repro.core.quant import quantize_colwise, _dequantize_nocount
    q_row = quantize_rowwise(x, scale_mode=scale_mode, tag="q_err_row")
    x_rt = dequantize(q_row, jnp.float32, tag="dq_err")
    q_col_rt = quantize_colwise(x_rt, scale_mode=scale_mode, tag="q_err_col_rt")
    q_col = quantize_colwise(x, scale_mode=scale_mode, tag="q_err_col")
    return _dequantize_nocount(q_col_rt) - _dequantize_nocount(q_col)
