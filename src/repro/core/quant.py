"""Tile-quantized FP8 tensors (QTensor) and the quantize/dequantize ops.

Quantization follows the paper (Eq. 2): per-tile scaling over 128 contiguous
elements, scale = po2(ceil)(amax / 448) by default (power-of-two scales are the
enabler for the scaling-aware transpose, §3.1).  ``scale_mode='linear'``
reproduces the conventional TE/DeepSeek recipe (s = amax/448, arbitrary float)
used as the double-quantization-error baseline.

A ``QTensor`` carries:
  data  : fp8 payload (e4m3 by default)
  scale : f32 power-of-two scales, one per tile; shape[i] = data.shape[i]/tile[i]
  tile  : static per-axis tile sizes, e.g. (1, 128) row-wise, (128, 128) weights

Tile-metadata convention (normative — every producer and consumer in the
repo follows it, and ``tests/test_kernels.py`` asserts it on the kernel
wrappers):

  * ``len(tile) == data.ndim`` always.  Leading batch/expert axes get
    explicit 1s — e.g. a (E, C, K) row-tiled activation is ``(1, 1, TILE)``,
    never a 2-tuple broadcast against a 3-D payload.
  * Row-wise tiles are ``(1,) * (ndim - 1) + (TILE,)`` — use ``row_tile``.
  * Weight blocks are ``(1,) * (ndim - 2) + (TILE, TILE)``.
  * ``scale.shape[i] * tile[i] == data.shape[i]`` for every axis
    (``_scale_shape`` enforces divisibility at quantize time).

Every quantize/dequantize call is recorded on the active CastLedger (see
``casts.py``) — this is how the 12-vs-2 cast accounting of Fig. 2 is asserted.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import casts
from repro.core.fp8 import E4M3, FMT_MAX, TILE, po2_scale


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    data: jax.Array
    scale: jax.Array
    tile: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def dtype(self):
        return self.data.dtype

    def nbytes_model(self) -> int:
        """Bytes this tensor occupies (payload + scales) — used by memory bench."""
        return self.data.size * 1 + self.scale.size * 4


# ---------------------------------------------------------------------------
# checkpoint_name tags for the activation-residency plan (train/memory.py).
# The MemoryPlan policies select saveables BY NAME (jax.checkpoint_policies.
# save_only_these_names): 'full' keeps the BF16 stage boundaries, while
# 'fp8_resident' keeps only the QTensor stage outputs — so the backward
# recomputes from e4m3+scale instead of wide bf16 activations.
# ---------------------------------------------------------------------------
FP8_SAVE_NAMES = ("fp8_qx_data", "fp8_qx_scale",      # dispatched FFN input
                  "fp8_qa_data", "fp8_qa_scale")      # post-activation GEMM2 in
BF16_STAGE_NAMES = ("stage_attn_out",                 # attn residual-out
                    "stage_ffn_in",                   # post-ln2 FFN input
                    "stage_ffn_h",                    # the bf16 island h
                    "stage_expert_out")               # expert out (combine in)


def tag_saveable(x, name: str):
    """Name a tensor for the residency policies (value-identity; None passes).

    bf16 tensors are pinned with an explicit reduce_precision(8, 7) first:
    XLA keeps excess precision through bf16 fusions, so without the pin a
    policy that SAVES the tensor (materializing real bf16) would compute
    slightly different bits than one that recomputes it — the pin makes
    every residency policy evaluate the identical function (jax inserts the
    same op on saved-residual producers; see jax#22244)."""
    if x is None:
        return None
    from jax.ad_checkpoint import checkpoint_name
    if x.dtype == jnp.bfloat16:
        x = jax.lax.reduce_precision(x, 8, 7)
    return checkpoint_name(x, name)


def tag_qtensor(q: "QTensor", name: str) -> "QTensor":
    """Tag a QTensor's payload + scales as '<name>_data' / '<name>_scale'.

    The fp8 payload is tagged AS ITS uint8 BIT PATTERN (the same bitcast
    idiom as the fused wire messages): jax's remat inserts
    reduce_precision(finfo(dtype)) on saved-residual producers, which is
    ill-defined for the no-inf e4m3fn format (overflow lanes turn NaN under
    XLA fusion) — integer residuals skip that machinery and the bits are
    the value anyway.  Only the fwd rules of the FFN/dispatch custom_vjps
    call this, so autodiff never differentiates through the bitcast."""
    u8 = jax.lax.bitcast_convert_type(q.data, jnp.uint8)
    u8 = tag_saveable(u8, f"{name}_data")
    data = jax.lax.bitcast_convert_type(u8, q.data.dtype)
    return QTensor(data, tag_saveable(q.scale, f"{name}_scale"), q.tile)


# ---------------------------------------------------------------------------
# FP8 health stats for the numerics guardrails (train/guards.py).
#
# When a collector is armed (train_step traces under `collect_stats()`),
# the instrumented sites record a (N_SITES, 2) f32 matrix — their row is
# [saturation fraction, underflow-flush fraction] of their tensor, every
# other row zero; max-merge keeps per-site resolution.  The recorded
# values are
# TRACERS, and recording must happen in a trace region that can hand them
# back out: any enclosing lax.scan body / jax.checkpoint block / shard_map
# body drains its own records into an explicit output before returning
# (`drain_stats` + `reinject_stats` at the outer level) — models/lm.py
# threads them through every stack driver and both MLP/MoE shard_maps.
#
# Crucially, recording must sit OUTSIDE any custom_vjp: fwd/bwd rules are
# traced to their own jaxprs, so a record made inside one is a foreign
# tracer by the time the surrounding region drains (UnexpectedTracerError).
# quantize() itself (which runs inside the entry/FFN custom_vjps) therefore
# never records; the entry sites record via `record_entry_stats` at their
# CALL sites, and the gradient wire records inside grad_comm's
# quantize_bucket (plain code in the train-step body).  The backward-island
# quantizes (q_bwd_*) are covered by the grad-norm/nonfinite guards one
# reduction later instead.  With no collector armed this machinery adds
# ZERO ops — the default jaxpr is bitwise-unchanged.
# ---------------------------------------------------------------------------
STATS_LEN = 2                      # [sat_frac, flush_frac], max-merged
# Instrumented quantize sites, one row of the collected matrix each.  The
# collected value is a (N_SITES, STATS_LEN) f32 matrix — PR 7 carried a
# single max-merged (2,) vector; the per-site rows ride the SAME carries
# (every drain/reinject threading point is shape-generic), so site
# resolution costs no extra threading and no extra host syncs.  The scalar
# guard thresholds keep their old meaning as the max over sites, while the
# obs layer exports the full matrix as a per-site time series (the input
# the ROADMAP's guard-driven adaptive precision controller needs).
STAT_SITES = ("q_entry_mlp",       # dense-MLP / shared-expert entry quantize
              "q_entry_moe",       # MoE dispatch entry quantize
              "dp_wire")           # DP gradient-wire bucket quantize
N_SITES = len(STAT_SITES)
_SITE_ROW = {t: i for i, t in enumerate(STAT_SITES)}
_SITE_ROW["q_entry"] = 0           # legacy alias (pre-split call sites)
STATS_TAGS = frozenset(_SITE_ROW)

_QSTATS: contextvars.ContextVar[Optional["QuantStatsCollector"]] = \
    contextvars.ContextVar("quant_stats", default=None)


class QuantStatsCollector:
    def __init__(self):
        self.vals: List[jax.Array] = []


def stats_armed() -> bool:
    return _QSTATS.get() is not None


def zero_stats() -> jax.Array:
    return jnp.zeros((N_SITES, STATS_LEN), jnp.float32)


def site_maxima(stats: jax.Array) -> jax.Array:
    """(N_SITES, STATS_LEN) -> (STATS_LEN,) max over sites — the scalar
    [sat_frac, flush_frac] pair the guard thresholds compare against
    (identical to the pre-per-site collector's merged value)."""
    return jnp.max(jnp.asarray(stats, jnp.float32), axis=0)


def record_stat_pair(tag: str, sat_frac, flush_frac) -> None:
    col = _QSTATS.get()
    if col is not None:
        pair = jnp.stack([jnp.asarray(sat_frac, jnp.float32),
                          jnp.asarray(flush_frac, jnp.float32)])
        col.vals.append(zero_stats().at[_SITE_ROW[tag]].set(pair))


def drain_stats() -> jax.Array:
    """Max-merge and CLEAR the collected stats (call inside the trace
    region whose records you are extracting)."""
    col = _QSTATS.get()
    if col is None or not col.vals:
        return zero_stats()
    out = col.vals[0]
    for v in col.vals[1:]:
        out = jnp.maximum(out, v)
    col.vals.clear()
    return out


def reinject_stats(vec) -> None:
    """Re-record a drained stats vector at the CURRENT trace level (after
    a scan / checkpoint block returned it as an explicit output)."""
    col = _QSTATS.get()
    if col is not None:
        col.vals.append(jnp.asarray(vec, jnp.float32))


@contextlib.contextmanager
def collect_stats():
    col = QuantStatsCollector()
    tok = _QSTATS.set(col)
    try:
        yield col
    finally:
        _QSTATS.reset(tok)


def _maybe_record_stats(tag: str, xf, data, fmax: float) -> None:
    """sat = pre-clip overflow fraction; flush = nonzero inputs whose fp8
    encoding flushed to zero (below the subnormal floor).  `xf` is the
    already-scaled tensor, `data` its fp8 payload.  Callers must sit outside
    any custom_vjp (see the stats block comment)."""
    if _QSTATS.get() is None or tag not in STATS_TAGS:
        return
    xa = jnp.abs(xf.astype(jnp.float32))
    sat = jnp.mean((xa > fmax).astype(jnp.float32))
    flush = jnp.mean(((data.astype(jnp.float32) == 0) & (xa > 0)
                      ).astype(jnp.float32))
    record_stat_pair(tag, sat, flush)


def record_entry_stats(tag: str, x, q: Optional["QTensor"] = None,
                       scale_mode: str = "po2", fmt=E4M3) -> None:
    """Record sat/flush for a forward entry quantize from its CALL SITE
    (outside the custom_vjp whose fwd rule performed the quantization).

    With `q` LAYOUT-ALIGNED to x (quantize_entry's return), its payload and
    scales are reused; without (the MoE dispatch returns a permuted/padded
    QTensor), the row-wise scale + payload are recomputed — one amax +
    cast pass, and only while a collector is armed."""
    if _QSTATS.get() is None or tag not in STATS_TAGS:
        return
    fmax = FMT_MAX[fmt]
    tile = row_tile(x.ndim)
    if q is None:
        scale = compute_scale(x, tile, fmt, scale_mode)
    else:
        scale = q.scale
    xf = _tiled_op(x.astype(jnp.float32), scale, tile, lambda a, b: a / b)
    data = q.data if q is not None else \
        jnp.clip(xf, -fmax, fmax).astype(fmt)
    _maybe_record_stats(tag, xf, data, fmax)


def row_tile(ndim: int) -> Tuple[int, ...]:
    """Canonical row-wise tile metadata for an ndim-D payload: last axis in
    TILE-wide tiles, every other axis at element granularity."""
    return (1,) * (ndim - 1) + (TILE,)


def _scale_shape(shape, tile):
    assert len(shape) == len(tile), (shape, tile)
    for s, t in zip(shape, tile):
        if s % t:
            raise ValueError(f"shape {shape} not divisible by tile {tile}")
    return tuple(s // t for s, t in zip(shape, tile))


def _upsample_scale(scale: jax.Array, tile) -> jax.Array:
    """Broadcast per-tile scales back to element resolution (materializes —
    prefer _tiled_mul/_tiled_div, which broadcast through a reshape)."""
    out = scale
    for ax, t in enumerate(tile):
        if t != 1:
            out = jnp.repeat(out, t, axis=ax)
    return out


def _split_shape(shape, tile):
    """(n0, n1, ...) -> interleaved (n0/t0, t0, ...) with 1s for the scale."""
    xs, ss = [], []
    for n, t in zip(shape, tile):
        if t == 1:
            xs.append(n)
            ss.append(n)
        else:
            xs.extend((n // t, t))
            ss.extend((n // t, 1))
    return tuple(xs), tuple(ss)


def _tiled_op(x, scale, tile, op):
    """x <op> per-tile-scale WITHOUT materializing an upsampled scale tensor
    (reshape-broadcast; §Perf: saves a full-size f32 round trip per Q/DQ)."""
    xs, ss = _split_shape(x.shape, tile)
    out = op(x.reshape(xs), scale.reshape(ss))
    return out.reshape(x.shape)


def _tile_amax(x: jax.Array, tile) -> jax.Array:
    """amax over each tile; returns array of shape _scale_shape(x.shape, tile).

    Computed in the INPUT dtype (max is exact in any float format) and
    widened to f32 only at the reduced size — avoids materializing a full
    f32 copy of the tensor (§Perf iteration: memory-term)."""
    shp = []
    red_axes = []
    for ax, (n, t) in enumerate(zip(x.shape, tile)):
        if t == 1:
            shp.append(n)
        else:
            shp.extend((n // t, t))
            red_axes.append(len(shp) - 1)
    y = jnp.abs(x.reshape(shp))
    return jnp.max(y, axis=tuple(red_axes)).astype(jnp.float32)


def compute_scale(x: jax.Array, tile, fmt=E4M3, scale_mode: str = "po2") -> jax.Array:
    amax = _tile_amax(x, tile)
    fmax = FMT_MAX[fmt]
    if scale_mode == "po2":
        return po2_scale(amax, fmax)
    elif scale_mode == "linear":  # conventional recipe: s = amax / 448
        return jnp.where(amax > 0, amax / fmax, jnp.float32(1.0))
    raise ValueError(scale_mode)


def quantize(x: jax.Array, tile, fmt=E4M3, scale_mode: str = "po2",
             tag: str = "q", kind: str = "quantize") -> QTensor:
    """Quantize a dense tensor to per-tile fp8. Counted on the CastLedger.

    kind='quantize' is an explicit cast; kind='fused_quantize' marks a
    quantization folded into a surrounding kernel (not counted by Fig. 2)."""
    casts.record(kind, tag, x.size)
    from repro.runtime import fault_injection
    x = fault_injection.apply("activation", tag, x)
    scale = compute_scale(x, tile, fmt, scale_mode)
    fmax = FMT_MAX[fmt]
    if x.dtype == jnp.bfloat16 and scale_mode == "po2":
        # division by a power of two is EXACT in bf16, and bf16 -> e4m3
        # rounds identically to f32 -> e4m3 (e4m3's mantissa is shorter):
        # same bits as the f32 path at half the intermediate bytes.
        xf = _tiled_op(x, scale.astype(jnp.bfloat16), tile,
                       lambda a, b: a / b)
        data = jnp.clip(xf, jnp.bfloat16(-fmax), jnp.bfloat16(fmax)).astype(fmt)
    else:
        xf = _tiled_op(x.astype(jnp.float32), scale, tile, lambda a, b: a / b)
        data = jnp.clip(xf, -fmax, fmax).astype(fmt)
    return QTensor(data=data, scale=scale, tile=tuple(tile))


def quantize_rowwise(x: jax.Array, fmt=E4M3, scale_mode="po2", tag="q_row",
                     kind="quantize") -> QTensor:
    """1 x TILE tiles along the last axis (Fprop/Dgrad activation layout)."""
    return quantize(x, row_tile(x.ndim), fmt, scale_mode, tag=tag, kind=kind)


def quantize_colwise(x: jax.Array, fmt=E4M3, scale_mode="po2", tag="q_col") -> QTensor:
    """TILE x 1 tiles along the second-to-last axis (Wgrad layout, untransposed)."""
    tile = (1,) * (x.ndim - 2) + (TILE, 1)
    return quantize(x, tile, fmt, scale_mode, tag=tag)


def quantize_blockwise(w: jax.Array, fmt=E4M3, scale_mode="po2", tag="q_wblk") -> QTensor:
    """TILE x TILE blocks over the last two axes (weight layout, DeepGEMM-style)."""
    tile = (1,) * (w.ndim - 2) + (TILE, TILE)
    return quantize(w, tile, fmt, scale_mode, tag=tag)


def dequantize(q: QTensor, dtype=jnp.bfloat16, tag: str = "dq",
               kind: str = "dequantize") -> jax.Array:
    """Counted on the CastLedger."""
    casts.record(kind, tag, q.data.size)
    return _dequantize_nocount(q, dtype)


def _dequantize_nocount(q: QTensor, dtype=jnp.float32) -> jax.Array:
    if dtype == jnp.bfloat16:
        # e4m3 -> bf16 is exact, and x * po2 is exact in bf16: skip the f32
        # intermediate (halves dequant bytes; bit-identical for po2 scales)
        return _tiled_op(q.data.astype(jnp.bfloat16),
                         q.scale.astype(jnp.bfloat16), q.tile,
                         lambda a, b: a * b)
    return _tiled_op(q.data.astype(jnp.float32), q.scale, q.tile,
                     lambda a, b: a * b).astype(dtype)


# ---------------------------------------------------------------------------
# FP8 GEMM contract.  The kernel consumes fp8 payloads + per-tile scales and
# accumulates in f32 (MXU contract); this XLA-path implementation upcasts at
# the MXU boundary — NOT a counted "cast" because no materialized Q/DQ tensor
# round-trips through HBM (the upcast lives inside the fused GEMM on TPU).
# ---------------------------------------------------------------------------
def qdot(qx: QTensor, qw: QTensor, out_dtype=jnp.bfloat16,
         precision=None) -> jax.Array:
    """(..., M, K) tile-(1,TILE) @ (K, N) tile-(TILE,TILE) -> (..., M, N).

    Contraction over the last axis of qx and first payload axis of qw.
    """
    xf = _dequantize_nocount(qx, jnp.float32)
    wf = _dequantize_nocount(qw, jnp.float32)
    out = jnp.matmul(xf, wf, precision=precision)
    return out.astype(out_dtype)


def qdot_general(qx: QTensor, qw: QTensor, dimension_numbers,
                 out_dtype=jnp.bfloat16, precision=None) -> jax.Array:
    xf = _dequantize_nocount(qx, jnp.float32)
    wf = _dequantize_nocount(qw, jnp.float32)
    out = jax.lax.dot_general(xf, wf, dimension_numbers, precision=precision)
    return out.astype(out_dtype)
