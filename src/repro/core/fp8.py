"""FP8 format definitions and power-of-two scale arithmetic.

The paper (FP8-Flow-MoE §3.1) constrains all quantization scales to powers of
two so that re-scaling between row-wise and column-wise quantization layouts is
exact exponent arithmetic on the FP8 encoding.  This module centralizes the
format constants and the po2-scale helpers shared by the pure-JAX reference
path and the Pallas kernels.
"""
from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Formats.  E4M3 (fn variant: no inf, max 448) is used for all payload data;
# E5M2 is provided for gradients if a recipe asks for wider range; scales are
# UE8M0-style — an f32 that is always an exact power of two (we keep them as
# f32 for XLA-friendliness; the exponent-only property is what matters).
# ---------------------------------------------------------------------------
E4M3 = jnp.float8_e4m3fn
E5M2 = jnp.float8_e5m2

E4M3_MAX = 448.0          # largest finite e4m3fn magnitude
E5M2_MAX = 57344.0
E4M3_EXP_BIAS = 7         # value = (-1)^s * 2^(E-7) * (1 + M/8)   (normal)
E4M3_MANTISSA_BITS = 3
E4M3_MIN_NORMAL_EXP = -6  # E=1 -> 2^-6; E=0 is subnormal: 2^-6 * (M/8)

TILE = 128                # per-tile quantization granularity (paper Eq. 2)
BLOCK = 128               # transpose / weight block (128x128)

FMT_MAX = {E4M3: E4M3_MAX, E5M2: E5M2_MAX}
# normalize dtype instances (np.dtype('float8_e4m3fn')) to the same table
FMT_MAX.update({jnp.dtype(k): v for k, v in list(FMT_MAX.items())})


def po2_scale(amax: jnp.ndarray, fmt_max: float = E4M3_MAX) -> jnp.ndarray:
    """Smallest power-of-two scale s with amax / s <= fmt_max.

    Paper Eq. (2) computes s = amax/448; we round the exponent *up* to the
    next power of two (UE8M0) so the quantized magnitude never exceeds the
    format max.  amax == 0 maps to s = 1 (any scale works for the zero tile).
    """
    amax = jnp.asarray(amax, jnp.float32)
    safe = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny)
    exp = jnp.ceil(jnp.log2(safe / fmt_max))
    # clamp so 2**exp stays finite in f32 and representable as a scale
    exp = jnp.clip(exp, -126.0, 126.0)
    # ldexp, NOT exp2: XLA's f32 exp2 is not correctly rounded for
    # |exp| >= 13, so the "scale is an exact power of two" contract (the
    # enabler for the scaling-aware transpose AND the int8 exponent wire of
    # repro.dist) would silently break at large/small amax
    s = jnp.ldexp(jnp.float32(1.0), exp.astype(jnp.int32))
    return jnp.where(amax > 0, s, jnp.float32(1.0))


def is_po2(s: jnp.ndarray) -> jnp.ndarray:
    """True where s is an exact power of two (and positive)."""
    s = jnp.asarray(s, jnp.float32)
    m, _ = jnp.frexp(s)  # s = m * 2**e with m in [0.5, 1)
    return (s > 0) & (m == 0.5)


def cast_to(x: jnp.ndarray, fmt=E4M3) -> jnp.ndarray:
    """Saturating cast to fp8 (round-to-nearest-even via XLA convert)."""
    fmax = FMT_MAX[fmt]
    x = jnp.clip(x.astype(jnp.float32), -fmax, fmax)
    return x.astype(fmt)
