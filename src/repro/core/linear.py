"""Recipe-parameterized grouped expert FFN with hand-written VJPs (Fig. 2).

``expert_ffn(recipe, act, x_in, w13, w2)`` computes, per expert e:

    h = x[e] @ w13[e]          (E, C, Fh)     "grouped linear 1"
    a = act(h)                 (E, C, F)      SwiGLU (Fh=2F) or GELU (Fh=F)
    y = a  @ w2[e]             (E, C, D)      "grouped linear 2"

The backward pass is written BY HAND per recipe — this is the paper's whole
point: the recipes differ not in the math but in *where tensors change
format*:

  bf16       pure autodiff, no quantization (0 casts)
  blockwise  TE-style: FP8 only inside the GEMMs, BF16-saved activations,
             fresh column-wise quantizations for Wgrad (8 casts)
  naive_fp8  DeepSeek-style: FP8-saved activations whose Wgrad layouts are
             rebuilt via dequantize->transpose->requantize — the double-
             quantization-error path (10 casts here + 2 at the dispatch
             boundary in moe.py = the paper's 12)
  fp8_flow   this paper: scaling-aware direct transpose for every Wgrad
             layout, fused SwiGLU+quant / dSwiGLU+quant / Dgrad-epilogue
             quant; ONE explicit cast here (the BF16-island gradient
             quantize; the other is the entry quantize at dispatch)

For fp8 recipes the input ``x_in`` is a QTensor and — in fp8_flow — the
returned input-cotangent is ALSO a QTensor (fp8 payload + po2 scales), so the
gradient travels the dispatch all-to-all in FP8, mirroring the forward.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import casts
from repro.core.fp8 import TILE
from repro.core.quant import (QTensor, _dequantize_nocount, dequantize,
                              quantize_blockwise, quantize_rowwise,
                              record_entry_stats, tag_qtensor, tag_saveable)
from repro.core.recipes import Recipe
from repro.core.transpose import transpose_direct, transpose_naive


# ---------------------------------------------------------------------------
# Path selection: Pallas kernels (TPU / interpret) vs pure-XLA equivalents.
# ---------------------------------------------------------------------------
def _ggemm(recipe: Recipe, qx: QTensor, qw: QTensor, out_dtype=jnp.bfloat16,
           masked_m=None):
    """masked_m (int32 (E,), per-expert live rows) routes the Pallas path to
    the masked-layout kernel — bitwise-equal on the zero-padded dispatch
    buffers, so the XLA path may ignore it (padded rows are zero anyway)."""
    if recipe.use_pallas:
        from repro.kernels import ops
        if masked_m is not None:
            return ops.grouped_gemm_fp8_masked(qx, qw,
                                               masked_m).astype(out_dtype)
        return ops.grouped_gemm_fp8(qx, qw).astype(out_dtype)
    # XLA path mirrors the MXU contract: operands dequantized to bf16 (EXACT
    # for e4m3 payloads x po2 scales — bf16 has more mantissa than e4m3) and
    # the dot accumulates in f32.  Halves the materialized operand bytes.
    xf = _dequantize_nocount(qx, jnp.bfloat16)
    wf = _dequantize_nocount(qw, jnp.bfloat16)
    return jnp.matmul(xf, wf,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def _ggemm_nt(recipe: Recipe, qa: QTensor, qb: QTensor, out_dtype=jnp.float32,
              masked_m=None):
    """(E,M,C) x (E,N,C) -> (E,M,N), contraction over last axis of both."""
    if recipe.use_pallas:
        from repro.kernels import ops
        if masked_m is not None:
            return ops.grouped_gemm_nt_fp8_masked(qa, qb,
                                                  masked_m).astype(out_dtype)
        return ops.grouped_gemm_nt_fp8(qa, qb).astype(out_dtype)
    af = _dequantize_nocount(qa, jnp.bfloat16)
    bf = _dequantize_nocount(qb, jnp.bfloat16)
    return jnp.einsum("emc,enc->emn", af, bf,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def _ggemm_quant_out(recipe: Recipe, qx: QTensor, qw: QTensor,
                     masked_m=None) -> QTensor:
    """Grouped GEMM with fused FP8-quantizing epilogue (Dgrad1 path)."""
    casts.record("fused_quantize", "dgrad_epilogue", qx.data.shape[0])
    if recipe.use_pallas:
        from repro.kernels import ops
        if masked_m is not None:
            return ops.grouped_gemm_fp8_masked_quant_out(qx, qw, masked_m)
        return ops.grouped_gemm_fp8_quant_out(qx, qw)
    out = _ggemm(recipe, qx, qw, jnp.bfloat16)
    return quantize_rowwise(out, scale_mode=recipe.scale_mode,
                            tag="dgrad_out", kind="fused_quantize_inner")


def _q_row(recipe: Recipe, x, tag, fused=False) -> QTensor:
    kind = "fused_quantize" if fused else "quantize"
    if recipe.use_pallas and x.ndim == 3:
        from repro.kernels import ops
        casts.record(kind, tag, x.size)
        E, C, K = x.shape
        q = ops.quantize_rowwise(x.reshape(E * C, K))
        return QTensor(q.data.reshape(E, C, K), q.scale.reshape(E, C, K // TILE),
                       (1, 1, TILE))
    return quantize_rowwise(x, scale_mode=recipe.scale_mode, tag=tag, kind=kind)


def _t_direct(recipe: Recipe, q: QTensor) -> QTensor:
    """Scaling-aware direct transpose of the last two axes (casting-free)."""
    if recipe.use_pallas:
        from repro.kernels.fp8_transpose import fp8_transpose_pallas
        E, M, K = q.shape
        dt, st = jax.vmap(lambda d, s: fp8_transpose_pallas(d, s))(
            q.data, q.scale.reshape(E, M, K // TILE))
        return QTensor(dt, st, (1, 1, TILE))
    return transpose_direct(q)


def _t_naive(recipe: Recipe, q: QTensor) -> QTensor:
    """Dequantize -> transpose -> requantize (2 explicit casts)."""
    return transpose_naive(q, scale_mode=recipe.scale_mode)


def _block_t(qw: QTensor) -> QTensor:
    """Transpose a (TILE,TILE)-block-quantized weight — exact relabeling."""
    return QTensor(jnp.swapaxes(qw.data, -1, -2),
                   jnp.swapaxes(qw.scale, -1, -2), qw.tile)


def _fused_swiglu_quant(recipe: Recipe, h) -> QTensor:
    casts.record("fused_quantize", "swiglu_quant", h.size)
    if recipe.use_pallas:
        from repro.kernels import ops
        E, C, Fh = h.shape
        q = ops.fused_swiglu_quant(h.reshape(E * C, Fh))
        F = Fh // 2
        return QTensor(q.data.reshape(E, C, F), q.scale.reshape(E, C, F // TILE),
                       (1, 1, TILE))
    a = _swiglu(h)
    return quantize_rowwise(a, scale_mode=recipe.scale_mode,
                            tag="swiglu_quant", kind="fused_quantize_inner")


# ---------------------------------------------------------------------------
# Activations (computed in f32, the BF16 island of §3.2).
# ---------------------------------------------------------------------------
def _swiglu(h):
    g, u = jnp.split(h.astype(jnp.float32), 2, axis=-1)
    return (g * jax.lax.logistic(g) * u).astype(jnp.bfloat16)


def _dswiglu(h, ga):
    g, u = jnp.split(h.astype(jnp.float32), 2, axis=-1)
    ga = ga.astype(jnp.float32)
    s = jax.lax.logistic(g)
    silu = g * s
    dgate = ga * u * (s + silu * (1.0 - s))
    dup = ga * silu
    return jnp.concatenate([dgate, dup], axis=-1).astype(jnp.bfloat16)


def _geglu(h):
    g, u = jnp.split(h.astype(jnp.float32), 2, axis=-1)
    return (jax.nn.gelu(g, approximate=True) * u).astype(jnp.bfloat16)


def _dgeglu(h, ga):
    g, u = jnp.split(h.astype(jnp.float32), 2, axis=-1)
    ga = ga.astype(jnp.float32)
    _, vjp = jax.vjp(lambda t: jax.nn.gelu(t, approximate=True), g)
    dgate = vjp(ga * u)[0]
    dup = ga * jax.nn.gelu(g, approximate=True)
    return jnp.concatenate([dgate, dup], axis=-1).astype(jnp.bfloat16)


def _gelu(h):
    return jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(jnp.bfloat16)


def _dgelu(h, ga):
    h32 = h.astype(jnp.float32)
    _, vjp = jax.vjp(lambda t: jax.nn.gelu(t, approximate=True), h32)
    return vjp(ga.astype(jnp.float32))[0].astype(jnp.bfloat16)


def _relu(h):
    return jax.nn.relu(h.astype(jnp.float32)).astype(jnp.bfloat16)


def _drelu(h, ga):
    return jnp.where(h.astype(jnp.float32) > 0,
                     ga.astype(jnp.float32), 0.0).astype(jnp.bfloat16)


_ACT_FWD = {"swiglu": _swiglu, "geglu": _geglu, "gelu": _gelu, "relu": _relu}
_ACT_BWD = {"swiglu": _dswiglu, "geglu": _dgeglu, "gelu": _dgelu,
            "relu": _drelu}


def _act_fwd(act: str, h):
    return _ACT_FWD[act](h)


def _act_bwd(act: str, h, ga):
    return _ACT_BWD[act](h, ga)


# ---------------------------------------------------------------------------
# The recipe-dispatched expert FFN.
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def expert_ffn(recipe: Recipe, act: str, wg_axes: tuple, gx_axes: tuple,
               x_in, w13, w2, masked_m=None):
    """wg_axes: mesh axes to psum weight-gradients over (the DP reduction —
    tokens are sharded over them while weights are replicated).  gx_axes:
    axes to psum the input-gradient over (TP-sharded expert case).  Both are
    () outside shard_map.  masked_m: optional per-expert live-row counts
    (int32 (E,)) — routes the fp8_flow Pallas grouped GEMMs (fwd AND every
    backward Dgrad/Wgrad) through the masked layout; other recipes ignore
    it."""
    y, _ = _ffn_fwd(recipe, act, wg_axes, gx_axes, x_in, w13, w2, masked_m)
    return y


def _quant_weights(recipe: Recipe, w13, w2):
    # W8-resident serving: weights may arrive pre-quantized (serve/w8.py)
    qw13 = w13 if isinstance(w13, QTensor) else quantize_blockwise(
        w13, scale_mode=recipe.scale_mode, tag="q_w13")
    qw2 = w2 if isinstance(w2, QTensor) else quantize_blockwise(
        w2, scale_mode=recipe.scale_mode, tag="q_w2")
    return qw13, qw2


def _ffn_fwd(recipe: Recipe, act: str, wg_axes: tuple, gx_axes: tuple,
             x_in, w13, w2, masked_m=None):
    name = recipe.name
    if name == "bf16":
        x = x_in
        h = tag_saveable(jnp.matmul(x.astype(jnp.bfloat16),
                                    w13.astype(jnp.bfloat16)), "stage_ffn_h")
        a = _act_fwd(act, h)
        y = jnp.matmul(a, w2.astype(jnp.bfloat16))
        return y, (x, h, w13, w2)

    qw13, qw2 = _quant_weights(recipe, w13, w2)

    if name == "fp8_flow":
        y, (qx, qa, h_saved) = ffn_fwd_fp8_core(recipe, act, x_in, qw13, qw2,
                                                masked_m=masked_m)
        wit = (jnp.zeros((0,), w13.dtype), jnp.zeros((0,), w2.dtype))
        return y, (qx, qa, h_saved, qw13, qw2, wit, masked_m)

    if name == "naive_fp8":
        # x arrives in BF16 (the dispatch DQ'd it — Fig 2c's Q/DQ-around-comm)
        x = x_in
        qx = tag_qtensor(_q_row(recipe, x, "q_gemm1_in"), "fp8_qx")  # (3)
        h = _ggemm(recipe, qx, qw13, jnp.bfloat16)
        a = _act_fwd(act, h)                                 # separate kernel
        qa = tag_qtensor(_q_row(recipe, a, "q_gemm2_in"), "fp8_qa")  # (4)
        y = _ggemm(recipe, qa, qw2, jnp.bfloat16)
        # x and a are SAVED IN FP8 (DeepSeek's memory trick) — their Wgrad
        # layouts in bwd must go through dequant->transpose->requant.
        wit = (jnp.zeros((0,), w13.dtype), jnp.zeros((0,), w2.dtype))
        return y, (qx, qa, qw13, qw2, wit)

    if name == "blockwise":
        x = x_in                                             # bf16
        qx = _q_row(recipe, x, "q_gemm1_in")                 # explicit cast
        h = tag_saveable(_ggemm(recipe, qx, qw13, jnp.bfloat16),
                         "stage_ffn_h")
        a = _act_fwd(act, h)
        qa = _q_row(recipe, a, "q_gemm2_in")                 # explicit cast
        y = _ggemm(recipe, qa, qw2, jnp.bfloat16)
        wit = (jnp.zeros((0,), w13.dtype), jnp.zeros((0,), w2.dtype))
        return y, (x, h, qw13, qw2, wit)

    raise ValueError(name)


def _psum(v, axes):
    return jax.lax.psum(v, axes) if axes else v


# ---------------------------------------------------------------------------
# fp8_flow FFN core (shared by expert_ffn's VJP and the overlapped dispatch
# pipeline in core/moe.py, which hand-writes its backward so the one explicit
# island quantize can be hoisted OUT of the per-chunk loop).
# ---------------------------------------------------------------------------
def _use_swiglu_epilogue(recipe: Recipe, act: str, masked_m) -> bool:
    """The fused SwiGLU+quant GEMM-1 epilogue applies on the masked Pallas
    path only, and only when h need not be materialized for saving."""
    return (recipe.swiglu_epilogue and act == "swiglu" and recipe.use_pallas
            and masked_m is not None and not recipe.save_h)


def ffn_fwd_fp8_core(recipe: Recipe, act: str, qx: QTensor, qw13: QTensor,
                     qw2: QTensor, masked_m=None):
    """fp8_flow grouped FFN forward on an already-quantized input.
    Returns (y bf16, (qx, qa, h_saved)) — the residuals the backward core
    needs (the weights the caller already holds).  qx/qa come back
    checkpoint_name-tagged ('fp8_qx'/'fp8_qa'): callers must save THESE so
    the MemoryPlan 'fp8_resident' policy (train/memory.py) keeps the
    QTensor stage outputs resident across the forward/backward boundary."""
    qx = tag_qtensor(qx, "fp8_qx")
    if _use_swiglu_epilogue(recipe, act, masked_m):
        # GEMM-1 with the SwiGLU + re-quantize fused into its last K-step:
        # the BF16 island lives only in VMEM (bitwise the unfused pair).
        from repro.kernels import ops
        # same ledger entry as the unfused kernel pair (h.size = E*C*2F)
        casts.record("fused_quantize", "swiglu_quant",
                     qx.data.shape[0] * qx.data.shape[1] * qw13.data.shape[-1])
        qa = ops.grouped_gemm_swiglu_quant_masked(qx, qw13, masked_m)
        qa = tag_qtensor(qa, "fp8_qa")
        y = _ggemm(recipe, qa, qw2, jnp.bfloat16, masked_m=masked_m)
        return y, (qx, qa, None)
    h = _ggemm(recipe, qx, qw13, jnp.bfloat16,
               masked_m=masked_m)                           # BF16 island in
    h = tag_saveable(h, "stage_ffn_h")
    if act == "swiglu":
        qa = _fused_swiglu_quant(recipe, h)
    else:
        # fused <act>+quant: same one-pass contract as the SwiGLU kernel
        casts.record("fused_quantize", "act_quant", h.size)
        qa = quantize_rowwise(_act_fwd(act, h), scale_mode=recipe.scale_mode,
                              tag="act_quant", kind="fused_quantize_inner")
    qa = tag_qtensor(qa, "fp8_qa")
    y = _ggemm(recipe, qa, qw2, jnp.bfloat16, masked_m=masked_m)
    return y, (qx, qa, h if recipe.save_h else None)


def ffn_bwd_fp8_core(recipe: Recipe, act: str, gx_axes: tuple, qx: QTensor,
                     qa: QTensor, h_saved, qw13: QTensor, qw2: QTensor,
                     qg: QTensor, masked_m=None):
    """fp8_flow grouped FFN backward given an ALREADY-QUANTIZED output
    cotangent ``qg`` — the explicit BF16-island quantize happens in the
    caller (once per step, even when the FFN itself runs per micro-chunk).
    Returns (gx QTensor, wg13 f32, wg2 f32): the input-gradient is FP8 on
    both branches (fused Dgrad1 epilogue, or post-psum quantize when
    gx_axes); weight grads are UNREDUCED (the caller psums over its DP
    axes).  masked_m skips dead capacity tiles in all five grouped GEMMs
    (Dgrad rows beyond the count are zero because the combine's p_exp
    weighting zeros dead slots upstream; NT forms skip zero token
    columns)."""
    # Dgrad2: FP8 x FP8, block-transposed weight (exact relabeling)
    ga = _ggemm(recipe, qg, _block_t(qw2), jnp.bfloat16, masked_m=masked_m)
    # Wgrad2 via scaling-aware DIRECT transposes — zero casts
    wg2 = _ggemm_nt(recipe, _t_direct(recipe, qa), _t_direct(recipe, qg),
                    masked_m=masked_m)
    # BF16 island: recompute h (FP8 activation checkpointing) or reuse
    h = h_saved if h_saved is not None else _ggemm(recipe, qx, qw13,
                                                   jnp.bfloat16,
                                                   masked_m=masked_m)
    gh = _act_bwd(act, h, ga)
    casts.record("fused_quantize", "dact_quant", gh.size)
    qgh = quantize_rowwise(gh, scale_mode=recipe.scale_mode,
                           tag="dact_quant", kind="fused_quantize_inner")
    if gx_axes:
        # TP-sharded experts: the input-gradient partial-sums over the
        # F-shards first; the fused quantizing epilogue runs after the
        # psum (a reduction — kept out of FP8 by design).
        gx_f32 = _ggemm(recipe, qgh, _block_t(qw13), jnp.float32,
                        masked_m=masked_m)
        casts.record("fused_quantize", "dgrad_epilogue", gx_f32.size)
        gx = quantize_rowwise(_psum(gx_f32, gx_axes),
                              scale_mode=recipe.scale_mode,
                              tag="dgrad_out", kind="fused_quantize_inner")
    else:
        # Dgrad1 with fused quantizing epilogue -> FP8 input-gradient
        gx = _ggemm_quant_out(recipe, qgh, _block_t(qw13), masked_m=masked_m)
    # Wgrad1, again via direct transposes
    wg13 = _ggemm_nt(recipe, _t_direct(recipe, qx), _t_direct(recipe, qgh),
                     masked_m=masked_m)
    return gx, wg13, wg2


def _ffn_bwd(recipe: Recipe, act: str, wg_axes: tuple, gx_axes: tuple,
             res, gy):
    name = recipe.name
    gy = gy.astype(jnp.bfloat16)

    if name == "bf16":
        x, h, w13, w2 = res
        a = _act_fwd(act, h)
        ga = jnp.matmul(gy, jnp.swapaxes(w2.astype(jnp.bfloat16), -1, -2))
        wg2 = jnp.einsum("ecf,ecd->efd", a.astype(jnp.float32),
                         gy.astype(jnp.float32))
        gh = _act_bwd(act, h, ga)
        gx = jnp.matmul(gh, jnp.swapaxes(w13.astype(jnp.bfloat16), -1, -2))
        wg13 = jnp.einsum("eck,ecf->ekf", x.astype(jnp.float32),
                          gh.astype(jnp.float32))
        return (_psum(gx, gx_axes), _psum(wg13, wg_axes).astype(w13.dtype),
                _psum(wg2, wg_axes).astype(w2.dtype), None)

    if name == "fp8_flow":
        qx, qa, h_saved, qw13, qw2, (wit13, wit2), masked_m = res
        w13_dt, w2_dt = wit13.dtype, wit2.dtype
        # ---- the single explicit backward cast: BF16 island -> FP8 ----
        qg = _q_row(recipe, gy, "q_bwd_island")
        gx_q, wg13, wg2 = ffn_bwd_fp8_core(recipe, act, gx_axes, qx, qa,
                                           h_saved, qw13, qw2, qg,
                                           masked_m=masked_m)
        return (gx_q, _psum(wg13, wg_axes).astype(w13_dt),
                _psum(wg2, wg_axes).astype(w2_dt), None)

    if name == "naive_fp8":
        qx, qa, qw13, qw2, (wit13, wit2) = res
        w13_dt, w2_dt = wit13.dtype, wit2.dtype
        qg = _q_row(recipe, gy, "q_bwd_dgrad2")              # explicit (5)
        ga = _ggemm(recipe, qg, _block_t(qw2), jnp.bfloat16)
        # Wgrad column layouts for the FP8-SAVED activations must be rebuilt
        # via dequantize->transpose->requantize — the double-quantization-
        # error path (2 explicit casts each: 6,7 / 10,11); the BF16-live
        # gradients are freshly column-quantized (8 / 12).
        qaT = _t_naive(recipe, qa)                           # dq+q (6,7)
        qgT = _q_row(recipe, jnp.swapaxes(gy, -1, -2), "q_bwd_wgrad2_g")  # (8)
        wg2 = _ggemm_nt(recipe, qaT, qgT)
        h = _ggemm(recipe, qx, qw13, jnp.bfloat16)
        gh = _act_bwd(act, h, ga)
        qgh = _q_row(recipe, gh, "q_bwd_dgrad1")             # explicit (9)
        gx = _ggemm(recipe, qgh, _block_t(qw13), jnp.bfloat16)  # bf16 combine
        qxT = _t_naive(recipe, qx)                           # dq+q (10,11)
        qghT = _q_row(recipe, jnp.swapaxes(gh, -1, -2), "q_bwd_wgrad1_g")  # (12)
        wg13 = _ggemm_nt(recipe, qxT, qghT)
        return (_psum(gx, gx_axes), _psum(wg13, wg_axes).astype(w13_dt),
                _psum(wg2, wg_axes).astype(w2_dt), None)

    if name == "blockwise":
        x, h, qw13, qw2, (wit13, wit2) = res
        w13_dt, w2_dt = wit13.dtype, wit2.dtype
        qg = _q_row(recipe, gy, "q_bwd_dgrad2")              # explicit
        ga = _ggemm(recipe, qg, _block_t(qw2), jnp.bfloat16)
        a = _act_fwd(act, h)
        # fresh column-wise quantizations from the BF16-saved tensors
        qaT = _q_row(recipe, jnp.swapaxes(a, -1, -2), "q_bwd_wgrad2_a")
        qgT = _q_row(recipe, jnp.swapaxes(gy, -1, -2), "q_bwd_wgrad2_g")
        wg2 = _ggemm_nt(recipe, qaT, qgT)
        gh = _act_bwd(act, h, ga)
        qgh = _q_row(recipe, gh, "q_bwd_dgrad1")             # explicit
        gx = _ggemm(recipe, qgh, _block_t(qw13), jnp.bfloat16)
        qghT = _q_row(recipe, jnp.swapaxes(gh, -1, -2), "q_bwd_wgrad1_g")
        qxT = _q_row(recipe, jnp.swapaxes(x, -1, -2), "q_bwd_wgrad1_x")
        wg13 = _ggemm_nt(recipe, qxT, qghT)
        return (_psum(gx, gx_axes), _psum(wg13, wg_axes).astype(w13_dt),
                _psum(wg2, wg_axes).astype(w2_dt), None)

    raise ValueError(name)


expert_ffn.defvjp(_ffn_fwd, _ffn_bwd)


# ---------------------------------------------------------------------------
# Entry/exit bridges between the BF16 residual stream and the FP8 pathway.
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(0,))
def quantize_entry(recipe: Recipe, x) -> QTensor:
    """The paper's 'entry point' cast (explicit, counted).  Backward: the
    FP8 input-gradient QTensor is dequantized INSIDE the consuming add
    (fused), closing the FP8 loop."""
    return quantize_rowwise(x, scale_mode=recipe.scale_mode, tag="q_entry")


def _qe_fwd(recipe, x):
    return quantize_entry(recipe, x), jnp.zeros((0,), x.dtype)


def _qe_bwd(recipe, wit, qg: QTensor):
    casts.record("fused_dequantize", "entry_bwd", qg.data.size)
    return (_dequantize_nocount(qg, wit.dtype),)


quantize_entry.defvjp(_qe_fwd, _qe_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def dequantize_exit(recipe: Recipe, q: QTensor):
    """naive_fp8's post-dispatch DQ (explicit) paired with an explicit bwd
    quantize — the Q/DQ-around-comm cost of Table 1."""
    return dequantize(q, jnp.bfloat16, tag="dq_post_dispatch")


def _de_fwd(recipe, q):
    return dequantize_exit(recipe, q), (q.scale.shape, q.tile)


def _de_bwd(recipe, res, g):
    scale_shape, tile = res
    qg = quantize_rowwise(g, scale_mode=recipe.scale_mode, tag="q_bwd_dispatch")
    return (qg,)


dequantize_exit.defvjp(_de_fwd, _de_bwd)


def dense_mlp(recipe: Recipe, act: str, x, w13, w2):
    """Dense-arch specialization: the FP8-centric MLP (no dispatch).

    x: (T, D); w13: (D, Fh); w2: (F, D).  T, D, F must be 128-multiples."""
    T, D = x.shape
    Tp = (T + 127) // 128 * 128
    Dp = (D + 127) // 128 * 128
    if Tp != T or Dp != D:
        # zero-pad to the 128-tile alignment the FP8 pathway needs; zero
        # rows/cols contribute nothing to outputs or gradients
        x = jnp.pad(x, ((0, Tp - T), (0, Dp - D)))
        w13 = jnp.pad(w13, ((0, Dp - D), (0, 0)))
        w2 = jnp.pad(w2, ((0, 0), (0, Dp - D)))
    x3 = x.reshape(1, Tp, Dp)
    w13_3, w2_3 = w13[None], w2[None]
    if recipe.name in ("bf16", "blockwise", "naive_fp8"):
        # blockwise/naive quantize inside the FFN (per-GEMM Q); no dispatch
        # boundary exists for a dense MLP.
        return expert_ffn(recipe, act, (), (), x3.astype(jnp.bfloat16)
                          if recipe.name != "bf16" else x3,
                          w13_3, w2_3)[0][:T, :D]
    # fp8_flow: quantize once at entry, FP8-native pathway end to end
    qx = quantize_entry(recipe, x3)
    record_entry_stats("q_entry_mlp", x3, qx)
    y = expert_ffn(recipe, act, (), (), qx, w13_3, w2_3)
    return y[0][:T, :D]
