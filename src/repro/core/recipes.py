"""Precision-recipe configuration — the four dataflows of paper Fig. 2.

  bf16      (2a)  FP32/BF16 mixed precision, no quantization anywhere.
  blockwise (2b)  TransformerEngine-style: FP8 confined to the grouped
                  linears; BF16 communication; activations saved in BF16.
                  8 explicit activation casts per MoE fwd+bwd.
  naive_fp8 (2c)  DeepSeek-V3-style drop-in FP8 kernels: FP8 dispatch with
                  Q/DQ at the comm boundary, FP8-saved activations whose
                  Wgrad layouts are rebuilt by dequantize->transpose->
                  requantize — the double-quantization-error sites.
                  12 explicit activation casts per MoE fwd+bwd.
  fp8_flow  (2d)  This paper: po2 scales, scaling-aware direct transpose,
                  fused SwiGLU+quant / dSwiGLU+quant / Dgrad-epilogue-quant,
                  FP8 dispatch both directions.  2 explicit casts: the entry
                  quantize (fwd) and the BF16-island gradient quantize (bwd).
"""
from __future__ import annotations

import dataclasses

RECIPES = ("bf16", "blockwise", "naive_fp8", "fp8_flow")


@dataclasses.dataclass(frozen=True)
class Recipe:
    name: str = "fp8_flow"
    # 'po2' enables the scaling-aware transpose; 'linear' reproduces the
    # conventional-amax-scale baseline (double quantization error nonzero).
    scale_mode: str = "po2"
    # Pallas kernels vs pure-XLA path (same math; XLA path used for the
    # 512-device dry-run lowering, Pallas for TPU runtime + kernel tests).
    use_pallas: bool = False
    # Save gemm1 output h in bf16 (AC off) vs recompute from the saved FP8
    # input in backward (FP8 activation-checkpoint compression, AC=sel).
    save_h: bool = False
    # Store the dispatched expert input in FP8 for backward (always true for
    # fp8 recipes; bf16 recipe saves bf16).
    e5m2_grads: bool = False  # use E5M2 for gradient tensors (wider range)
    # Route expert grouped GEMMs through the MASKED layout: per-expert live
    # row counts (from the dispatch plan) skip dead capacity tiles on the
    # MXU.  Bitwise-equal to the padded layout on the zero-padded dispatch
    # buffers, so the padded path stays available as the A/B baseline.
    masked_experts: bool = False
    # Fuse the inter-GEMM SwiGLU + row-wise e4m3 re-quantize into GEMM-1's
    # last-K-step epilogue (masked Pallas path only; requires masked_experts,
    # use_pallas and save_h=False — h never materializes, so there is
    # nothing to save).
    swiglu_epilogue: bool = False

    def __post_init__(self):
        if self.name not in RECIPES:
            raise ValueError(f"unknown recipe {self.name}; pick from {RECIPES}")

    @property
    def is_fp8(self) -> bool:
        return self.name != "bf16"

    @property
    def fp8_dispatch(self) -> bool:
        return self.name in ("naive_fp8", "fp8_flow")

    @property
    def fp8_dispatch_bwd(self) -> bool:
        return self.name == "fp8_flow"


BF16 = Recipe(name="bf16")
BLOCKWISE = Recipe(name="blockwise", scale_mode="linear")
NAIVE_FP8 = Recipe(name="naive_fp8", scale_mode="linear")
FP8_FLOW = Recipe(name="fp8_flow", scale_mode="po2")


def get_recipe(name: str, **kw) -> Recipe:
    base = {"bf16": BF16, "blockwise": BLOCKWISE,
            "naive_fp8": NAIVE_FP8, "fp8_flow": FP8_FLOW}[name]
    return dataclasses.replace(base, **kw) if kw else base
