"""Unified decoder-LM trunk covering all assigned architecture families:
dense GQA (starcoder2/qwen/llava), local:global patterns (gemma2/3), SSM
(mamba2), hybrid (hymba), MoE (qwen3-moe/grok/deepseek), encoder-decoder
(seamless), with VLM/audio stub frontends.

Layer parameters are stacked (L, ...) and scanned in pattern groups;
rematerialization is owned by the MemoryPlan (train/memory.py), which wraps
each group/layer per cfg.remat_policy.  The MLP/MoE stage runs inside
shard_map so the paper's
FP8 dispatch/dataflow recipes apply uniformly (core/moe.py, core/linear.py);
attention/norm/embedding run under pjit auto-sharding in BF16.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import quant as quant_stats
from repro.core.linear import dense_mlp, expert_ffn, quantize_entry
from repro.core.moe import (DispatchPlan, MoEConfig, moe_block,
                            moe_block_decode, moe_block_decode_overlapped,
                            moe_block_overlapped, moe_block_tp)
from repro.core.recipes import Recipe
from repro.models.layers import apply_norm, attn_block, stage_ln_attn
from repro.models.ssm import mamba2_block
from repro.train.memory import MemoryPlan


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """How this run maps onto the mesh (None mesh = single-process tests)."""
    mesh: object = None
    dp_axes: tuple = ()            # axes sharding the batch/tokens
    tp_axis: str = "model"
    moe_mode: str = "ep"           # 'ep' (E >= tp) or 'tp' (E < tp)
    fsdp_axis: Optional[str] = None  # gather MoE/MLP weights over this axis
    shard_map_mlp: bool = True     # run dense MLP through shard_map (train)
    mlp_tp: bool = False           # TP-shard d_ff (psum combine) instead of
                                   # DP-over-all-axes; DP wins when the
                                   # activation psum volume > weight traffic
    moe_tp_combine: str = "local_first"  # TP-MoE combine ordering (§Perf):
                                   # 'psum_first' | 'local_first' |
                                   # 'reduce_scatter'
    moe_overlap: Optional[DispatchPlan] = None  # chunked/overlapped EP
                                   # dispatch pipeline (core/moe.py): when
                                   # set, train/prefill EP MoE layers run
                                   # moe_block_overlapped and the shared
                                   # expert is issued BEFORE the dispatch so
                                   # its GEMMs overlap the first chunk's
                                   # fused all-to-all; decode-path MoE layers
                                   # run moe_block_decode_overlapped (the
                                   # chunk-pipelined combine psum)
    stage_layers: bool = False     # run the decoder stacks through the
                                   # UNROLLED staged layer program
                                   # (_run_stack_unrolled) instead of the
                                   # monolithic lax.scan: per-layer trace
                                   # regions with a two-layer carry window —
                                   # what the streaming DP wire's backward
                                   # consumes (repro.dist schedule='stream')

    @property
    def token_axes_moe(self):      # EP: tokens also sharded over tp (SP)
        return self.dp_axes + (self.tp_axis,)


NO_PLAN = ParallelPlan(mesh=None, dp_axes=(), shard_map_mlp=False)

# weight of the summed router aux losses in the training loss — shared by
# forward() and the staged backward (train_step._streamed_grads), which
# feeds it in as each layer's aux cotangent
AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# Parameter initialization (stacked layers).  For the dry-run this is only
# ever called under jax.eval_shape — no memory is allocated.
# ---------------------------------------------------------------------------
def _dense_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _layer_params(cfg: ArchConfig, key, kind: str, moe_layer: bool, dtype):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    g = cfg.gate_factor
    ks = jax.random.split(key, 24)
    p = {}
    sc = 0.02

    def norm_params(i, name):
        p[f"{name}_s"] = jnp.zeros((D,), jnp.float32)
        if cfg.norm == "layernorm":
            p[f"{name}_s"] = jnp.ones((D,), jnp.float32)
            p[f"{name}_b"] = jnp.zeros((D,), jnp.float32)

    norm_params(0, "ln1")
    norm_params(1, "ln2")

    if kind in ("global", "local", "hybrid"):
        p["wq"] = _dense_init(ks[0], (D, H * hd), sc, dtype)
        p["wk"] = _dense_init(ks[1], (D, KV * hd), sc, dtype)
        p["wv"] = _dense_init(ks[2], (D, KV * hd), sc, dtype)
        p["wo"] = _dense_init(ks[3], (H * hd, D), sc / cfg.n_layers**0.5, dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((H * hd,), jnp.float32)
            p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
            p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((hd,), jnp.float32)
            p["k_norm"] = jnp.zeros((hd,), jnp.float32)

    if kind in ("ssm", "hybrid") and cfg.ssm_state:
        di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        p["in_proj"] = _dense_init(ks[4], (D, 2 * di + 2 * N + nh), sc, dtype)
        p["conv_w"] = _dense_init(ks[5], (cfg.ssm_conv, di + 2 * N), 0.2,
                                  jnp.float32)
        p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32))
        p["D"] = jnp.ones((nh,), jnp.float32)
        p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
        p["norm_s"] = jnp.zeros((di,), jnp.float32)
        p["out_proj"] = _dense_init(ks[6], (di, D), sc / cfg.n_layers**0.5,
                                    dtype)

    if moe_layer:
        E, Fe = cfg.n_experts, cfg.d_ff_expert
        p["w_router"] = _dense_init(ks[7], (D, E), sc, jnp.float32)
        p["we13"] = _dense_init(ks[8], (E, D, g, Fe), sc, dtype)
        p["we2"] = _dense_init(ks[9], (E, Fe, D), sc / cfg.n_layers**0.5, dtype)
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * Fe
            p["ws13"] = _dense_init(ks[10], (D, g, Fs), sc, dtype)
            p["ws2"] = _dense_init(ks[11], (Fs, D), sc / cfg.n_layers**0.5,
                                   dtype)
    elif cfg.d_ff and kind != "ssm":
        p["w13"] = _dense_init(ks[12], (D, g, cfg.d_ff), sc, dtype)
        p["w2"] = _dense_init(ks[13], (cfg.d_ff, D), sc / cfg.n_layers**0.5,
                              dtype)
    return p


def _stack_layers(cfg, key, layer_ids, kinds, moe_flags, dtype):
    """Build per-layer params and stack along dim 0 (for lax.scan)."""
    keys = jax.random.split(key, max(len(layer_ids), 1))

    def one(i):
        li = layer_ids[i]
        return _layer_params(cfg, keys[i], kinds[li % len(kinds)] if False
                             else kinds[i], moe_flags[i], dtype)

    trees = [one(i) for i in range(len(layer_ids))]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def layer_kinds(cfg: ArchConfig):
    return [cfg.pattern[i % len(cfg.pattern)] for i in range(cfg.n_layers)]


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    kq = jax.random.split(key, 8)
    Vp, D = cfg.vocab_padded, cfg.d_model
    params = {
        "embed": _dense_init(kq[0], (Vp, D), 0.02, dtype),
        "final_norm_s": (jnp.ones if cfg.norm == "layernorm" else jnp.zeros)(
            (D,), jnp.float32),
    }
    if cfg.norm == "layernorm":
        params["final_norm_b"] = jnp.zeros((D,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(kq[1], (D, Vp), 0.02, dtype)

    kinds = layer_kinds(cfg)
    nd = cfg.n_dense_layers if cfg.moe else 0
    if nd:
        params["dense_layers"] = _stack_layers(
            cfg, kq[2], list(range(nd)), kinds[:nd], [False] * nd, dtype)
    main_ids = list(range(nd, cfg.n_layers))
    params["layers"] = _stack_layers(
        cfg, kq[3], main_ids, kinds[nd:], [cfg.moe] * len(main_ids), dtype)

    if cfg.encdec:
        enc_kinds = ["global"] * cfg.n_enc_layers
        params["enc_layers"] = _stack_layers(
            cfg, kq[4], list(range(cfg.n_enc_layers)), enc_kinds,
            [False] * cfg.n_enc_layers, dtype)
        # decoder cross-attention params (stacked over decoder layers)
        def cross(i, k):
            hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv
            return {
                "wq": _dense_init(k, (D, H * hd), 0.02, dtype),
                "wk": _dense_init(k, (D, KV * hd), 0.02, dtype),
                "wv": _dense_init(k, (D, KV * hd), 0.02, dtype),
                "wo": _dense_init(k, (H * hd, D), 0.02, dtype),
                "ln_s": jnp.zeros((D,), jnp.float32),
            }
        ck = jax.random.split(kq[5], cfg.n_layers)
        trees = [cross(i, ck[i]) for i in range(cfg.n_layers)]
        params["cross_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    return params


# ---------------------------------------------------------------------------
# MLP / MoE stage dispatch (shard_map around the recipe pathways).
# ---------------------------------------------------------------------------
def mlp_tp_ok(F: int, tp: int) -> bool:
    """F can TP-shard over `tp` only if the shard stays 128-tile aligned
    (the FP8 transpose/quant block constraint)."""
    return F % tp == 0 and (F // tp) % 128 == 0


def _mlp_stage(cfg, recipe, plan, p, x):
    """Dense MLP.  x: (B, S, D) -> (B, S, D).

    Two sharded modes:
      TP  — d_ff over the model axis, tokens over dp, psum combine
            (requires (d_ff/tp) % 128 == 0 for the FP8 tile constraint);
      DP  — weights replicated on the model axis, tokens sharded over
            dp + model (no redundant compute, Wgrad psums over all axes).
    """
    B, S, D = x.shape
    g = cfg.gate_factor
    w13, w2 = p["w13"], p["w2"]
    F = w13.shape[-1]
    if not plan.shard_map_mlp or plan.mesh is None:
        y = dense_mlp(recipe, cfg.act, x.reshape(B * S, D),
                      w13.reshape(D, g * F), w2)
        return y.reshape(B, S, D)

    from repro.compat import pvary, shard_map
    tp_size = plan.mesh.shape[plan.tp_axis]
    use_tp = plan.mlp_tp and mlp_tp_ok(F, tp_size)
    gather = plan.fsdp_axis
    armed = quant_stats.stats_armed()
    all_axes = tuple(plan.mesh.axis_names)

    def body(x3, w13_l, w2_l):
        if gather:
            w13_l = jax.lax.all_gather(w13_l, gather, axis=0, tiled=True)
            w2_l = jax.lax.all_gather(w2_l, gather, axis=1, tiled=True)
        Dl, gl, Fl = w13_l.shape
        Bl, Sl, _ = x3.shape
        # flatten LOCALLY: merging sharded B and S dims at the shard_map
        # boundary forces XLA into full-replication resharding (measured
        # 53 GB/layer of involuntary all-gather on the pod mesh)
        y = _dense_mlp_sharded(recipe, cfg.act, plan, x3.reshape(Bl * Sl, Dl),
                               w13_l.reshape(Dl, gl * Fl), w2_l, tp=use_tp)
        y = y.reshape(Bl, Sl, Dl)
        if armed:
            # guard stats recorded inside this body are tracers of the
            # shard_map trace — thread them out per-shard, max-merge outside
            sv = quant_stats.drain_stats()
            sv = pvary(sv, tuple(
                a for a in all_axes if a not in getattr(sv, "vma", all_axes)))
            return y, sv[None]
        return y

    fs = plan.fsdp_axis
    dp = plan.dp_axes if B % _axes_prod(plan) == 0 else None
    seq_ax = plan.tp_axis if S % tp_size == 0 else None
    if use_tp:
        tok_spec = P(dp, None, None)
        w13_spec = P(fs, None, plan.tp_axis)
        w2_spec = P(plan.tp_axis, fs)
    else:
        # DP mode: tokens sharded over dp (batch) AND tp (seq) — matches the
        # SP residual sharding exactly: zero boundary resharding
        tok_spec = P(dp, seq_ax, None)
        w13_spec = P(fs, None, None)
        w2_spec = P(None, fs)
    out_specs = (tok_spec, P(all_axes, None)) if armed else tok_spec
    sm = shard_map(body, mesh=plan.mesh,
                   in_specs=(tok_spec, w13_spec, w2_spec),
                   out_specs=out_specs)
    if armed:
        y, sv = sm(x, w13, w2)
        quant_stats.reinject_stats(jnp.max(sv, axis=0))
        return y
    return sm(x, w13, w2)


def _dense_mlp_sharded(recipe, act, plan, xf, w13_l, w2_l, *, tp: bool):
    """Inside shard_map: dense MLP, TP (psum over tp_axis) or DP mode.
    Pads tokens AND the contraction dim to the 128-tile alignment the FP8
    pathway needs (e.g. hymba's d_model=1600); zero rows/cols are exact."""
    T, D = xf.shape
    Tp = (T + 127) // 128 * 128
    Dp = (D + 127) // 128 * 128
    if Tp != T or Dp != D:
        xf = jnp.pad(xf, ((0, Tp - T), (0, Dp - D)))
    if Dp != D:
        w13_l = jnp.pad(w13_l, ((0, Dp - D), (0, 0)))
        w2_l = jnp.pad(w2_l, ((0, 0), (0, Dp - D)))
    x3 = xf.reshape(1, Tp, D if Dp == D else Dp)
    dp = tuple(a for a in plan.dp_axes if a != plan.fsdp_axis)
    if tp:
        wg_axes, gx_axes = dp, (plan.tp_axis,)
    else:
        wg_axes, gx_axes = dp + (plan.tp_axis,), ()
    if recipe.name == "fp8_flow":
        qx = quantize_entry(recipe, x3)
        quant_stats.record_entry_stats("q_entry_mlp", x3, qx)
        y = expert_ffn(recipe, act, wg_axes, gx_axes, qx, w13_l[None],
                       w2_l[None])
    else:
        y = expert_ffn(recipe, act, wg_axes, gx_axes,
                       x3.astype(jnp.bfloat16), w13_l[None], w2_l[None])
    if tp:
        y = jax.lax.psum(y, plan.tp_axis)
    return y[0][:T, :D]


def _moe_stage(cfg, recipe, plan, p, x, decode=False):
    """MoE block.  x: (B, S, D) -> (B, S, D), aux-loss scalar."""
    B, S, D = x.shape
    g = cfg.gate_factor
    mcfg = MoEConfig(n_experts=cfg.n_experts, top_k=cfg.top_k, d_model=D,
                     d_ff=cfg.d_ff_expert, capacity_factor=cfg.capacity_factor,
                     ep_axis=plan.tp_axis, act=cfg.act,
                     dp_axes=(plan.dp_axes if not plan.fsdp_axis else tuple(
                         a for a in plan.dp_axes if a != plan.fsdp_axis)))
    we13, we2, wr = p["we13"], p["we2"], p["w_router"]

    if plan.mesh is None:
        # Fully-local MoE (EP=1, ep_axis=None: every collective an identity).
        # This is the path the DistPlan train step takes: the whole step is
        # already inside a shard_map over the DP axis (repro.dist), so the
        # forward must not open a nested shard_map.
        from repro.core.quant import QTensor as _QT0
        if isinstance(we13, _QT0):
            raise ValueError("W8-resident MoE weights need a mesh plan")
        E_l, Dl, gl, Fl = we13.shape
        mcfg_local = dataclasses.replace(mcfg, ep_axis=None, dp_axes=())
        y, m = moe_block(recipe, mcfg_local, x.reshape(B * S, D), wr,
                         we13.reshape(E_l, Dl, gl * Fl), we2)
        y = y.reshape(B, S, D)
        if cfg.n_shared_experts:
            y = y + _mlp_stage(cfg, recipe, plan,
                               {"w13": p["ws13"], "w2": p["ws2"]}, x)
        return y, jnp.mean(m["aux_loss"])

    from repro.compat import shard_map
    gather = plan.fsdp_axis
    # decode-EP only exists when experts are EP-sharded; TP-experts (E < tp)
    # use the same TP block for decode (forward-only)
    mode = (("decode" if plan.moe_mode == "ep" else "tp")
            if decode else plan.moe_mode)

    from repro.core.quant import QTensor as _QT
    w8 = isinstance(we13, _QT)

    def body(xf, wr_l, we13_l, we2_l):
        if w8:
            # W8-resident: fp8 payload + po2 scales live on-chip; no gather,
            # no per-step weight quantization (serve/w8.py)
            from repro.core.fp8 import TILE as _T
            from repro.serve.w8 import retile, w8_merge_gate
            we13_r = w8_merge_gate(retile(we13_l, (1, _T, 1, _T)))
            we2_l = retile(we2_l, (1, _T, _T))
        else:
            if gather:
                we13_l = jax.lax.all_gather(we13_l, gather, axis=1,
                                            tiled=True)
                we2_l = jax.lax.all_gather(we2_l, gather, axis=2, tiled=True)
            E_l, Dl, gl, Fl = we13_l.shape
            we13_r = we13_l.reshape(E_l, Dl, gl * Fl)
        if mode == "ep":
            if plan.moe_overlap is not None:
                y, m = moe_block_overlapped(
                    recipe, mcfg, xf, wr_l, we13_r, we2_l,
                    n_chunks=plan.moe_overlap.chunks_for(xf.shape[0]))
            else:
                y, m = moe_block(recipe, mcfg, xf, wr_l, we13_r, we2_l)
        elif mode == "tp":
            y, m = moe_block_tp(recipe, mcfg, xf, wr_l, we13_r, we2_l,
                                tp_axis=plan.tp_axis,
                                combine_mode=plan.moe_tp_combine)
        elif plan.moe_overlap is not None:
            # prefetching decode path: chunk c+1's router/dispatch/expert
            # stages run while chunk c's combine psum is on the wire
            y, m = moe_block_decode_overlapped(
                recipe, mcfg, xf, wr_l, we13_r, we2_l,
                n_chunks=plan.moe_overlap.decode_chunks_for(xf.shape[0]))
        else:
            y, m = moe_block_decode(recipe, mcfg, xf, wr_l, we13_r, we2_l)
        # aux loss leaves the shard_map as a per-shard (1,) array; the mean
        # happens outside (robust to size-1 mesh axes in the vma system)
        aux = m["aux_loss"][None]
        return y, aux

    if mode == "ep":
        tok_axes = plan.token_axes_moe if not decode else plan.dp_axes
        e_spec0 = plan.tp_axis
        out_tok_axes = tok_axes
    else:
        tok_axes = plan.dp_axes
        e_spec0 = None
        out_tok_axes = (tok_axes + (plan.tp_axis,)
                        if plan.moe_tp_combine == "reduce_scatter"
                        else tok_axes)
    if mode == "decode":
        tok_axes = plan.dp_axes
        e_spec0 = plan.tp_axis
        out_tok_axes = tok_axes
    we13_spec = (P(e_spec0, gather, None, None) if mode != "tp"
                 else P(None, gather, None, plan.tp_axis))
    we2_spec = (P(e_spec0, None, gather) if mode != "tp"
                else P(None, plan.tp_axis, gather))
    if w8:
        from repro.core.quant import QTensor as _QT2
        # QTensor weights: spec pytree matches (data, scale); scales shard
        # on the same (leading expert) axis
        we13_spec = _QT2(data=P(e_spec0, None, None, None),
                         scale=P(e_spec0, None, None, None),
                         tile=we13.tile)
        we2_spec = _QT2(data=P(e_spec0, None, None),
                        scale=P(e_spec0, None, None), tile=we2.tile)
    # 3D boundary specs (batch over dp, seq over tp where applicable) —
    # merging sharded dims at the boundary forces full-replication resharding
    tp_size = plan.mesh.shape[plan.tp_axis]
    dp3 = plan.dp_axes if B % _axes_prod(plan) == 0 else None
    seq3 = plan.tp_axis if (plan.tp_axis in (tok_axes if isinstance(
        tok_axes, tuple) else (tok_axes,)) and S % tp_size == 0) else None
    out_seq3 = plan.tp_axis if (plan.tp_axis in (out_tok_axes if isinstance(
        out_tok_axes, tuple) else (out_tok_axes,)) and S % tp_size == 0)         else None

    all_axes = tuple(plan.mesh.axis_names)

    armed = quant_stats.stats_armed()

    def body3(x3, wr_l, we13_l, we2_l):
        Bl, Sl, Dl = x3.shape
        y, aux = body(x3.reshape(Bl * Sl, Dl), wr_l, we13_l, we2_l)
        # broadcast the aux scalar onto every mesh axis so one out_spec
        # (sharded over all axes) is valid in every mode/mesh
        from repro.compat import pvary
        aux = pvary(aux, tuple(
            a for a in all_axes if a not in getattr(aux, "vma", all_axes)))
        y = y.reshape(Bl, -1, Dl)
        if armed:
            # guard stats recorded inside this body are tracers of the
            # shard_map trace — thread them out per-shard, max-merge outside
            sv = quant_stats.drain_stats()
            sv = pvary(sv, tuple(
                a for a in all_axes if a not in getattr(sv, "vma", all_axes)))
            return y, aux, sv[None]
        return y, aux

    out3 = (P(dp3, out_seq3, None), P(all_axes)) + \
        ((P(all_axes, None),) if armed else ())
    sm = shard_map(body3, mesh=plan.mesh,
                   in_specs=(P(dp3, seq3, None), P(None, None),
                             we13_spec, we2_spec),
                   out_specs=out3)

    # Overlap lever (§dispatch pipeline): with moe_overlap set, the shared
    # expert — which depends only on x, never on the dispatch — is ISSUED
    # BEFORE the MoE shard_map, so its dense GEMMs are ready to run while the
    # first chunk's fused dispatch all-to-all is on the wire.  Without the
    # overlap plan it stays after the MoE (the historical ordering).
    shared_out = None
    if (cfg.n_shared_experts and not decode
            and plan.moe_overlap is not None and mode == "ep"):
        shared_out = _mlp_stage(cfg, recipe, plan,
                                {"w13": p["ws13"], "w2": p["ws2"]}, x)

    if armed:
        y, aux, sv = sm(x, wr, we13, we2)
        quant_stats.reinject_stats(jnp.max(sv, axis=0))
    else:
        y, aux = sm(x, wr, we13, we2)
    aux = jnp.mean(aux)

    if cfg.n_shared_experts:
        if shared_out is not None:
            y = y + shared_out
        elif decode:
            y = y + _mlp_decode(cfg, {"w13": p["ws13"], "w2": p["ws2"]}, x)
        else:
            y = y + _mlp_stage(cfg, recipe, plan,
                               {"w13": p["ws13"], "w2": p["ws2"]}, x)
    return y, aux


# ---------------------------------------------------------------------------
# Layer groups + full forward.
# ---------------------------------------------------------------------------
def _residual_constraint(plan, x, decode=False):
    """Sequence-parallel sharding of the residual stream (B, S, D): tokens
    over dp axes AND seq over the model axis.  This is what bounds the
    scan-remat carry memory at scale; XLA inserts the gather/scatter pair
    around attention (Megatron-SP pattern)."""
    if plan.mesh is None or decode:
        return x
    B, S, D = x.shape
    tp = plan.mesh.shape[plan.tp_axis]
    seq_ax = plan.tp_axis if S % tp == 0 else None
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(
            plan.mesh, P(plan.dp_axes if B % _axes_prod(plan) == 0 else None,
                         seq_ax, None)))


def _axes_prod(plan):
    out = 1
    for a in plan.dp_axes:
        out *= plan.mesh.shape[a]
    return out


def _sub_layer(cfg, recipe, plan, kind, moe_layer, p, x, positions,
               cache=None, cache_pos=None, ssm_state=None, conv_state=None,
               causal=True):
    """One transformer layer.  Returns (x, aux, new_cache, new_ssm, new_conv).

    Staged decomposition (models/layers.LAYER_STAGES): stage 'attn' is
    stage_ln_attn (pure-attention kinds) or the mixer fan-out below; the MoE
    stages (router -> dispatch -> expert -> combine) run inside _moe_stage /
    core.moe."""
    aux = jnp.float32(0.0)
    new_cache, new_ssm, new_conv = None, None, None
    decode = cache is not None or ssm_state is not None

    if kind == "ssm":
        h = apply_norm(cfg.norm, x, p, "ln1")
        mix, new_ssm, new_conv = mamba2_block(
            cfg, p, h, state=ssm_state, conv_state=conv_state, decode=decode)
        x = x + mix
    elif kind == "hybrid":
        h = apply_norm(cfg.norm, x, p, "ln1")
        attn_out, new_cache = attn_block(
            cfg, p, h, positions=positions, layer_window=0, cache=cache,
            cache_pos=cache_pos, causal=causal, plan=plan)
        ssm_out, new_ssm, new_conv = mamba2_block(
            cfg, p, h, state=ssm_state, conv_state=conv_state, decode=decode)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        window = cfg.window if kind == "local" else 0
        x, new_cache = stage_ln_attn(
            cfg, p, x, positions=positions, layer_window=window, cache=cache,
            cache_pos=cache_pos, causal=causal, plan=plan)

    if kind == "ssm" and not cfg.d_ff:      # mamba2: mixer-only blocks
        x = _residual_constraint(plan, x, decode=decode)
        return x, aux, new_cache, new_ssm, new_conv

    from repro.core.quant import tag_saveable
    h2 = tag_saveable(apply_norm(cfg.norm, x, p, "ln2"), "stage_ffn_in")
    if moe_layer:
        if decode:
            mlp_out, aux = _moe_stage(cfg, recipe, plan, p, h2, decode=True)
        else:
            mlp_out, aux = _moe_stage(cfg, recipe, plan, p, h2)
    else:
        # dense layers have no router/dispatch/combine; the whole FFN is
        # one 'expert' stage so profiles line up across layer kinds
        from repro.obs.trace import stage_annotation
        with stage_annotation("expert"):
            mlp_out = _mlp_stage(cfg, recipe, plan, p, h2)
    out = x + mlp_out
    out = _residual_constraint(plan, out, decode=cache is not None
                               or ssm_state is not None)
    return out, aux, new_cache, new_ssm, new_conv


def _run_stack(cfg, recipe, plan, stack_params, pattern, n_layers, moe, x,
               positions, causal=True):
    """Scan over a homogeneous stack of layers, pattern-grouped: the stack is
    reshaped (n_groups, len(pattern), ...) and the pattern is unrolled inside
    the scan body.  Rematerialization is owned by the MemoryPlan
    (train/memory.py): the body is wrapped per cfg.remat_policy, and the
    'pair' policy folds TWO pattern groups into each checkpointed body
    (halving trace sites) when the depth allows."""
    pattern = _pattern_or_fallback(pattern, n_layers)
    mem = MemoryPlan.from_config(cfg)
    glen = len(pattern)
    ng = n_layers // glen
    fold = mem.group_factor(ng)
    if fold > 1:
        pattern = pattern * fold
        glen *= fold
        ng //= fold

    # guard-stats threading (train/guards.py): quantize-site stats recorded
    # inside the scan body are TRACERS of that body — they must ride the
    # carry out (drained in-body, max-merged) and be reinjected at this
    # level.  Unarmed (the default), the carry and jaxpr are unchanged.
    armed = quant_stats.stats_armed()

    def group_body(carry, pslice):
        xc, aux = carry[:2]
        for i in range(glen):
            pi = jax.tree.map(lambda a: a[i], pslice)
            xc, a, _, _, _ = _sub_layer(cfg, recipe, plan, pattern[i], moe,
                                        pi, xc, positions, causal=causal)
            aux = aux + a
        if armed:
            return (xc, aux, jnp.maximum(carry[2],
                                         quant_stats.drain_stats())), None
        return (xc, aux), None

    body = mem.wrap(group_body)
    grouped = jax.tree.map(
        lambda a: a.reshape(ng, glen, *a.shape[1:]), stack_params)
    init = (x, jnp.float32(0.0)) + \
        ((quant_stats.zero_stats(),) if armed else ())
    carry, _ = jax.lax.scan(body, init, grouped)
    x, aux = carry[:2]
    if armed:
        quant_stats.reinject_stats(carry[2])
    return x, aux


# ---------------------------------------------------------------------------
# Staged layer program: the unrolled stack driver + per-layer iteration the
# streaming DP wire (train/train_step._streamed_grads) drives directly.
# ---------------------------------------------------------------------------
def layer_forward(cfg, recipe, plan, kind, moe_layer, p, x, positions,
                  causal=True):
    """One decoder layer of the staged program (train/prefill path):
    stage 'attn', then the MLP/MoE stages (router -> dispatch -> expert ->
    combine inside core.moe).  Returns (x_out, aux) — the differentiable
    unit the per-layer backward emits gradients for."""
    out, aux, _, _, _ = _sub_layer(cfg, recipe, plan, kind, moe_layer, p, x,
                                   positions, causal=causal)
    return out, aux


def _pattern_or_fallback(pattern, n_layers: int):
    """THE single copy of the kind-sequence fallback rule: a pattern whose
    length does not divide the stack depth degrades to its first kind.
    Every stack driver (scan, unrolled, per-layer iteration) derives its
    kinds through here, so the staged backward's layer kinds can never
    desynchronize from the forward's."""
    return pattern if n_layers % len(pattern) == 0 else (pattern[0],)


def stack_patterns(cfg: ArchConfig):
    """(dense_pattern, main_pattern) as every stack driver resolves them."""
    nd = cfg.n_dense_layers if cfg.moe else 0
    return (cfg.pattern[0],), _pattern_or_fallback(cfg.pattern,
                                                   cfg.n_layers - nd)


def iter_layer_slices(cfg: ArchConfig, params):
    """Static per-layer walk of the stacked decoder stacks in forward order:
    yields (stack_name, layer_index, kind, moe_layer, per-layer params).
    The kind sequence matches _run_stack's pattern grouping exactly, so the
    staged and scanned forwards compute the same function."""
    nd = cfg.n_dense_layers if cfg.moe else 0
    dense_pat, main_pat = stack_patterns(cfg)
    if nd and "dense_layers" in params:
        for l in range(nd):
            yield ("dense_layers", l, dense_pat[l % len(dense_pat)], False,
                   jax.tree.map(lambda a, _l=l: a[_l],
                                params["dense_layers"]))
    for j in range(cfg.n_layers - nd):
        yield ("layers", j, main_pat[j % len(main_pat)], cfg.moe,
               jax.tree.map(lambda a, _j=j: a[_j], params["layers"]))


def _run_stack_unrolled(cfg, recipe, plan, stack_params, pattern, n_layers,
                        moe, x, positions, causal=True):
    """Staged (unrolled) stack driver: same math as _run_stack, but each
    layer is its own trace region with a TWO-LAYER CARRY WINDOW — layer L's
    scalar epilogue (the aux-loss landing) is deferred until after layer
    L+1's attn/router/dispatch stages have been issued, and the backward of
    the unrolled program emits per-layer gradient leaves in reverse layer
    order (what the streaming DP wire consumes).  The residual stream
    itself is strictly sequential; the real cross-layer overlap lives in
    the stage pipelines it enables (the chunked dispatch a2a and the
    decode combine-psum chain in core/moe.py).  Rematerialization is owned
    by the MemoryPlan: each checkpoint block holds one layer (or two under
    the 'pair' policy — the compile-time lever)."""
    pattern = _pattern_or_fallback(pattern, n_layers)
    mem = MemoryPlan.from_config(cfg)
    armed = quant_stats.stats_armed()
    aux = jnp.float32(0.0)
    pending = None                  # the two-layer window's deferred scalar
    for blk in mem.layer_blocks(n_layers):
        ps = tuple(jax.tree.map(lambda a, _l=l: a[_l], stack_params)
                   for l in blk)
        kinds = tuple(pattern[l % len(pattern)] for l in blk)

        def f(ps_, xc, _kinds=kinds):
            a_blk = jnp.float32(0.0)
            for p, kind in zip(ps_, _kinds):
                xc, a = layer_forward(cfg, recipe, plan, kind, moe, p, xc,
                                      positions, causal=causal)
                a_blk = a_blk + a
            if armed:   # guard stats: drained in-block, threaded out
                return xc, a_blk, quant_stats.drain_stats()
            return xc, a_blk

        if armed:
            x, a, sv = mem.wrap(f)(ps, x)
            quant_stats.reinject_stats(sv)
        else:
            x, a = mem.wrap(f)(ps, x)
        if pending is not None:     # the previous block's epilogue lands
            aux = aux + pending     # only after this block was issued
        pending = a
    if pending is not None:
        aux = aux + pending
    return x, aux


# ---------------------------------------------------------------------------
# Forward (train / prefill) + loss.
# ---------------------------------------------------------------------------
def _embed_tokens(cfg, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _lm_logits(cfg, params, x, plan=None):
    """Logits stay BF16 and VOCAB-SHARDED over the model axis; the residual
    enters seq-gathered so the two 'model' shardings never conflict (else XLA
    replicates the (T, V) tensor — 2.3 GiB/device at 152k vocab)."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if plan is not None and plan.mesh is not None:
        B, S, D = x.shape
        dp = plan.dp_axes if B % _axes_prod(plan) == 0 else None
        x = jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(plan.mesh, P(dp, None, None)))
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if plan is not None and plan.mesh is not None:
        Vp = logits.shape[-1]
        v_ax = plan.tp_axis if Vp % plan.mesh.shape[plan.tp_axis] == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(plan.mesh, P(dp, None, v_ax)))
    if cfg.final_softcap:
        logits = (cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)).astype(logits.dtype)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :],
                           jnp.asarray(-1e4, logits.dtype), logits)
    return logits   # BF16, vocab-sharded — f32 only inside the CE kernel


@jax.custom_vjp
def _xent(logits, targets, mask):
    """Cross-entropy over BF16 vocab-sharded logits.  The custom VJP keeps
    both the forward reductions and the backward dlogits in BF16 payloads
    (f32 math fused elementwise) — the (T, V) tensor never exists in f32."""
    loss, _ = _xent_fwd_impl(logits, targets, mask)
    return loss


def _xent_fwd_impl(logits, targets, mask):
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)) + m
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((lse - gold) * mask) / denom
    return loss, (logits, targets, mask, lse, denom)


def _xent_fwd(logits, targets, mask):
    return _xent_fwd_impl(logits, targets, mask)


def _xent_bwd(res, g):
    logits, targets, mask, lse, denom = res
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    dlogits = (p - onehot) * (mask * g / denom)[..., None]
    return dlogits.astype(logits.dtype), None, None


_xent.defvjp(_xent_fwd, _xent_bwd)


def forward(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan, params,
            batch, compute_loss=True):
    """batch: {'tokens' (B,S_tok) int32, 'targets' (B,S_tok), 'mask' (B,S_tok),
    optional 'prefix' (B,P,D) [vlm/audio frontend stub embeddings],
    optional 'enc_input' (B,S_enc,D) [seamless]}.
    Returns (loss, metrics) or (logits, metrics)."""
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    if cfg.frontend != "none" and "prefix" in batch:
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
    B, S, D = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    aux_total = jnp.float32(0.0)

    cross_kv_src = None
    if cfg.encdec:
        enc = batch["enc_input"].astype(x.dtype)
        enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
        enc, aux_e = _run_stack(cfg, recipe, plan, params["enc_layers"],
                                ("global",), cfg.n_enc_layers, False, enc,
                                enc_pos, causal=False)
        aux_total += aux_e
        enc = apply_norm(cfg.norm, enc, {"enc_norm_s": None} if False else
                         {"final_norm_s": params["final_norm_s"],
                          "final_norm_b": params.get("final_norm_b")},
                         "final_norm")
        cross_kv_src = enc

    # staged (unrolled, two-layer window) vs monolithic-scan stack driver
    run_stack = _run_stack_unrolled if plan.stage_layers else _run_stack

    nd = cfg.n_dense_layers if cfg.moe else 0
    if nd:
        x, aux_d = run_stack(cfg, recipe, plan, params["dense_layers"],
                             (cfg.pattern[0],), nd, False, x, positions)
        aux_total += aux_d

    if cfg.encdec:
        x, aux_m = _run_encdec_decoder(cfg, recipe, plan, params, x,
                                       positions, cross_kv_src)
    else:
        x, aux_m = run_stack(cfg, recipe, plan, params["layers"], cfg.pattern,
                             cfg.n_layers - nd, cfg.moe, x, positions)
    aux_total += aux_m

    x = apply_norm(cfg.norm, x, {"final_norm_s": params["final_norm_s"],
                                 "final_norm_b": params.get("final_norm_b")},
                   "final_norm")
    if cfg.frontend != "none" and "prefix" in batch:
        x = x[:, batch["prefix"].shape[1]:]
    logits = _lm_logits(cfg, params, x, plan)
    metrics = {"aux_loss": aux_total}
    if quant_stats.stats_armed():
        # final drain: every stack driver reinjected its threaded stats at
        # this level, so the merged matrix exits value_and_grad via has_aux
        sv = quant_stats.drain_stats()
        sm = quant_stats.site_maxima(sv)
        metrics["quant_sat_frac"] = sm[0]
        metrics["quant_flush_frac"] = sm[1]
        metrics["quant_site_stats"] = sv
    if not compute_loss:
        return logits, metrics
    mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
    loss = _xent(logits, batch["targets"], mask) + AUX_LOSS_COEF * aux_total
    metrics["loss"] = loss
    return loss, metrics


def _run_encdec_decoder(cfg, recipe, plan, params, x, positions, enc):
    """Decoder stack with cross-attention (scanned; cross params stacked)."""
    armed = quant_stats.stats_armed()

    def group_body(carry, pslice):
        xc, aux = carry[:2]
        p_self, p_cross = pslice
        xc, a, _, _, _ = _sub_layer(cfg, recipe, plan, "global", cfg.moe,
                                    p_self, xc, positions)
        h = rms_or_ln(cfg, xc, p_cross)
        from repro.models.layers import attn_block as _ab
        kv = _project_cross_kv(cfg, p_cross, enc)
        c_out, _ = _ab(cfg, p_cross, h, positions=positions, cross_kv=kv)
        xc = xc + c_out
        aux = aux + a
        if armed:
            return (xc, aux, jnp.maximum(carry[2],
                                         quant_stats.drain_stats())), None
        return (xc, aux), None

    body = MemoryPlan.from_config(cfg).wrap(group_body)
    init = (x, jnp.float32(0.0)) + \
        ((quant_stats.zero_stats(),) if armed else ())
    carry, _ = jax.lax.scan(
        body, init, (params["layers"], params["cross_layers"]))
    if armed:
        quant_stats.reinject_stats(carry[2])
    return carry[0], carry[1]


def rms_or_ln(cfg, x, p_cross):
    from repro.models.layers import rmsnorm
    return rmsnorm(x, p_cross["ln_s"])


def _project_cross_kv(cfg, p, enc):
    B, Se, D = enc.shape
    KV, hd = cfg.n_kv, cfg.head_dim
    k = jnp.einsum("bsd,dn->bsn", enc, p["wk"].astype(enc.dtype))
    v = jnp.einsum("bsd,dn->bsn", enc, p["wv"].astype(enc.dtype))
    return k.reshape(B, Se, KV, hd), v.reshape(B, Se, KV, hd)


# ---------------------------------------------------------------------------
# Serving: KV/SSM caches + single-token decode step.
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               cache_dtype=jnp.bfloat16, fp8_kv: bool = False):
    """Cache pytree.  fp8_kv stores K/V payloads in e4m3 with per-(token,
    head) po2 scales — the beyond-paper KV-compression option (halves the
    decode memory-roofline term)."""
    kinds = layer_kinds(cfg)
    nd = cfg.n_dense_layers if cfg.moe else 0
    KV, hd = cfg.n_kv, cfg.head_dim
    kv_dtype = jnp.float8_e4m3fn if fp8_kv else cache_dtype

    def attn_cache(n):
        return {
            "k": jnp.zeros((n, batch, max_len, KV, hd), kv_dtype),
            "v": jnp.zeros((n, batch, max_len, KV, hd), kv_dtype),
        }

    def ssm_cache(n):
        di, N = cfg.d_inner, cfg.ssm_state
        H, Pd = cfg.ssm_heads, cfg.ssm_headdim
        return {
            "state": jnp.zeros((n, batch, H, Pd, N), jnp.float32),
            "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, di + 2 * N),
                              jnp.float32),
        }

    cache = {}
    main_kinds = kinds[nd:]
    n_main = len(main_kinds)
    if any(k != "ssm" for k in main_kinds):
        cache["main_attn"] = attn_cache(n_main)
    if any(k in ("ssm", "hybrid") for k in main_kinds):
        cache["main_ssm"] = ssm_cache(n_main)
    if nd:
        cache["dense_attn"] = attn_cache(nd)
    if cfg.encdec:
        # cross-attention K/V are computed once at prefill and fixed
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), cache_dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), cache_dtype),
        }
    return cache


def _cache_rw(cfg, p, kind, x, positions, pos, kc, vc, recipe, plan,
              moe_layer):
    """One decode layer given its cache slices; returns (x, new_k, new_v...)."""
    raise NotImplementedError  # folded into decode_step's scan body below


def decode_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan, params,
                cache, tokens, pos):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 (shared
    position — the fixed-batch bench path) OR (B,) int32 per-request
    positions (continuous batching; cache rows [0, pos_b) are filled).
    Returns (logits (B,1,V), new_cache)."""
    x = _embed_tokens(cfg, params, tokens)
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full((1,), pos,
                                                            jnp.int32)
    kinds = layer_kinds(cfg)
    nd = cfg.n_dense_layers if cfg.moe else 0
    new_cache = dict(cache)

    def run_decode_stack(x, stack_params, stack_kinds, moe, attn_c, ssm_c,
                         cross_c=None, cross_params=None):
        glen = len(stack_kinds) if len(set(stack_kinds)) > 1 else 1
        n = len(stack_kinds)
        # decode scans layer-by-layer (glen folded in as static python loop
        # is unnecessary: window flag differs per layer kind, so scan groups)
        pat = cfg.pattern if n % len(cfg.pattern) == 0 else (stack_kinds[0],)
        glen = len(pat)
        ng = n // glen

        def body(carry, xs):
            xc = carry
            pslice = xs["p"]
            outs = {}
            for i in range(glen):
                pi = jax.tree.map(lambda a: a[i], pslice)
                kind = pat[i]
                kc = vc = st = cv = None
                if attn_c is not None:
                    kc = xs["k"][i]
                    vc = xs["v"][i]
                if ssm_c is not None:
                    st = xs["state"][i]
                    cv = xs["conv"][i]
                window = cfg.window if kind == "local" else 0
                aux = jnp.float32(0.0)
                h = apply_norm(cfg.norm, xc, pi, "ln1")
                nk = nv = nst = ncv = None
                if kind == "ssm":
                    mix, nst, ncv = mamba2_block(cfg, pi, h, state=st,
                                                 conv_state=cv, decode=True)
                elif kind == "hybrid":
                    a_out, (nk, nv) = attn_block(
                        cfg, pi, h, positions=positions, layer_window=0,
                        cache=(kc, vc), cache_pos=pos)
                    s_out, nst, ncv = mamba2_block(cfg, pi, h, state=st,
                                                   conv_state=cv, decode=True)
                    mix = 0.5 * (a_out + s_out)
                else:
                    mix, (nk, nv) = attn_block(
                        cfg, pi, h, positions=positions, layer_window=window,
                        cache=(kc, vc), cache_pos=pos)
                xc = xc + mix
                if cross_params is not None:
                    pc = xs["pc"]
                    hc = rms_or_ln(cfg, xc, pc)
                    ck = xs["ck"]
                    cv_ = xs["cv_"]
                    c_out, _ = attn_block(cfg, pc, hc, positions=positions,
                                          cache=(ck, cv_), cache_pos=pos,
                                          cross_kv=(ck.astype(hc.dtype),
                                                    cv_.astype(hc.dtype)))
                    xc = xc + c_out
                if not (kind == "ssm" and not cfg.d_ff):
                    h2 = apply_norm(cfg.norm, xc, pi, "ln2")
                    if moe:
                        mo, _ = _moe_stage(cfg, recipe, plan, pi, h2,
                                           decode=True)
                    else:
                        mo = _mlp_decode(cfg, pi, h2)
                    xc = xc + mo
                outs.setdefault("k", []).append(nk)
                outs.setdefault("v", []).append(nv)
                outs.setdefault("state", []).append(nst)
                outs.setdefault("conv", []).append(ncv)
            emit = {}
            if attn_c is not None:
                emit["k"] = jnp.stack([o if o is not None else xs["k"][i]
                                       for i, o in enumerate(outs["k"])])
                emit["v"] = jnp.stack([o if o is not None else xs["v"][i]
                                       for i, o in enumerate(outs["v"])])
            if ssm_c is not None:
                emit["state"] = jnp.stack(
                    [o if o is not None else xs["state"][i]
                     for i, o in enumerate(outs["state"])])
                emit["conv"] = jnp.stack(
                    [o if o is not None else xs["conv"][i]
                     for i, o in enumerate(outs["conv"])])
            return xc, emit

        xs = {"p": jax.tree.map(
            lambda a: a.reshape(ng, glen, *a.shape[1:]), stack_params)}
        if attn_c is not None:
            xs["k"] = attn_c["k"].reshape(ng, glen, *attn_c["k"].shape[1:])
            xs["v"] = attn_c["v"].reshape(ng, glen, *attn_c["v"].shape[1:])
        if ssm_c is not None:
            xs["state"] = ssm_c["state"].reshape(
                ng, glen, *ssm_c["state"].shape[1:])
            xs["conv"] = ssm_c["conv"].reshape(
                ng, glen, *ssm_c["conv"].shape[1:])
        if cross_params is not None:
            xs["pc"] = cross_params
            xs["ck"] = cross_c["k"]
            xs["cv_"] = cross_c["v"]
        x, emits = jax.lax.scan(body, x, xs)
        out_attn = out_ssm = None
        if attn_c is not None:
            out_attn = {"k": emits["k"].reshape(n, *emits["k"].shape[2:]),
                        "v": emits["v"].reshape(n, *emits["v"].shape[2:])}
        if ssm_c is not None:
            out_ssm = {
                "state": emits["state"].reshape(n, *emits["state"].shape[2:]),
                "conv": emits["conv"].reshape(n, *emits["conv"].shape[2:])}
        return x, out_attn, out_ssm

    if nd:
        x, d_attn, _ = run_decode_stack(
            x, params["dense_layers"], kinds[:nd], False,
            cache.get("dense_attn"), None)
        new_cache["dense_attn"] = d_attn

    x, m_attn, m_ssm = run_decode_stack(
        x, params["layers"], kinds[nd:], cfg.moe,
        cache.get("main_attn"), cache.get("main_ssm"),
        cross_c=cache.get("cross"),
        cross_params=params.get("cross_layers"))
    if m_attn is not None:
        new_cache["main_attn"] = m_attn
    if m_ssm is not None:
        new_cache["main_ssm"] = m_ssm

    x = apply_norm(cfg.norm, x, {"final_norm_s": params["final_norm_s"],
                                 "final_norm_b": params.get("final_norm_b")},
                   "final_norm")
    logits = _lm_logits(cfg, params, x, plan)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged serving: chunked prefill + per-request decode over paged KV pools
# (serve/paged_kv.py).  Each request sits at its own depth (`pos` is a (B,)
# vector), KV rows live in fixed-size pages addressed through per-request
# page tables, and page payloads are FP8-e4m3 with per-row po2 scales (or
# BF16 with the fallback pools).  Attention-only decoder stacks.
# ---------------------------------------------------------------------------
def _run_paged_stack(cfg, recipe, plan, stack_params, stack_kinds, moe, x,
                     pool, positions, page_idx, slot_idx, *, decode,
                     page_tables=None, pos=None, history=False):
    """Scan a layer stack against its paged K/V pools.

    pool: {"k": {"data" (n,P,ps,KV,hd) [, "scale"]}, "v": {...}}.
    page_idx/slot_idx: (N,) write coordinates for this step's rows (scratch
    page 0 for masked rows).  decode=True reads the paged history through
    `page_tables` and masks by per-request `pos`; decode=False (prefill) runs
    causal flash attention over the in-flight chunk — with history=True
    (a chunked-prefill CONTINUATION) the chunk's queries additionally attend
    to the previously prefilled rows, read back through `page_tables` after
    this chunk's rows are written (absolute-position causal masking keeps
    unwritten/scratch rows out of every receptive field).
    Returns (x, new_pool)."""
    from repro.models.layers import flash_attention, project_qkv
    from repro.serve.paged_kv import page_read, page_write_rows

    n = len(stack_kinds)
    pat = cfg.pattern if n % len(cfg.pattern) == 0 else (stack_kinds[0],)
    glen = len(pat)
    ng = n // glen

    def body(xc, xs):
        new_pools = []
        for i in range(glen):
            pi = jax.tree.map(lambda a: a[i], xs["p"])
            kc = jax.tree.map(lambda a: a[i], xs["k"])
            vc = jax.tree.map(lambda a: a[i], xs["v"])
            window = cfg.window if pat[i] == "local" else 0
            h = apply_norm(cfg.norm, xc, pi, "ln1")
            q, k, v = project_qkv(cfg, pi, h, positions)
            rows_k = k[:, 0] if decode else k[0]
            rows_v = v[:, 0] if decode else v[0]
            kc = page_write_rows(kc, rows_k, page_idx, slot_idx)
            vc = page_write_rows(vc, rows_v, page_idx, slot_idx)
            if decode:
                from repro.models.layers import decode_attention
                kd = page_read(kc, page_tables, jnp.bfloat16)
                vd = page_read(vc, page_tables, jnp.bfloat16)
                o = decode_attention(q, kd.astype(q.dtype),
                                     vd.astype(q.dtype), pos=pos,
                                     window=window, softcap=cfg.attn_softcap)
            elif history:
                # chunked-prefill continuation: attend over the request's
                # full paged history (this chunk's rows included — they were
                # just written above) with absolute-position causal masking
                kd = page_read(kc, page_tables, jnp.bfloat16)
                vd = page_read(vc, page_tables, jnp.bfloat16)
                Skv = kd.shape[1]
                bk = next(b for b in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                          if Skv % b == 0)
                o = flash_attention(q, kd.astype(q.dtype), vd.astype(q.dtype),
                                    q_pos=positions,
                                    kv_pos=jnp.arange(Skv, dtype=jnp.int32),
                                    causal=True, window=window,
                                    softcap=cfg.attn_softcap, block_k=bk)
            else:
                o = flash_attention(q, k, v, q_pos=positions,
                                    kv_pos=positions, causal=True,
                                    window=window, softcap=cfg.attn_softcap)
            B, S = xc.shape[:2]
            mix = jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1),
                             pi["wo"].astype(xc.dtype))
            xc = xc + mix
            h2 = apply_norm(cfg.norm, xc, pi, "ln2")
            if moe:
                mo, _ = _moe_stage(cfg, recipe, plan, pi, h2, decode=decode)
            else:
                mo = _mlp_decode(cfg, pi, h2) if decode \
                    else _mlp_stage(cfg, recipe, plan, pi, h2)
            xc = xc + mo
            new_pools.append({"k": kc, "v": vc})
        emit = jax.tree.map(lambda *ys: jnp.stack(ys), *new_pools)
        return xc, emit

    grouped = lambda t: jax.tree.map(
        lambda a: a.reshape(ng, glen, *a.shape[1:]), t)
    xs = {"p": grouped(stack_params), "k": grouped(pool["k"]),
          "v": grouped(pool["v"])}
    x, emits = jax.lax.scan(body, x, xs)
    new_pool = jax.tree.map(lambda a: a.reshape(n, *a.shape[2:]), emits)
    return x, new_pool


def _paged_stacks(cfg):
    """(kinds, nd) after validating the arch is paged-serving capable."""
    kinds = layer_kinds(cfg)
    if cfg.encdec or cfg.frontend != "none" or any(
            k in ("ssm", "hybrid") for k in kinds):
        raise NotImplementedError(
            "paged serving supports attention-only decoder stacks")
    return kinds, (cfg.n_dense_layers if cfg.moe else 0)


def paged_decode_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                      params, pools, page_tables, tokens, pos, active):
    """One continuous-batching decode step over paged pools.

    tokens (B, 1) int32; pos (B,) int32 per-request positions (this token's
    position; rows [0, pos_b) are resident); active (B,) bool — inactive
    slots write to the scratch page and their outputs are garbage;
    page_tables (B, max_pages) int32.  Returns (logits (B,1,V), new_pools)."""
    kinds, nd = _paged_stacks(cfg)
    x = _embed_tokens(cfg, params, tokens)
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]
    ps = pools["main_attn"]["k"]["data"].shape[2]
    page_idx = jnp.where(active,
                         page_tables[jnp.arange(B), pos // ps], 0)
    slot_idx = pos % ps

    new_pools = dict(pools)
    if nd:
        x, new_pools["dense_attn"] = _run_paged_stack(
            cfg, recipe, plan, params["dense_layers"], kinds[:nd], False, x,
            pools["dense_attn"], positions, page_idx, slot_idx, decode=True,
            page_tables=page_tables, pos=pos)
    x, new_pools["main_attn"] = _run_paged_stack(
        cfg, recipe, plan, params["layers"], kinds[nd:], cfg.moe, x,
        pools["main_attn"], positions, page_idx, slot_idx, decode=True,
        page_tables=page_tables, pos=pos)

    x = apply_norm(cfg.norm, x, {"final_norm_s": params["final_norm_s"],
                                 "final_norm_b": params.get("final_norm_b")},
                   "final_norm")
    logits = _lm_logits(cfg, params, x, plan)
    return logits, new_pools


def paged_prefill(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                  params, pools, page_table_row, tokens, length,
                  start=None, history: bool = False):
    """Prefill ONE request's prompt chunk into its pages.

    tokens (1, S) int32, right-padded to the static bucket S (a power of two
    so flash blocking divides); length: scalar int32 valid token count IN
    THIS CHUNK; page_table_row (max_pages,) int32.  Rows >= length land on
    the scratch page; causal masking keeps them out of every valid query's
    receptive field.

    Chunked prefill: `start` (scalar int32, default 0) offsets this chunk
    inside the prompt — positions/RoPE/page coordinates are absolute — and
    history=True (static) makes the chunk's queries attend to the previously
    prefilled rows [0, start) through the page table.  Returns
    (logits (1, 1, V) at absolute position start+length-1, new_pools)."""
    kinds, nd = _paged_stacks(cfg)
    x = _embed_tokens(cfg, params, tokens)
    S = x.shape[1]
    rel = jnp.arange(S, dtype=jnp.int32)
    start = jnp.int32(0) if start is None else jnp.asarray(start, jnp.int32)
    positions = start + rel
    ps = pools["main_attn"]["k"]["data"].shape[2]
    page_idx = jnp.where(rel < length, page_table_row[positions // ps], 0)
    slot_idx = positions % ps

    new_pools = dict(pools)
    if nd:
        x, new_pools["dense_attn"] = _run_paged_stack(
            cfg, recipe, plan, params["dense_layers"], kinds[:nd], False, x,
            pools["dense_attn"], positions, page_idx, slot_idx, decode=False,
            page_tables=page_table_row[None], history=history)
    x, new_pools["main_attn"] = _run_paged_stack(
        cfg, recipe, plan, params["layers"], kinds[nd:], cfg.moe, x,
        pools["main_attn"], positions, page_idx, slot_idx, decode=False,
        page_tables=page_table_row[None], history=history)

    x = apply_norm(cfg.norm, x, {"final_norm_s": params["final_norm_s"],
                                 "final_norm_b": params.get("final_norm_b")},
                   "final_norm")
    x_last = jax.lax.dynamic_slice_in_dim(
        x, jnp.clip(length - 1, 0, S - 1), 1, axis=1)
    logits = _lm_logits(cfg, params, x_last, plan)
    return logits, new_pools


def _mlp_decode(cfg, p, x):
    """Forward-only dense MLP for decode (BF16 einsum; TP via pjit auto)."""
    B, S, D = x.shape
    w13 = p["w13"].astype(x.dtype)                    # (D, g, F)
    h = jnp.einsum("bsd,dgf->bsgf", x, w13)
    if cfg.gate_factor == 2:
        gt, up = h[..., 0, :], h[..., 1, :]
        gf = gt.astype(jnp.float32)
        a = (jax.nn.silu(gf) if cfg.act == "swiglu"
             else jax.nn.gelu(gf, approximate=True)) * up.astype(jnp.float32)
    else:
        hf = h[..., 0, :].astype(jnp.float32)
        a = jax.nn.gelu(hf, approximate=True) if cfg.act == "gelu" \
            else jax.nn.relu(hf)
    return jnp.einsum("bsf,fd->bsd", a.astype(x.dtype),
                      p["w2"].astype(x.dtype))
