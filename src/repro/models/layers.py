"""Transformer layer primitives: norms, RoPE, blocked (flash-style) attention
with GQA / sliding-window / logit-softcap / qk-norm, and cache-decode
attention.  Pure JAX; attention stays BF16 in every recipe (the paper's FP8
scope is the MoE/MLP stage).

Staged layer program: each decoder layer decomposes into the named stages

    attn -> router -> dispatch -> expert -> combine     (MoE layers)
    attn -> ffn                                         (dense layers)

`stage_ln_attn` below is the 'attn' stage (pre-norm + mixer + residual);
the MoE stages live in core/moe.py (decode_stage_router / _dispatch /
_expert + the combine psum/a2a) and models/lm.py drives them — either
fused inside the monolithic scan (`_run_stack`) or unrolled with a
two-layer carry window (`_run_stack_unrolled` / the streaming dist
backward) so work can be issued across layer and stage boundaries."""
from __future__ import annotations

import jax
import jax.numpy as jnp

LAYER_STAGES = ("attn", "router", "dispatch", "expert", "combine")

NEG_INF = -1e30


def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(kind, x, p, name):
    if kind == "layernorm":
        return layernorm(x, p[f"{name}_s"], p[f"{name}_b"])
    return rmsnorm(x, p[f"{name}_s"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention: online softmax over KV blocks keeps the
# (S x S) logits matrix out of HBM — required for the 32k prefill shapes to
# fit the 16 GB dry-run budget.
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=0,
                    softcap=0.0, block_k=256, carry_sharding=None):
    """q: (B, Sq, H, hd); k,v: (B, Skv, KV, hd).  GQA via head grouping.
    window > 0 masks kv older than `window` positions behind the query.
    carry_sharding: (mesh, dp, seq_ax) — pins the online-softmax carry to the
    q sharding so the scan carry never replicates (context parallelism).

    Custom VJP: the backward pass RECOMPUTES scores block-by-block (flash
    backward) instead of letting autodiff save the (Sq x Skv) probability
    matrix — without this the 32k shapes cannot fit HBM."""
    spec = _FlashSpec(causal=causal, window=window, softcap=softcap,
                      block_k=min(block_k, k.shape[1]),
                      carry_sharding=carry_sharding)
    return _flash(spec, q, k, v, q_pos, kv_pos)


import dataclasses as _dc
from functools import partial as _partial


@_dc.dataclass(frozen=True)
class _FlashSpec:
    causal: bool
    window: int
    softcap: float
    block_k: int
    carry_sharding: object  # hashable tuple (mesh, dp, seq_ax) or None


def _mask_for(spec, q_pos, pblk, Sq, bk):
    mask = jnp.ones((Sq, bk), bool)
    if spec.causal:
        mask &= pblk[None, :] <= q_pos[:, None]
    if spec.window:
        mask &= pblk[None, :] > (q_pos[:, None] - spec.window)
    return mask


def _constrain_carry(spec, qf, m0, l0, a0):
    if spec.carry_sharding is None:
        return qf, m0, l0, a0
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh, dp, seq_ax = spec.carry_sharding
    c4 = NamedSharding(mesh, P(dp, seq_ax, None, None))
    c5 = NamedSharding(mesh, P(dp, seq_ax, None, None, None))
    return (jax.lax.with_sharding_constraint(qf, c5),
            jax.lax.with_sharding_constraint(m0, c4),
            jax.lax.with_sharding_constraint(l0, c4),
            jax.lax.with_sharding_constraint(a0, c5))


def _flash_fwd_impl(spec, q, k, v, q_pos, kv_pos):
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # fold the softmax scale into q ONCE (saves a full-scores multiply per
    # kv block — §Perf memory-term iteration)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, hd)
    bk = spec.block_k
    nb = Skv // bk
    assert nb * bk == Skv, (Skv, bk)
    kb = jnp.moveaxis(k.astype(jnp.float32).reshape(B, nb, bk, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.astype(jnp.float32).reshape(B, nb, bk, KV, hd), 1, 0)
    pb = kv_pos.reshape(nb, bk)

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    qf, m0, l0, a0 = _constrain_carry(spec, qf, m0, l0, a0)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk = blk
        s = jnp.einsum("bqkgh,bckh->bqkgc", qf, kblk)
        if spec.softcap:
            s = spec.softcap * jnp.tanh(s / spec.softcap)
        mask = _mask_for(spec, q_pos, pblk, Sq, bk)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqkgc,bckh->bqkgh",
                                                     p, vblk)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (B,Sq,KV,G)
    o4 = out.reshape(B, Sq, H, hd).astype(q.dtype)
    if spec.carry_sharding is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh, dp, seq_ax = spec.carry_sharding
        o4 = jax.lax.with_sharding_constraint(
            o4, NamedSharding(mesh, P(dp, seq_ax, None, None)))
    return o4, (out, lse)


@_partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(spec, q, k, v, q_pos, kv_pos):
    return _flash_fwd_impl(spec, q, k, v, q_pos, kv_pos)[0]


def _flash_fwd(spec, q, k, v, q_pos, kv_pos):
    o, (out, lse) = _flash_fwd_impl(spec, q, k, v, q_pos, kv_pos)
    return o, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(spec, res, g):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, hd)
    gf = g.astype(jnp.float32).reshape(B, Sq, KV, G, hd)
    bk = spec.block_k
    nb = Skv // bk
    kb = jnp.moveaxis(k.astype(jnp.float32).reshape(B, nb, bk, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.astype(jnp.float32).reshape(B, nb, bk, KV, hd), 1, 0)
    pb = kv_pos.reshape(nb, bk)
    # D_i = rowsum(g * out)
    Drow = jnp.sum(gf * out, axis=-1)                     # (B,Sq,KV,G)

    dq0 = jnp.zeros_like(qf)

    def body(dq, blk):
        kblk, vblk, pblk = blk
        s_raw = jnp.einsum("bqkgh,bckh->bqkgc", qf, kblk)
        if spec.softcap:
            t = jnp.tanh(s_raw / spec.softcap)
            s_capped = spec.softcap * t
        else:
            s_capped = s_raw
        mask = _mask_for(spec, q_pos, pblk, Sq, bk)
        s_m = jnp.where(mask[None, :, None, None, :], s_capped, NEG_INF)
        p = jnp.exp(s_m - lse[..., None])                 # (B,Sq,KV,G,c)
        dv = jnp.einsum("bqkgc,bqkgh->bckh", p, gf)
        dp = jnp.einsum("bqkgh,bckh->bqkgc", gf, vblk)
        ds = p * (dp - Drow[..., None])                   # d s_capped
        if spec.softcap:
            ds = ds * (1.0 - t * t)                       # through tanh
        # scale folded into qf: dq needs ds*scale@k (applied at the end),
        # dk needs ds@(q*scale) = ds@qf directly
        dq_blk = jnp.einsum("bqkgc,bckh->bqkgh", ds, kblk)
        dk = jnp.einsum("bqkgc,bqkgh->bckh", ds, qf)
        return dq + dq_blk, (dk, dv)

    dq, (dk_s, dv_s) = jax.lax.scan(body, dq0, (kb, vb, pb))
    dq = dq * scale        # complete d(q*scale)/dq
    dk = jnp.moveaxis(dk_s, 0, 1).reshape(B, Skv, KV, hd)
    dv = jnp.moveaxis(dv_s, 0, 1).reshape(B, Skv, KV, hd)
    return (dq.reshape(B, Sq, H, hd).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(q, k_cache, v_cache, *, pos, window=0, softcap=0.0):
    """Single-step decode: q (B, 1, H, hd); caches (B, Smax, KV, hd).
    pos: current position — scalar (shared phase, the fixed-batch bench path)
    OR a (B,) vector (continuous batching: each request at its own depth);
    kv rows [0, pos_b] are valid.
    For windowed layers with a SCALAR pos only the last `window` cache rows
    are read (dynamic_slice) — the local-attention memory saving is real; the
    per-request path reads the full cache and window-masks (starts differ
    per row, so a shared slice does not exist)."""
    B, _, H, hd = q.shape
    _, Smax, KV, _ = k_cache.shape
    pos = jnp.asarray(pos)
    per_request = pos.ndim == 1
    if window and window < Smax and not per_request:
        start = jnp.clip(pos - window + 1, 0, Smax - window)
        k_r = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        v_r = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        kv_pos = start + jnp.arange(window)
    else:
        k_r, v_r = k_cache, v_cache
        kv_pos = jnp.arange(Smax)
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qf, k_r.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    if per_request:
        mask = kv_pos[None, :] <= pos[:, None]          # (B, c)
        if window:
            mask &= kv_pos[None, :] > (pos[:, None] - window)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    else:
        s = jnp.where((kv_pos <= pos)[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", p, v_r.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + attention [+ cache update]).
# ---------------------------------------------------------------------------
def _cp_constrain(plan, q, k, v):
    """Sequence-parallel (context-parallel) attention sharding: queries stay
    seq-sharded over the model axis (matching the residual-stream SP), keys/
    values are gathered — uniform across head counts (DESIGN.md §4).
    Returns (q, k, v, carry_sharding) for flash_attention."""
    if plan is None or plan.mesh is None:
        return q, k, v, None
    import numpy as _np
    from jax.sharding import NamedSharding, PartitionSpec as P
    tp = plan.mesh.shape[plan.tp_axis]
    B, S = q.shape[0], q.shape[1]
    dp_size = int(_np.prod([plan.mesh.shape[a] for a in plan.dp_axes])) \
        if plan.dp_axes else 1
    dp = plan.dp_axes if B % max(1, dp_size) == 0 else None
    seq_ax = plan.tp_axis if S % tp == 0 else None
    q = jax.lax.with_sharding_constraint(
        q, NamedSharding(plan.mesh, P(dp, seq_ax, None, None)))
    k = jax.lax.with_sharding_constraint(
        k, NamedSharding(plan.mesh, P(dp, None, None, None)))
    v = jax.lax.with_sharding_constraint(
        v, NamedSharding(plan.mesh, P(dp, None, None, None)))
    return q, k, v, (plan.mesh, dp, seq_ax)


def project_qkv(cfg, p, x, positions, cross_kv=None):
    """QKV projections + bias + qk-norm + RoPE (shared by the train/prefill,
    dense-cache decode, and paged decode paths).
    x: (B, S, D); positions: (S,) or (B, S).  Returns q (B,S,H,hd) and
    k, v (B,S,KV,hd) (or the passed-through cross_kv)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = jnp.einsum("bsd,dn->bsn", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    if cross_kv is None:
        k = jnp.einsum("bsd,dn->bsn", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dn->bsn", x, p["wv"].astype(x.dtype))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        k = k.reshape(B, S, KV, hd)
        v = v.reshape(B, S, KV, hd)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"]) if cross_kv is None else k
    if cross_kv is None and cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def stage_ln_attn(cfg, p, x, *, positions, layer_window=0, cache=None,
                  cache_pos=None, causal=True, plan=None):
    """Named stage 'attn' for pure-attention layer kinds: pre-norm +
    attention + residual add.  Returns (x + attn_out, new_cache).  The
    stage output is checkpoint_name-tagged so the MemoryPlan 'full' policy
    (train/memory.py) can keep the bf16 stage boundary resident."""
    from repro.core.quant import tag_saveable
    from repro.obs.trace import stage_annotation
    with stage_annotation("attn"):
        h = apply_norm(cfg.norm, x, p, "ln1")
        out, new_cache = attn_block(cfg, p, h, positions=positions,
                                    layer_window=layer_window, cache=cache,
                                    cache_pos=cache_pos, causal=causal,
                                    plan=plan)
        return tag_saveable(x + out, "stage_attn_out"), new_cache


def attn_block(cfg, p, x, *, positions, layer_window=0, cache=None,
               cache_pos=None, cross_kv=None, causal=True, plan=None):
    """cfg: ArchConfig; p: layer param dict; x: (B, S, D).
    cache: optional (k_cache, v_cache) for decode; cache_pos scalar (shared
    phase) or (B,) per-request; cross_kv: (k, v) already projected encoder
    states for cross-attention."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q, k, v = project_qkv(cfg, p, x, positions, cross_kv=cross_kv)

    carry_sharding = None
    if cache is None:
        q, k, v, carry_sharding = _cp_constrain(plan, q, k, v)

    if cache is not None:
        k_cache, v_cache = cache
        if cross_kv is None:
            if jnp.ndim(cache_pos) == 1:   # per-request write rows
                rows = jnp.arange(B)
                k_cache = k_cache.at[rows, cache_pos].set(
                    k[:, 0].astype(k_cache.dtype))
                v_cache = v_cache.at[rows, cache_pos].set(
                    v[:, 0].astype(v_cache.dtype))
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    k_cache, k.astype(k_cache.dtype), cache_pos, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    v_cache, v.astype(v_cache.dtype), cache_pos, axis=1)
        o = decode_attention(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                             pos=cache_pos, window=layer_window,
                             softcap=cfg.attn_softcap)
        new_cache = (k_cache, v_cache)
    else:
        is_causal = causal and cross_kv is None
        kv_pos = positions if cross_kv is None else \
            jnp.arange(k.shape[1], dtype=positions.dtype)
        o = flash_attention(q, k, v, q_pos=positions, kv_pos=kv_pos,
                            causal=is_causal, window=layer_window,
                            softcap=cfg.attn_softcap,
                            carry_sharding=carry_sharding)
        new_cache = None
    out = jnp.einsum("bsn,nd->bsd", o.reshape(B, S, H * hd),
                     p["wo"].astype(x.dtype))
    return out, new_cache
