"""Mamba2 (SSD — state-space duality) layer, chunked training form + O(1)
decode recurrence.  Pure JAX/BF16: the recurrent state accumulation is
exactly the reduction class the paper keeps out of FP8 (DESIGN.md §6);
the in/out projections could use the FP8 linear recipe but their irregular
widths (2*di + 2*N + nh) break 128-tile alignment, so they stay BF16 —
recorded as partial applicability."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def _segsum(log_a):
    """log_a: (..., Q).  out[..., i, j] = sum_{j < k <= i} log_a_k (else -inf)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]            # sum_(j,i]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD forward.
      x : (b, S, H, P)   per-head inputs
      dt: (b, S, H)      positive step sizes
      A : (H,)           negative per-head decay rates
      B : (b, S, N)      input maps   (n_groups = 1, broadcast over heads)
      C : (b, S, N)      output maps
    Returns y (b, S, H, P) and the final state (b, H, P, N)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, (S, Q)

    xb = x.reshape(b, nc, Q, H, P)
    dtb = dt.reshape(b, nc, Q, H)
    Bb = B.reshape(b, nc, Q, N)
    Cb = C.reshape(b, nc, Q, N)
    log_a = (dtb * A[None, None, None, :])                # (b,nc,Q,H) negative

    # intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(log_a, -1, -2)))     # (b,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)        # (b,nc,Q,Q)
    M = scores[:, :, None] * L                            # (b,nc,H,Q,Q)
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M, dtb, xb)

    # chunk state contributions
    csum = jnp.cumsum(log_a, axis=2)                      # (b,nc,Q,H)
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)     # (b,nc,Q,H)
    S_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                         Bb, dtb * decay_to_end, xb)      # (b,nc,H,P,N)
    a_chunk = jnp.exp(csum[:, :, -1, :])                  # (b,nc,H)

    # inter-chunk scan (sequential over nc — the recurrent reduction)
    def body(state, inp):
        S_c, a_c = inp                                    # (b,H,P,N),(b,H)
        new = state * a_c[..., None, None] + S_c
        return new, state                                 # emit state BEFORE chunk

    init = jnp.zeros((b, H, P, N), jnp.float32)
    final, prev_states = jax.lax.scan(
        body, init, (jnp.moveaxis(S_chunk, 1, 0).astype(jnp.float32),
                     jnp.moveaxis(a_chunk, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (b,nc,H,P,N)

    decay_from_start = jnp.exp(csum)                      # (b,nc,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cb, decay_from_start, prev_states.astype(Cb.dtype))
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, final


def mamba2_block(cfg, p, x, *, state=None, conv_state=None, decode=False):
    """Full Mamba2 mixer.  x: (B, S, D).
    Training (decode=False): returns (y, None, None).
    Decode (S == 1): returns (y, new_state (B,H,P,N), new_conv (B,conv-1,ch))."""
    Bsz, S, D = x.shape
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    conv = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)

    # causal depthwise conv over [xs|B|C]
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)          # (B,S,ch)
    if decode:
        hist = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        new_conv = hist[:, -(conv - 1):]
        xbc = jnp.einsum("bck,ck->bk", hist[:, -conv:],
                         p["conv_w"].astype(xbc.dtype))[:, None, :]
    else:
        pad = jnp.zeros((Bsz, conv - 1, xbc.shape[-1]), xbc.dtype)
        hist = jnp.concatenate([pad, xbc], axis=1)
        xbc = sum(hist[:, i:i + S] * p["conv_w"][i].astype(xbc.dtype)
                  for i in range(conv))
        new_conv = None
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,)
    xh = xs.reshape(Bsz, S, H, P)

    if decode:
        a = jnp.exp(dt[:, 0, :] * A[None, :])             # (B,H)
        dBx = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0], dt[:, 0], xh[:, 0])
        new_state = state * a[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], new_state)[:, None]
        y = y.reshape(Bsz, 1, H, P)
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)

    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    # gated RMSNorm (mamba2) then output projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x.dtype), p["norm_s"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    return out, new_state if decode else None, new_conv
