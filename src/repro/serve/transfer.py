"""Casting-free KV page migration: the wire codec for prefill/decode
disaggregation.

The paged KV cache stores e4m3 payloads + per-row po2 scales, which makes a
page the cheapest possible — and *casting-free* — wire format for moving a
request between replicas: migration is a pure BITCAST of what is already in
the pool.  The codec packs, per page batch, for every (stack, k/v) pool:

  * the e4m3 payload bytes verbatim (``bitcast_convert_type`` to uint8), and
  * the f32 po2 scales as int8 exponents via the ``dist/scale_sync`` bit
    codec (``scale_to_exp_i8_bits`` — shift/bias on the f32 bit pattern,
    value-identical to the frexp/ldexp wire codec of the DP gradient wire),

into ONE uint8 message (host header + device payload).  Unpacking on the
receiver bitcasts straight back into its pool, so a migrated page is
bit-for-bit the donor's page: zero quantize/dequantize ops ride the
migration path.  That is not just asserted on values — ``assert_casting_free``
walks the codec's jaxprs and proves NO floating-point-typed primitive other
than pure data movement (gather/scatter/bitcast/reshape/...) exists, which
is exactly the casting-free property the paper's recipe gives the training
dataflow, applied to the serving wire (FP8-LM makes the same observation for
gradient traffic: FP8 payload + pre-agreed scales halve the wire with zero
re-quantization).

Page batches are padded to a power-of-two bucket (scratch-page rows — never
read back) so the fleet compiles O(log max_pages) gather/scatter programs,
mirroring the engine's prefill buckets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.scale_sync import exp_i8_to_scale_bits, scale_to_exp_i8_bits
from repro.serve.paged_kv import SCRATCH_PAGE

_MAGIC = 0x4B56_5747          # "KVWG": KV wire, guarded by a header check
_VERSION = 1

# Primitives that may touch floating-point-typed values inside the codec:
# pure data movement.  Anything numeric (div/mul of a quantize, convert of a
# cast, reduce_max of an amax pass) is absent from this set, so the
# casting-free assert below is structural, not statistical.
_DATA_MOVEMENT = frozenset({
    "gather", "scatter", "slice", "dynamic_slice", "dynamic_update_slice",
    "concatenate", "reshape", "broadcast_in_dim", "transpose", "squeeze",
    "bitcast_convert_type", "copy", "rev", "pad",
})


def _is_int_like(dt) -> bool:
    dt = jnp.dtype(dt)
    return jnp.issubdtype(dt, jnp.integer) or dt == jnp.dtype(jnp.bool_)


def _walk_eqns(jaxpr, visit):
    for eqn in jaxpr.eqns:
        visit(eqn)
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                inner = v.jaxpr
                _walk_eqns(getattr(inner, "jaxpr", inner), visit)


def check_casting_free(jaxpr) -> None:
    """Raise AssertionError if `jaxpr` contains any primitive that performs
    numeric work on a floating-point-typed value.  Floats (f32 scales, e4m3
    payloads, bf16 pools) may only flow through data-movement primitives;
    ``convert_element_type`` is only allowed between integer types (the
    exponent bias arithmetic) — so no quantize (div + convert-to-fp8) and no
    dequantize (convert-from-fp8 + mul) can hide anywhere in the codec."""
    def visit(eqn):
        dts = [v.aval.dtype for v in list(eqn.invars) + list(eqn.outvars)
               if hasattr(v, "aval") and hasattr(v.aval, "dtype")]
        name = eqn.primitive.name
        if name == "convert_element_type":
            assert all(_is_int_like(d) for d in dts), \
                f"codec is not casting-free: convert_element_type on {dts}"
            return
        if any(not _is_int_like(d) for d in dts):
            assert name in _DATA_MOVEMENT, \
                f"codec is not casting-free: float-typed `{name}`"
    _walk_eqns(jaxpr, visit)


def _u8(x: jax.Array) -> jax.Array:
    """Bitcast to uint8; multi-byte dtypes grow a trailing byte axis that is
    folded into the last dim (same idiom as the DP gradient wire)."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint8)
    if u.ndim == x.ndim:
        return u
    return u.reshape(*x.shape[:-1], -1)


def _bucket(n: int) -> int:
    """Next power-of-two page-batch bucket (0 stays 0)."""
    return 1 << max(0, n - 1).bit_length() if n else 0


@dataclasses.dataclass(frozen=True)
class TransferMeta:
    """Per-migration header fields (ride the wire as int32 words).  The
    prompt tokens ARE the radix identity: the receiver re-shares the shipped
    pages by inserting the prompt's full-block prefix into its own radix
    tree, so later migrations of the same tenant dedupe against them."""
    rid: int
    n_pages: int                  # REAL page count (<= the padded bucket)
    page_size: int
    bytes_per_page: int           # geometry fingerprint (fleet must agree)
    pos: int                      # tokens prefilled (== len(prompt))
    max_new_tokens: int
    temperature: float            # rides the wire as raw f32 bits
    prompt: Tuple[int, ...]
    generated: Tuple[int, ...]    # tokens sampled so far (the prefill token)

    _N_HEAD = 11                  # header words before the token arrays

    def to_bytes(self) -> np.ndarray:
        tbits = int(np.float32(self.temperature).view(np.int32))
        head = np.array([_MAGIC, _VERSION, self.rid, self.n_pages,
                         self.page_size, self.bytes_per_page, self.pos,
                         self.max_new_tokens, tbits,
                         len(self.prompt), len(self.generated)], np.int32)
        words = np.concatenate([head,
                                np.asarray(self.prompt, np.int32),
                                np.asarray(self.generated, np.int32)])
        return words.view(np.uint8)

    @classmethod
    def from_bytes(cls, msg: np.ndarray) -> Tuple["TransferMeta", int]:
        """Parse a packed message's header; returns (meta, payload offset)."""
        nh = cls._N_HEAD
        head = msg[:nh * 4].view(np.int32)
        if int(head[0]) != _MAGIC or int(head[1]) != _VERSION:
            raise ValueError("not a KV transfer message (bad magic/version)")
        n_prompt, n_gen = int(head[9]), int(head[10])
        off = (nh + n_prompt + n_gen) * 4
        words = msg[nh * 4:off].view(np.int32)
        return cls(rid=int(head[2]), n_pages=int(head[3]),
                   page_size=int(head[4]), bytes_per_page=int(head[5]),
                   pos=int(head[6]), max_new_tokens=int(head[7]),
                   temperature=float(np.int32(int(head[8])).view(np.float32)),
                   prompt=tuple(int(t) for t in words[:n_prompt]),
                   generated=tuple(int(t) for t in words[n_prompt:])), off


class KVTransferCodec:
    """Bitcast pack/unpack of KV pages for one pool geometry.

    Built from a pools pytree (donor and receiver must share geometry — the
    ``bytes_per_page`` fingerprint in the header is checked on adopt).  The
    device work is two jitted programs per page-batch bucket: a gather that
    flattens the selected pages of every (stack, k/v) pool into one uint8
    vector, and a scatter (pools donated) that writes received bytes into
    the receiver's reserved pages.  Both are float-op-free by construction;
    ``assert_casting_free`` proves it on the traced jaxprs.
    """

    def __init__(self, pools):
        self.parts: List[Tuple[str, str, bool, object, int, int, int, int]] \
            = []
        page_size = None
        for stack in sorted(pools):
            for kv in ("k", "v"):
                p = pools[stack][kv]
                L, _, ps, KV, hd = p["data"].shape
                self.parts.append((stack, kv, "scale" in p,
                                   jnp.dtype(p["data"].dtype), L, ps, KV, hd))
                page_size = ps
        if page_size is None:
            raise ValueError("empty pools")
        self.page_size = page_size
        self.bytes_per_page = sum(
            L * ps * KV * (hd * dt.itemsize + (1 if has_scale else 0))
            for (_, _, has_scale, dt, L, ps, KV, hd) in self.parts)
        self._gather = jax.jit(self._gather_impl)
        self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))

    # -- device programs (pure bitcast + data movement) --------------------
    def _gather_impl(self, pools, ids: jax.Array) -> jax.Array:
        out = []
        for (stack, kv, has_scale, _, _, _, _, _) in self.parts:
            p = pools[stack][kv]
            out.append(_u8(p["data"][:, ids]).reshape(-1))
            if has_scale:
                exp = scale_to_exp_i8_bits(p["scale"][:, ids])
                out.append(_u8(exp).reshape(-1))
        return jnp.concatenate(out)

    def _scatter_impl(self, pools, payload: jax.Array,
                      ids: jax.Array) -> Dict:
        n = ids.shape[0]
        pools = jax.tree.map(lambda x: x, pools)   # shallow rebuild
        off = 0
        for (stack, kv, has_scale, dt, L, ps, KV, hd) in self.parts:
            p = dict(pools[stack][kv])
            nb = L * n * ps * KV * hd * dt.itemsize
            raw = payload[off:off + nb]
            off += nb
            if dt.itemsize == 1:
                vals = jax.lax.bitcast_convert_type(
                    raw.reshape(L, n, ps, KV, hd), dt)
            else:
                vals = jax.lax.bitcast_convert_type(
                    raw.reshape(L, n, ps, KV, hd, dt.itemsize), dt)
            p["data"] = p["data"].at[:, ids].set(vals)
            if has_scale:
                nbs = L * n * ps * KV
                exp = jax.lax.bitcast_convert_type(
                    payload[off:off + nbs].reshape(L, n, ps, KV, 1), jnp.int8)
                off += nbs
                p["scale"] = p["scale"].at[:, ids].set(
                    exp_i8_to_scale_bits(exp))
            pools[stack][kv] = p
        return pools

    # -- host API ----------------------------------------------------------
    def bytes_for(self, n_pages: int) -> int:
        """Wire payload bytes for an n-page batch (bucket-padded, as
        shipped; the transfer-bytes budget meters this)."""
        return _bucket(n_pages) * self.bytes_per_page

    def _pad_ids(self, page_ids: Sequence[int]) -> jnp.ndarray:
        b = _bucket(len(page_ids))
        ids = list(page_ids) + [SCRATCH_PAGE] * (b - len(page_ids))
        return jnp.asarray(ids, jnp.int32)

    def pack(self, pools, page_ids: Sequence[int],
             meta: TransferMeta) -> np.ndarray:
        """One uint8 message: header + bucket-padded page payload (padding
        gathers the scratch page; the receiver's padding writes land back in
        its own scratch page and are never read)."""
        header = meta.to_bytes()
        if not page_ids:
            return np.asarray(header)
        payload = np.asarray(self._gather(pools, self._pad_ids(page_ids)))
        return np.concatenate([header, payload])

    def unpack(self, msg: np.ndarray) -> Tuple[TransferMeta, np.ndarray]:
        meta, off = TransferMeta.from_bytes(msg)
        if meta.bytes_per_page != self.bytes_per_page:
            raise ValueError(
                f"pool geometry mismatch: message bytes/page "
                f"{meta.bytes_per_page} != local {self.bytes_per_page}")
        return meta, msg[off:]

    def scatter(self, pools, payload: np.ndarray,
                dst_ids: Sequence[int]):
        """Write a received payload into `dst_ids` (REAL pages; padding up
        to the bucket is scratch-directed).  Returns the updated pools."""
        if not len(dst_ids):
            return pools
        return self._scatter(pools, jnp.asarray(payload),
                             self._pad_ids(dst_ids))

    # -- the zero-requantization proof -------------------------------------
    def assert_casting_free(self, pools, n: int = 2) -> None:
        """Trace both codec programs and assert their jaxprs contain zero
        floating-point numeric ops (see check_casting_free) — migration can
        not quantize, dequantize, or cast anything, by construction."""
        ids = jnp.zeros((_bucket(n),), jnp.int32)
        gj = jax.make_jaxpr(self._gather_impl)(pools, ids)
        check_casting_free(gj.jaxpr)
        payload = jnp.zeros((self.bytes_for(n),), jnp.uint8)
        sj = jax.make_jaxpr(self._scatter_impl)(pools, payload, ids)
        check_casting_free(sj.jaxpr)
