"""Continuous-batching FP8 serving engine.

The piece the ROADMAP's "heavy traffic" north star needs between the model
and the world: a request queue feeding interleaved prefill/decode over
(a) W8-resident FP8 expert weights (serve/w8.py — quantized ONCE, the
grouped GEMMs consume the paper's blockwise-po2 format directly) and
(b) a paged FP8-e4m3 KV cache with per-row po2 scales (serve/paged_kv.py).

Execution model
---------------
One engine *tick* = one call into a single jitted step function:

    engine_step(..., bucket=<static>) =
        [prefill one admitted request's prompt chunk]   (if bucket)
      + [decode every resident request one token]       (if any resident)
      + [sample (greedy / temperature+top-k)]

All shapes are STATIC per (bucket, any_decode, history): decode always runs
over the full `max_batch` slot array behind an `active` mask, and prompts
are padded to a power-of-two bucket — so XLA compiles O(|buckets|) programs
total and never recompiles as the batch mix changes (requests
arrive/finish/evict).

Chunked prefill (``prefill_chunk``): long prompts are sliced into bounded
token chunks, one chunk per tick, each writing its pages at an absolute
``start`` offset and (for continuations) attending to the already-prefilled
history through the page table.  Residents keep decoding every tick, so
decode latency under mixed load is bounded by ONE chunk's compute instead of
a whole long prompt; the continuation has strict FCFS priority over new
admissions.

Scheduling is FCFS with decode priority and a reserved-token budget
(serve/scheduler.py); KV pages come from a host-side free-list with
youngest-first eviction under pressure (restart semantics).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.recipes import Recipe
from repro.models.lm import (ParallelPlan, paged_decode_step, paged_prefill)
from repro.obs.metrics import po2_buckets
from repro.obs.sink import null_telemetry
from repro.serve.paged_kv import (PageAllocator, copy_page, init_paged_cache,
                                  pool_nbytes)
from repro.serve.scheduler import Request, RequestState, Scheduler

# latency histogram edges: 2^-4 .. 2^14 ms covers sub-ms decode ticks
# through multi-second saturated TTFTs
_LAT_BUCKETS = po2_buckets(-4, 14)


class TraceResults(dict):
    """run()'s return value: the rid -> per-request result dict it always
    was, plus `.stats` (run-level aggregate counters)."""
    stats: dict

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.stats = {}


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (all static — they shape the compiled programs)."""
    max_batch: int = 8                 # resident-request slots
    page_size: int = 16                # tokens per KV page
    n_pages: int = 256                 # pool pages (page 0 is scratch)
    max_pages_per_req: int = 16        # page-table width
    token_budget: int = 2048           # sum(prompt+max_new) over residents
    prefill_buckets: Sequence[int] = (16, 32, 64, 128)
    prefill_chunk: Optional[int] = None  # chunked prefill: max prompt tokens
                                       # per tick (None = whole prompt in one
                                       # tick).  Bounds how long residents'
                                       # decodes can stall behind a long
                                       # prompt; prompts may then exceed the
                                       # largest bucket (chunks must fit it)
    fp8_kv: bool = True                # e4m3 pages w/ po2 scales, else bf16
    w8_weights: bool = False           # pre-quantize expert weights (fp8_flow)
    prefix_cache: bool = False         # radix prefix cache over the KV pool:
                                       # shared page-aligned prompt prefixes
                                       # are quantized+prefilled once and
                                       # reused (refcounted pages; LRU leaf
                                       # eviction under pool pressure)
    top_k: int = 0                     # 0 -> full-vocab sampling
    eos_id: Optional[int] = None
    seed: int = 0
    role: str = "mixed"                # disaggregation tier:
                                       #   "mixed"   — classic engine (prefill
                                       #               + decode interleaved)
                                       #   "prefill" — admission + chunked
                                       #               prefill only; finished
                                       #               prefills PARK in the
                                       #               handoff queue for KV
                                       #               migration to a decode
                                       #               replica
                                       #   "decode"  — no admission; requests
                                       #               arrive pre-filled via
                                       #               the adopt path and run
                                       #               the masked decode batch

    @property
    def max_len(self) -> int:
        return self.max_pages_per_req * self.page_size


def sample_tokens(logits, key, temps, top_k: int):
    """logits (N, V); temps (N,) — greedy where temp <= 0, else
    temperature + (optional) top-k categorical."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / jnp.maximum(temps, 1e-6)[:, None]
    if top_k:
        kth = jax.lax.top_k(lf, top_k)[0][:, -1][:, None]
        lf = jnp.where(lf < kth, -1e30, lf)
    sampled = jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def make_engine_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                     ecfg: ServeConfig):
    """The one jitted step: optional bucketed prefill chunk + masked
    full-batch decode + sampling.  `bucket`/`any_decode`/`history` are
    static; `history` marks a chunked-prefill CONTINUATION (the chunk's
    queries attend to the already-prefilled pages at absolute offset
    `pf_start`)."""

    @partial(jax.jit, static_argnames=("bucket", "any_decode", "history"),
             donate_argnums=(1,))
    def engine_step(params, pools, page_tables, last_tok, pos, active, temps,
                    pf_tokens, pf_len, pf_ptrow, pf_start, pf_temp, key, *,
                    bucket: Optional[int], any_decode: bool,
                    history: bool = False):
        out = {}
        if bucket is not None:
            lg, pools = paged_prefill(cfg, recipe, plan, params, pools,
                                      pf_ptrow, pf_tokens, pf_len,
                                      start=pf_start, history=history)
            out["prefill_tok"] = sample_tokens(
                lg[:, -1, :], jax.random.fold_in(key, 0), pf_temp[None],
                ecfg.top_k)[0]
        if any_decode:
            lg, pools = paged_decode_step(cfg, recipe, plan, params, pools,
                                          page_tables, last_tok[:, None],
                                          pos, active)
            out["decode_toks"] = sample_tokens(
                lg[:, -1, :], jax.random.fold_in(key, 1), temps, ecfg.top_k)
        return pools, out

    return engine_step


class ServeEngine:
    """Continuous-batching serving over paged FP8 KV + W8-resident weights.

    Usage::

        eng = ServeEngine(cfg, recipe, plan, params, ServeConfig(...))
        results = eng.run([Request(prompt=[...], max_new_tokens=8), ...])
    """

    def __init__(self, cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                 params, ecfg: ServeConfig = ServeConfig(), telemetry=None):
        self.cfg, self.recipe, self.plan, self.ecfg = cfg, recipe, plan, ecfg
        self.tel = telemetry if telemetry is not None else null_telemetry()
        if ecfg.role not in ("mixed", "prefill", "decode"):
            raise ValueError(f"unknown role {ecfg.role!r}")
        if ecfg.prefill_chunk is not None and (
                ecfg.prefill_chunk < 1
                or ecfg.prefill_chunk > max(ecfg.prefill_buckets)):
            raise ValueError(
                f"prefill_chunk {ecfg.prefill_chunk} must be in "
                f"[1, {max(ecfg.prefill_buckets)}] (largest bucket)")
        if ecfg.w8_weights and recipe.name == "fp8_flow":
            from repro.serve.w8 import quantize_params_for_serving
            params = quantize_params_for_serving(params)
        self.params = params
        self.pools = init_paged_cache(cfg, ecfg.n_pages, ecfg.page_size,
                                      fp8_kv=ecfg.fp8_kv)
        self.alloc = PageAllocator(ecfg.n_pages, ecfg.page_size)
        self.prefix_cache = None
        release_hook = None
        if ecfg.prefix_cache:
            from repro.serve.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(ecfg.page_size, telemetry=self.tel)
            # the single scheduler release point: decref instead of free, so
            # cache-held pages survive their writer finishing/being evicted
            release_hook = lambda st, pages, alloc: alloc.decref(pages)
        self.sched = Scheduler(ecfg.max_batch, ecfg.token_budget,
                               release_hook=release_hook)
        self._step_fn = make_engine_step(cfg, recipe, plan, ecfg)
        self._key = jax.random.key(ecfg.seed)
        self._tick_count = 0
        self.max_concurrent = 0
        self.total_decoded = 0
        self.n_rejected = 0
        self.n_prefill_chunks = 0
        self.n_migrated_out = 0
        # prefill tier: states whose prefill completed this/earlier ticks,
        # parked (slot/pages/budget held) until the router migrates their KV
        # to a decode replica and the receiver acks
        self.handoff: deque = deque()
        self._codec = None

    @property
    def codec(self):
        """KV page transfer codec for this engine's pool geometry (lazy —
        only disaggregated fleets pay for tracing it)."""
        if self._codec is None:
            from repro.serve.transfer import KVTransferCodec
            self._codec = KVTransferCodec(self.pools)
        return self._codec

    # -- queue -------------------------------------------------------------
    def _reject(self, req: Request, msg: str):
        """A request that can NEVER be served is dropped (counted) and the
        caller gets the ValueError it always did."""
        self.n_rejected += 1
        self.tel.counter("serve_rejected_total").inc()
        self.tel.record("request_rejected", rid=req.rid, reason=msg)
        raise ValueError(msg)

    def submit(self, req: Request) -> None:
        ecfg = self.ecfg
        if ecfg.role == "decode":
            self._reject(req, "decode-tier replica does not admit requests "
                         "(route to the prefill tier; KV arrives via adopt)")
        P = len(req.prompt)
        if P < 1 or req.max_new_tokens < 1:
            self._reject(req, "empty prompt / zero max_new_tokens")
        if ecfg.prefill_chunk is None and P > max(ecfg.prefill_buckets):
            self._reject(req, f"prompt {P} exceeds the largest prefill "
                         f"bucket {max(ecfg.prefill_buckets)} "
                         f"(set prefill_chunk to slice long prompts)")
        if P + req.max_new_tokens > ecfg.max_len:
            self._reject(req, f"request needs {P + req.max_new_tokens} "
                         f"tokens > max_len {ecfg.max_len}")
        if req.reserved_tokens > ecfg.token_budget:
            self._reject(req, "request alone exceeds the token budget")
        if self.alloc.pages_for(P + req.max_new_tokens) > ecfg.n_pages - 1:
            self._reject(req, "request alone exceeds the KV pool")
        self.sched.submit(req)

    # -- one tick ----------------------------------------------------------
    def _alloc_pages(self, n: int):
        """Pool allocation with the prefix cache as the first pressure
        valve: LRU unreferenced radix leaves are dropped before any
        resident request is considered for eviction."""
        if self.prefix_cache is not None:
            return self.prefix_cache.alloc_pages(self.alloc, n)
        return self.alloc.alloc(n)

    def _grow_pages(self, st: RequestState) -> bool:
        """Ensure st's page table covers its next write; evicts YOUNGER
        residents under pressure (st self-evicts when it is the youngest —
        the oldest resident always progresses).  False if st got unseated."""
        need = st.next_pos // self.ecfg.page_size + 1
        while len(st.pages) < need:
            got = self._alloc_pages(1)
            if got is not None:
                st.pages.extend(got)
                continue
            # evict_youngest(requester=st) always has a victim (st itself at
            # worst); the too-small-pool case is rejected in submit()
            ev = self.sched.evict_youngest(self.alloc, requester=st)
            assert ev is not None
            self.tel.counter("serve_evicted_total").inc()
            self.tel.record("request_evicted", rid=ev.req.rid,
                            by=st.req.rid, n_evictions=ev.n_evictions)
            if ev is st:
                return False
        return st.slot in self.sched.active

    def tick(self, now: float, results: Dict[int, dict]) -> bool:
        """One engine tick; returns True if any work ran."""
        ecfg, sched = self.ecfg, self.sched

        # decode set: resident + prefilled, with page headroom (may evict).
        # Parked states (prefill tier, awaiting migration) are excluded:
        # their KV is frozen until the receiver copies it.
        for slot in sorted(sched.active):
            st = sched.active.get(slot)
            if st is not None and st.prefilled and not st.parked:
                self._grow_pages(st)
        decode_slots = [s for s in sorted(sched.active)
                        if sched.active[s].prefilled
                        and not sched.active[s].parked]

        # decode-priority prefill work: at most one prefill CHUNK rides this
        # tick.  An in-flight chunked prefill continues before anything new
        # is admitted (it was admitted first — FCFS), so decode is never
        # starved by more than one bounded chunk per tick.  A decode-tier
        # replica never prefills: its requests arrive pre-filled via adopt.
        pf = sched.mid_prefill() if self.ecfg.role != "decode" else None
        if pf is None and self.ecfg.role != "decode":
            pf = sched.try_admit(self.alloc, now,
                                 prefix_cache=self.prefix_cache)
            if pf is not None and pf.cached_tokens:
                self.tel.record("prefix_hit", rid=pf.req.rid,
                                cached_tokens=pf.cached_tokens,
                                shared_pages=pf.n_shared_pages,
                                cow=pf.cow_page is not None)
        if pf is not None and pf.cow_page is not None:
            # whole-prompt hit: duplicate the boundary page so the
            # recomputed final-token row writes a PRIVATE copy and the
            # shared original stays immutable
            src, dst = pf.cow_page
            pf.cow_page = None
            ctx = self.plan.mesh if self.plan.mesh is not None \
                else contextlib.nullcontext()
            with ctx:
                self.pools = copy_page(self.pools, jnp.int32(src),
                                       jnp.int32(dst))
        if pf is None and not decode_slots:
            return False

        B, mp = ecfg.max_batch, ecfg.max_pages_per_req
        pt = np.zeros((B, mp), np.int32)
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        last = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        for s in decode_slots:
            st = sched.active[s]
            pt[s, :len(st.pages)] = st.pages
            pos[s] = st.next_pos
            active[s] = True
            last[s] = st.generated[-1]
            temps[s] = st.req.temperature

        bucket = None
        history = False
        chunk = 0
        final_chunk = False
        pf_tokens = np.zeros((1, 1), np.int32)
        pf_len = np.int32(0)
        pf_ptrow = np.zeros((mp,), np.int32)
        pf_start = np.int32(0)
        pf_temp = np.float32(0.0)
        if pf is not None:
            P = len(pf.req.prompt)
            chunk = P - pf.prefill_pos
            if ecfg.prefill_chunk:
                chunk = min(chunk, ecfg.prefill_chunk)
            final_chunk = pf.prefill_pos + chunk >= P
            bucket = min(b for b in ecfg.prefill_buckets if b >= chunk)
            pf_tokens = np.zeros((1, bucket), np.int32)
            pf_tokens[0, :chunk] = pf.req.prompt[
                pf.prefill_pos:pf.prefill_pos + chunk]
            pf_len = np.int32(chunk)
            pf_start = np.int32(pf.prefill_pos)
            history = pf.prefill_pos > 0
            pf_ptrow[:len(pf.pages)] = pf.pages
            pf_temp = np.float32(pf.req.temperature)

        key = jax.random.fold_in(self._key, self._tick_count)
        ctx = self.plan.mesh if self.plan.mesh is not None \
            else contextlib.nullcontext()
        with ctx:
            self.pools, out = self._step_fn(
                self.params, self.pools, jnp.asarray(pt), jnp.asarray(last),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(temps),
                jnp.asarray(pf_tokens), pf_len, jnp.asarray(pf_ptrow),
                pf_start, pf_temp, key, bucket=bucket,
                any_decode=bool(decode_slots), history=history)
        out = jax.device_get(out)
        self._tick_count += 1
        self.max_concurrent = max(self.max_concurrent,
                                  len(decode_slots) + (pf is not None))
        tel = self.tel
        tel.counter("serve_ticks_total").inc()
        tel.counter("serve_decode_tokens_total").inc(len(decode_slots))
        if pf is not None:
            self.n_prefill_chunks += 1
            tel.counter("serve_prefill_chunks_total").inc()
        used = (ecfg.n_pages - 1) - self.alloc.free_pages
        tel.gauge("kv_used_pages").set(used)
        tel.histogram("kv_used_pages_hist",
                      edges=po2_buckets(0, 20)).observe(used)
        if tel.enabled:
            tel.record("serve_tick", tick=self._tick_count - 1,
                       n_decode=len(decode_slots), bucket=bucket,
                       chunk=int(chunk), kv_used_pages=used,
                       n_waiting=len(sched.waiting),
                       reserved_tokens=sched.reserved_tokens)

        if pf is not None:
            pf.prefill_pos += chunk
            if final_chunk:
                if self.prefix_cache is not None:
                    # every full prompt page is now written and stable
                    # (decode rows land beyond them) -> publish the prefix;
                    # blocks already cached keep their canonical pages
                    self.prefix_cache.insert(pf.req.prompt, pf.pages,
                                             self.alloc)
                # only the last chunk's logits are meaningful (the prompt's
                # final position) — intermediate chunks just fill pages
                self._emit(pf, int(out["prefill_tok"]), now, results)
                if self.ecfg.role == "prefill" \
                        and self.sched.active.get(pf.slot) is pf:
                    # prefill tier: done here — park (slot/pages/budget stay
                    # held so the KV survives) and queue for migration; the
                    # router ships the pages to a decode replica and acks
                    pf.parked = True
                    self.handoff.append(pf)
                    self.tel.gauge("handoff_queue_depth").set(
                        len(self.handoff))
        if decode_slots:
            toks = out["decode_toks"]
            for s in decode_slots:
                st = sched.active.get(s)
                if st is None:
                    continue
                self._emit(st, int(toks[s]), now, results)
        return True

    def _emit(self, st: RequestState, tok: int, now: float,
              results: Dict[int, dict]) -> None:
        st.generated.append(tok)
        st.prefilled = True
        self.total_decoded += 1
        if st.first_token_time is None:
            st.first_token_time = now
            self.tel.histogram("serve_ttft_ms", edges=_LAT_BUCKETS).observe(
                (now - st.req.arrival_time) * 1e3)
        elif st.last_token_time is not None:
            self.tel.histogram("serve_tbt_ms", edges=_LAT_BUCKETS).observe(
                (now - st.last_token_time) * 1e3)
        st.last_token_time = now
        if st.done(self.ecfg.eos_id):
            self.sched.finish(st.slot, self.alloc, now)
            self.tel.counter("serve_finished_total").inc()
            n_tok = len(st.generated)
            ttft_ms = (st.first_token_time - st.req.arrival_time) * 1e3
            tbt_ms_mean = ((now - st.first_token_time) * 1e3
                           / max(n_tok - 1, 1))
            self.tel.record("request_done", rid=st.req.rid, n_tokens=n_tok,
                            ttft_ms=ttft_ms, tbt_ms_mean=tbt_ms_mean,
                            wait_ms=(st.admit_time - st.req.arrival_time)
                            * 1e3, n_evictions=st.n_evictions,
                            cached_tokens=st.cached_tokens)
            results[st.req.rid] = {
                "tokens": list(st.generated),
                "arrival": st.req.arrival_time,
                "admit": st.admit_time,
                "first_token": st.first_token_time,
                "finish": now,
                "n_evictions": st.n_evictions,
                "cached_tokens": st.cached_tokens,
            }

    # -- disaggregation: casting-free KV migration -------------------------
    # Two-phase protocol (router-orchestrated):
    #   1. receiver.reserve_for_adopt(meta)  — pin locally-cached prompt
    #      pages (incref) FIRST, then reserve fresh pages; all-or-nothing.
    #   2. donor.pack_handoff(st, skip)      — bitcast-pack only the pages
    #      the receiver lacks; receiver.commit_adopt scatters them in and
    #      installs the RequestState into the decode batch.
    #   3. donor.release_parked(st)          — ONLY after the receiver ack:
    #      pages leave via the release funnel (cache pages stay shareable).
    def pack_handoff(self, st: RequestState, skip_pages: int = 0):
        """Donor: one uint8 wire message carrying ``st.pages[skip_pages:]``
        (the receiver already holds bit-identical copies of the first
        `skip_pages` — content-addressable po2 pages make that dedupe
        sound) plus the request's resume metadata."""
        from repro.serve.transfer import TransferMeta
        ship = st.pages[skip_pages:]
        meta = TransferMeta(rid=st.req.rid, n_pages=len(ship),
                            page_size=self.ecfg.page_size,
                            bytes_per_page=self.codec.bytes_per_page,
                            pos=st.prefill_pos,
                            max_new_tokens=st.req.max_new_tokens,
                            temperature=st.req.temperature,
                            prompt=tuple(st.req.prompt),
                            generated=tuple(st.generated))
        ctx = self.plan.mesh if self.plan.mesh is not None \
            else contextlib.nullcontext()
        with ctx:
            return self.codec.pack(self.pools, ship, meta)

    def reserve_for_adopt(self, req):
        """Receiver phase 1: returns (shared, fresh) page lists covering the
        migrating prompt, or None if a slot / the token budget / the pool
        cannot take it right now (the donor keeps the request parked and the
        router retries).  `req` is anything with .prompt/.max_new_tokens (a
        Request or a TransferMeta).  Locally-cached blocks are pinned by
        incref BEFORE the fresh tail allocates — the tail alloc may evict
        cache leaves, and a bare cache ref would make the match itself a
        victim (same ordering as try_admit)."""
        sched, ecfg = self.sched, self.ecfg
        if not sched._free_slots:
            return None
        P = len(req.prompt)
        n_total = self.alloc.pages_for(P)
        shared = (self.prefix_cache.match_pages(req.prompt)
                  if self.prefix_cache is not None else [])[:n_total]
        cached = len(shared) * ecfg.page_size
        if sched.reserved_tokens + P + req.max_new_tokens - cached \
                > sched.token_budget:
            return None
        self.alloc.incref(shared)
        n_fresh = n_total - len(shared)
        fresh = (self._alloc_pages(n_fresh) or None) if n_fresh else []
        if fresh is None:
            self.alloc.decref(shared)
            return None
        return shared, fresh

    def abort_adopt(self, shared, fresh) -> None:
        """Receiver: roll phase 1 back (decref pins, free fresh pages)."""
        self.alloc.decref(list(shared) + list(fresh))

    def commit_adopt(self, meta, payload, shared, fresh, now: float,
                     timing: Optional[dict] = None) -> RequestState:
        """Receiver phase 2: scatter the shipped page bytes into the fresh
        pages (pure bitcast — the pages land bit-identical to the donor's),
        rebuild the RequestState at the request's `pos`, install it in the
        decode batch, and publish the prompt prefix into the local radix
        tree so later migrations/admissions of the same tenant re-share
        these pages."""
        if fresh:
            ctx = self.plan.mesh if self.plan.mesh is not None \
                else contextlib.nullcontext()
            with ctx:
                self.pools = self.codec.scatter(self.pools, payload, fresh)
        timing = timing or {}
        req = Request(prompt=list(meta.prompt),
                      max_new_tokens=meta.max_new_tokens,
                      arrival_time=timing.get("arrival", now),
                      temperature=meta.temperature, rid=meta.rid)
        st = RequestState(req=req, slot=-1, pages=list(shared) + list(fresh),
                          admit_seq=-1, admit_time=timing.get("admit", now),
                          generated=list(meta.generated),
                          first_token_time=timing.get("first"),
                          last_token_time=timing.get("last"),
                          prefilled=True, prefill_pos=meta.pos,
                          cached_tokens=len(shared) * self.ecfg.page_size,
                          n_shared_pages=len(shared))
        self.sched.adopt(st)
        if self.prefix_cache is not None:
            self.prefix_cache.insert(meta.prompt, st.pages, self.alloc)
        return st

    def release_parked(self, st: RequestState) -> None:
        """Donor phase 3 (post-ack): the parked request's slot, budget and
        pages are released through the scheduler funnel — with a prefix
        cache the prompt pages stay resident for future local hits, so
        migrating a tenant does not evict its prefix from the prefill
        tier."""
        st.parked = False
        self.sched.release(st, self.alloc)
        self.n_migrated_out += 1

    # -- driver ------------------------------------------------------------
    def run(self, requests: Sequence[Request],
            realtime: bool = True) -> Dict[int, dict]:
        """Drive a full trace to completion.  With realtime=True arrivals
        are honored against the wall clock (Poisson traces); otherwise every
        request is enqueued immediately (closed-loop saturation)."""
        pending = deque(sorted(requests, key=lambda r: r.arrival_time))
        results = TraceResults()
        t0 = time.perf_counter()
        idle_spins = 0
        while pending or not self.sched.idle():
            now = time.perf_counter() - t0
            while pending and (not realtime
                               or pending[0].arrival_time <= now):
                self.submit(pending.popleft())
            if self.tick(now, results):
                idle_spins = 0
                continue
            if pending:
                time.sleep(max(0.0, min(0.002,
                                        pending[0].arrival_time - now)))
                continue
            idle_spins += 1
            if idle_spins > 1000:
                raise RuntimeError(
                    "scheduler deadlock: waiting requests can never be "
                    "admitted (check token_budget / n_pages)")
        results.stats = self.stats()
        self.tel.record("serve_summary", **results.stats)
        self.tel.flush()
        return results

    def stats(self) -> Dict[str, int]:
        """Run-level aggregate counters (also on run()'s TraceResults.stats
        and in the obs registry as serve_* counters)."""
        s = self.sched.stats()
        out = {"ticks": self._tick_count, "admitted": s["admitted"],
               "evicted": s["evicted"], "finished": s["finished"],
               "rejected": self.n_rejected,
               "prefill_chunks": self.n_prefill_chunks,
               "decode_tokens": self.total_decoded,
               "max_concurrent": self.max_concurrent,
               "adopted": s["adopted"],
               "migrated_out": self.n_migrated_out,
               "role": self.ecfg.role}
        if self.prefix_cache is not None:
            out.update(self.prefix_cache.stats())
        return out

    # -- reporting ---------------------------------------------------------
    def kv_bytes(self) -> int:
        return pool_nbytes(self.pools)
