"""Radix prefix cache over the paged FP8-e4m3 KV pool.

Production traffic is dominated by shared prompt prefixes — system prompts,
few-shot headers, multi-turn history.  The paper's per-row po2 scales make
FP8 KV pages deterministic given (tokens, positions, chunk geometry): the
quantize is idempotent (Eq. 5-8), so a page written once for a prefix is
bit-for-bit the page any identical prefix would write, i.e. pages are
content-addressable and safely shareable.

This module maps FULL-PAGE-ALIGNED token prefixes to refcounted page ids in
the existing pool through a radix tree at token-BLOCK granularity (one tree
edge element == one ``page_size`` token block == one page id):

  * ``lookup(prompt)`` walks the tree and returns the longest cached
    page-aligned prefix: the request stitches those SHARED pages (incref)
    ahead of freshly allocated tail pages, starts prefill at the matched
    length, and skips the matched prefill FLOPs entirely.  A whole-prompt
    hit is capped at ``len(prompt) - 1`` (the last token must be recomputed
    for its logits) and the final cached page is returned as copy-on-write:
    the engine duplicates it so the recomputed row lands in a private page.
  * ``insert(prompt, pages)`` is called once a request's prefill completes:
    blocks already on the tree are skipped (their pages stay canonical),
    the new suffix is recorded and its pages gain a cache reference, so
    they survive the owner request finishing.
  * Eviction is LRU over UNREFERENCED radix leaves: when the free list runs
    dry (``alloc_pages``), the least-recently-matched leaf trims the
    maximal tail of pages only the cache still references (refcount 1);
    pages pinned by resident requests are never victims, so a shared
    prefix in use can never be yanked.

The tree compresses paths rtp-llm/SGLang-style: one node holds a run of
blocks from a single insert; a later insert diverging mid-edge splits the
node at the (block-aligned) divergence point.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.paged_kv import PageAllocator

Block = Tuple[int, ...]


@dataclasses.dataclass
class PrefixMatch:
    """One lookup result.  `pages` are the cached pages covering the match
    IN ORDER; `tokens` is the prefill start position (`len(pages) *
    page_size`, except a whole-prompt hit where it is `len(prompt) - 1`);
    `cow` marks that the LAST page must be copied before the request may
    write its recomputed final-token row into it."""
    pages: List[int]
    tokens: int
    cow: bool = False


class RadixNode:
    __slots__ = ("blocks", "pages", "children", "parent", "last_used")

    def __init__(self, blocks: List[Block], pages: List[int],
                 parent: Optional["RadixNode"]):
        self.blocks = blocks           # edge label: consecutive token blocks
        self.pages = pages             # parallel page ids, one per block
        self.children: Dict[Block, "RadixNode"] = {}   # keyed by first block
        self.parent = parent
        self.last_used = 0

    def is_leaf(self) -> bool:
        return not self.children


class PrefixCache:
    """Radix tree of page-aligned prompt prefixes -> shared KV pages."""

    def __init__(self, page_size: int, telemetry=None):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        if telemetry is None:
            from repro.obs.sink import null_telemetry
            telemetry = null_telemetry()
        self.tel = telemetry
        self.root = RadixNode([], [], None)
        self._clock = itertools.count(1)
        self.n_cached_pages = 0
        self.n_hits = 0
        self.n_lookups = 0
        self.hit_tokens = 0
        self.n_evictions = 0
        self.n_evicted_pages = 0

    # -- internals ---------------------------------------------------------
    def _blocks(self, tokens: Sequence[int]) -> List[Block]:
        ps = self.page_size
        return [tuple(tokens[i * ps:(i + 1) * ps])
                for i in range(len(tokens) // ps)]

    def _walk(self, blocks: List[Block], touch: bool):
        """Longest-prefix walk.  Returns (node, n_node_blocks_matched,
        pages, n_blocks_matched_total): `node` is the deepest node entered,
        with its first `n_node_blocks_matched` edge blocks matched (< len
        means the walk died mid-edge)."""
        node, i, pages = self.root, 0, []
        now = next(self._clock)
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                return node, len(node.blocks), pages, i
            m = 0
            while m < len(child.blocks) and i + m < len(blocks) \
                    and child.blocks[m] == blocks[i + m]:
                m += 1
            pages.extend(child.pages[:m])
            i += m
            if touch:
                child.last_used = now
            if m < len(child.blocks):
                return child, m, pages, i
            node = child
        return node, len(node.blocks), pages, i

    def _iter_nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                yield n
            stack.extend(n.children.values())

    # -- queries -----------------------------------------------------------
    def match_tokens(self, tokens: Sequence[int]) -> int:
        """Cached-prefix length in tokens WITHOUT touching LRU clocks or hit
        counters (the router's peek)."""
        _, _, pages, _ = self._walk(self._blocks(tokens), touch=False)
        return min(len(pages) * self.page_size, max(len(tokens) - 1, 0))

    def lookup(self, tokens: Sequence[int]) -> Optional[PrefixMatch]:
        """Longest cached page-aligned prefix of `tokens`, or None.
        Refreshes LRU clocks along the matched path but counts NO hit
        stats — admission may still fail on budget/slots, so the scheduler
        calls ``record_admitted`` exactly once per admitted request."""
        _, _, pages, _ = self._walk(self._blocks(tokens), touch=True)
        if not pages:
            return None
        matched = len(pages) * self.page_size
        cow = matched >= len(tokens)
        if cow:
            # whole-prompt hit: recompute the last token for its logits; its
            # KV row lands in the final cached page -> copy-on-write
            matched = len(tokens) - 1
        if matched <= 0:
            return None
        return PrefixMatch(pages=list(pages), tokens=matched, cow=cow)

    def match_pages(self, tokens: Sequence[int]) -> List[int]:
        """Cached pages covering `tokens`' longest full-block prefix, with
        NO cow capping and no hit accounting (LRU clocks are refreshed —
        the pages are about to be pinned).  The disaggregation adopt path
        uses this to dedupe a migration against the RECEIVER's cache: any
        block the receiver already holds is shared by incref instead of
        shipped over the wire, and because po2-quantized pages are
        content-addressable the local page is bit-identical to the one the
        donor would have sent."""
        _, _, pages, _ = self._walk(self._blocks(tokens), touch=True)
        return list(pages)

    def record_admitted(self, match: Optional[PrefixMatch]) -> None:
        """Per-request hit accounting, called once per successful
        admission (with match=None for a miss)."""
        self.n_lookups += 1
        if match is None:
            return
        self.n_hits += 1
        self.hit_tokens += match.tokens
        self.tel.counter("prefix_hits").inc()
        self.tel.counter("prefix_hit_tokens").inc(match.tokens)

    # -- mutation ----------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               allocator: PageAllocator) -> int:
        """Record `tokens`' full-block prefix, mapping each NEW block to the
        request's corresponding page (the cache increfs those — they outlive
        the request).  Blocks already on the tree keep their existing
        canonical pages (a concurrent miss's duplicate pages stay private
        and die with their request).  Returns the number of newly cached
        pages."""
        blocks = self._blocks(tokens)
        if len(pages) < len(blocks):
            raise ValueError(f"insert needs one page per full block: "
                             f"{len(pages)} pages < {len(blocks)} blocks")
        node, m, _, i = self._walk(blocks, touch=True)
        if i >= len(blocks):
            return 0
        if m < len(node.blocks):
            self._split(node, m)
        tail = RadixNode(list(blocks[i:]), list(pages[i:len(blocks)]), node)
        tail.last_used = next(self._clock)
        allocator.incref(tail.pages)
        node.children[tail.blocks[0]] = tail
        self.n_cached_pages += len(tail.pages)
        self.tel.gauge("shared_pages").set(self.n_cached_pages)
        return len(tail.pages)

    def _split(self, node: RadixNode, m: int) -> None:
        """Split `node`'s edge after its m-th block: node keeps the prefix,
        a new child carries the suffix (and inherits node's children)."""
        assert 0 < m < len(node.blocks)
        suffix = RadixNode(node.blocks[m:], node.pages[m:], node)
        suffix.children = node.children
        for c in suffix.children.values():
            c.parent = suffix
        suffix.last_used = node.last_used
        node.blocks = node.blocks[:m]
        node.pages = node.pages[:m]
        node.children = {suffix.blocks[0]: suffix}

    # -- eviction ----------------------------------------------------------
    def _evict_one(self, allocator: PageAllocator) -> int:
        """Trim the LRU-most leaf's maximal unreferenced tail (pages whose
        only reference is the cache's own); drops the leaf entirely when the
        whole edge trims.  Returns pages freed (0 => nothing evictable)."""
        best = None
        for n in self._iter_nodes():
            if n.is_leaf() and allocator.refcount(n.pages[-1]) == 1 \
                    and (best is None or n.last_used < best.last_used):
                best = n
        if best is None:
            return 0
        k = len(best.pages)
        while k > 0 and allocator.refcount(best.pages[k - 1]) == 1:
            k -= 1
        dropped = best.pages[k:]
        allocator.decref(dropped)
        del best.blocks[k:]
        del best.pages[k:]
        if k == 0:
            parent = best.parent
            for key, c in list(parent.children.items()):
                if c is best:
                    del parent.children[key]
        self.n_cached_pages -= len(dropped)
        self.n_evictions += 1
        self.n_evicted_pages += len(dropped)
        self.tel.counter("cache_evictions").inc()
        self.tel.gauge("shared_pages").set(self.n_cached_pages)
        return len(dropped)

    def alloc_pages(self, allocator: PageAllocator,
                    n: int) -> Optional[List[int]]:
        """Allocate n pages, evicting LRU unreferenced radix leaves while
        the free list is dry.  None once nothing cache-held remains to
        evict (the caller falls back to scheduler eviction)."""
        got = allocator.alloc(n)
        while got is None:
            if self._evict_one(allocator) == 0:
                return None
            got = allocator.alloc(n)
        return got

    # -- introspection -----------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {"prefix_lookups": self.n_lookups,
                "prefix_hits": self.n_hits,
                "prefix_hit_tokens": self.hit_tokens,
                "shared_pages": self.n_cached_pages,
                "cache_evictions": self.n_evictions,
                "cache_evicted_pages": self.n_evicted_pages}

    def check_invariants(self, allocator: PageAllocator) -> None:
        """Structural invariants (tests call this after every mutation):
        every node's pages are live with refcount >= 1, page count matches
        block count, children are keyed by their first block, and the total
        page tally matches ``n_cached_pages``."""
        total = 0
        for n in self._iter_nodes():
            assert n.blocks and len(n.blocks) == len(n.pages), \
                f"edge/page mismatch: {len(n.blocks)} vs {len(n.pages)}"
            assert all(allocator.refcount(p) >= 1 for p in n.pages), \
                "cached page without a live reference"
            for key, c in n.children.items():
                assert c.blocks[0] == key and c.parent is n
            total += len(n.pages)
        for key, c in self.root.children.items():
            assert c.blocks[0] == key and c.parent is self.root
        assert total == self.n_cached_pages, \
            f"page tally {total} != n_cached_pages {self.n_cached_pages}"
