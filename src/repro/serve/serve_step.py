"""The jitted serving step: one decode token against a resident KV/SSM cache.

Unified with the continuous-batching engine: sampling routes through
``engine.sample_tokens`` (greedy where temp <= 0, else temperature + optional
top-k — identical semantics to the engine's decode lane) and ``pos`` is
honored per request: pass a scalar for the fixed-phase bench path or a (B,)
vector for continuous-batching shapes (each request at its own depth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.recipes import Recipe
from repro.models.lm import ParallelPlan, decode_step, init_cache


def make_serve_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan,
                    top_k: int = 0):
    """Returns serve_step(params, cache, tokens, pos[, temps, key]).

    tokens: (B, 1) int32; pos: scalar int32 OR (B,) int32 per-request
    positions; temps: optional (B,) f32 sampling temperatures (None/<=0 ->
    greedy, matching the engine); key: PRNG key for the stochastic path.
    Returns (next_tok (B, 1) int32, new_cache)."""
    from repro.serve.engine import sample_tokens

    def serve_step(params, cache, tokens, pos, temps=None, key=None):
        if temps is not None and key is None:
            # a fixed default key would make every step's categorical draw
            # perfectly correlated — degenerate "temperature" sampling
            raise ValueError("stochastic sampling (temps) needs a per-step "
                             "PRNG key; thread a split key through the loop")
        logits, new_cache = decode_step(cfg, recipe, plan, params, cache,
                                        tokens, pos)
        B = tokens.shape[0]
        if temps is None:
            temps = jnp.zeros((B,), jnp.float32)
            key = jax.random.key(0)            # unused: every row is greedy
        next_tok = sample_tokens(logits[:, -1, :], key, temps, top_k)
        return next_tok[:, None], new_cache

    return serve_step


def make_prefill(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan):
    """Prefill forward (logits only) — lowered for the prefill_32k cells."""
    from repro.models.lm import forward

    def prefill(params, batch):
        logits, metrics = forward(cfg, recipe, plan, params, batch,
                                  compute_loss=False)
        return logits[:, -1, :]

    return prefill
