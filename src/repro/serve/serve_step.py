"""The jitted serving step: one decode token against a resident KV/SSM cache
(continuous-batching style: `pos` is per-request; this reference serve step
uses a shared position for the dry-run shapes, which model fixed-phase
decode benches)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.recipes import Recipe
from repro.models.lm import ParallelPlan, decode_step, init_cache


def make_serve_step(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(cfg, recipe, plan, params, cache,
                                        tokens, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return serve_step


def make_prefill(cfg: ArchConfig, recipe: Recipe, plan: ParallelPlan):
    """Prefill forward (logits only) — lowered for the prefill_32k cells."""
    from repro.models.lm import forward

    def prefill(params, batch):
        logits, metrics = forward(cfg, recipe, plan, params, batch,
                                  compute_loss=False)
        return logits[:, -1, :]

    return prefill
