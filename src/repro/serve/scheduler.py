"""Request scheduler for the continuous-batching serving engine.

Policy (LightLLM/vLLM-style, sized for the paper's FP8-resident decode):

  * FCFS admission — only the HEAD of the waiting queue is ever considered,
    so an admissible request can never be overtaken (no starvation).
  * Decode priority — one admission per engine tick (the jitted step carries
    a single bucketed prefill); resident requests keep decoding every tick
    and the prefill rides along in the same jitted step.
  * Reserved-token budget — a request is admitted only while
    sum(prompt_len + max_new_tokens) over resident requests stays within
    ``token_budget``; the reservation covers the worst-case length, so the
    invariant holds for the request's whole lifetime.
  * Eviction — when the paged-KV allocator cannot extend a growing request,
    the YOUNGEST resident request is evicted (restart semantics: its pages
    are freed, generated tokens are discarded, and it re-queues at the front
    of the waiting line, which preserves FCFS order).
  * Chunked prefill — long prompts prefill in bounded token slices
    (``ServeConfig.prefill_chunk``), one slice per tick, so resident decodes
    are never starved behind a long monolithic prefill.  The in-flight
    continuation has strict priority over new admissions (it was admitted
    first — FCFS), so at most one request is ever mid-prefill.

The scheduler is pure host-side bookkeeping: it never touches jax.  The
engine owns the device arrays and the page allocator and consults the
scheduler for admission/eviction decisions.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional

from repro.serve.paged_kv import PageAllocator

_rid_counter = itertools.count()


@dataclasses.dataclass
class Request:
    """One serving request (token ids in, sampling knobs, arrival time)."""
    prompt: List[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    temperature: float = 0.0            # <= 0 -> greedy
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))

    @property
    def reserved_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class RequestState:
    """Lifecycle bookkeeping for an admitted request."""
    req: Request
    slot: int
    pages: List[int]
    admit_seq: int
    admit_time: float
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None   # TBT accounting (obs/)
    finish_time: Optional[float] = None
    prefilled: bool = False
    prefill_pos: int = 0           # tokens prefilled so far (chunked prefill:
                                   # advances one bounded slice per tick;
                                   # == len(prompt) once prefill is complete.
                                   # A prefix-cache hit starts this at the
                                   # matched length, so the tail rides the
                                   # SAME continuation machinery)
    n_evictions: int = 0
    cached_tokens: int = 0         # prompt tokens served from shared prefix
                                   # pages — their prefill is skipped and
                                   # they are discounted from the budget
    n_shared_pages: int = 0        # leading pages of `pages` held via incref
                                   # (read-only; the request must not write)
    cow_page: Optional[tuple] = None  # (src, dst): boundary page to copy
                                   # before this request's first chunk runs
    parked: bool = False           # prefill-tier disaggregation: prefill is
                                   # complete and the request sits in the
                                   # handoff queue awaiting KV migration; it
                                   # keeps its slot/pages/budget (the KV must
                                   # survive until the receiver acks) but is
                                   # excluded from decode and from eviction

    @property
    def next_pos(self) -> int:
        """Position the next fed token's KV row is written at.  Prefill
        fills rows [0, prompt); the first decode feeds the prefill-sampled
        token and writes row `prompt`; each later decode advances by one."""
        return len(self.req.prompt) + max(len(self.generated) - 1, 0)

    def done(self, eos_id: Optional[int]) -> bool:
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return bool(self.generated) and eos_id is not None \
            and self.generated[-1] == eos_id


class Scheduler:
    """FCFS + decode-priority + reserved-token-budget admission control.

    `release_hook` is the single exit point for a resident's pages: every
    path that returns pages (finish, eviction) funnels through it, so a
    prefix cache can intercept releases (decref shared pages, keep cached
    ones alive) without forking the scheduler.  The default hook is the
    allocator's own single-owner free.
    """

    def __init__(self, max_batch: int, token_budget: int, release_hook=None):
        self.max_batch = max_batch
        self.token_budget = token_budget
        self.release_hook = release_hook   # callable(state, pages, allocator)
        self.waiting: deque = deque()
        self.active: Dict[int, RequestState] = {}      # slot -> state
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self._admit_seq = itertools.count()
        self.n_finished = 0
        self.n_evictions = 0
        self.n_admitted = 0
        self.n_adopted = 0                             # disagg: migrated in
        self.cached_prompt_tokens = 0                  # prefix-cache skips
        self._eviction_counts: Dict[int, int] = {}     # rid -> times evicted

    # -- introspection -----------------------------------------------------
    @property
    def reserved_tokens(self) -> int:
        """Worst-case token reservation over residents.  Tokens served from
        shared prefix pages are discounted: their KV rows already exist (and
        are pinned by the request's refs for its whole lifetime), so only
        un-cached pages count against the budget."""
        return sum(st.req.reserved_tokens - st.cached_tokens
                   for st in self.active.values())

    @property
    def n_active(self) -> int:
        return len(self.active)

    def idle(self) -> bool:
        return not self.waiting and not self.active

    def stats(self) -> Dict[str, int]:
        """Aggregate scheduler counters (the engine folds these into its
        run-level stats and the obs registry)."""
        return {"admitted": self.n_admitted, "evicted": self.n_evictions,
                "finished": self.n_finished, "waiting": len(self.waiting),
                "active": self.n_active, "adopted": self.n_adopted}

    def mid_prefill(self) -> Optional[RequestState]:
        """The resident whose chunked prefill is still in flight, if any.
        At most one exists: the engine blocks new admissions while a
        continuation is pending (FCFS — it was admitted first)."""
        for slot in sorted(self.active):
            st = self.active[slot]
            if st.prefill_pos < len(st.req.prompt):
                return st
        return None

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    # -- admission ---------------------------------------------------------
    def try_admit(self, allocator: PageAllocator, now: float,
                  prefix_cache=None) -> Optional[RequestState]:
        """Admit the queue head if a slot, the token budget, and prompt pages
        all allow it.  Returns the new RequestState (pages allocated,
        prefill still pending) or None.  Strictly FCFS: if the head does not
        fit, nothing behind it is considered.

        With a prefix cache, the head's prompt is first matched against the
        radix tree: matched pages are shared (incref, zero prefill compute),
        only the un-cached tail reserves budget and allocates fresh pages,
        and `prefill_pos` starts at the matched length so the tail rides the
        chunked-prefill continuation path.  A whole-prompt hit keeps its
        last cached page as copy-on-write (`cow_page`) — the engine copies
        it before the final-token chunk writes into it."""
        if not self.waiting or not self._free_slots:
            return None
        req = self.waiting[0]
        match = prefix_cache.lookup(req.prompt) if prefix_cache is not None \
            else None
        cached_tokens = match.tokens if match else 0
        if self.reserved_tokens + req.reserved_tokens - cached_tokens \
                > self.token_budget:
            return None
        n_total = allocator.pages_for(len(req.prompt))
        shared = list(match.pages[:-1] if match.cow else match.pages) \
            if match else []
        # pin the matched pages BEFORE allocating the tail: the tail alloc
        # may evict cache leaves, and a bare cache ref would make the match
        # itself a victim
        allocator.incref(shared)
        n_fresh = n_total - len(shared)
        fresh = (prefix_cache.alloc_pages(allocator, n_fresh)
                 if prefix_cache is not None else allocator.alloc(n_fresh)) \
            if n_fresh else []
        if fresh is None:
            allocator.decref(shared)
            return None
        self.waiting.popleft()
        slot = self._free_slots.pop()
        st = RequestState(req=req, slot=slot, pages=shared + fresh,
                          admit_seq=next(self._admit_seq), admit_time=now,
                          n_evictions=self._eviction_counts.get(req.rid, 0),
                          cached_tokens=cached_tokens,
                          n_shared_pages=len(shared),
                          prefill_pos=cached_tokens)
        if match and match.cow:
            st.cow_page = (match.pages[-1], fresh[0])
        self.active[slot] = st
        self.n_admitted += 1
        self.cached_prompt_tokens += cached_tokens
        if prefix_cache is not None:
            prefix_cache.record_admitted(match)
        return st

    # -- eviction / completion --------------------------------------------
    def evict_youngest(self, allocator: PageAllocator,
                       requester: Optional[RequestState] = None
                       ) -> Optional[RequestState]:
        """Free the youngest resident request (restart semantics) to relieve
        page pressure; it re-queues at the FRONT of the waiting line (it was
        admitted before anything still waiting, so FCFS order is preserved).

        Seniority rule: only residents STRICTLY YOUNGER than ``requester``
        are victims; if the requester is itself the youngest, IT is evicted.
        The oldest resident is therefore never unseated, which guarantees
        forward progress (no evict-each-other livelock between two growing
        requests).  ``requester=None`` evicts the globally youngest.
        Parked residents (disaggregation handoff: prefill done, awaiting KV
        migration) are never victims — losing their KV before the receiver
        copies it would orphan the handoff.  Returns the evicted state, or
        None if nothing is resident."""
        live = [st for st in self.active.values() if not st.parked]
        if requester is None:
            victims = live
        else:
            victims = [st for st in live
                       if st.admit_seq > requester.admit_seq] or [requester]
        if not victims:
            return None
        st = max(victims, key=lambda s: s.admit_seq)
        self._release(st, allocator)
        st.generated.clear()           # restart: KV + tokens are recomputed
        st.prefilled = False
        st.prefill_pos = 0             # chunked-prefill progress is discarded
        st.cached_tokens = 0           # re-admission re-matches the cache
        st.n_shared_pages = 0
        st.cow_page = None
        st.n_evictions += 1
        self.n_evictions += 1
        self._eviction_counts[st.req.rid] = st.n_evictions
        self.waiting.appendleft(st.req)
        return st

    def finish(self, slot: int, allocator: PageAllocator,
               now: float) -> RequestState:
        st = self.active[slot]
        st.finish_time = now
        self._release(st, allocator)
        self.n_finished += 1
        return st

    # -- disaggregation (prefill/decode handoff) ---------------------------
    def adopt(self, st: RequestState) -> None:
        """Install a migrated RequestState (pages already reserved/written by
        the engine's adopt path) into a free slot on the DECODE tier.  The
        state arrives with prefill complete; it joins the masked decode batch
        on the next tick.  Budget accounting is the same worst-case
        reservation as try_admit — the router only migrates when it fits."""
        if not self._free_slots:
            raise RuntimeError("adopt with no free slot (router must check)")
        st.slot = self._free_slots.pop()
        st.admit_seq = next(self._admit_seq)
        st.parked = False
        self.active[st.slot] = st
        self.n_adopted += 1

    def release(self, st: RequestState, allocator: PageAllocator) -> None:
        """Public release for the donor side of a migration: after the
        receiver acks, the parked state's pages leave through the SAME
        release funnel as finish/evict (so the prefix cache sees the decref
        and cached pages stay shareable for future local hits)."""
        self._release(st, allocator)

    def _release(self, st: RequestState, allocator: PageAllocator) -> None:
        """The ONLY place a resident's pages leave the scheduler — both
        finish() and evict_youngest() funnel here, so `release_hook` sees
        every release (the prefix cache decrefs instead of freeing)."""
        pages, st.pages = st.pages, []
        if self.release_hook is not None:
            self.release_hook(st, pages, allocator)
        else:
            allocator.free(pages)
        del self.active[st.slot]
        self._free_slots.append(st.slot)
