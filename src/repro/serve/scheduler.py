"""Request scheduler for the continuous-batching serving engine.

Policy (LightLLM/vLLM-style, sized for the paper's FP8-resident decode):

  * FCFS admission — only the HEAD of the waiting queue is ever considered,
    so an admissible request can never be overtaken (no starvation).
  * Decode priority — one admission per engine tick (the jitted step carries
    a single bucketed prefill); resident requests keep decoding every tick
    and the prefill rides along in the same jitted step.
  * Reserved-token budget — a request is admitted only while
    sum(prompt_len + max_new_tokens) over resident requests stays within
    ``token_budget``; the reservation covers the worst-case length, so the
    invariant holds for the request's whole lifetime.
  * Eviction — when the paged-KV allocator cannot extend a growing request,
    the YOUNGEST resident request is evicted (restart semantics: its pages
    are freed, generated tokens are discarded, and it re-queues at the front
    of the waiting line, which preserves FCFS order).
  * Chunked prefill — long prompts prefill in bounded token slices
    (``ServeConfig.prefill_chunk``), one slice per tick, so resident decodes
    are never starved behind a long monolithic prefill.  The in-flight
    continuation has strict priority over new admissions (it was admitted
    first — FCFS), so at most one request is ever mid-prefill.

The scheduler is pure host-side bookkeeping: it never touches jax.  The
engine owns the device arrays and the page allocator and consults the
scheduler for admission/eviction decisions.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional

from repro.serve.paged_kv import PageAllocator

_rid_counter = itertools.count()


@dataclasses.dataclass
class Request:
    """One serving request (token ids in, sampling knobs, arrival time)."""
    prompt: List[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    temperature: float = 0.0            # <= 0 -> greedy
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))

    @property
    def reserved_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class RequestState:
    """Lifecycle bookkeeping for an admitted request."""
    req: Request
    slot: int
    pages: List[int]
    admit_seq: int
    admit_time: float
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None   # TBT accounting (obs/)
    finish_time: Optional[float] = None
    prefilled: bool = False
    prefill_pos: int = 0           # tokens prefilled so far (chunked prefill:
                                   # advances one bounded slice per tick;
                                   # == len(prompt) once prefill is complete)
    n_evictions: int = 0

    @property
    def next_pos(self) -> int:
        """Position the next fed token's KV row is written at.  Prefill
        fills rows [0, prompt); the first decode feeds the prefill-sampled
        token and writes row `prompt`; each later decode advances by one."""
        return len(self.req.prompt) + max(len(self.generated) - 1, 0)

    def done(self, eos_id: Optional[int]) -> bool:
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return bool(self.generated) and eos_id is not None \
            and self.generated[-1] == eos_id


class Scheduler:
    """FCFS + decode-priority + reserved-token-budget admission control."""

    def __init__(self, max_batch: int, token_budget: int):
        self.max_batch = max_batch
        self.token_budget = token_budget
        self.waiting: deque = deque()
        self.active: Dict[int, RequestState] = {}      # slot -> state
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self._admit_seq = itertools.count()
        self.n_finished = 0
        self.n_evictions = 0
        self.n_admitted = 0
        self._eviction_counts: Dict[int, int] = {}     # rid -> times evicted

    # -- introspection -----------------------------------------------------
    @property
    def reserved_tokens(self) -> int:
        return sum(st.req.reserved_tokens for st in self.active.values())

    @property
    def n_active(self) -> int:
        return len(self.active)

    def idle(self) -> bool:
        return not self.waiting and not self.active

    def stats(self) -> Dict[str, int]:
        """Aggregate scheduler counters (the engine folds these into its
        run-level stats and the obs registry)."""
        return {"admitted": self.n_admitted, "evicted": self.n_evictions,
                "finished": self.n_finished, "waiting": len(self.waiting),
                "active": self.n_active}

    def mid_prefill(self) -> Optional[RequestState]:
        """The resident whose chunked prefill is still in flight, if any.
        At most one exists: the engine blocks new admissions while a
        continuation is pending (FCFS — it was admitted first)."""
        for slot in sorted(self.active):
            st = self.active[slot]
            if st.prefill_pos < len(st.req.prompt):
                return st
        return None

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    # -- admission ---------------------------------------------------------
    def try_admit(self, allocator: PageAllocator,
                  now: float) -> Optional[RequestState]:
        """Admit the queue head if a slot, the token budget, and prompt pages
        all allow it.  Returns the new RequestState (pages allocated,
        prefill still pending) or None.  Strictly FCFS: if the head does not
        fit, nothing behind it is considered."""
        if not self.waiting or not self._free_slots:
            return None
        req = self.waiting[0]
        if self.reserved_tokens + req.reserved_tokens > self.token_budget:
            return None
        pages = allocator.alloc(allocator.pages_for(len(req.prompt)))
        if pages is None:
            return None
        self.waiting.popleft()
        slot = self._free_slots.pop()
        st = RequestState(req=req, slot=slot, pages=pages,
                          admit_seq=next(self._admit_seq), admit_time=now,
                          n_evictions=self._eviction_counts.get(req.rid, 0))
        self.active[slot] = st
        self.n_admitted += 1
        return st

    # -- eviction / completion --------------------------------------------
    def evict_youngest(self, allocator: PageAllocator,
                       requester: Optional[RequestState] = None
                       ) -> Optional[RequestState]:
        """Free the youngest resident request (restart semantics) to relieve
        page pressure; it re-queues at the FRONT of the waiting line (it was
        admitted before anything still waiting, so FCFS order is preserved).

        Seniority rule: only residents STRICTLY YOUNGER than ``requester``
        are victims; if the requester is itself the youngest, IT is evicted.
        The oldest resident is therefore never unseated, which guarantees
        forward progress (no evict-each-other livelock between two growing
        requests).  ``requester=None`` evicts the globally youngest.
        Returns the evicted state, or None if nothing is resident."""
        if requester is None:
            victims = list(self.active.values())
        else:
            victims = [st for st in self.active.values()
                       if st.admit_seq > requester.admit_seq] or [requester]
        if not victims:
            return None
        st = max(victims, key=lambda s: s.admit_seq)
        self._release(st, allocator)
        st.generated.clear()           # restart: KV + tokens are recomputed
        st.prefilled = False
        st.prefill_pos = 0             # chunked-prefill progress is discarded
        st.n_evictions += 1
        self.n_evictions += 1
        self._eviction_counts[st.req.rid] = st.n_evictions
        self.waiting.appendleft(st.req)
        return st

    def finish(self, slot: int, allocator: PageAllocator,
               now: float) -> RequestState:
        st = self.active[slot]
        st.finish_time = now
        self._release(st, allocator)
        self.n_finished += 1
        return st

    def _release(self, st: RequestState, allocator: PageAllocator) -> None:
        allocator.free(st.pages)
        st.pages = []
        del self.active[st.slot]
        self._free_slots.append(st.slot)
