"""Paged KV cache: fixed-size pages, a host-side free-list allocator, and
FP8-e4m3 page payloads with per-row po2 scales (BF16 fallback).

Layout (vLLM-style block tables, shared across layers):

  pool["data"]  : (L, n_pages, page_size, KV, hd)   e4m3 or bf16 payload
  pool["scale"] : (L, n_pages, page_size, KV, 1)    f32 po2 scales (fp8 only)

One page id addresses the same page row in EVERY layer of a stack, so a
request needs exactly one page table (max_pages,) int32 regardless of depth.
Page 0 is reserved as the scratch page: writes for inactive slots / padded
prefill rows land there and are never read back (attention masks by `pos`),
which keeps every scatter dense and branch-free under jit.

Quantization reuses ``core/quant``: each written K/V row is a per-(token,
head) tile over hd elements — ``quantize(..., tile=(..,1,hd))`` producing a
``QTensor`` whose payload+scales are scattered into the page; reads gather
pages and rebuild a ``QTensor`` for ``_dequantize_nocount``.  po2 scales make
the FP8 page round-trip add no double-quantization error beyond the single
entry quantization (the paper's Eq. 5-8 idempotence property).
"""
from __future__ import annotations

from collections import deque
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, _dequantize_nocount, quantize

SCRATCH_PAGE = 0


# ---------------------------------------------------------------------------
# Host-side refcounted free-list allocator.
# ---------------------------------------------------------------------------
class PageAllocator:
    """Refcounted free-list over page ids [1, n_pages); page 0 is scratch.

    `alloc` hands pages out with refcount 1.  A page becomes SHARED when a
    second owner takes a reference (`incref`) — the prefix cache does this
    for every page it maps, and every request reusing a cached prefix does
    it again for the pages it stitches into its table.  Owners return pages
    through `decref`; the page rejoins the free list only when the count
    reaches 0, so a shared prefix page survives its original writer
    finishing for as long as the cache (or any reader) still references it.
    `free` is the legacy single-owner spelling of `decref`.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is scratch)")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free = deque(range(1, n_pages))
        self._refs: Dict[int, int] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return len(self._refs)

    @property
    def shared_pages(self) -> int:
        """Pages with more than one outstanding reference."""
        return sum(1 for c in self._refs.values() if c > 1)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages at refcount 1, or None (caller decides to wait/evict the
        scheduler's residents/drop cache leaves) — never partial."""
        if n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        return out

    def incref(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"incref of unallocated page {p}")
            self._refs[p] += 1

    def decref(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; returns the pages that reached
        refcount 0 and went back to the free list."""
        freed = []
        for p in pages:
            c = self._refs.get(p)
            if c is None:
                raise ValueError(f"double free / foreign page {p}")
            if c == 1:
                del self._refs[p]
                self._free.append(p)
                freed.append(p)
            else:
                self._refs[p] = c - 1
        return freed

    def free(self, pages: List[int]) -> None:
        """Legacy single-owner release (== decref; raises on double free)."""
        self.decref(pages)


# ---------------------------------------------------------------------------
# Device-side pools.
# ---------------------------------------------------------------------------
def init_pool(n_layers: int, n_pages: int, page_size: int, n_kv: int,
              head_dim: int, fp8: bool = True):
    """One K or V pool for an n_layers-deep stack."""
    shape = (n_layers, n_pages, page_size, n_kv, head_dim)
    if fp8:
        return {"data": jnp.zeros(shape, jnp.float8_e4m3fn),
                "scale": jnp.ones(shape[:-1] + (1,), jnp.float32)}
    return {"data": jnp.zeros(shape, jnp.bfloat16)}


def init_paged_cache(cfg, n_pages: int, page_size: int, fp8_kv: bool = True):
    """Paged pools mirroring the dense ``init_cache`` stack structure.
    Only attention stacks are supported (the serving engine targets the
    attention+MoE families; SSM/enc-dec state is not paged)."""
    from repro.models.lm import layer_kinds
    kinds = layer_kinds(cfg)
    if cfg.encdec or cfg.frontend != "none" or any(
            k in ("ssm", "hybrid") for k in kinds):
        raise NotImplementedError(
            "paged KV serving supports attention-only decoder stacks")
    nd = cfg.n_dense_layers if cfg.moe else 0
    pools = {"main_attn": {
        "k": init_pool(cfg.n_layers - nd, n_pages, page_size, cfg.n_kv,
                       cfg.head_dim, fp8_kv),
        "v": init_pool(cfg.n_layers - nd, n_pages, page_size, cfg.n_kv,
                       cfg.head_dim, fp8_kv)}}
    if nd:
        pools["dense_attn"] = {
            "k": init_pool(nd, n_pages, page_size, cfg.n_kv, cfg.head_dim,
                           fp8_kv),
            "v": init_pool(nd, n_pages, page_size, cfg.n_kv, cfg.head_dim,
                           fp8_kv)}
    return pools


def pool_nbytes(pools) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pools))


def _quantize_rows(rows):
    """rows (..., KV, hd) -> (payload e4m3, scale f32 (..., KV, 1)): one po2
    scale per (token, head) — `fused_quantize` kind, i.e. folded into the
    cache write, not a counted Fig.-2 cast."""
    tile = (1,) * (rows.ndim - 1) + (rows.shape[-1],)
    q = quantize(rows, tile, tag="q_kv_page", kind="fused_quantize")
    return q.data, q.scale


def page_write_rows(pool_l, rows, page_idx, slot_idx):
    """Scatter token rows into ONE LAYER's pool slice.
    pool_l: {"data": (P, ps, KV, hd) [, "scale": (P, ps, KV, 1)]}
    rows: (N, KV, hd) values to write; page_idx, slot_idx: (N,) int32
    (point inactive writes at SCRATCH_PAGE)."""
    out = dict(pool_l)
    if "scale" in pool_l:
        data, scale = _quantize_rows(rows)
        out["data"] = pool_l["data"].at[page_idx, slot_idx].set(data)
        out["scale"] = pool_l["scale"].at[page_idx, slot_idx].set(scale)
    else:
        out["data"] = pool_l["data"].at[page_idx, slot_idx].set(
            rows.astype(pool_l["data"].dtype))
    return out


@partial(jax.jit, donate_argnums=(0,))
def copy_page(pools, src, dst):
    """Copy ONE page's rows (payload + scales, every layer of every stack)
    src -> dst.  The prefix cache's copy-on-write: when a request's whole
    page-aligned prompt hits the cache it still must recompute its LAST
    token for logits, and that token's KV row lands inside the final cached
    page — so the boundary page is duplicated into a private page the
    request may write, and the shared original stays immutable.  `src`/`dst`
    are traced scalars: one compile covers every page pair."""
    return jax.tree.map(lambda leaf: leaf.at[:, dst].set(leaf[:, src]),
                        pools)


def page_read(pool_l, page_tables, dtype=jnp.bfloat16):
    """Gather a request-batch view from ONE LAYER's pool slice.
    page_tables: (B, max_pages) int32 (unused entries -> SCRATCH_PAGE).
    Returns (B, max_pages * page_size, KV, hd) in `dtype`; rows beyond each
    request's length are garbage and MUST be masked by position (the
    attention `pos` mask does this)."""
    data = pool_l["data"][page_tables]        # (B, np, ps, KV, hd)
    B, npg, ps, KV, hd = data.shape
    data = data.reshape(B, npg * ps, KV, hd)
    if "scale" in pool_l:
        scale = pool_l["scale"][page_tables].reshape(B, npg * ps, KV, 1)
        q = QTensor(data=data, scale=scale, tile=(1, 1, 1, hd))
        return _dequantize_nocount(q, dtype)
    return data.astype(dtype)
