"""W8-resident serving: pre-quantized FP8 expert/MLP weights.

Extends the paper's FP8-weight format (blockwise po2 scales — the exact
layout the training GEMMs consume) to SERVING residency: instead of
FSDP-sharded BF16 weights gathered per layer (the collective-bound decode
baseline, EXPERIMENTS.md §Perf cell 3), the big weights live on-chip as
e4m3 payload + po2 scales — half the bytes, zero gather traffic, and the
grouped GEMM consumes them directly (weights are quantized ONCE here, not
per step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8 import TILE
from repro.core.quant import QTensor, quantize

# param leaves converted to resident FP8 for serving (the big matmul weights;
# norms/router/biases stay f32, attention projections stay bf16 — they are
# small and latency-critical)
_W8_LEAVES = {
    "we13": (1, 1, TILE, 1, TILE),   # (L, E, D, g, Fe)
    "we2": (1, 1, TILE, TILE),       # (L, E, Fe, D)
}


def _pad_ok(shape, tile):
    return all(n % t == 0 for n, t in zip(shape, tile))


def quantize_params_for_serving(params):
    """Replace the big matmul weights with blockwise-po2 QTensors."""
    def conv(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        name = keys[-1]
        if name in _W8_LEAVES:
            tile = _W8_LEAVES[name]
            if len(tile) == leaf.ndim and _pad_ok(leaf.shape, tile):
                return quantize(leaf, tile, tag=f"q_w8_{name}",
                                kind="fused_quantize")
        return leaf

    return jax.tree_util.tree_map_with_path(conv, params)


def is_w8(p) -> bool:
    return isinstance(p, QTensor)


def w8_merge_gate(q: QTensor):
    """(E, D, g, Fe) blockwise QTensor -> (E, D, g*Fe): exact block
    relabeling (gate/up halves stay contiguous)."""
    E, D, g, Fe = q.data.shape
    return QTensor(data=q.data.reshape(E, D, g * Fe),
                   scale=q.scale.reshape(E, D // TILE, g * Fe // TILE),
                   tile=(1, TILE, TILE))


def retile(q: QTensor, tile) -> QTensor:
    """Fix up the static tile metadata after tree-level slicing."""
    return QTensor(data=q.data, scale=q.scale, tile=tuple(tile))
