"""Prefix-aware multi-replica router: one front door over N ServeEngines.

One engine instance is not a production service.  The router owns the
global request queue and, per arriving request, scores every replica
flexlb-style (rtp-llm's ``KvCacheManager`` + load-balance scoring):

    score = w_prefix * cached_prefix_overlap - w_load * load

  * **overlap** — the fraction of the request's prompt already resident in
    that replica's radix prefix cache (``PrefixCache.match_tokens``, a pure
    peek: no LRU touch, no hit accounting).  Routing a request to the
    replica that already holds its prefix turns the prefill into a cache
    hit: the prompt is quantized once per FLEET, not once per replica.
  * **load** — the mean of the replica's reserved-token-budget fill and its
    KV-pool page occupancy, so a cold replica absorbs new tenants instead
    of piling every popular prefix onto one engine.

Ties (e.g. a fleet of cold replicas) break toward the least-loaded replica,
then round-robin, so unprefixed traffic still spreads.

The driver interleaves `engine.tick` across replicas in one thread — the
same cooperative loop ServeEngine.run uses, generalized to N engines — and
merges per-request results/stats into one TraceResults.  Every routing
decision lands in telemetry (`route` records + per-replica counters) so the
reporter can show placement quality next to hit rates.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.obs.sink import null_telemetry
from repro.serve.engine import ServeEngine, TraceResults
from repro.serve.scheduler import Request


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    w_prefix: float = 1.0       # weight of cached-prefix overlap
    w_load: float = 1.0         # weight of the load penalty
    min_overlap: float = 0.0    # overlap below this scores as 0 (ignore
                                # trivial matches when balancing load)


class ReplicaRouter:
    """Score-and-dispatch over N ServeEngine replicas."""

    def __init__(self, engines: Sequence[ServeEngine],
                 rcfg: RouterConfig = RouterConfig(), telemetry=None):
        if not engines:
            raise ValueError("router needs at least one replica")
        self.engines = list(engines)
        self.rcfg = rcfg
        self.tel = telemetry if telemetry is not None else null_telemetry()
        self.n_routed = 0
        self._rr = 0                      # round-robin tie-break cursor
        self.route_counts = [0] * len(self.engines)

    # -- scoring -----------------------------------------------------------
    def overlap(self, idx: int, req: Request) -> float:
        eng = self.engines[idx]
        if eng.prefix_cache is None or not req.prompt:
            return 0.0
        ov = eng.prefix_cache.match_tokens(req.prompt) / len(req.prompt)
        return ov if ov >= self.rcfg.min_overlap else 0.0

    def load(self, idx: int) -> float:
        eng = self.engines[idx]
        budget_fill = eng.sched.reserved_tokens / max(eng.ecfg.token_budget,
                                                      1)
        pool = max(eng.ecfg.n_pages - 1, 1)
        page_fill = (pool - eng.alloc.free_pages) / pool
        return 0.5 * (budget_fill + page_fill)

    def score(self, idx: int, req: Request) -> float:
        return self.rcfg.w_prefix * self.overlap(idx, req) \
            - self.rcfg.w_load * self.load(idx)

    # -- dispatch ----------------------------------------------------------
    def _candidates(self) -> List[int]:
        """Replica indices eligible for NEW requests (a disaggregated fleet
        restricts this to the prefill tier)."""
        return list(range(len(self.engines)))

    def route(self, req: Request) -> int:
        """Pick a replica for `req` (argmax score; ties toward the least
        loaded, then round-robin) and submit it there."""
        scored = [(self.score(i, req), -self.load(i), i)
                  for i in self._candidates()]
        best = max(s for s, _, _ in scored)
        tied = [t for t in scored if t[0] >= best - 1e-12]
        if len(tied) > 1:
            best_load = max(l for _, l, _ in tied)
            tied = [t for t in tied if t[1] >= best_load - 1e-12]
        idx = tied[(self._rr % len(tied))][2] if len(tied) > 1 else tied[0][2]
        if len(tied) > 1:
            self._rr += 1
        ov = self.overlap(idx, req)
        self.engines[idx].submit(req)
        self.n_routed += 1
        self.route_counts[idx] += 1
        self.tel.counter("router_decisions",
                         labels={"replica": str(idx)}).inc()
        if self.tel.enabled:
            self.tel.record("route", rid=req.rid, replica=idx,
                            overlap=round(ov, 4),
                            load=round(self.load(idx), 4),
                            prompt_tokens=len(req.prompt))
        return idx

    # -- driver ------------------------------------------------------------
    def _busy(self) -> bool:
        return not all(e.sched.idle() for e in self.engines)

    def _drain(self, now: float, results: Dict[int, dict]) -> bool:
        """Router-level work between engine ticks (the disaggregated fleet
        migrates parked prefills and requeues decode-tier evictions here).
        Returns True iff anything progressed."""
        return False

    def run(self, requests: Sequence[Request],
            realtime: bool = True) -> Dict[int, dict]:
        """Drive a trace across the fleet: route each request at its
        arrival, interleave one tick per replica, merge results."""
        pending = deque(sorted(requests, key=lambda r: r.arrival_time))
        results = TraceResults()
        t0 = time.perf_counter()
        idle_spins = 0
        while pending or self._busy():
            now = time.perf_counter() - t0
            while pending and (not realtime
                               or pending[0].arrival_time <= now):
                self.route(pending.popleft())
            progressed = any([e.tick(now, results) for e in self.engines])
            # Drain runs every loop and counts as progress: on a SATURATED
            # fleet every engine's tick can return False (all at budget, no
            # admissible head) while the handoff queue is nonempty — the old
            # guard credited only engine ticks, so a fleet that was one
            # migration away from unblocking tripped the deadlock error.
            # Migration frees donor budget / fills decode slots, so crediting
            # it keeps the idle counter honest.
            if self._drain(now, results):
                progressed = True
            if progressed:
                idle_spins = 0
                continue
            if pending:
                time.sleep(max(0.0, min(0.002,
                                        pending[0].arrival_time - now)))
                continue
            idle_spins += 1
            if idle_spins > 1000:
                raise RuntimeError(
                    "router deadlock: waiting requests can never be "
                    "admitted on any replica (check token_budget / n_pages)")
        results.stats = self.stats()
        self.tel.record("router_summary", **{
            k: v for k, v in results.stats.items()
            if not isinstance(v, (list, dict))})
        self.tel.flush()
        return results

    def stats(self) -> Dict[str, object]:
        """Fleet aggregate + per-replica breakdown."""
        agg: Dict[str, object] = {"replicas": len(self.engines),
                                  "routed": self.n_routed,
                                  "route_counts": list(self.route_counts)}
        per = [e.stats() for e in self.engines]
        for key in ("ticks", "admitted", "evicted", "finished", "rejected",
                    "prefill_chunks", "decode_tokens", "prefix_hits",
                    "prefix_lookups", "prefix_hit_tokens", "cache_evictions",
                    "shared_pages", "cache_evicted_pages",
                    "adopted", "migrated_out"):
            vals = [p[key] for p in per if key in p]
            if vals:
                agg[key] = sum(vals)
        agg["per_replica"] = per
        return agg


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Knobs for the disaggregated (prefill/decode) fleet."""
    transfer_budget_bytes: int = 1 << 20   # wire bytes per drain cycle; at
                                           # least one migration always goes
                                           # through (no starvation), the
                                           # budget shapes burst smoothness


class DisaggRouter(ReplicaRouter):
    """Two-tier fleet: prefill replicas (admission + chunked prefill only)
    feed decode replicas through a casting-free KV-page migration queue.

    Why disaggregate: in a mixed engine a long prompt's prefill chunks ride
    every tick alongside resident decodes, so decode time-between-tokens
    inherits the chunk's compute (the interference the chunked-prefill work
    bounded but could not remove).  Splitting tiers makes decode ticks pure
    decode — TBT no longer sees prefill compute at all — at the price of one
    page-granular KV migration per request, which the FP8 pool makes cheap
    (~1 B/elem) and EXACT (pure bitcast, provably zero re-quantization:
    `KVTransferCodec.assert_casting_free`).

    Handoff protocol per request (router-orchestrated, two-phase):

      1. a prefill replica finishes the last chunk, emits the first token,
         and PARKS the request (pages/slot/budget held) in its handoff queue;
      2. the drain step picks the longest-waiting parked request, scores the
         decode tier (prefix overlap − load, same weights as routing),
         reserves pages on the winner — blocks already in the receiver's
         radix cache are SHARED (incref), not shipped: po2 pages are
         content-addressable, the local copy is bit-identical — and ships
         the rest as one uint8 message under ``transfer_budget_bytes``;
      3. the receiver scatters the bytes into its pool, adopts the request
         into its decode batch at the request's `pos`, re-publishes the
         prompt prefix into its own radix tree (so the NEXT migration of
         this tenant dedupes), and acks; only then does the donor release
         the parked pages through its cache-aware funnel.

    Decode-tier evictions (pool pressure) restart through the prefill tier:
    the drain requeues them with the router, preserving restart semantics.
    """

    def __init__(self, prefill_engines: Sequence[ServeEngine],
                 decode_engines: Sequence[ServeEngine],
                 rcfg: RouterConfig = RouterConfig(),
                 dcfg: DisaggConfig = DisaggConfig(), telemetry=None):
        for e in prefill_engines:
            if e.ecfg.role != "prefill":
                raise ValueError("prefill tier engine has role "
                                 f"{e.ecfg.role!r} (want 'prefill')")
        for e in decode_engines:
            if e.ecfg.role != "decode":
                raise ValueError("decode tier engine has role "
                                 f"{e.ecfg.role!r} (want 'decode')")
        if not decode_engines:
            raise ValueError("disaggregated fleet needs a decode tier")
        super().__init__(list(prefill_engines) + list(decode_engines),
                         rcfg, telemetry)
        self.prefill_engines = list(prefill_engines)
        self.decode_engines = list(decode_engines)
        self.dcfg = dcfg
        self.n_migrations = 0
        self.kv_transfer_bytes = 0
        self.deduped_pages = 0
        self.shipped_pages = 0
        self.requeued_evictions = 0
        self.budget_deferrals = 0
        self.reserve_failures = 0

    def _candidates(self) -> List[int]:
        # new requests only ever land on the prefill tier
        return list(range(len(self.prefill_engines)))

    # -- receiver choice ---------------------------------------------------
    def _recv_score(self, eng: ServeEngine, req: Request) -> float:
        ov = 0.0
        if eng.prefix_cache is not None and req.prompt:
            ov = eng.prefix_cache.match_tokens(req.prompt) / len(req.prompt)
            if ov < self.rcfg.min_overlap:
                ov = 0.0
        i = self.engines.index(eng)
        return self.rcfg.w_prefix * ov - self.rcfg.w_load * self.load(i)

    # -- the drain: migrations + eviction requeues -------------------------
    def _drain(self, now: float, results: Dict[int, dict]) -> bool:
        progressed = False
        # decode-tier evictions restart via the prefill tier (a decode
        # replica never admits, so anything in its waiting queue would
        # starve there)
        for eng in self.decode_engines:
            while eng.sched.waiting:
                req = eng.sched.waiting.popleft()
                self.route(req)
                self.requeued_evictions += 1
                progressed = True

        budget = self.dcfg.transfer_budget_bytes
        spent = 0
        migrated = 0
        while True:
            donors = [e for e in self.prefill_engines if e.handoff]
            if not donors:
                break
            # FIFO across the tier: longest-parked request first
            donor = min(donors,
                        key=lambda e: (e.handoff[0].first_token_time or 0.0,
                                       e.handoff[0].admit_seq))
            st = donor.handoff[0]
            recvs = sorted(self.decode_engines,
                           key=lambda e: self._recv_score(e, st.req),
                           reverse=True)
            res = recv = None
            for cand in recvs:
                res = cand.reserve_for_adopt(st.req)
                if res is not None:
                    recv = cand
                    break
            if res is None:
                self.reserve_failures += 1
                break              # decode tier full right now; retry later
            shared, fresh = res
            cost = donor.codec.bytes_for(len(fresh))
            if migrated > 0 and spent + cost > budget:
                # budget exhausted this cycle — but the FIRST migration of a
                # cycle always goes through, so a single page batch larger
                # than the budget cannot starve forever
                recv.abort_adopt(shared, fresh)
                self.budget_deferrals += 1
                break
            t_mig = time.perf_counter()
            msg = donor.pack_handoff(st, skip_pages=len(shared))
            meta, payload = recv.codec.unpack(msg)
            timing = {"arrival": st.req.arrival_time,
                      "admit": st.admit_time,
                      "first": st.first_token_time,
                      "last": st.last_token_time}
            recv.commit_adopt(meta, payload, shared, fresh, now,
                              timing=timing)
            donor.handoff.popleft()
            donor.release_parked(st)           # the receiver ack
            mig_ms = (time.perf_counter() - t_mig) * 1e3
            spent += cost
            migrated += 1
            self.n_migrations += 1
            self.kv_transfer_bytes += cost
            self.deduped_pages += len(shared)
            self.shipped_pages += len(fresh)
            self.tel.counter("kv_transfer_bytes").inc(cost)
            self.tel.counter("migrations_total").inc()
            self.tel.histogram("migration_ms").observe(mig_ms)
            if self.tel.enabled:
                self.tel.record(
                    "migration", rid=st.req.rid,
                    donor=self.engines.index(donor),
                    receiver=self.engines.index(recv),
                    shipped_pages=len(fresh), deduped_pages=len(shared),
                    bytes=cost, ms=round(mig_ms, 3),
                    queue_ms=round((now - (st.first_token_time or now))
                                   * 1e3, 3))
            progressed = True
        depth = sum(len(e.handoff) for e in self.prefill_engines)
        self.tel.gauge("handoff_queue_depth").set(depth)
        return progressed

    # -- reporting ---------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        agg = super().stats()
        agg["disagg"] = {
            "prefill_replicas": len(self.prefill_engines),
            "decode_replicas": len(self.decode_engines),
            "migrations": self.n_migrations,
            "kv_transfer_bytes": self.kv_transfer_bytes,
            "shipped_pages": self.shipped_pages,
            "deduped_pages": self.deduped_pages,
            "requeued_evictions": self.requeued_evictions,
            "budget_deferrals": self.budget_deferrals,
            "reserve_failures": self.reserve_failures,
            "transfer_budget_bytes": self.dcfg.transfer_budget_bytes,
        }
        return agg

    def run(self, requests: Sequence[Request],
            realtime: bool = True) -> Dict[int, dict]:
        results = super().run(requests, realtime)
        self.tel.record("disagg_summary", **results.stats["disagg"])
        self.tel.flush()
        return results
