"""Prefix-aware multi-replica router: one front door over N ServeEngines.

One engine instance is not a production service.  The router owns the
global request queue and, per arriving request, scores every replica
flexlb-style (rtp-llm's ``KvCacheManager`` + load-balance scoring):

    score = w_prefix * cached_prefix_overlap - w_load * load

  * **overlap** — the fraction of the request's prompt already resident in
    that replica's radix prefix cache (``PrefixCache.match_tokens``, a pure
    peek: no LRU touch, no hit accounting).  Routing a request to the
    replica that already holds its prefix turns the prefill into a cache
    hit: the prompt is quantized once per FLEET, not once per replica.
  * **load** — the mean of the replica's reserved-token-budget fill and its
    KV-pool page occupancy, so a cold replica absorbs new tenants instead
    of piling every popular prefix onto one engine.

Ties (e.g. a fleet of cold replicas) break toward the least-loaded replica,
then round-robin, so unprefixed traffic still spreads.

The driver interleaves `engine.tick` across replicas in one thread — the
same cooperative loop ServeEngine.run uses, generalized to N engines — and
merges per-request results/stats into one TraceResults.  Every routing
decision lands in telemetry (`route` records + per-replica counters) so the
reporter can show placement quality next to hit rates.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.obs.sink import null_telemetry
from repro.serve.engine import ServeEngine, TraceResults
from repro.serve.scheduler import Request


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    w_prefix: float = 1.0       # weight of cached-prefix overlap
    w_load: float = 1.0         # weight of the load penalty
    min_overlap: float = 0.0    # overlap below this scores as 0 (ignore
                                # trivial matches when balancing load)


class ReplicaRouter:
    """Score-and-dispatch over N ServeEngine replicas."""

    def __init__(self, engines: Sequence[ServeEngine],
                 rcfg: RouterConfig = RouterConfig(), telemetry=None):
        if not engines:
            raise ValueError("router needs at least one replica")
        self.engines = list(engines)
        self.rcfg = rcfg
        self.tel = telemetry if telemetry is not None else null_telemetry()
        self.n_routed = 0
        self._rr = 0                      # round-robin tie-break cursor
        self.route_counts = [0] * len(self.engines)

    # -- scoring -----------------------------------------------------------
    def overlap(self, idx: int, req: Request) -> float:
        eng = self.engines[idx]
        if eng.prefix_cache is None or not req.prompt:
            return 0.0
        ov = eng.prefix_cache.match_tokens(req.prompt) / len(req.prompt)
        return ov if ov >= self.rcfg.min_overlap else 0.0

    def load(self, idx: int) -> float:
        eng = self.engines[idx]
        budget_fill = eng.sched.reserved_tokens / max(eng.ecfg.token_budget,
                                                      1)
        pool = max(eng.ecfg.n_pages - 1, 1)
        page_fill = (pool - eng.alloc.free_pages) / pool
        return 0.5 * (budget_fill + page_fill)

    def score(self, idx: int, req: Request) -> float:
        return self.rcfg.w_prefix * self.overlap(idx, req) \
            - self.rcfg.w_load * self.load(idx)

    # -- dispatch ----------------------------------------------------------
    def route(self, req: Request) -> int:
        """Pick a replica for `req` (argmax score; ties toward the least
        loaded, then round-robin) and submit it there."""
        n = len(self.engines)
        scored = [(self.score(i, req), -self.load(i), i) for i in range(n)]
        best = max(s for s, _, _ in scored)
        tied = [t for t in scored if t[0] >= best - 1e-12]
        if len(tied) > 1:
            best_load = max(l for _, l, _ in tied)
            tied = [t for t in tied if t[1] >= best_load - 1e-12]
        idx = tied[(self._rr % len(tied))][2] if len(tied) > 1 else tied[0][2]
        if len(tied) > 1:
            self._rr += 1
        ov = self.overlap(idx, req)
        self.engines[idx].submit(req)
        self.n_routed += 1
        self.route_counts[idx] += 1
        self.tel.counter("router_decisions",
                         labels={"replica": str(idx)}).inc()
        if self.tel.enabled:
            self.tel.record("route", rid=req.rid, replica=idx,
                            overlap=round(ov, 4),
                            load=round(self.load(idx), 4),
                            prompt_tokens=len(req.prompt))
        return idx

    # -- driver ------------------------------------------------------------
    def run(self, requests: Sequence[Request],
            realtime: bool = True) -> Dict[int, dict]:
        """Drive a trace across the fleet: route each request at its
        arrival, interleave one tick per replica, merge results."""
        pending = deque(sorted(requests, key=lambda r: r.arrival_time))
        results = TraceResults()
        t0 = time.perf_counter()
        idle_spins = 0
        while pending or not all(e.sched.idle() for e in self.engines):
            now = time.perf_counter() - t0
            while pending and (not realtime
                               or pending[0].arrival_time <= now):
                self.route(pending.popleft())
            progressed = [e.tick(now, results) for e in self.engines]
            if any(progressed):
                idle_spins = 0
                continue
            if pending:
                time.sleep(max(0.0, min(0.002,
                                        pending[0].arrival_time - now)))
                continue
            idle_spins += 1
            if idle_spins > 1000:
                raise RuntimeError(
                    "router deadlock: waiting requests can never be "
                    "admitted on any replica (check token_budget / n_pages)")
        results.stats = self.stats()
        self.tel.record("router_summary", **{
            k: v for k, v in results.stats.items()
            if not isinstance(v, (list, dict))})
        self.tel.flush()
        return results

    def stats(self) -> Dict[str, object]:
        """Fleet aggregate + per-replica breakdown."""
        agg: Dict[str, object] = {"replicas": len(self.engines),
                                  "routed": self.n_routed,
                                  "route_counts": list(self.route_counts)}
        per = [e.stats() for e in self.engines]
        for key in ("ticks", "admitted", "evicted", "finished", "rejected",
                    "prefill_chunks", "decode_tokens", "prefix_hits",
                    "prefix_lookups", "prefix_hit_tokens", "cache_evictions"):
            vals = [p[key] for p in per if key in p]
            if vals:
                agg[key] = sum(vals)
        agg["per_replica"] = per
        return agg
