"""The metrics registry: counters, gauges, and histograms with explicit
power-of-two bucket edges.

Pure host-side bookkeeping — nothing in this module touches jax.  Values
that originate on the device (loss, grad_norm, the guard bitmask, the
per-site FP8 sat/flush matrix) become registry samples only AFTER the train
loop's existing once-per-step metrics fetch, so arming the registry can
never add a host sync (tests/test_obs.py holds the jaxpr/HLO to that).

po2 buckets: every latency/size histogram uses power-of-two edges by
default.  Two reasons: (a) merges are trivial — two histograms with the
same exponent range add countwise, no rebinning; (b) they match the
repo's po2-scale worldview, so a bucket index IS an exponent and the
reporter can print `2^k` labels without float noise.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple


def po2_buckets(lo_exp: int, hi_exp: int) -> Tuple[float, ...]:
    """Bucket edges 2^lo_exp .. 2^hi_exp inclusive (exact floats)."""
    if hi_exp < lo_exp:
        raise ValueError(f"empty bucket range [{lo_exp}, {hi_exp}]")
    return tuple(float(2.0 ** e) for e in range(lo_exp, hi_exp + 1))


# default edges for millisecond latencies: 2^-6 ms (~16us) .. 2^14 ms (~16s)
MS_BUCKETS = po2_buckets(-6, 14)
# token/byte-ish counts: 1 .. 2^24
COUNT_BUCKETS = po2_buckets(0, 24)
# fractions in [0, 1]: 2^-20 .. 2^0
FRAC_BUCKETS = po2_buckets(-20, 0)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels=None):
        self.name, self.labels = name, labels or {}
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({n})")
        self.value += n


class Gauge:
    """Last-write-wins sample."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels=None):
        self.name, self.labels = name, labels or {}
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-edge histogram (cumulative-le semantics at render time).

    counts[i] is the number of observations in (edges[i-1], edges[i]];
    counts[0] covers (-inf, edges[0]], counts[-1] covers (edges[-1], +inf).
    """

    __slots__ = ("name", "labels", "edges", "counts", "sum", "count")

    def __init__(self, name: str, edges: Sequence[float] = MS_BUCKETS,
                 labels=None):
        edges = tuple(float(e) for e in edges)
        if list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram {name}: edges must be strictly "
                             f"increasing, got {edges}")
        self.name, self.labels = name, labels or {}
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def merge(self, other: "Histogram") -> None:
        """Countwise add (same edges required — trivially true for po2)."""
        if other.edges != self.edges:
            raise ValueError(f"cannot merge {self.name}: edge mismatch")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper edge of the bucket holding
        the q-th observation; conservative, like Prometheus)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target and c:
                return self.edges[i] if i < len(self.edges) \
                    else self.edges[-1]
        return self.edges[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def _key(name: str, labels: Optional[dict]):
    return (name, tuple(sorted((labels or {}).items())))


class Registry:
    """Name+labels-keyed get-or-create registry, thread-safe (the serving
    engine and a trace driver may observe from different threads)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[tuple, object] = {}

    def _get(self, cls, name, labels, *args):
        k = _key(name, labels)
        with self._lock:
            m = self._metrics.get(k)
            if m is None:
                m = cls(name, *args, labels=dict(k[1]))
                self._metrics[k] = m
            elif not isinstance(m, cls):
                raise TypeError(f"{name} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str, labels: Optional[dict] = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: Optional[dict] = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, edges: Sequence[float] = MS_BUCKETS,
                  labels: Optional[dict] = None) -> Histogram:
        return self._get(Histogram, name, labels, edges)

    def __iter__(self):
        with self._lock:
            return iter(list(self._metrics.values()))

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict snapshot (JSONL-safe)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self:
            name = _flat_name(m)
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = {
                    "edges": list(m.edges), "counts": list(m.counts),
                    "sum": m.sum, "count": m.count}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text-exposition-format snapshot (0.0.4)."""
        by_name: Dict[str, list] = {}
        for m in self:
            by_name.setdefault(m.name, []).append(m)
        lines = []
        for name in sorted(by_name):
            ms = by_name[name]
            kind = ("counter" if isinstance(ms[0], Counter) else
                    "gauge" if isinstance(ms[0], Gauge) else "histogram")
            lines.append(f"# TYPE {name} {kind}")
            for m in sorted(ms, key=lambda m: sorted(m.labels.items())):
                if isinstance(m, (Counter, Gauge)):
                    lines.append(f"{name}{_label_str(m.labels)} "
                                 f"{_fmt(m.value)}")
                    continue
                acc = 0
                for edge, c in zip(m.edges, m.counts):
                    acc += c
                    lines.append(
                        f"{name}_bucket"
                        f"{_label_str(m.labels, le=_fmt(edge))} {acc}")
                lines.append(f"{name}_bucket"
                             f"{_label_str(m.labels, le='+Inf')} {m.count}")
                lines.append(f"{name}_sum{_label_str(m.labels)} "
                             f"{_fmt(m.sum)}")
                lines.append(f"{name}_count{_label_str(m.labels)} "
                             f"{m.count}")
        return "\n".join(lines) + "\n"


def _flat_name(m) -> str:
    if not m.labels:
        return m.name
    lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
    return f"{m.name}{{{lbl}}}"


def _label_str(labels: dict, **extra) -> str:
    items = {**labels, **extra}
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(items.items()))
    return "{" + body + "}"


def _fmt(v: float) -> str:
    return repr(float(v)) if v != int(v) else str(int(v))
