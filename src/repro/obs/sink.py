"""Structured event + metric sinks, and the Telemetry facade the train
loop / serving engine / launchers talk to.

Every record is one flat JSON-able dict with at least {"t": wall-clock
seconds, "kind": <event kind>}.  Three sinks:

  JsonlSink     one JSON line per record, append-only — the run artifact
                `python -m repro.obs.report` consumes.
  MemorySink    bounded in-memory ring (tests, and the reporter's live use).
  NullSink      swallows everything (telemetry off).

The Telemetry facade binds a Registry + sinks + the legacy human log_fn:
typed events replace the loop's former unstructured f-strings — each
`tel.event(kind, msg=..., **fields)` writes the structured record to the
sinks AND renders the human line through log_fn, so `--obs` changes what is
*kept*, not what is printed.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, Iterable, List, Optional

from repro.obs.metrics import MS_BUCKETS, Registry


class NullSink:
    def emit(self, record: dict) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink(NullSink):
    """Append-only JSONL file sink (one record per line)."""

    def __init__(self, path: str):
        self.path = str(path)
        self._f = open(self.path, "a", buffering=1)

    def emit(self, record: dict) -> None:
        self._f.write(json.dumps(record, default=_json_default,
                                 sort_keys=True) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class MemorySink(NullSink):
    """Bounded in-memory ring, for tests and live inspection."""

    def __init__(self, capacity: int = 65536):
        self.records: deque = deque(maxlen=capacity)

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def of_kind(self, kind: str) -> List[dict]:
        return [r for r in self.records if r.get("kind") == kind]


class MultiSink(NullSink):
    def __init__(self, *sinks):
        self.sinks = sinks

    def emit(self, record: dict) -> None:
        for s in self.sinks:
            s.emit(record)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def _json_default(o):
    """numpy scalars/arrays and other array-likes -> plain python."""
    if hasattr(o, "item") and getattr(o, "ndim", 1) == 0:
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


class Telemetry:
    """Registry + sinks + human log, one handle.

    `enabled` is False only for the shared `null_telemetry` fallback — call
    sites stay unconditional and pay a no-op when telemetry is off.
    """

    def __init__(self, sinks: Iterable = (), registry: Optional[Registry]
                 = None, log_fn=None, clock=time.time):
        sinks = tuple(sinks)
        self.sink = (NullSink() if not sinks else sinks[0]
                     if len(sinks) == 1 else MultiSink(*sinks))
        self.registry = registry if registry is not None else Registry()
        self.log_fn = log_fn
        self.clock = clock
        self.enabled = True
        # static per-step counter increments (e.g. the modelled DP-wire
        # bytes/step a launcher registers once from the GradLayout)
        self.per_step_counters: Dict[str, float] = {}

    # -- events ------------------------------------------------------------
    def event(self, kind: str, msg: Optional[str] = None, **fields) -> dict:
        """Emit one typed event.  `msg` is the human rendering (kept
        verbatim for log_fn); the sinks get the structured fields."""
        rec = {"t": self.clock(), "kind": kind, **fields}
        if msg is not None:
            rec["msg"] = msg
        self.sink.emit(rec)
        if self.log_fn is not None:
            self.log_fn(msg if msg is not None else _render(kind, fields))
        return rec

    def record(self, kind: str, **fields) -> dict:
        """Emit a structured record WITHOUT a human line (high-rate data:
        per-step samples, per-tick serve records)."""
        rec = {"t": self.clock(), "kind": kind, **fields}
        self.sink.emit(rec)
        return rec

    # -- metrics -----------------------------------------------------------
    def counter(self, name, labels=None):
        return self.registry.counter(name, labels)

    def gauge(self, name, labels=None):
        return self.registry.gauge(name, labels)

    def histogram(self, name, edges=MS_BUCKETS, labels=None):
        return self.registry.histogram(name, edges, labels)

    def span(self, name: str):
        from repro.obs.trace import Span
        return Span(self, name)

    def step(self, step: int, values: Dict[str, float],
             spans: Optional[Dict[str, float]] = None,
             extra: Optional[dict] = None) -> None:
        """One training-step sample: gauge every scalar, observe the span
        histograms, and write a single 'step' record.  `values` must
        already be host-side (the loop's existing per-step fetch); `extra`
        carries structured non-scalar payloads (e.g. the per-site quant
        stats dict) into the record without touching the registry."""
        for k, v in values.items():
            self.gauge(f"train_{k}").set(v)
        spans = spans or {}
        for k, ms in spans.items():
            self.histogram("train_span_ms", labels={"span": k}).observe(ms)
        for k, n in self.per_step_counters.items():
            self.counter(k).inc(n)
        self.record("step", step=step, **values,
                    **{f"{k}_ms": v for k, v in spans.items()},
                    **(extra or {}))

    # -- export ------------------------------------------------------------
    def emit_registry(self, **fields) -> None:
        self.record("registry", snapshot=self.registry.snapshot(), **fields)

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.registry.to_prometheus())

    def flush(self) -> None:
        self.sink.flush()

    def close(self) -> None:
        self.sink.close()


def _render(kind: str, fields: dict) -> str:
    body = " ".join(f"{k}={v}" for k, v in fields.items())
    return f"[obs] {kind}{(' ' + body) if body else ''}"


class _NullTelemetry(Telemetry):
    def __init__(self):
        super().__init__(sinks=(NullSink(),))
        self.enabled = False


def null_telemetry(log_fn=None) -> Telemetry:
    """A telemetry handle that keeps registry bookkeeping (cheap, host-side)
    but sinks nothing; with log_fn set, events still render human lines, so
    the loop's behavior with telemetry off is unchanged."""
    t = _NullTelemetry()
    t.log_fn = log_fn
    return t
