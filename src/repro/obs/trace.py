"""Stage-level tracing: device-side named scopes + host-side spans.

Two different clocks, two different tools:

  annotate(name)   TRACE-TIME ONLY — a jax.named_scope over a region of the
                   traced program.  Adds zero ops (it names the HLO, so
                   `jax.profiler` timelines and XLA dumps show the staged
                   layer program's attn/router/dispatch/expert/combine
                   stages, the streaming-wire bucket issue points, and the
                   MemoryPlan remat blocks by name).  Safe to leave on
                   unconditionally: tests/test_obs.py asserts the annotated
                   build adds no host-transfer ops.

  Span(tel, name)  HOST wall-clock — measures a with-block in ms, feeds the
                   telemetry's 'span' histogram, and (when the profiler is
                   active) brackets the block in a jax.profiler
                   TraceAnnotation so host phases line up with device rows
                   on the trace viewer.  This is how train/loop.py splits
                   the formerly-conflated `dt` into an honest device-step
                   span and the blocking host-fetch span.
"""
from __future__ import annotations

import contextlib
import time

import jax

STAGES = ("attn", "router", "dispatch", "expert", "combine")


def annotate(name: str):
    """Device-side named scope (zero ops; trace-time metadata only)."""
    try:
        return jax.named_scope(name)
    except Exception:                      # pragma: no cover - old jax
        return contextlib.nullcontext()


def stage_annotation(stage: str):
    """Named scope for one stage of the staged layer program."""
    return annotate(f"stage/{stage}")


class Span:
    """Host-side wall-clock span; records into telemetry on exit.

    Usage::

        with tel.span("device_step") as sp:
            ...work...
        print(sp.ms)
    """

    __slots__ = ("tel", "name", "t0", "ms", "_prof")

    def __init__(self, tel, name: str):
        self.tel, self.name = tel, name
        self.t0 = 0.0
        self.ms = 0.0
        self._prof = None

    def __enter__(self):
        try:
            self._prof = jax.profiler.TraceAnnotation(f"host/{self.name}")
            self._prof.__enter__()
        except Exception:                  # pragma: no cover - no profiler
            self._prof = None
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.ms = (time.perf_counter() - self.t0) * 1e3
        if self._prof is not None:
            self._prof.__exit__(*exc)
        if self.tel is not None:
            self.tel.histogram("span_ms", labels={"span": self.name}) \
                .observe(self.ms)
        return False
