"""CLI reporter over obs JSONL streams.

  PYTHONPATH=src python -m repro.obs.report run.jsonl [serve.jsonl ...]

Renders, for whatever record kinds the stream contains:

  * step-time breakdown — device-step vs host-fetch spans (the honest
    split train/loop.py emits), loss trajectory, guard-flag counts;
  * guard-event timeline — every skip/rollback/demote/repromote with its
    decoded flag names;
  * per-site FP8 numerics — saturation / underflow-flush max+mean per
    quantize site (the input the ROADMAP's adaptive-precision controller
    will consume);
  * cast-ledger snapshots — activation-cast counts per traced program;
  * serve summary — tick counters, KV-pool occupancy, TTFT/TBT stats;
  * benchmark records — the unified benchmarks/common.py emit() stream.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def load_records(paths) -> List[dict]:
    recs = []
    for p in paths:
        with open(p) as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    recs.append(json.loads(line))
                except json.JSONDecodeError as e:
                    print(f"[report] {p}:{ln}: skipping bad record ({e})",
                          file=sys.stderr)
    return recs


def by_kind(recs) -> Dict[str, List[dict]]:
    out: Dict[str, List[dict]] = {}
    for r in recs:
        out.setdefault(r.get("kind", "?"), []).append(r)
    return out


def _stats(xs):
    if not xs:
        return dict(n=0, mean=0.0, p50=0.0, p95=0.0, max=0.0)
    xs = sorted(xs)
    n = len(xs)
    return dict(n=n, mean=sum(xs) / n, p50=xs[n // 2],
                p95=xs[min(n - 1, int(0.95 * n))], max=xs[-1])


def _fmt_ms(s):
    return (f"n={s['n']:<5d} mean={s['mean']:8.2f}ms p50={s['p50']:8.2f}ms "
            f"p95={s['p95']:8.2f}ms max={s['max']:8.2f}ms")


def render_steps(steps, out):
    out(f"== train: {len(steps)} steps ==")
    losses = [r["loss"] for r in steps if "loss" in r]
    if losses:
        out(f"  loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
            f"(min {min(losses):.4f})")
    for key, label in (("device_ms", "device step"),
                       ("fetch_ms", "host fetch"),
                       ("total_ms", "total")):
        vals = [r[key] for r in steps if key in r]
        if vals:
            out(f"  {label:<12s} {_fmt_ms(_stats(vals))}")
    dev = sum(r.get("device_ms", 0.0) for r in steps)
    fet = sum(r.get("fetch_ms", 0.0) for r in steps)
    if dev and fet:
        out(f"  host-fetch share of device+fetch: "
            f"{100.0 * fet / (dev + fet):.1f}%")
    flagged = [r for r in steps if r.get("guard_flags")]
    if flagged:
        out(f"  guarded steps flagged: {len(flagged)}/{len(steps)} "
            f"(steps {[r['step'] for r in flagged][:12]}"
            f"{'...' if len(flagged) > 12 else ''})")


def render_sites(steps, out):
    sites: Dict[str, List[tuple]] = {}
    for r in steps:
        for site, pair in (r.get("quant_sites") or {}).items():
            if isinstance(pair, dict):            # {"sat": x, "flush": y}
                pair = (pair.get("sat", 0.0), pair.get("flush", 0.0))
            sites.setdefault(site, []).append(tuple(pair))
    if not sites:
        return
    out("== FP8 numerics: per-quantize-site sat/flush ==")
    out(f"  {'site':<16s} {'sat_max':>9s} {'sat_mean':>9s} "
        f"{'flush_max':>10s} {'flush_mean':>11s}")
    for site in sorted(sites):
        sat = [p[0] for p in sites[site]]
        fl = [p[1] for p in sites[site]]
        out(f"  {site:<16s} {max(sat):9.4f} {sum(sat)/len(sat):9.4f} "
            f"{max(fl):10.4f} {sum(fl)/len(fl):11.4f}")


def render_guard_events(events, out):
    out(f"== guard events: {len(events)} ==")
    for r in events:
        extra = {k: v for k, v in r.items()
                 if k not in ("t", "kind", "step", "event", "flags",
                              "flag_names", "msg")}
        detail = " ".join(f"{k}={v}" for k, v in extra.items())
        out(f"  step {r.get('step', '?'):>6} {r.get('event', '?'):<14s} "
            f"flags={r.get('flag_names', r.get('flags', 0))}"
            f"{(' ' + detail) if detail else ''}")


def render_casts(recs, out):
    out("== cast-ledger snapshots ==")
    for r in recs:
        label = r.get("fn", "?") + (" [demoted]" if r.get("demoted") else "")
        out(f"  [{label}] step={r.get('step')} activation_casts="
            f"{r.get('activation_casts')} fused={r.get('fused_casts')} "
            f"total={r.get('total')}")
        for tag, n in sorted((r.get("by_tag") or {}).items()):
            out(f"      {tag} x{n}")


def render_wire(recs, out):
    out("== DP wire layout ==")
    for r in recs:
        out(f"  buckets={r.get('n_buckets')} wire_rows={r.get('wire_rows')} "
            f"~{r.get('grad_bytes_per_step', 0) / 2**20:.1f} MiB grad "
            f"bytes/step/device (wire={r.get('wire')})")


def render_serve(kinds, out):
    ticks = kinds.get("serve_tick", [])
    done = kinds.get("request_done", [])
    summ = kinds.get("serve_summary", [])
    out(f"== serve: {len(ticks)} ticks, {len(done)} requests ==")
    if ticks:
        occ = [r["kv_used_pages"] for r in ticks if "kv_used_pages" in r]
        dec = [r.get("n_decode", 0) for r in ticks]
        if occ:
            out(f"  kv pages used: mean {sum(occ)/len(occ):.1f} "
                f"max {max(occ)}")
        out(f"  decode batch: mean {sum(dec)/len(dec):.1f} max "
            f"{max(dec) if dec else 0}")
    if done:
        ttft = [r["ttft_ms"] for r in done if "ttft_ms" in r]
        tbt = [r["tbt_ms_mean"] for r in done if r.get("tbt_ms_mean")
               is not None]
        if ttft:
            out(f"  TTFT        {_fmt_ms(_stats(ttft))}")
        if tbt:
            out(f"  TBT (mean)  {_fmt_ms(_stats(tbt))}")
        ev = sum(r.get("n_evictions", 0) for r in done)
        if ev:
            out(f"  evictions across finished requests: {ev}")
        cached = sum(r.get("cached_tokens", 0) for r in done)
        if cached:
            out(f"  prompt tokens served from prefix cache: {cached}")
    for r in summ:
        c = {k: v for k, v in r.items() if k not in ("t", "kind")}
        out("  totals: " + " ".join(f"{k}={int(v)}"
                                    for k, v in sorted(c.items())))
    hits = kinds.get("prefix_hit", [])
    if hits:
        toks = [r.get("cached_tokens", 0) for r in hits]
        cow = sum(1 for r in hits if r.get("cow"))
        out(f"  prefix cache: {len(hits)} hit admissions, "
            f"{sum(toks)} cached tokens "
            f"(max {max(toks)}/request, {cow} copy-on-write)")
    routes = kinds.get("route", [])
    if routes:
        per: Dict[int, List[dict]] = {}
        for r in routes:
            per.setdefault(r.get("replica", -1), []).append(r)
        out(f"  router: {len(routes)} decisions over {len(per)} replicas")
        for idx in sorted(per):
            ov = [r.get("overlap", 0.0) for r in per[idx]]
            ld = [r.get("load", 0.0) for r in per[idx]]
            out(f"    replica {idx}: {len(per[idx])} requests  "
                f"mean_overlap={sum(ov)/len(ov):.2f}  "
                f"mean_load={sum(ld)/len(ld):.2f}")
    for r in kinds.get("router_summary", []):
        c = {k: v for k, v in r.items() if k not in ("t", "kind")}
        out("  fleet totals: " + " ".join(f"{k}={int(v)}"
                                          for k, v in sorted(c.items())))


def render_disagg(kinds, out):
    migs = kinds.get("migration", [])
    summ = kinds.get("disagg_summary", [])
    out(f"== disaggregation: {len(migs)} KV migrations ==")
    if migs:
        bts = [r.get("bytes", 0) for r in migs]
        out(f"  wire: {sum(bts) / 2**20:.2f} MiB total, "
            f"mean {sum(bts)/len(bts)/2**10:.1f} KiB/migration")
        shipped = sum(r.get("shipped_pages", 0) for r in migs)
        dedup = sum(r.get("deduped_pages", 0) for r in migs)
        if shipped + dedup:
            out(f"  pages: {shipped} shipped, {dedup} deduped against "
                f"receiver caches "
                f"({100.0 * dedup / (shipped + dedup):.1f}% not re-sent)")
        q = [r["queue_ms"] for r in migs if "queue_ms" in r]
        if q:
            out(f"  handoff queue {_fmt_ms(_stats(q))}")
        ms = [r["ms"] for r in migs if "ms" in r]
        if ms:
            out(f"  migration    {_fmt_ms(_stats(ms))}")
        # per-tier placement: who donated, who received
        for key, label in (("donor", "prefill tier"),
                           ("receiver", "decode tier")):
            per: Dict[int, int] = {}
            for r in migs:
                per[r.get(key, -1)] = per.get(r.get(key, -1), 0) + 1
            parts = " ".join(f"r{idx}:{n}" for idx, n in sorted(per.items()))
            out(f"  {label:<13s} {parts}")
    for r in summ:
        budget = r.get("transfer_budget_bytes", 0)
        n_mig = max(r.get("migrations", 0), 1)
        util = (r.get("kv_transfer_bytes", 0) / n_mig / budget) if budget \
            else 0.0
        out("  totals: " + " ".join(
            f"{k}={int(v)}" for k, v in sorted(r.items())
            if k not in ("t", "kind")))
        out(f"  transfer budget: {budget / 2**20:.2f} MiB/cycle, mean "
            f"utilization {100.0 * util:.1f}%/migration, "
            f"{r.get('budget_deferrals', 0)} deferrals")


def render_bench(recs, out):
    out(f"== benchmark records: {len(recs)} ==")
    out(f"  {'name':<36s} {'value':>14s} {'units':<8s} {'source':<9s} "
        f"derived")
    for r in recs:
        out(f"  {str(r.get('name')):<36s} {r.get('value', 0):>14.2f} "
            f"{str(r.get('units', '')):<8s} {str(r.get('source', '')):<9s} "
            f"{r.get('derived', '')}")


def render(recs, out=print) -> int:
    """Render every known section; returns the number of records used."""
    kinds = by_kind(recs)
    steps = kinds.get("step", [])
    if steps:
        render_steps(steps, out)
        render_sites(steps, out)
    if "guard" in kinds:
        render_guard_events(kinds["guard"], out)
    if "cast_ledger" in kinds:
        render_casts(kinds["cast_ledger"], out)
    if "wire_layout" in kinds:
        render_wire(kinds["wire_layout"], out)
    if any(k in kinds for k in ("serve_tick", "request_done",
                                "serve_summary", "prefix_hit", "route",
                                "router_summary")):
        render_serve(kinds, out)
    if any(k in kinds for k in ("migration", "disagg_summary")):
        render_disagg(kinds, out)
    if "bench" in kinds:
        render_bench(kinds["bench"], out)
    other = [k for k in kinds if k not in
             ("step", "guard", "cast_ledger", "wire_layout", "serve_tick",
              "request_done", "serve_summary", "prefix_hit", "route",
              "router_summary", "migration", "disagg_summary", "bench",
              "registry")]
    if other:
        out("== other records ==")
        for k in sorted(other):
            out(f"  {k}: {len(kinds[k])}")
    return len(recs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", help="obs JSONL file(s)")
    args = ap.parse_args(argv)
    recs = load_records(args.paths)
    if not recs:
        print("[report] no records found", file=sys.stderr)
        return 1
    try:
        n = render(recs)
        print(f"[report] {n} records from {len(args.paths)} file(s)")
    except BrokenPipeError:        # e.g. piped into `head`
        sys.stderr.close()
        return 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
