"""Unified telemetry: zero-sync metrics registry, structured sinks, and
stage-level tracing shared by the train loop, the serving engine, and the
benchmark suite.

Design split (the same jit-boundary discipline as train/guards.py):

  on-device  everything worth observing inside the step is ALREADY an
             output of the jitted program — the metrics dict train_step
             returns (loss, grad_norm, guard_flags, the per-site FP8
             sat/flush matrix) and the serve step's sampled tokens.  The
             obs layer never adds a device->host transfer: it consumes the
             per-step fetch the loop was doing anyway (asserted the same
             way benchmarks/guard_overhead_ab.py asserts the guard
             bitmask's zero-sync contract).
  on-host    the registry (obs/metrics.py) aggregates those fetched values
             into counters/gauges/po2-bucket histograms; sinks
             (obs/sink.py) stream typed events + metric samples to JSONL /
             an in-memory ring / a Prometheus text snapshot; the reporter
             (obs/report.py, `python -m repro.obs.report run.jsonl`)
             renders step-time breakdowns, guard timelines, and per-site
             numerics summaries after the fact.

Device-side *tracing* (obs/trace.py) is trace-time only: jax.named_scope
annotations on the staged layer program (attn -> router -> dispatch ->
expert -> combine), the streaming-wire bucket issue points, and the
MemoryPlan remat blocks — zero ops, they only name the HLO.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               po2_buckets)
from repro.obs.sink import (JsonlSink, MemorySink, MultiSink, NullSink,
                            Telemetry, null_telemetry)
from repro.obs.trace import Span, annotate, stage_annotation

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "po2_buckets",
    "JsonlSink", "MemorySink", "MultiSink", "NullSink", "Telemetry",
    "null_telemetry", "Span", "annotate", "stage_annotation",
]
