"""Fault tolerance & elasticity for the training runtime.

Three mechanisms, all exercised by tests (single-process simulation of the
multi-host control plane — the JAX device mesh is rebuilt exactly as a real
coordinator would after `jax.distributed` membership changes):

1. **Heartbeat / failure detection** — `HealthMonitor` tracks per-host step
   latencies; a host is `failed` when it misses `timeout` seconds, `straggler`
   when its latency exceeds `straggler_factor` x the fleet median.

2. **Elastic re-meshing** — on failure, `shrink_mesh` drops the failure
   domain (a slice of the `data` axis), rebuilds the mesh with the survivors,
   and the caller restores the latest checkpoint with the new shardings
   (checkpointing.restore re-shards transparently).  Batch is rebalanced by
   re-deriving the data shards from shard indices (data/pipeline.py is a pure
   function of (step, shard)), so no data is lost or duplicated.

3. **Straggler mitigation** — rather than waiting on a slow host, its data
   shard is deterministically re-assigned round-robin to healthy hosts for
   the next step (`reassign_shards`), bounding step time at the median
   host's speed (+ the reassignment fraction).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class HostStatus:
    last_beat: float
    latencies: List[float] = dataclasses.field(default_factory=list)
    failed: bool = False


class HealthMonitor:
    def __init__(self, hosts: List[int], timeout: float = 60.0,
                 straggler_factor: float = 2.0, now=time.monotonic):
        self._now = now
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.hosts: Dict[int, HostStatus] = {
            h: HostStatus(last_beat=now()) for h in hosts}

    def beat(self, host: int, step_latency: Optional[float] = None):
        st = self.hosts[host]
        st.last_beat = self._now()
        if step_latency is not None:
            st.latencies.append(step_latency)
            st.latencies = st.latencies[-16:]

    def failed_hosts(self) -> List[int]:
        t = self._now()
        out = []
        for h, st in self.hosts.items():
            if st.failed or (t - st.last_beat) > self.timeout:
                st.failed = True
                out.append(h)
        return out

    def stragglers(self) -> List[int]:
        med = np.median([np.mean(st.latencies) for st in self.hosts.values()
                         if st.latencies and not st.failed] or [0.0])
        if med <= 0:
            return []
        return [h for h, st in self.hosts.items()
                if st.latencies and not st.failed
                and np.mean(st.latencies) > self.straggler_factor * med]


def shrink_mesh(mesh_shape, axes, failed_fraction_of_data: int = 1):
    """New (shape, axes) after dropping `failed_fraction_of_data` slices of
    the data axis.  Keeps the model axis intact (TP/EP groups must stay
    whole — a failed host kills its whole model-parallel replica)."""
    shape = list(mesh_shape)
    data_idx = axes.index("data")
    new_data = shape[data_idx] - failed_fraction_of_data
    if new_data < 1:
        raise RuntimeError("cannot shrink below one data replica")
    shape[data_idx] = new_data
    return tuple(shape), tuple(axes)


def reassign_shards(n_shards: int, bad: List[int]) -> Dict[int, List[int]]:
    """Round-robin reassignment of bad hosts' data shards to healthy hosts.
    Returns {healthy_host: [shard_ids it now also owns]}."""
    healthy = [h for h in range(n_shards) if h not in bad]
    if not healthy:
        raise RuntimeError("no healthy hosts")
    extra: Dict[int, List[int]] = {h: [] for h in healthy}
    for i, b in enumerate(sorted(bad)):
        extra[healthy[i % len(healthy)]].append(b)
    return extra


class ElasticTrainer:
    """Glue object used by launch/train.py: owns the monitor, decides when
    to re-mesh, and exposes the shard map for the data pipeline."""

    def __init__(self, n_data_shards: int, timeout: float = 60.0,
                 now=time.monotonic):
        self.monitor = HealthMonitor(list(range(n_data_shards)),
                                     timeout=timeout, now=now)
        self.n_data_shards = n_data_shards
        self.generation = 0

    def step_report(self, host: int, latency: float):
        self.monitor.beat(host, latency)

    def plan_step(self):
        """Returns (needs_remesh, shard_assignment)."""
        failed = self.monitor.failed_hosts()
        if failed:
            self.generation += 1
            self.n_data_shards -= len(failed)
            for h in failed:
                del self.monitor.hosts[h]
            return True, None
        stragglers = self.monitor.stragglers()
        if stragglers:
            return False, reassign_shards(self.n_data_shards, stragglers)
        return False, None
