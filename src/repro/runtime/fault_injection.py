"""Deterministic fault injection for the numerics guardrails.

Every guard in train/guards.py has a fault here that proves detection AND
recovery (tests/test_guards.py drives the matrix).  Three fault families:

  numeric   injected INTO THE TRACED COMPUTATION at a named hook point —
            a NaN into a chosen activation quantize site, a bit-flip in an
            FP8 wire payload (byte 0x7f = e4m3fn NaN), or a poisoned bucket
            scale exponent (int8 127 = 2^127).  Hooks are consulted at
            TRACE time via a contextvar (`apply`), so the default path
            compiles to an identical jaxpr when no fault is armed.
  host      a simulated host failure: flips the HealthMonitor's `failed`
            bit so the existing ElasticTrainer re-mesh path fires.
  disk      checkpoint corruption on the filesystem: rewrite a shard's
            payload bytes (valid npz, wrong data — caught by the restore
            fingerprint check) or truncate the npz (caught by the load
            guard).  Both must surface as CheckpointCorruptError.

jit-caching caveat: arming a contextvar at CALL time does nothing to a
function that was already traced clean.  `FaultPlan.wrap` therefore wraps
the UN-jitted step function and keeps one `jax.jit` instance per distinct
fault spec — the spec is baked in at trace time (`with activate(spec):`),
and clean steps reuse the one clean executable (no per-step recompiles).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NUMERIC_KINDS = ("nan_activation", "payload_bitflip", "wire_scale")
DISK_KINDS = ("ckpt_corrupt", "ckpt_truncate")
HOST_KINDS = ("host_failure",)
KINDS = NUMERIC_KINDS + DISK_KINDS + HOST_KINDS

# numeric fault kind -> the hook point it fires at
_POINT_OF = {"nan_activation": "activation",
             "payload_bitflip": "wire_payload",
             "wire_scale": "wire_exp"}


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.  `site` scopes numeric faults to a quantize tag
    (e.g. 'q_entry'; empty = any hooked site) and names the host id for
    host_failure / the checkpoint step for disk faults (empty = latest)."""
    kind: str
    step: int
    site: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind}; "
                             f"pick from {KINDS}")


_ACTIVE: contextvars.ContextVar[Optional[Fault]] = contextvars.ContextVar(
    "active_fault", default=None)


@contextlib.contextmanager
def activate(fault: Optional[Fault]):
    """Arm `fault` for the duration of a TRACE (see module docstring)."""
    tok = _ACTIVE.set(fault)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def apply(point: str, tag: str, x):
    """Hook call sites: returns `x` poisoned iff the armed fault targets
    this (point, tag).  A no-op returning `x` unchanged when nothing is
    armed — the hook contributes zero ops to the clean jaxpr."""
    f = _ACTIVE.get()
    if f is None or _POINT_OF.get(f.kind) != point:
        return x
    if f.site and f.site != tag:
        return x
    flat = x.reshape(-1)
    if point == "activation":
        bad = jnp.asarray(jnp.nan, x.dtype)
    elif point == "wire_payload":
        # 0x7f is the e4m3fn NaN encoding — a single flipped byte on the wire
        bad = jax.lax.bitcast_convert_type(jnp.uint8(0x7F), x.dtype)
    elif point == "wire_exp":
        bad = jnp.asarray(127, x.dtype)      # scale 2^127: absurd exponent
    else:  # pragma: no cover - _POINT_OF keeps this unreachable
        raise ValueError(point)
    return flat.at[0].set(bad).reshape(x.shape)


# ---------------------------------------------------------------------------
# The schedule: which fault fires at which loop step.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    faults: Tuple[Fault, ...] = ()

    def _at(self, step: int, kinds) -> Optional[Fault]:
        for f in self.faults:
            if f.step == step and f.kind in kinds:
                return f
        return None

    def numeric_for(self, step: int) -> Optional[Fault]:
        return self._at(step, NUMERIC_KINDS)

    def host_for(self, step: int) -> Optional[Fault]:
        return self._at(step, HOST_KINDS)

    def disk_for(self, step: int) -> Optional[Fault]:
        return self._at(step, DISK_KINDS)

    def wrap(self, raw_step_fn) -> "FaultStepper":
        """Wrap an UN-jitted train_step; the loop resolves the per-step
        executable via `.for_step(step)`."""
        return FaultStepper(raw_step_fn, self)


class FaultStepper:
    """Per-fault-spec jit cache around a raw (un-jitted) train_step."""

    def __init__(self, raw_fn, plan: FaultPlan):
        self._raw = raw_fn
        self._plan = plan
        self._cache = {}

    def for_step(self, step: int):
        fault = self._plan.numeric_for(step)
        if fault not in self._cache:
            raw = self._raw
            if fault is None:
                self._cache[fault] = jax.jit(raw)
            else:
                def faulted(state, batch, _f=fault):
                    with activate(_f):          # armed during TRACING
                        return raw(state, batch)
                self._cache[fault] = jax.jit(faulted)
        return self._cache[fault]

    def __call__(self, state, batch):           # clean-path convenience
        return self.for_step(-1)(state, batch)


def apply_host_fault(fault: Fault, elastic) -> None:
    """Mark a host failed on the existing HealthMonitor — the next
    `ElasticTrainer.plan_step()` sees it and triggers the re-mesh path.
    No-op when the host was already evicted: the rewound loop REPLAYS the
    failure step, and a dead host cannot die twice."""
    host = int(fault.site or 0)
    st = elastic.monitor.hosts.get(host)
    if st is not None:
        st.failed = True


# ---------------------------------------------------------------------------
# Disk faults (operate on the checkpoint layout of checkpoint/checkpointing).
# ---------------------------------------------------------------------------
def _shard_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}", "shard_0.npz")


def corrupt_checkpoint_shard(ckpt_dir: str, step: int) -> None:
    """Rewrite the largest leaf's payload bytes (bitwise NOT) through a
    VALID npz re-save: the archive still loads, so only the manifest
    fingerprint / per-leaf shape checks can catch it."""
    path = _shard_path(ckpt_dir, step)
    with np.load(path) as data:
        raw = {k: np.array(data[k]) for k in data.files}
    victim = max(raw, key=lambda k: raw[k].size)
    raw[victim] = np.ascontiguousarray(~raw[victim])
    np.savez(path, **raw)


def truncate_checkpoint_shard(ckpt_dir: str, step: int) -> None:
    """Chop the shard file in half — a crash/partial-write torn shard."""
    path = _shard_path(ckpt_dir, step)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)


def apply_disk_fault(fault: Fault, ckpt_dir: str) -> Optional[int]:
    """Apply a scheduled disk fault to the newest complete checkpoint (or
    the explicit step in `fault.site`).  Returns the poisoned step."""
    from repro.checkpoint import checkpointing
    step = int(fault.site) if fault.site else \
        checkpointing.latest_step(ckpt_dir)
    if step is None:
        return None
    if fault.kind == "ckpt_corrupt":
        corrupt_checkpoint_shard(ckpt_dir, step)
    else:
        truncate_checkpoint_shard(ckpt_dir, step)
    return step
