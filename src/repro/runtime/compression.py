"""FP8 gradient compression for the cross-pod data-parallel reduction.

The multi-pod mesh reduces gradients over the `pod` axis across the (slow)
inter-pod links.  Extending the paper's FP8-communication idea beyond the
MoE dispatch, `compressed_psum` performs the pod reduction on an e4m3
payload + po2 scales: reduce-scatter in FP8, local f32 accumulation,
all-gather in FP8 — halving inter-pod gradient bytes (plus 1/128 scale
overhead) at a quantization error bounded by the po2 tile quantizer.

Error feedback (residual carrying) keeps the compression unbiased over
steps: the quantization residual of step t is added back at step t+1.

NOTE: the TRAIN-path gradient reduction now lives in repro.dist
(DistPlan): bucketized, scale-agreed (no re-quantization of the reduced
value), ZeRO-1-sharded, packed into one uint8 message per bucket.  This
module remains the standalone psum-shaped primitive for the cross-pod hop
and the compression-error benches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.fp8 import TILE, E4M3_MAX
from repro.core.quant import quantize_rowwise, _dequantize_nocount


def _q_flat(x):
    """Quantize an arbitrary tensor as flat (rows, TILE) e4m3 + scales."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.reshape(-1, TILE)
    q = quantize_rowwise(rows, tag="grad_compress", kind="fused_quantize")
    return q, n, pad


def _dq_flat(q, n, pad, shape, dtype):
    flat = _dequantize_nocount(q, jnp.float32).reshape(-1)
    if pad:
        flat = flat[:n]
    return flat.reshape(shape).astype(dtype)


def compressed_psum(x, axis_name: str):
    """psum over `axis_name` with FP8 wire format (inside shard_map).

    reduce_scatter(e4m3) -> local dequant+sum in f32 -> all_gather(e4m3).
    Byte cost: 2 x (N/P x 1B + scales) per hop instead of 2 x N x 4B."""
    q, n, pad, = _q_flat(x)
    P = compat.axis_size(axis_name)
    rows = q.data.shape[0]
    rpad = (-rows) % P
    if rpad:
        data = jnp.pad(q.data, ((0, rpad), (0, 0)))
        scale = jnp.pad(q.scale, ((0, rpad), (0, 0)), constant_values=1.0)
    else:
        data, scale = q.data, q.scale
    # reduce-scatter the fp8 payload: exchange shards, sum dequantized
    dsh = jax.lax.all_to_all(
        data.reshape(P, -1, TILE), axis_name, 0, 0, tiled=False)
    ssh = jax.lax.all_to_all(
        scale.reshape(P, -1, 1), axis_name, 0, 0, tiled=False)
    local = jnp.sum(dsh.astype(jnp.float32) * ssh, axis=0)   # f32 accumulate
    # requantize the reduced shard and all-gather it
    from repro.core.quant import quantize_rowwise as qr
    q2 = qr(local, tag="grad_compress2", kind="fused_quantize")
    gd = jax.lax.all_gather(q2.data, axis_name, axis=0, tiled=True)
    gs = jax.lax.all_gather(q2.scale, axis_name, axis=0, tiled=True)
    out = (gd.astype(jnp.float32) * gs).reshape(-1)[:rows * TILE]
    if pad:
        out = out[:n]
    return out.reshape(x.shape).astype(x.dtype)


def compress_decompress(x):
    """Round-trip quantizer for error-feedback accounting + tests."""
    q, n, pad = _q_flat(x)
    return _dq_flat(q, n, pad, x.shape, x.dtype)
