"""StarCoder2-15B [arXiv:2402.19173]: dense GQA, RoPE, GELU MLP, LayerNorm
with biases, sliding-window 4k is NOT used at 15B scale (full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_15b", n_layers=40, d_model=6144, n_heads=48, n_kv=4,
    head_dim=128, d_ff=24576, vocab=49152, act="gelu", norm="layernorm",
    qkv_bias=True, rope_theta=1e5, pattern=("global",),
    fsdp=True, grad_accum=1,
)
