"""Architecture configuration schema + shape/parallelism plans.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py``;
``reduced()`` derives the CPU smoke-test variant.  The shape grid (train_4k /
prefill_32k / decode_32k / long_500k) is defined here and consumed by
``launch/dryrun.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int                      # dense-MLP hidden (per gate half if gated)
    vocab: int
    act: str = "swiglu"            # 'swiglu' | 'geglu' | 'gelu' | 'relu'
    norm: str = "rmsnorm"          # 'rmsnorm' | 'layernorm'
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    # layer-kind pattern, cycled over depth: 'global' | 'local' | 'ssm' | 'hybrid'
    pattern: Tuple[str, ...] = ("global",)
    window: int = 4096
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0      # DeepSeek-style always-on experts
    n_dense_layers: int = 0        # dense-MLP prologue layers (DeepSeek)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # --- encoder-decoder / frontends ---
    encdec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"         # 'none' | 'audio' | 'vision'
    frontend_len: int = 0          # stub prefix length (patch/frame embeds)
    tie_embeddings: bool = False
    # --- parallelism / memory plan ---
    fsdp: bool = False             # shard param dim0 over 'data' too
    attn_tp: bool = True           # TP attention (requires n_heads % tp == 0)
    grad_accum: int = 1            # microbatching (memory fit at train_4k)
    # activation-residency policy (train/memory.py MemoryPlan):
    #   'none' | 'full' | 'fp8_resident' | 'pair'
    # (legacy sweep alias: a bool normalizes to 'full'/'none')
    remat_policy: str = "full"
    # long_500k applicability (sub-quadratic rule, DESIGN.md §6)
    subquadratic: bool = False

    def __post_init__(self):
        if isinstance(self.remat_policy, bool):   # legacy remat=True/False
            object.__setattr__(self, "remat_policy",
                               "full" if self.remat_policy else "none")

    @property
    def remat(self) -> bool:
        """Legacy read alias: whether ANY rematerialization is active."""
        return self.remat_policy != "none"

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def gate_factor(self) -> int:
        return 2 if self.act in ("swiglu", "geglu") else 1

    def n_params(self) -> int:
        """Approximate parameter count (embedding + layers)."""
        D, hd = self.d_model, self.head_dim
        emb = self.vocab_padded * D * (1 if self.tie_embeddings else 2)
        per_attn = D * (self.n_heads + 2 * self.n_kv) * hd + self.n_heads * hd * D
        g = self.gate_factor
        per_dense = D * self.d_ff * g + self.d_ff * D
        per_moe = (self.n_experts * (D * self.d_ff_expert * g +
                                     self.d_ff_expert * D) + D * self.n_experts)
        if self.n_shared_experts:
            per_moe += (D * self.n_shared_experts * self.d_ff_expert * g +
                        self.n_shared_experts * self.d_ff_expert * D)
        per_ssm = 0
        if self.ssm_state:
            di, ng, hs = self.d_inner, 1, self.ssm_heads
            per_ssm = (D * (2 * di + 2 * ng * self.ssm_state + hs)
                       + di * D + self.ssm_conv * (di + 2 * self.ssm_state))
        n = emb
        kinds = [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]
        for i, kind in enumerate(kinds):
            if kind == "ssm":
                n += per_ssm
                continue
            n += per_attn if kind in ("global", "local") else per_attn + per_ssm
            if self.moe and i >= self.n_dense_layers:
                n += per_moe
            elif self.d_ff:
                n += per_dense
        if self.encdec:
            n += self.n_enc_layers * (per_attn + per_dense)
            n += self.n_layers * per_attn  # decoder cross-attention
        return n

    def active_params(self) -> int:
        """Activated parameters per token (MoE top-k accounting)."""
        if not self.moe:
            return self.n_params()
        full = self.n_params()
        D, g = self.d_model, self.gate_factor
        per_e = D * self.d_ff_expert * g + self.d_ff_expert * D
        n_moe_layers = self.n_layers - self.n_dense_layers
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_e
        return full - inactive

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant: same family, tiny dims."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_dense_layers=min(self.n_dense_layers, 1),
            d_model=256,
            n_heads=4, n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            head_dim=64,
            d_ff=256 if self.d_ff else 0,
            d_ff_expert=128 if self.moe else 0,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            vocab=512,
            window=64,
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            frontend_len=8 if self.frontend != "none" else 0,
            fsdp=False, grad_accum=1,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig):
    """The shape cells defined for this arch (DESIGN.md §6 skip rules)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
