"""DeepSeek-V2-Lite 16B (the paper's convergence-validation model, Fig. 6):
27 layers, 64 routed experts top-6 + 2 shared, first layer dense.
MLA is simplified to GQA (the paper's contribution is MoE-side; DESIGN.md
§7).  Dense d_ff 10944 -> 10880 (128-aligned)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite", n_layers=27, d_model=2048, n_heads=16, n_kv=16,
    head_dim=128, d_ff=10880, vocab=102400, act="swiglu",
    rope_theta=1e4, moe=True, n_experts=64, top_k=6, d_ff_expert=1408,
    n_shared_experts=2, n_dense_layers=1, grad_accum=1,
)
