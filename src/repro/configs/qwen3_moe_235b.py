"""Qwen3-235B-A22B [hf:Qwen/Qwen3-30B-A3B scaled]: 128 experts top-8,
per-expert d_ff 1536, qk-norm, GQA 64H/4KV.  The paper's primary target
shape: full FP8-Flow-MoE recipe with EP dispatch."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b", n_layers=94, d_model=4096, n_heads=64, n_kv=4,
    head_dim=128, d_ff=0, vocab=151936, act="swiglu", qk_norm=True,
    rope_theta=1e6, moe=True, n_experts=128, top_k=8, d_ff_expert=1536,
    fsdp=True, grad_accum=1,
)
