"""Hymba-1.5B [arXiv:2411.13676]: parallel attention + mamba heads in every
layer (fused hybrid head), GQA 25H/5KV, SwiGLU MLP.  Meta-tokens are
omitted (noted in DESIGN.md).  Hybrid -> long_500k applies."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba_15b", n_layers=32, d_model=1600, n_heads=25, n_kv=5,
    head_dim=64, d_ff=5504, vocab=32001, act="swiglu", pattern=("hybrid",),
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    rope_theta=1e4, tie_embeddings=True, subquadratic=True,
    attn_tp=False,  # 25 heads not divisible by the model axis
    grad_accum=1,
)
