"""Gemma2-9B [arXiv:2408.00118]: alternating local:global (window 4096),
attn logit softcap 50, final softcap 30, GeGLU. Sliding-window dominant ->
long_500k applies (global layers read the full cache; reported as the
dominant memory term)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2_9b", n_layers=42, d_model=3584, n_heads=16, n_kv=8,
    head_dim=256, d_ff=14336, vocab=256000, act="geglu",
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, rope_theta=1e4,
    tie_embeddings=True, subquadratic=True, fsdp=True, grad_accum=1,
)
