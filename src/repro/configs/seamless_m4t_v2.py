"""SeamlessM4T-large-v2 [arXiv:2308.11596]: encoder-decoder backbone (the
speech frontend is a stub providing precomputed frame embeddings), MHA,
ReLU FFN, vocab 256206 (padded to 256256)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_v2", n_layers=24, d_model=1024, n_heads=16, n_kv=16,
    head_dim=64, d_ff=8192, vocab=256206, act="relu", norm="layernorm",
    rope_theta=0.0,  # learned/sinusoidal in the original; stub uses none
    encdec=True, n_enc_layers=24, frontend="audio", frontend_len=0, fsdp=True,
    grad_accum=1,
)
