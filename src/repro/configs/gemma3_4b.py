"""Gemma3-4B [unverified]: 5 local : 1 global pattern, window 1024, GeGLU,
qk-norm, head_dim 256 decoupled from d_model, 262k vocab, 128k context.
Sub-quadratic (sliding-window dominant) -> long_500k applies."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b", n_layers=34, d_model=2560, n_heads=8, n_kv=4,
    head_dim=256, d_ff=10240, vocab=262144, act="geglu", qk_norm=True,
    rope_theta=1e6, pattern=("local", "local", "local", "local", "local",
                             "global"),
    window=1024, tie_embeddings=True, subquadratic=True, fsdp=True,
    attn_tp=False,  # 8 heads < 16-wide model axis
    grad_accum=1,
)
