"""LLaVA-NeXT-34B [hf:llava-hf, unverified]: Yi/NH2-34B text backbone with
anyres vision tiling; the vision tower + projector are a stub supplying
precomputed patch embeddings (2880 = 5 tiles x 576)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava_next_34b", n_layers=60, d_model=7168, n_heads=56, n_kv=8,
    head_dim=128, d_ff=20480, vocab=64000, act="swiglu",
    rope_theta=5e6, frontend="vision", frontend_len=2880,
    attn_tp=False,  # 56 % 16 != 0
    fsdp=True, grad_accum=1,
)
