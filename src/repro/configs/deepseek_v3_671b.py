"""DeepSeek-V3 671B (the paper's efficiency-evaluation model, Tables 2/3):
61 layers, 256 routed experts top-8 + 1 shared, 3 dense prologue layers.
MLA simplified to GQA 128H/16KV (DESIGN.md §7)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_v3_671b", n_layers=61, d_model=7168, n_heads=128, n_kv=16,
    head_dim=128, d_ff=18432, vocab=129280, act="swiglu",
    rope_theta=1e4, moe=True, n_experts=256, top_k=8, d_ff_expert=2048,
    n_shared_experts=1, n_dense_layers=3, fsdp=True, grad_accum=1,
)
