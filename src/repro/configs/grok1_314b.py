"""Grok-1 314B [hf:xai-org/grok-1, unverified]: 8 experts top-2 with huge
per-expert FFN (32768) -> experts are TP-sharded (E < model-axis width);
the FP8 dataflow applies without the dispatch all-to-all (DESIGN.md §6)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok1_314b", n_layers=64, d_model=6144, n_heads=48, n_kv=8,
    head_dim=128, d_ff=0, vocab=131072, act="geglu",
    rope_theta=1e4, moe=True, n_experts=8, top_k=2, d_ff_expert=32768,
    fsdp=True, grad_accum=1,
)
