"""Architecture registry: one config per assigned arch + the paper's own."""
from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, applicable_shapes

ARCH_IDS = [
    "starcoder2_15b", "qwen15_05b", "gemma3_4b", "gemma2_9b",
    "seamless_m4t_v2", "mamba2_27b", "hymba_15b", "qwen3_moe_235b",
    "grok1_314b", "llava_next_34b", "deepseek_v2_lite", "deepseek_v3_671b",
]


def get_arch(name: str) -> ArchConfig:
    import importlib
    name = name.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG
