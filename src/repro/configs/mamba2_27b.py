"""Mamba2-2.7B [arXiv:2405.21060]: pure SSD (state-space duality), 64 mixer
layers, no attention, no MLP, d_state=128, headdim=64.  Attention-free ->
long_500k applies (O(1) state decode)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_27b", n_layers=64, d_model=2560, n_heads=0, n_kv=0,
    head_dim=0, d_ff=0, vocab=50280, pattern=("ssm",),
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    rope_theta=0.0, tie_embeddings=True, subquadratic=True, attn_tp=False,
    grad_accum=1,
)
