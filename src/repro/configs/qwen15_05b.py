"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]: dense MHA (kv==heads), QKV bias,
SwiGLU, RMSNorm, huge vocab (151936)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen15_05b", n_layers=24, d_model=1024, n_heads=16, n_kv=16,
    head_dim=64, d_ff=2816, vocab=151936, act="swiglu", qkv_bias=True,
    rope_theta=1e6, tie_embeddings=True, grad_accum=1,
)
