"""AdamW with FP32 master weights, optional BF16 moments, global-norm clip.

Built from scratch (no optax dependency).  At scale the optimizer state is
the dominant memory term, so each piece is dtype-configurable:
  master  : f32 copy of params (params themselves may live in bf16)
  m, v    : f32 or bf16 (bf16 moments are standard at >100B scale)
State sharding (ZeRO-1 over the data axis) is applied by the caller via
in/out shardings on the update step — the math here is sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32      # bf16 at >100B scale
    master_weights: bool = True


def init_state(cfg: AdamWConfig, params):
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype),
                          params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype),
                          params),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def apply_updates(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, master):
        g32 = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        base = master.astype(jnp.float32) if master is not None \
            else p.astype(jnp.float32)
        new_master = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                  + cfg.weight_decay * base)
        return (new_master.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype), new_master if master is not None
                else None)

    masters = state.get("master")
    if masters is None:
        masters = jax.tree.map(lambda _: None, params,
                               is_leaf=lambda x: x is None)
        triples = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                               params, grads, state["m"], state["v"])
    else:
        triples = jax.tree.map(upd, params, grads, state["m"], state["v"],
                               masters)

    new_params = jax.tree.map(lambda t: t[0], triples,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_state = {
        "step": step,
        "m": jax.tree.map(lambda t: t[1], triples,
                          is_leaf=lambda x: isinstance(x, tuple)),
        "v": jax.tree.map(lambda t: t[2], triples,
                          is_leaf=lambda x: isinstance(x, tuple)),
    }
    if cfg.master_weights:
        new_state["master"] = jax.tree.map(
            lambda t: t[3], triples, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
