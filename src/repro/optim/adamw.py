"""AdamW with FP32 master weights, optional low-precision state, global-norm
clip.

Built from scratch (no optax dependency).  At scale the optimizer state is
the dominant memory term, so each piece is dtype-configurable two ways:
  moment_dtype    : legacy knob — f32 or bf16 moments, leaf-shaped arrays
  state_policy    : repro.dist.opt_state.StatePolicy — FP8-split state
                    (e4m3 m / bf16 v / po2-scaled f16 master behind QTensor)
                    for large leaves; small/1-D leaves keep f32
State sharding (ZeRO-1 over the data axis) is applied by the caller — either
via in/out shardings on the update step, or explicitly by the DistPlan train
step (repro.dist), which reuses `adamw_math` on flat owned shards so there is
ONE copy of the update math.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32      # bf16 at >100B scale
    master_weights: bool = True
    # FP8-split state (dist.opt_state.StatePolicy); None = legacy behavior
    state_policy: Optional[Any] = None


def adamw_math(cfg: AdamWConfig, g32, m32, v32, base32, lr, b1c, b2c):
    """The single copy of the update math (f32 in, f32 out).  `g32` arrives
    pre-clipped.  Shared by the per-leaf path below and the ZeRO-1 flat-shard
    path (repro.dist.opt_state.flat_bucket_update)."""
    m_new = cfg.b1 * m32 + (1 - cfg.b1) * g32
    v_new = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
    mhat = m_new / b1c
    vhat = v_new / b2c
    new_master = base32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                + cfg.weight_decay * base32)
    return new_master, m_new, v_new


def init_state(cfg: AdamWConfig, params):
    pol = cfg.state_policy
    if pol is None:
        state = {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype),
                              params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype),
                              params),
        }
        if cfg.master_weights:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32),
                                           params)
        return state

    from repro.dist import opt_state as ost

    def init_m(p):
        return ost.zeros_encoded(pol.m if pol.applies(p) else "f32", p)

    def init_v(p):
        return ost.zeros_encoded(pol.v if pol.applies(p) else "f32", p)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(init_m, params),
        "v": jax.tree.map(init_v, params),
    }
    if cfg.master_weights:
        # policy leaves encode straight from the param (po2 division is
        # exact in bf16) — no full-tree f32 temporaries
        state["master"] = jax.tree.map(
            lambda p: ost.encode(pol.master if pol.applies(p) else "f32", p),
            params)
    return state


def global_norm(grads):
    """Global L2 norm accumulated in ONE fused f32 scalar pass: per-leaf
    squared sums are stacked and reduced once — no chained adds, no
    materialized f32 copies of the leaves (the cast fuses into the sum)."""
    parts = [jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads)]
    if not parts:
        return jnp.float32(0.0)
    return jnp.sqrt(jnp.sum(jnp.stack(parts)))


def clip_factor(cfg: AdamWConfig, gnorm):
    return jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)


def bias_corrections(cfg: AdamWConfig, step):
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    return b1c, b2c


def apply_updates(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = clip_factor(cfg, gnorm)
    b1c, b2c = bias_corrections(cfg, step)
    lr = cfg.lr * lr_scale
    pol = cfg.state_policy
    if pol is not None:
        from repro.dist import opt_state as ost

    def upd(p, g, m, v, master):
        g32 = g.astype(jnp.float32) * clip
        if pol is None:
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            base = master.astype(jnp.float32) if master is not None \
                else p.astype(jnp.float32)
        else:
            m32 = ost.decode(m, p.shape, p.size)
            v32 = ost.decode(v, p.shape, p.size)
            base = ost.decode(master, p.shape, p.size) if master is not None \
                else p.astype(jnp.float32)
        new_master, m_new, v_new = adamw_math(cfg, g32, m32, v32, base,
                                              lr, b1c, b2c)
        if pol is None:
            enc_m, enc_v = m_new.astype(m.dtype), v_new.astype(v.dtype)
            enc_master = new_master if master is not None else None
        else:
            enc_m = ost.encode_like(m_new, m)
            enc_v = ost.encode_like(v_new, v)
            enc_master = ost.encode_like(new_master, master) \
                if master is not None else None
        return (new_master.astype(p.dtype), enc_m, enc_v, enc_master)

    masters = state.get("master")
    if masters is None:
        triples = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                               params, grads, state["m"], state["v"])
    else:
        triples = jax.tree.map(upd, params, grads, state["m"], state["v"],
                               masters)

    is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
    new_params = jax.tree.map(lambda t: t[0], triples, is_leaf=is_tup)
    new_state = {
        "step": step,
        "m": jax.tree.map(lambda t: t[1], triples, is_leaf=is_tup),
        "v": jax.tree.map(lambda t: t[2], triples, is_leaf=is_tup),
    }
    if cfg.master_weights:
        new_state["master"] = jax.tree.map(lambda t: t[3], triples,
                                           is_leaf=is_tup)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
