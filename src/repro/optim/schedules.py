"""LR schedules: linear warmup + cosine decay (the paper trains with the
standard DeepSeek recipe; exact constants are configurable)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup_steps=2000, total_steps=100_000,
                  min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = (step + 1.0) / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)
