"""Eq. 1 reproduction: magnitude of the double quantization error
E = Q_col(D(Q_row(X))) - Q_col(X) under linear vs po2 scales, and the added
re-layout error of naive vs direct transpose."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.quant import quantize_rowwise, _dequantize_nocount
from repro.core.transpose import (double_quant_error, transpose_direct,
                                  transpose_naive)


def run():
    r = np.random.default_rng(0)
    for spread in [0.5, 1.5, 2.5]:
        x = jnp.asarray((r.normal(size=(512, 512))
                         * np.exp(r.normal(size=(512, 512)) * spread)
                         ).astype(np.float32))
        e_lin = float(jnp.mean(jnp.abs(double_quant_error(x, "linear"))))
        e_po2 = float(jnp.mean(jnp.abs(double_quant_error(x, "po2"))))
        scale = float(jnp.mean(jnp.abs(x)))
        emit(f"eq1_double_quant_spread{spread}", 0.0,
             f"E_linear={e_lin / scale:.2e};E_po2={e_po2 / scale:.2e};"
             f"reduction={e_lin / max(e_po2, 1e-30):.0f}x")

        ref = np.asarray(x).T
        q_lin = quantize_rowwise(x, scale_mode="linear")
        q_po2 = quantize_rowwise(x, scale_mode="po2")
        base_l = np.abs(np.asarray(_dequantize_nocount(
            q_lin, jnp.float32)).T - ref).mean()
        base_p = np.abs(np.asarray(_dequantize_nocount(
            q_po2, jnp.float32)).T - ref).mean()
        add_n = np.abs(np.asarray(_dequantize_nocount(
            transpose_naive(q_lin, "linear"), jnp.float32)) - ref
        ).mean() - base_l
        add_d = np.abs(np.asarray(_dequantize_nocount(
            transpose_direct(q_po2), jnp.float32)) - ref).mean() - base_p
        emit(f"relayout_added_error_spread{spread}", 0.0,
             f"naive_linear=+{add_n / base_l:.1%};"
             f"direct_po2=+{add_d / base_p:.1%};"
             f"base_po2_vs_linear={base_p / base_l:.2f}x")


if __name__ == "__main__":
    run()
