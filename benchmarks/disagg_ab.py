"""Prefill/decode disaggregation A/B: bitwise parity, zero-requantization
proof, and the modeled time-between-tokens win under long-prefill
interference.

A mixed engine runs every resident decode in the SAME jitted tick as the
current prefill chunk, so a long prompt taxes every in-flight generation:
each decode token's latency inherits the chunk's compute.  The two-tier
fleet (serve/router.py DisaggRouter) moves finished prefills to dedicated
decode replicas by migrating their FP8 KV pages bit-for-bit
(serve/transfer.py — pure bitcast of e4m3 payload + po2-exponent scales,
provably casting-free), making decode ticks pure decode.

Usage:
  PYTHONPATH=src python benchmarks/disagg_ab.py --dry-run   # CI smoke
  PYTHONPATH=src python benchmarks/disagg_ab.py             # timed

Acceptance gates (checked in BOTH modes):
  * transfer codec: pack -> unpack -> scatter round-trip is BIT-IDENTICAL
    on the live pools, and both codec jaxprs contain ZERO floating-point
    numeric ops (assert_casting_free — migration cannot quantize,
    dequantize, or cast anything);
  * same mixed-interference trace through a 1-prefill + 1-decode fleet and
    a single-tier engine produces BITWISE-IDENTICAL generated tokens;
  * every migrated request's prompt pages on the receiver are bit-equal to
    the donor's (payload bytes AND po2 scale exponents);
  * modeled decode TBT under interference: per-tick cost = prefill-chunk
    tokens + decode batch size (what one jitted tick computes); p99 over
    per-decode-token costs must improve by >= the threshold on the decode
    tier, where chunk == 0 STRUCTURALLY;
  * a one-page-batch transfer budget still migrates every request (the
    budget throttles bursts, it can never starve the handoff queue).
Timed mode additionally reports wall-clock per-token TBT percentiles.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

try:
    from benchmarks.common import emit
except ModuleNotFoundError:          # invoked as `python benchmarks/...py`
    sys.path.insert(0, os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import emit

TBT_P99_IMPROVEMENT_MIN = 1.3        # modeled single-tier/disagg p99 ratio


def modeled_tbt_costs(tick_records):
    """Per-decode-token modeled latency: each token generated in a tick
    costs that tick's total compute units (prefill chunk tokens + decode
    batch size) — the interference model the disaggregation removes."""
    costs = []
    for r in tick_records:
        k = int(r.get("n_decode", 0))
        if k:
            costs.extend([int(r.get("chunk", 0)) + k] * k)
    return np.asarray(costs, np.float64)


def page_bytes(eng, pages):
    """Flat uint8 gather of `pages` from an engine's live pools (payload
    bytes + scale exponents, via the transfer codec itself).  One page per
    gather: bucket padding would drag in SCRATCH_PAGE rows, whose garbage
    differs across engines."""
    return np.concatenate([
        np.asarray(eng.codec._gather(eng.pools, eng.codec._pad_ids([p])))
        for p in pages])


def run(dry_run: bool = False):
    import jax
    import jax.numpy as jnp
    from benchmarks.serve_throughput import make_mixed_interference_trace
    from repro.configs import get_arch
    from repro.core.recipes import get_recipe
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import ParallelPlan, init_params
    from repro.obs.sink import MemorySink, Telemetry
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.router import DisaggConfig, DisaggRouter

    cfg = get_arch("qwen3_moe_235b").reduced()
    plan = ParallelPlan(mesh=make_test_mesh(), dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    recipe = get_recipe("fp8_flow")

    n_requests = 9 if dry_run else 24
    ecfg_kw = dict(max_batch=4, page_size=4, n_pages=64,
                   max_pages_per_req=16, token_budget=256,
                   prefill_buckets=(16, 32), prefill_chunk=4,
                   fp8_kv=True, w8_weights=True, prefix_cache=True, seed=0)

    def trace():
        return make_mixed_interference_trace(
            n_requests, rate_hz=50.0, seed=7, vocab=cfg.vocab,
            long_every=3, long_prompt=40, max_prompt=6,
            min_new=8, max_new=12)

    def engine(role="mixed"):
        sink = MemorySink()
        tel = Telemetry(sinks=(sink,))
        eng = ServeEngine(cfg, recipe, plan, params,
                          ServeConfig(role=role, **ecfg_kw), telemetry=tel)
        return eng, sink

    # -- gate 1: codec round-trip + casting-free jaxprs --------------------
    single, single_sink = engine("mixed")
    single.codec.assert_casting_free(single.pools, n=3)
    probe = [1, 2, 3, 4]                  # pow2 batch: no scratch padding
    ids = single.codec._pad_ids(probe)
    src = np.asarray(single.codec._gather(single.pools, ids))
    blank = jax.tree.map(jnp.zeros_like, single.pools)
    blank = single.codec.scatter(blank, src, probe)
    rt = np.asarray(single.codec._gather(blank, ids))
    assert (rt == src).all(), "codec round-trip is not bit-identical"

    # -- single-tier baseline ---------------------------------------------
    reqs1 = trace()
    t0 = time.perf_counter()
    res1 = single.run(reqs1, realtime=False)
    dt_single = time.perf_counter() - t0
    toks1 = [res1[q.rid]["tokens"] for q in reqs1]
    assert len(res1) == n_requests

    # -- disaggregated fleet, same trace -----------------------------------
    pe, _ = engine("prefill")
    de, de_sink = engine("decode")
    router = DisaggRouter([pe], [de])
    reqs2 = trace()
    t0 = time.perf_counter()
    res2 = router.run(reqs2, realtime=False)
    dt_disagg = time.perf_counter() - t0
    toks2 = [res2[q.rid]["tokens"] for q in reqs2]
    dstats = router.stats()["disagg"]

    # -- gate 2: bitwise parity --------------------------------------------
    for i, (a, b) in enumerate(zip(toks1, toks2)):
        assert a == b, (f"request {i}: disagg tokens diverge from "
                        f"single-tier: {a} vs {b}")
    assert dstats["migrations"] == n_requests, \
        f"{dstats['migrations']} migrations != {n_requests} requests"

    # -- gate 3: migrated pages bit-equal donor vs receiver ----------------
    # with the prefix cache on, the donor keeps every migrated prompt's
    # full-block pages and the receiver republished them on adopt — gather
    # both through the codec and compare raw bytes (payload + exponents)
    n_compared = 0
    for q in reqs2:
        dp = pe.prefix_cache.match_pages(q.prompt)
        rp = de.prefix_cache.match_pages(q.prompt)
        n = min(len(dp), len(rp))
        if not n:
            continue
        a, b = page_bytes(pe, dp[:n]), page_bytes(de, rp[:n])
        assert (a == b).all(), \
            f"migrated pages for rid {q.rid} are not bit-equal"
        n_compared += n
    assert n_compared > 0, "no migrated pages left to compare"

    # -- gate 4: modeled TBT under interference ----------------------------
    costs_single = modeled_tbt_costs(single_sink.of_kind("serve_tick"))
    disagg_ticks = de_sink.of_kind("serve_tick")
    costs_disagg = modeled_tbt_costs(disagg_ticks)
    assert all(int(r.get("chunk", 0)) == 0 for r in disagg_ticks), \
        "decode tier ran a prefill chunk (tier split is broken)"
    p99_s = float(np.percentile(costs_single, 99))
    p99_d = float(np.percentile(costs_disagg, 99))
    mean_s, mean_d = float(costs_single.mean()), float(costs_disagg.mean())
    ratio = p99_s / max(p99_d, 1e-9)
    assert ratio >= TBT_P99_IMPROVEMENT_MIN, \
        (f"modeled p99 TBT improvement {ratio:.2f}x < "
         f"{TBT_P99_IMPROVEMENT_MIN}x (single {p99_s:.1f} vs disagg "
         f"{p99_d:.1f} cost units)")

    emit("disagg/modeled_p99_tbt_ratio", ratio,
         derived=f"{p99_s:.1f} -> {p99_d:.1f} cost units/token",
         units="x", kind="modeled")
    emit("disagg/modeled_mean_tbt_ratio", mean_s / max(mean_d, 1e-9),
         derived=f"{mean_s:.2f} -> {mean_d:.2f} cost units/token",
         units="x", kind="modeled")
    emit("disagg/kv_transfer_bytes", dstats["kv_transfer_bytes"],
         derived=f"{dstats['migrations']} migrations, "
                 f"{dstats['shipped_pages']} pages shipped",
         units="bytes", kind="measured")

    # -- gate 5: a tiny transfer budget throttles but never starves --------
    pe2, _ = engine("prefill")
    de2, _ = engine("decode")
    one_batch = pe2.codec.bytes_for(1)      # every cycle: ~one page batch
    router2 = DisaggRouter([pe2], [de2],
                           dcfg=DisaggConfig(transfer_budget_bytes=one_batch))
    reqs3 = trace()
    res3 = router2.run(reqs3, realtime=False)
    toks3 = [res3[q.rid]["tokens"] for q in reqs3]
    assert toks3 == toks1, "budget-throttled fleet diverged bitwise"
    d2 = router2.stats()["disagg"]
    assert d2["migrations"] == n_requests, \
        "transfer budget starved the handoff queue"
    emit("disagg/budget_deferrals", d2["budget_deferrals"],
         derived=f"budget={one_batch}B/cycle", units="count",
         kind="measured")

    if dry_run:
        print(f"disagg_ab: dry-run OK ({n_requests}/{n_requests} requests "
              f"bitwise disagg==single, casting-free codec, "
              f"{dstats['migrations']} migrations "
              f"({dstats['kv_transfer_bytes']}B wire, "
              f"{n_compared} pages bit-verified), modeled p99 TBT "
              f"{ratio:.2f}x better under interference)")
        return

    # -- timed: wall-clock per-token TBT -----------------------------------
    emit("disagg/makespan_single_s", dt_single, units="s")
    emit("disagg/makespan_disagg_s", dt_disagg, units="s")
    for name, e in (("single", single), ("disagg_decode", de)):
        h = e.tel.registry.histogram("serve_tbt_ms")
        emit(f"disagg/p99_tbt_wall_{name}_ms", h.quantile(0.99), units="ms")
    print(f"disagg_ab: modeled p99 TBT {ratio:.2f}x better "
          f"({p99_s:.1f} -> {p99_d:.1f} cost units), "
          f"{dstats['migrations']} migrations "
          f"{dstats['kv_transfer_bytes'] / 2**10:.1f} KiB wire, "
          f"makespan {dt_single:.2f}s -> {dt_disagg:.2f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="structural gates only (CI): bitwise parity disagg "
                         "vs single-tier, casting-free codec assert, "
                         "migrated-page bit-equality, modeled TBT-"
                         "interference reduction, budget no-starvation")
    args = ap.parse_args()
    run(dry_run=args.dry_run)
