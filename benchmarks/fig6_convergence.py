"""Fig. 6 reproduction (reduced scale): BF16 vs FP8-Flow-MoE vs naive-FP8
loss curves on the DeepSeek-V2-Lite-family reduced config, identical data
order and hyperparameters.  Writes experiments/convergence.csv."""
from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig
from repro.models.lm import ParallelPlan
from repro.optim.adamw import AdamWConfig
from repro.train.loop import run as run_loop
from repro.train.train_step import init_train_state, make_train_step
from tests.conftest import make_mesh11

N_STEPS = int(os.environ.get("REPRO_CONV_STEPS", "60"))


def run():
    mesh = make_mesh11()
    cfg = get_arch("deepseek_v2_lite").reduced()
    curves = {}
    for name in ["bf16", "fp8_flow", "naive_fp8"]:
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
        opt = AdamWConfig(lr=3e-3)
        recipe = get_recipe(name)
        step = jax.jit(make_train_step(cfg, recipe, plan, opt,
                                       total_steps=N_STEPS, warmup_steps=5))
        state = init_train_state(cfg, opt, jax.random.key(0))
        data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        with mesh:
            _, hist = run_loop(step, state, data, n_steps=N_STEPS,
                               log_every=10 ** 9, log_fn=lambda *a: None)
        curves[name] = [h["loss"] for h in hist]

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/convergence.csv", "w") as f:
        f.write("step," + ",".join(curves) + "\n")
        for i in range(N_STEPS):
            f.write(f"{i}," + ",".join(f"{curves[k][i]:.4f}"
                                       for k in curves) + "\n")
    final = {k: float(np.mean(v[-10:])) for k, v in curves.items()}
    gap_flow = abs(final["fp8_flow"] - final["bf16"])
    gap_naive = abs(final["naive_fp8"] - final["bf16"])
    emit("fig6_convergence", 0.0,
         f"bf16={final['bf16']:.4f};fp8_flow={final['fp8_flow']:.4f};"
         f"naive={final['naive_fp8']:.4f};flow_gap={gap_flow:.4f};"
         f"naive_gap={gap_naive:.4f};csv=experiments/convergence.csv")


if __name__ == "__main__":
    run()
