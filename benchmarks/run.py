"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see each module's docstring
for what 'derived' contains).  Set REPRO_BENCH_FAST=1 to skip the two
compile-heavy entries (table 2/3 probes and the convergence run)."""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    from benchmarks import (double_quant_error, fig1_transpose,
                            fig34_permute, fig5_swiglu, table1_comm)
    modules = [
        ("eq1_double_quant", double_quant_error),
        ("fig1_transpose", fig1_transpose),
        ("fig34_permute", fig34_permute),
        ("fig5_swiglu", fig5_swiglu),
        ("table1_comm", table1_comm),
    ]
    if not fast:
        from benchmarks import fig6_convergence, table23_throughput
        modules += [
            ("fig6_convergence", fig6_convergence),
            ("table23_throughput", table23_throughput),
        ]

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
