"""Continuous-batching serving throughput: tok/s and p50/p99 latency under a
synthetic Poisson arrival trace, fp8_flow (W8-resident weights + FP8 paged
KV) vs bf16 (BF16 weights + BF16 paged KV).

  PYTHONPATH=src python benchmarks/serve_throughput.py --reduced \
      [--requests 32] [--rate 20] [--arch qwen3_moe_235b] \
      [--prefill-chunk 16] [--compare-prefill]

Reports, per recipe (and per prefill mode with --compare-prefill, which runs
the SAME trace chunked vs monolithic so the decode-latency / TTFT win of
bounded prefill slices is measured, not asserted):
  tok/s        — generated tokens / makespan
  p50/p99 lat  — request completion latency (arrival -> last token)
  p50/p99 ttft — time to first token (arrival -> first sampled token)
  kv bytes     — resident paged-pool footprint (FP8 pages ~halve this)

The trace has more requests than engine slots, so admission/eviction and
batch-mix churn are exercised for real (max concurrent < #requests).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def make_trace(n_requests: int, rate_hz: float, seed: int, vocab: int,
               max_prompt: int = 24, max_new: int = 12):
    """Poisson arrivals (exp inter-arrival gaps), variable prompt lengths."""
    from repro.serve.scheduler import Request
    r = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for _ in range(n_requests):
        t += float(r.exponential(1.0 / rate_hz))
        plen = int(r.integers(3, max_prompt + 1))
        reqs.append(Request(
            prompt=list(r.integers(1, vocab, plen)),
            max_new_tokens=int(r.integers(2, max_new + 1)),
            arrival_time=t))
    return reqs


def run_recipe(recipe_name: str, cfg, plan, params, args,
               prefill_chunk=None):
    import jax
    from repro.core.recipes import get_recipe
    from repro.serve.engine import ServeConfig, ServeEngine

    recipe = get_recipe(recipe_name)
    fp8 = recipe.name == "fp8_flow"
    ecfg = ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        n_pages=args.n_pages, max_pages_per_req=args.max_pages,
        token_budget=args.token_budget, prefill_buckets=(16, 32, 64),
        prefill_chunk=prefill_chunk, fp8_kv=fp8, w8_weights=fp8, seed=0)
    eng = ServeEngine(cfg, recipe, plan, params, ecfg)
    reqs = make_trace(args.requests, args.rate, args.seed, cfg.vocab,
                      max_prompt=args.max_prompt)
    assert len(reqs) > ecfg.max_batch, "trace must oversubscribe the batch"

    t0 = time.perf_counter()
    results = eng.run(reqs, realtime=not args.closed_loop)
    makespan = time.perf_counter() - t0

    lats = np.array([v["finish"] - v["arrival"] for v in results.values()])
    ttfts = np.array([v["first_token"] - v["arrival"]
                      for v in results.values()])
    n_tok = sum(len(v["tokens"]) for v in results.values())
    return {
        "recipe": recipe_name,
        "prefill": f"chunk{prefill_chunk}" if prefill_chunk else "mono",
        "finished": len(results),
        "tok_s": n_tok / makespan,
        "p50_lat": float(np.percentile(lats, 50)),
        "p99_lat": float(np.percentile(lats, 99)),
        "p50_ttft": float(np.percentile(ttfts, 50)),
        "p99_ttft": float(np.percentile(ttfts, 99)),
        "max_concurrent": eng.max_concurrent,
        "kv_bytes": eng.kv_bytes(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_235b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=128)
    ap.add_argument("--max-pages", type=int, default=8)
    ap.add_argument("--token-budget", type=int, default=512)
    ap.add_argument("--closed-loop", action="store_true",
                    help="ignore arrival times (saturation throughput)")
    ap.add_argument("--recipes", default="fp8_flow,bf16")
    ap.add_argument("--max-prompt", type=int, default=24,
                    help="longest trace prompt (chunked prefill may exceed "
                         "the largest bucket; monolithic cannot)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="bound prefill to N-token slices per tick")
    ap.add_argument("--compare-prefill", action="store_true",
                    help="run each recipe twice — monolithic vs chunked "
                         "prefill on the SAME trace — to measure the "
                         "p50/p99 TTFT effect of bounded prefill slices")
    args = ap.parse_args()

    import jax
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh, make_test_mesh
    from repro.launch.sharding import make_plan
    from repro.models.lm import ParallelPlan, init_params

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh()
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    else:
        mesh = make_production_mesh()
        plan = make_plan(cfg, mesh)
    params = init_params(cfg, jax.random.key(0))

    print("recipe,prefill,finished,tok_s,p50_lat_s,p99_lat_s,p50_ttft_s,"
          "p99_ttft_s,max_concurrent,kv_MiB")

    def report(r):
        print(f"{r['recipe']},{r['prefill']},{r['finished']},{r['tok_s']:.1f},"
              f"{r['p50_lat']:.3f},{r['p99_lat']:.3f},"
              f"{r['p50_ttft']:.3f},{r['p99_ttft']:.3f},"
              f"{r['max_concurrent']},{r['kv_bytes']/2**20:.1f}")

    for name in args.recipes.split(","):
        if args.compare_prefill:
            chunk = args.prefill_chunk or 16
            report(run_recipe(name.strip(), cfg, plan, params, args,
                              prefill_chunk=None))
            report(run_recipe(name.strip(), cfg, plan, params, args,
                              prefill_chunk=chunk))
        else:
            report(run_recipe(name.strip(), cfg, plan, params, args,
                              prefill_chunk=args.prefill_chunk))


if __name__ == "__main__":
    main()
