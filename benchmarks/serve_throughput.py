"""Continuous-batching serving throughput: tok/s and p50/p99 latency under a
synthetic Poisson arrival trace, fp8_flow (W8-resident weights + FP8 paged
KV) vs bf16 (BF16 weights + BF16 paged KV).

  PYTHONPATH=src python benchmarks/serve_throughput.py --reduced \
      [--requests 32] [--rate 20] [--arch qwen3_moe_235b] \
      [--prefill-chunk 16] [--compare-prefill] \
      [--shared-prefix 4] [--compare-prefix-cache]

Reports, per recipe (and per prefill mode with --compare-prefill, which runs
the SAME trace chunked vs monolithic so the decode-latency / TTFT win of
bounded prefill slices is measured, not asserted):
  tok/s        — generated tokens / makespan
  p50/p99 lat  — request completion latency (arrival -> last token)
  p50/p99 ttft — time to first token (arrival -> first sampled token)
  hit rate     — prefix-cache hit tokens / total prompt tokens (cache on)
  kv bytes     — resident paged-pool footprint (FP8 pages ~halve this)

--shared-prefix K generates a MULTI-TENANT trace: K tenants, each with its
own fixed system prompt, every request = tenant prefix + unique tail — the
workload the radix prefix cache targets.  --compare-prefix-cache runs the
same trace cache-on vs cache-off so the hit-rate -> TTFT effect is measured.

--preset decode_heavy (short prompts, long generations) and
--preset mixed_interference (decode-heavy foreground + periodic long-prompt
prefills) target the time-between-tokens TAIL: every run also reports
per-token p50/p99 TBT from the engine's serve_tbt_ms histogram, which is
what the prefill/decode disaggregation A/B (benchmarks/disagg_ab.py)
improves under interference.

Every result row also flows through benchmarks/common.emit(), so with
REPRO_BENCH_JSONL set the per-request TTFT percentiles, throughput, and
cache-hit-rate land in the unified bench JSONL stream the obs reporter
renders.

The trace has more requests than engine slots, so admission/eviction and
batch-mix churn are exercised for real (max concurrent < #requests).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np


def make_trace(n_requests: int, rate_hz: float, seed: int, vocab: int,
               max_prompt: int = 24, max_new: int = 12):
    """Poisson arrivals (exp inter-arrival gaps), variable prompt lengths."""
    from repro.serve.scheduler import Request
    r = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for _ in range(n_requests):
        t += float(r.exponential(1.0 / rate_hz))
        plen = int(r.integers(3, max_prompt + 1))
        reqs.append(Request(
            prompt=list(r.integers(1, vocab, plen)),
            max_new_tokens=int(r.integers(2, max_new + 1)),
            arrival_time=t))
    return reqs


def make_shared_prefix_trace(n_requests: int, rate_hz: float, seed: int,
                             vocab: int, n_tenants: int = 4,
                             prefix_len: int = 16, max_tail: int = 8,
                             max_new: int = 12):
    """Multi-tenant Poisson trace: K tenants x (shared system prompt +
    unique tail).  Tenants are drawn uniformly per arrival, so every
    tenant's prefix recurs throughout the trace — the canonical
    prefix-cache workload (system prompts / few-shot headers)."""
    from repro.serve.scheduler import Request
    r = np.random.default_rng(seed)
    prefixes = [list(r.integers(1, vocab, prefix_len))
                for _ in range(n_tenants)]
    t = 0.0
    reqs = []
    for _ in range(n_requests):
        t += float(r.exponential(1.0 / rate_hz))
        tenant = int(r.integers(0, n_tenants))
        tail = list(r.integers(1, vocab, int(r.integers(1, max_tail + 1))))
        reqs.append(Request(
            prompt=prefixes[tenant] + tail,
            max_new_tokens=int(r.integers(2, max_new + 1)),
            arrival_time=t))
    return reqs


def make_decode_heavy_trace(n_requests: int, rate_hz: float, seed: int,
                            vocab: int, max_prompt: int = 6,
                            min_new: int = 12, max_new: int = 20):
    """Short prompts, long generations — the TBT-dominated regime (chat
    turns): per-request cost is almost entirely decode ticks, so the
    time-between-tokens tail IS the user experience."""
    from repro.serve.scheduler import Request
    r = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for _ in range(n_requests):
        t += float(r.exponential(1.0 / rate_hz))
        reqs.append(Request(
            prompt=list(r.integers(1, vocab, int(r.integers(3,
                                                            max_prompt + 1)))),
            max_new_tokens=int(r.integers(min_new, max_new + 1)),
            arrival_time=t))
    return reqs


def make_mixed_interference_trace(n_requests: int, rate_hz: float, seed: int,
                                  vocab: int, long_every: int = 4,
                                  long_prompt: int = 48, max_prompt: int = 6,
                                  min_new: int = 12, max_new: int = 20):
    """Decode-heavy foreground + periodic LONG-prompt interferers (every
    `long_every`-th arrival carries a `long_prompt`-token prompt with a
    short generation).  In a mixed engine each interferer's prefill chunks
    ride the same ticks as resident decodes, dragging the TBT tail — the
    exact pathology prefill/decode disaggregation removes, and what the
    disagg A/B measures."""
    from repro.serve.scheduler import Request
    r = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(r.exponential(1.0 / rate_hz))
        if long_every and i % long_every == long_every - 1:
            reqs.append(Request(
                prompt=list(r.integers(1, vocab, long_prompt)),
                max_new_tokens=int(r.integers(2, 5)),
                arrival_time=t))
        else:
            reqs.append(Request(
                prompt=list(r.integers(1, vocab,
                                       int(r.integers(3, max_prompt + 1)))),
                max_new_tokens=int(r.integers(min_new, max_new + 1)),
                arrival_time=t))
    return reqs


def build_trace(args, vocab):
    preset = getattr(args, "preset", "poisson")
    if preset == "decode_heavy":
        return make_decode_heavy_trace(args.requests, args.rate, args.seed,
                                       vocab)
    if preset == "mixed_interference":
        return make_mixed_interference_trace(
            args.requests, args.rate, args.seed, vocab,
            long_every=args.long_every, long_prompt=args.long_prompt)
    if args.shared_prefix:
        return make_shared_prefix_trace(
            args.requests, args.rate, args.seed, vocab,
            n_tenants=args.shared_prefix, prefix_len=args.prefix_len,
            max_tail=args.max_tail)
    return make_trace(args.requests, args.rate, args.seed, vocab,
                      max_prompt=args.max_prompt)


def run_recipe(recipe_name: str, cfg, plan, params, args,
               prefill_chunk=None, prefix_cache=False):
    from repro.core.recipes import get_recipe
    from repro.obs.sink import Telemetry
    from repro.serve.engine import _LAT_BUCKETS, ServeConfig, ServeEngine

    recipe = get_recipe(recipe_name)
    fp8 = recipe.name == "fp8_flow"
    ecfg = ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        n_pages=args.n_pages, max_pages_per_req=args.max_pages,
        token_budget=args.token_budget, prefill_buckets=(16, 32, 64),
        prefill_chunk=prefill_chunk, fp8_kv=fp8, w8_weights=fp8,
        prefix_cache=prefix_cache, seed=0)
    # sink-less telemetry: the registry's serve_tbt_ms histogram gives the
    # per-TOKEN inter-token percentiles (request means hide the tail the
    # decode-heavy presets exist to expose)
    tel = Telemetry(sinks=())
    eng = ServeEngine(cfg, recipe, plan, params, ecfg, telemetry=tel)
    reqs = build_trace(args, cfg.vocab)
    assert len(reqs) > ecfg.max_batch, "trace must oversubscribe the batch"
    total_prompt = sum(len(q.prompt) for q in reqs)

    t0 = time.perf_counter()
    results = eng.run(reqs, realtime=not args.closed_loop)
    makespan = time.perf_counter() - t0

    lats = np.array([v["finish"] - v["arrival"] for v in results.values()])
    ttfts = np.array([v["first_token"] - v["arrival"]
                      for v in results.values()])
    n_tok = sum(len(v["tokens"]) for v in results.values())
    hit_tokens = sum(v["cached_tokens"] for v in results.values())
    tbt_hist = tel.registry.histogram("serve_tbt_ms", edges=_LAT_BUCKETS)
    return {
        "recipe": recipe_name,
        "preset": getattr(args, "preset", "poisson"),
        "p50_tbt_ms": tbt_hist.quantile(0.5),
        "p99_tbt_ms": tbt_hist.quantile(0.99),
        "prefill": f"chunk{prefill_chunk}" if prefill_chunk else "mono",
        "cache": "on" if prefix_cache else "off",
        "finished": len(results),
        "tok_s": n_tok / makespan,
        "mean_ttft": float(ttfts.mean()),
        "p50_lat": float(np.percentile(lats, 50)),
        "p99_lat": float(np.percentile(lats, 99)),
        "p50_ttft": float(np.percentile(ttfts, 50)),
        "p99_ttft": float(np.percentile(ttfts, 99)),
        "hit_rate": hit_tokens / total_prompt,
        "max_concurrent": eng.max_concurrent,
        "kv_bytes": eng.kv_bytes(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_235b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=128)
    ap.add_argument("--max-pages", type=int, default=8)
    ap.add_argument("--token-budget", type=int, default=512)
    ap.add_argument("--closed-loop", action="store_true",
                    help="ignore arrival times (saturation throughput)")
    ap.add_argument("--preset", default="poisson",
                    choices=("poisson", "decode_heavy", "mixed_interference"),
                    help="trace shape: poisson (uniform prompts), "
                         "decode_heavy (short prompts / long generations — "
                         "TBT-dominated), mixed_interference (decode-heavy "
                         "foreground + periodic long prefills, the workload "
                         "the disagg A/B measures TBT tails on)")
    ap.add_argument("--long-every", type=int, default=4,
                    help="mixed_interference: every Nth arrival is a long "
                         "prefill interferer")
    ap.add_argument("--long-prompt", type=int, default=48,
                    help="mixed_interference: interferer prompt tokens")
    ap.add_argument("--recipes", default="fp8_flow,bf16")
    ap.add_argument("--max-prompt", type=int, default=24,
                    help="longest trace prompt (chunked prefill may exceed "
                         "the largest bucket; monolithic cannot)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="bound prefill to N-token slices per tick")
    ap.add_argument("--compare-prefill", action="store_true",
                    help="run each recipe twice — monolithic vs chunked "
                         "prefill on the SAME trace — to measure the "
                         "p50/p99 TTFT effect of bounded prefill slices")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="K",
                    help="multi-tenant trace: K tenants x (shared system "
                         "prompt + unique tail) instead of fully random "
                         "prompts")
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt length per tenant")
    ap.add_argument("--max-tail", type=int, default=8,
                    help="longest per-request unique tail")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the radix prefix cache")
    ap.add_argument("--compare-prefix-cache", action="store_true",
                    help="run each recipe cache-on vs cache-off on the "
                         "SAME trace — measures hit rate vs TTFT/p99")
    args = ap.parse_args()

    import jax
    try:
        from benchmarks.common import emit
    except ModuleNotFoundError:      # invoked as `python benchmarks/...py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        from benchmarks.common import emit
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh, make_test_mesh
    from repro.launch.sharding import make_plan
    from repro.models.lm import ParallelPlan, init_params

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh()
        plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    else:
        mesh = make_production_mesh()
        plan = make_plan(cfg, mesh)
    params = init_params(cfg, jax.random.key(0))

    print("recipe,preset,prefill,cache,finished,tok_s,p50_lat_s,p99_lat_s,"
          "p50_ttft_s,p99_ttft_s,p50_tbt_ms,p99_tbt_ms,hit_rate,"
          "max_concurrent,kv_MiB")

    def report(r):
        print(f"{r['recipe']},{r['preset']},{r['prefill']},{r['cache']},"
              f"{r['finished']},{r['tok_s']:.1f},"
              f"{r['p50_lat']:.3f},{r['p99_lat']:.3f},"
              f"{r['p50_ttft']:.3f},{r['p99_ttft']:.3f},"
              f"{r['p50_tbt_ms']:.2f},{r['p99_tbt_ms']:.2f},"
              f"{r['hit_rate']:.3f},"
              f"{r['max_concurrent']},{r['kv_bytes']/2**20:.1f}")
        tag = f"serve/{r['recipe']}/{r['preset']}/{r['prefill']}" \
              f"/cache_{r['cache']}"
        emit(f"{tag}/tok_s", r["tok_s"], units="tok/s")
        emit(f"{tag}/mean_ttft_ms", r["mean_ttft"] * 1e3, units="ms")
        emit(f"{tag}/p50_ttft_ms", r["p50_ttft"] * 1e3, units="ms")
        emit(f"{tag}/p99_ttft_ms", r["p99_ttft"] * 1e3, units="ms")
        emit(f"{tag}/p99_lat_ms", r["p99_lat"] * 1e3, units="ms")
        emit(f"{tag}/p50_tbt_ms", r["p50_tbt_ms"], units="ms")
        emit(f"{tag}/p99_tbt_ms", r["p99_tbt_ms"], units="ms")
        emit(f"{tag}/cache_hit_rate", r["hit_rate"],
             derived=f"{r['finished']} reqs", units="frac")

    for name in args.recipes.split(","):
        name = name.strip()
        chunk = args.prefill_chunk
        if args.compare_prefill:
            chunk = chunk or 16
            report(run_recipe(name, cfg, plan, params, args,
                              prefill_chunk=None,
                              prefix_cache=args.prefix_cache))
            report(run_recipe(name, cfg, plan, params, args,
                              prefill_chunk=chunk,
                              prefix_cache=args.prefix_cache))
        elif args.compare_prefix_cache:
            for cache in (False, True):
                report(run_recipe(name, cfg, plan, params, args,
                                  prefill_chunk=chunk, prefix_cache=cache))
        else:
            report(run_recipe(name, cfg, plan, params, args,
                              prefill_chunk=chunk,
                              prefix_cache=args.prefix_cache))


if __name__ == "__main__":
    main()
