"""Table 2/3 reproduction (modeled): per-recipe roofline step time -> TGS
(tokens/chip/s) for the paper's MoE workload on the single-pod mesh.

Wall-clock TGS cannot be measured on this CPU container; the modeled TGS is
max(compute, memory, collective) roofline time from the trip-count-correct
component probes (roofline/probe.py), per recipe — reproducing the paper's
ORDERING (fp8_flow > blockwise ~ bf16) and the mechanism (fewer cast ops +
FP8 wire bytes).  Reads cached sweep results when present; probing all
recipes live takes ~4 x 60 s of XLA compilation on this machine, so the
default target is the paper-scale-but-fits v2-lite config; set
REPRO_T23_ARCH=qwen3_moe_235b for the big one.
"""
from __future__ import annotations

import os

from benchmarks.common import emit

ARCH = os.environ.get("REPRO_T23_ARCH", "deepseek_v2_lite")
RECIPES = ["bf16", "blockwise", "naive_fp8", "fp8_flow"]


def run():
    # needs the 512-virtual-device mesh; jax may already be initialized with
    # 1 device in this process -> re-exec the probe loop in a subprocess
    import subprocess
    import sys
    if os.environ.get("_REPRO_T23_CHILD") != "1":
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=512")
        env["_REPRO_T23_CHILD"] = "1"
        env.setdefault("PYTHONPATH", "src:.")
        out = subprocess.run(
            [sys.executable, "-c",
             "from benchmarks import table23_throughput as m; m.run()"],
            env=env, capture_output=True, text=True, timeout=3000)
        sys.stdout.write(out.stdout)
        if out.returncode != 0:
            sys.stderr.write(out.stderr[-2000:])
            raise RuntimeError("table23 child failed")
        return
    import jax
    from repro.configs import get_arch
    from repro.configs.base import SHAPES
    from repro.core.recipes import get_recipe
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import make_plan
    from repro.models.lm import init_params
    from repro.roofline import probe as probe_mod
    from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    cfg = get_arch(ARCH)
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=False)
    plan = make_plan(cfg, mesh)
    params_shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    tokens = shape.global_batch * shape.seq_len

    results = {}
    for name in RECIPES:
        recipe = get_recipe(name)
        cost = probe_mod.probe_train(cfg, recipe, plan, mesh, params_shapes,
                                     shape.global_batch // cfg.grad_accum,
                                     shape.seq_len)
        t = max(cost["flops"] / PEAK_FLOPS_BF16,
                cost["hbm_bytes"] / HBM_BW,
                cost["coll_bytes"] / ICI_BW)
        results[name] = (t, cost)
        tgs = tokens / t / 256
        emit(f"table23_{ARCH}_{name}", t * 1e6,
             f"modeled_TGS={tgs:.0f};"
             f"t_comp_ms={cost['flops'] / PEAK_FLOPS_BF16 * 1e3:.1f};"
             f"t_mem_ms={cost['hbm_bytes'] / HBM_BW * 1e3:.1f};"
             f"t_coll_ms={cost['coll_bytes'] / ICI_BW * 1e3:.1f}")
        jax.clear_caches()

    base = results["bf16"][0]
    for name in RECIPES[1:]:
        emit(f"table23_{ARCH}_speedup_{name}", 0.0,
             f"vs_bf16={base / results[name][0]:.3f}x")


if __name__ == "__main__":
    run()
