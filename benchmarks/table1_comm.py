"""Table 1 reproduction: dispatch all-to-all with and without Q/DQ at the
communication boundary, for the paper's (M, N, EP) grid.

Modeled on v5e ICI/HBM constants (no wall-clock fabric on this container):
  BF16 comm      = 2 * M*N bytes over ICI
  FP8 comm       = (M*N payload + M*N/128*4 scale) bytes (the paper's
                   'doubled buffers' effect: scales ride a second buffer)
  Q/DQ           = HBM-bound casts: read 2B + write 1B (Q); 1B + 2B (DQ)
Speedups reported for COMM alone and ALL (comm + Q/DQ) — reproducing the
paper's finding that one Q/DQ pair costs ~1/3 of the FP8 comm win at small
scales, and that FP8-Flow-MoE removes exactly that term.
"""
from __future__ import annotations

from benchmarks.common import emit, hbm_model_us, ici_model_us

GRID = [
    (24576, 2048, 8), (24576, 5120, 8), (32768, 7168, 8),
    (24576, 2048, 16), (24576, 5120, 16), (32768, 7168, 16),
    (24576, 2048, 32), (24576, 5120, 32), (32768, 7168, 32),
]


def run():
    for (m, n, ep) in GRID:
        elems = m * n
        # per-chip payloads cross (ep-1)/ep of the fabric; constant factor
        # cancels in the ratio — we report raw wire bytes.
        bf16_us = ici_model_us(2 * elems)
        fp8_wire = elems + (elems // 128) * 4
        fp8_comm_us = ici_model_us(fp8_wire)
        q_us = hbm_model_us(elems * (2 + 1))
        dq_us = hbm_model_us(elems * (1 + 2))
        all_us = fp8_comm_us + q_us + dq_us
        emit(f"table1_comm_{m}x{n}_ep{ep}", fp8_comm_us,
             f"bf16_us={bf16_us:.0f};qdq_us={q_us + dq_us:.0f};"
             f"speedup_comm={bf16_us / fp8_comm_us:.2f}x;"
             f"speedup_all={bf16_us / all_us:.2f}x;"
             f"flow_removes_qdq=+{(bf16_us / fp8_comm_us - bf16_us / all_us):.2f}x")


if __name__ == "__main__":
    run()
