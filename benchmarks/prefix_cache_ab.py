"""Radix prefix cache A/B: hit rate, skipped-prefill-FLOPs model, and
bitwise hit-vs-miss parity on a multi-tenant shared-prefix trace.

The cache maps full-page-aligned prompt prefixes to refcounted FP8 pages
(serve/prefix_cache.py): a hit stitches the shared pages into the request's
page table and starts chunked prefill at the matched length, so the matched
tokens' prefill FLOPs are skipped outright and the shared prefix is
quantized once per pool, not once per request.  Because the per-row po2
quantize is deterministic (paper Eq. 5-8), reading a cached page is
bit-for-bit reading the page the request would have written itself — which
is what makes sharing safe and what the parity gate checks.

Usage:
  PYTHONPATH=src python benchmarks/prefix_cache_ab.py --dry-run   # CI smoke
  PYTHONPATH=src python benchmarks/prefix_cache_ab.py             # timed

Acceptance gates (checked in BOTH modes):
  * >= 50% of trace prompt tokens served from cache (K tenants x shared
    system prompt + unique tails; page-aligned matching loses < page_size
    tokens per request);
  * generated tokens are BITWISE IDENTICAL cache-on vs cache-off for every
    request (greedy decode; same trace, same geometry);
  * the linear-layer FLOPs model shows the skipped prefill work
    proportional to the hit rate;
  * a 2-replica prefix-aware router spreads the tenants across the fleet
    (every replica used, fleet-level hits recorded).
Timed mode additionally reports mean/p99 TTFT cache-on vs cache-off.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

try:
    from benchmarks.common import emit
except ModuleNotFoundError:          # invoked as `python benchmarks/...py`
    sys.path.insert(0, os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import emit


def linear_flops_per_token(cfg) -> float:
    """Matmul FLOPs one prompt token costs in prefill, counting the
    token-linear layers (QKVO + MLP/expert GEMMs; the O(T^2) attention
    score term is excluded, so the model is a LOWER bound on savings)."""
    attn = 2 * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim \
        + 2 * cfg.n_heads * cfg.head_dim * cfg.d_model
    if cfg.moe:
        d_ff = cfg.d_ff_expert or cfg.d_ff
        experts = cfg.top_k + cfg.n_shared_experts
        mlp = experts * 3 * 2 * cfg.d_model * d_ff
    else:
        mlp = 3 * 2 * cfg.d_model * cfg.d_ff
    return cfg.n_layers * (attn + mlp)


def run(dry_run: bool = False):
    import jax
    from benchmarks.serve_throughput import make_shared_prefix_trace
    from repro.configs import get_arch
    from repro.core.recipes import get_recipe
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import ParallelPlan, init_params
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.router import ReplicaRouter, RouterConfig

    cfg = get_arch("qwen3_moe_235b").reduced()
    plan = ParallelPlan(mesh=make_test_mesh(), dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    recipe = get_recipe("fp8_flow")

    n_requests = 10 if dry_run else 24
    # page-aligned chunk geometry (prefill_chunk == page_size) keeps the
    # hit path's chunk boundaries identical to the miss path's -> bitwise
    ecfg_kw = dict(max_batch=4, page_size=4, n_pages=64,
                   max_pages_per_req=8, token_budget=256,
                   prefill_buckets=(16, 32), prefill_chunk=4,
                   fp8_kv=True, w8_weights=True, seed=0)

    def trace():
        return make_shared_prefix_trace(
            n_requests, rate_hz=50.0, seed=7, vocab=cfg.vocab,
            n_tenants=3, prefix_len=8, max_tail=4, max_new=4)

    runs = {}
    for cache in (False, True):
        eng = ServeEngine(cfg, recipe, plan, params,
                          ServeConfig(prefix_cache=cache, **ecfg_kw))
        reqs = trace()
        t0 = time.perf_counter()
        results = eng.run(reqs, realtime=False)
        dt = time.perf_counter() - t0
        ttfts = np.array([v["first_token"] - v["arrival"]
                          for v in results.values()])
        runs[cache] = {
            "reqs": reqs, "results": results, "stats": eng.stats(),
            "makespan": dt, "mean_ttft": float(ttfts.mean()),
            "p99_ttft": float(np.percentile(ttfts, 99)),
        }

    off, on = runs[False], runs[True]

    # -- gate 1: bitwise hit-vs-miss parity (same trace, greedy) -----------
    toks_off = [off["results"][q.rid]["tokens"] for q in off["reqs"]]
    toks_on = [on["results"][q.rid]["tokens"] for q in on["reqs"]]
    assert len(toks_off) == len(toks_on) == n_requests, \
        f"finished {len(toks_off)} vs {len(toks_on)} of {n_requests}"
    for i, (a, b) in enumerate(zip(toks_off, toks_on)):
        assert a == b, (f"request {i}: cache-on tokens diverge from "
                        f"cache-off: {a} vs {b}")

    # -- gate 2: hit rate on the shared-prefix trace -----------------------
    total_prompt = sum(len(q.prompt) for q in on["reqs"])
    hit_tokens = on["stats"]["prefix_hit_tokens"]
    hit_rate = hit_tokens / total_prompt
    assert hit_rate >= 0.5, \
        f"prefix hit rate {hit_rate:.2f} < 0.5 on a shared-prefix trace"
    assert off["stats"].get("prefix_hit_tokens", 0) == 0

    # -- gate 3: skipped-prefill-FLOPs model -------------------------------
    fpt = linear_flops_per_token(cfg)
    saved = hit_tokens * fpt
    total = total_prompt * fpt
    assert saved / total == hit_rate > 0

    emit("prefix_cache/hit_rate", hit_rate,
         derived=f"{hit_tokens}/{total_prompt} prompt tokens", units="frac",
         kind="measured")
    emit("prefix_cache/skipped_prefill_gflops", saved / 1e9,
         derived=f"of {total / 1e9:.2f} GFLOP prompt linear work",
         units="GFLOP", kind="modeled")
    emit("prefix_cache/shared_pages", on["stats"]["shared_pages"],
         units="pages", kind="measured")

    # -- gate 4: 2-replica prefix-aware router smoke -----------------------
    engines = [ServeEngine(cfg, recipe, plan, params,
                           ServeConfig(prefix_cache=True, **ecfg_kw))
               for _ in range(2)]
    router = ReplicaRouter(engines, RouterConfig())
    rres = router.run(trace(), realtime=False)
    rstats = rres.stats
    assert rstats["routed"] == n_requests
    assert rstats["finished"] == n_requests
    assert all(c > 0 for c in rstats["route_counts"]), \
        f"router starved a replica: {rstats['route_counts']}"
    assert rstats["prefix_hits"] > 0, "no fleet-level prefix hits"
    fleet_hit_rate = rstats["prefix_hit_tokens"] / total_prompt
    emit("prefix_cache/router_fleet_hit_rate", fleet_hit_rate,
         derived=f"route_counts={rstats['route_counts']}", units="frac",
         kind="measured")

    if dry_run:
        print(f"prefix_cache_ab: dry-run OK (hit_rate={hit_rate:.2f}, "
              f"{n_requests}/{n_requests} requests bitwise on==off, "
              f"modeled {saved / 1e9:.2f} GFLOP prefill skipped, "
              f"router route_counts={rstats['route_counts']} "
              f"fleet_hit_rate={fleet_hit_rate:.2f})")
        return

    # -- timed: TTFT effect of the cache on the same trace -----------------
    emit("prefix_cache/mean_ttft_off_ms", off["mean_ttft"] * 1e3, units="ms")
    emit("prefix_cache/mean_ttft_on_ms", on["mean_ttft"] * 1e3, units="ms")
    emit("prefix_cache/p99_ttft_off_ms", off["p99_ttft"] * 1e3, units="ms")
    emit("prefix_cache/p99_ttft_on_ms", on["p99_ttft"] * 1e3, units="ms")
    print(f"prefix_cache_ab: hit_rate={hit_rate:.2f}  "
          f"mean_ttft {off['mean_ttft']*1e3:.0f} -> "
          f"{on['mean_ttft']*1e3:.0f} ms  "
          f"p99_ttft {off['p99_ttft']*1e3:.0f} -> "
          f"{on['p99_ttft']*1e3:.0f} ms  "
          f"makespan {off['makespan']:.2f} -> {on['makespan']:.2f} s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="structural gates only (CI): hit rate, bitwise "
                         "parity, FLOPs model, 2-replica router smoke")
    args = ap.parse_args()
    run(dry_run=args.dry_run)
