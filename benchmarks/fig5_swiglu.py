"""Fig. 5 reproduction: fused SwiGLU+quantization vs standalone SwiGLU
followed by a separate quantize kernel.

The paper's claim: the fused kernel matches the latency of the standalone
SwiGLU (i.e., quantization becomes free).  On v5e the predictor is HBM
bytes: standalone+quant re-reads/re-writes the activation; fused writes the
e4m3 payload directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bytes_of, emit, hbm_model_us, time_fn
from repro.core.linear import _swiglu
from repro.core.quant import quantize_rowwise

CASES = [(8192, 2816), (16384, 4096), (32768, 3072)]


def run():
    for (m, two_f) in CASES:
        r = np.random.default_rng(0)
        h = jnp.asarray(r.normal(size=(m, two_f)).astype(np.float32)
                        ).astype(jnp.bfloat16)

        def swiglu_only(h):
            return _swiglu(h)

        def unfused(h):
            a = _swiglu(h) * jnp.ones((), jnp.bfloat16)  # materialized
            q = quantize_rowwise(a, tag="bench")
            return q.data, q.scale

        def fused(h):
            # single pass: act + quant in one fusion (what the Pallas kernel
            # does on TPU; XLA fuses the chain into one loop on CPU too)
            q = quantize_rowwise(_swiglu(h), tag="bench")
            return q.data, q.scale

        f0 = jax.jit(swiglu_only)
        f1 = jax.jit(unfused)
        f2 = jax.jit(fused)
        us0 = time_fn(f0, h)
        us1 = time_fn(f1, h)
        us2 = time_fn(f2, h)
        b0 = bytes_of(f0.lower(h).compile())
        b1 = bytes_of(f1.lower(h).compile())
        b2 = bytes_of(f2.lower(h).compile())
        emit(f"fig5_swiglu_only_{m}x{two_f}", us0,
             f"model_us={hbm_model_us(b0):.1f}")
        emit(f"fig5_swiglu_quant_fused_{m}x{two_f}", us2,
             f"model_us={hbm_model_us(b2):.1f};"
             f"vs_swiglu_only={us2 / us0:.2f}x;"
             f"tpu_model_vs_only={b2 / b0:.2f}x")
        emit(f"fig5_swiglu_quant_unfused_{m}x{two_f}", us1,
             f"model_us={hbm_model_us(b1):.1f};"
             f"fused_speedup={us1 / us2:.2f}x;"
             f"tpu_model_speedup={b1 / b2:.2f}x")


if __name__ == "__main__":
    run()
