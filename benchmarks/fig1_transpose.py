"""Fig. 1 reproduction: Direct Transpose vs naive dequant->transpose->requant.

Reports, per tensor shape:
  - measured CPU wall time of both XLA-path implementations (ratio),
  - the HBM bytes-moved model (the quantity that determines the TPU ratio:
    naive round-trips the tensor through bf16/f32 twice; direct moves fp8
    bytes once) — the paper measures 2-3x on H-series GPUs; the byte model
    predicts ~3x on v5e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bytes_of, emit, hbm_model_us, time_fn
from repro.core.quant import quantize_rowwise
from repro.core.transpose import transpose_direct, transpose_naive

SHAPES = [(4096, 2048), (4096, 5120), (8192, 4096), (8192, 7168)]


def run():
    for (m, k) in SHAPES:
        r = np.random.default_rng(0)
        x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
        q = quantize_rowwise(x)

        f_direct = jax.jit(transpose_direct)
        f_naive = jax.jit(lambda q: transpose_naive(q, "po2"))
        us_d = time_fn(f_direct, q)
        us_n = time_fn(f_naive, q)

        b_d = bytes_of(f_direct.lower(q).compile())
        b_n = bytes_of(f_naive.lower(q).compile())
        emit(f"fig1_transpose_direct_{m}x{k}", us_d,
             f"model_us={hbm_model_us(b_d):.1f}")
        emit(f"fig1_transpose_naive_{m}x{k}", us_n,
             f"model_us={hbm_model_us(b_n):.1f};"
             f"cpu_speedup={us_n / us_d:.2f}x;"
             f"tpu_model_speedup={b_n / b_d:.2f}x")


if __name__ == "__main__":
    run()
