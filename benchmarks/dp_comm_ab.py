"""Quantized vs bf16 data-parallel gradient reduction A/B.

The legacy path all-reduces every gradient byte in >=bf16 on the DP axis.
The DistPlan wire (repro.dist) reduce-scatters e4m3 payloads + int8 po2
exponents packed into ONE uint8 message per bucket, with sensitive leaves
(norms/router/embeddings) on a bf16 psum fallback, and all-gathers only the
updated bf16 param shards (ZeRO-1).

This bench verifies the wire for real — it LOWERS the DistPlan train step
on an N-virtual-device CPU mesh and checks the jaxpr: one all_to_all per
bucket, uint8 on the wire, no f32 gradient all-reduce — and reports the
bytes-on-wire model (no TPU fabric on this container; ring factors
(P-1)/P per hop, all-reduce = 2 hops):

  PYTHONPATH=src python benchmarks/dp_comm_ab.py --dry-run     # CI smoke
  PYTHONPATH=src python benchmarks/dp_comm_ab.py --dry-run --overlap
  PYTHONPATH=src python benchmarks/dp_comm_ab.py --devices 8 --steps 3

--overlap additionally lowers the STREAMING schedule (DistPlan
schedule='stream': layer-aligned reverse-order buckets, each quantize +
reduce-scatter issued from inside the staged backward) and checks the
jaxpr for the structural property the schedule exists for: at least one
bucket reduce-scatter appears BEFORE the last backward GEMM (the post-hoc
step issues every one after), plus the modelled exposed-comm delta (greedy
hiding of each bucket's wire time behind the remaining layers' backward
compute).

Acceptance gate (dry-run): the FP8 bucket path moves >= 3x fewer gradient
bytes than a bf16 all-reduce of the same leaves (1.008 B/elem + amax
agreement vs 4 B/elem at P=8 -> ~3.7x).
"""
from __future__ import annotations

import argparse
import os
import sys


def run(devices: int = 8, arch: str = "qwen15_05b", steps: int = 2,
        dry_run: bool = False, overlap: bool = False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    try:
        from benchmarks.common import emit, time_fn
    except ModuleNotFoundError:      # invoked as `python benchmarks/...py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        from benchmarks.common import emit, time_fn
    from repro.compat import make_mesh
    from repro.configs import get_arch
    from repro.core.fp8 import TILE
    from repro.core.recipes import get_recipe
    from repro.data.pipeline import DataConfig, make_batch
    from repro.dist import DistPlan, build_layout
    from repro.dist.grad_comm import wire_grad_bytes, wire_param_bytes
    from repro.models.lm import ParallelPlan
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    ndev = jax.device_count()
    if ndev < devices:
        print(f"dp_comm_ab: only {ndev} devices visible (wanted {devices}); "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count=N",
              file=sys.stderr)
        devices = ndev
    P = devices
    mesh = make_mesh((P, 1), ("data", "model"))
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    cfg = get_arch(arch).reduced()
    recipe = get_recipe("fp8_flow")
    opt = AdamWConfig(lr=1e-3)
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=max(P, 8))

    dist_fp8 = DistPlan(wire="fp8")
    state = init_train_state(cfg, opt, jax.random.key(0), dist=dist_fp8)
    layout = build_layout(state["params"], dist_fp8)
    n_fp8 = layout.fp8_elems
    n_all = sum(int(np.prod(l.shape))
                for l in jax.tree.leaves(state["params"]))
    n_sens = n_all - n_fp8

    # ---- real lowering check: the fused uint8 wire must be in the HLO ----
    step = make_train_step(cfg, recipe, plan, opt, dist=dist_fp8,
                           total_steps=100, warmup_steps=5)
    batch = make_batch(data, 0)
    jaxpr = str(jax.make_jaxpr(step)(state, batch))
    n_a2a = jaxpr.count("all_to_all")
    assert n_a2a == len(layout.buckets), \
        f"expected 1 fused all_to_all per bucket, got {n_a2a} " \
        f"vs {len(layout.buckets)} buckets"
    assert "u8[" in jaxpr, "wire message is not uint8-packed"
    with mesh:
        jax.jit(step).lower(state, batch)      # the CI "it lowers" gate

    # ---- bytes-on-wire model --------------------------------------------
    fp8_grad = (sum(wire_grad_bytes(b.rows * TILE, P, "fp8")
                    for b in layout.buckets)
                + wire_grad_bytes(n_sens, P, "bf16", mode="none"))
    bf16_bucket = wire_grad_bytes(n_fp8, P, "bf16", mode="none")
    bf16_all = wire_grad_bytes(n_all, P, "bf16", mode="none")
    bucket_only = sum(wire_grad_bytes(b.rows * TILE, P, "fp8")
                      for b in layout.buckets)
    ratio_bucket = bf16_bucket / max(bucket_only, 1e-9)
    ratio_e2e = bf16_all / max(fp8_grad, 1e-9)
    gather = wire_param_bytes(n_fp8, P)

    emit(f"dp_comm_ab_p{P}_{arch}", 0.0,
         f"fp8_bucket_grad_B={bucket_only:.0f};"
         f"bf16_allreduce_same_leaves_B={bf16_bucket:.0f};"
         f"bucket_ratio={ratio_bucket:.2f}x;"
         f"end_to_end_grad_ratio={ratio_e2e:.2f}x;"
         f"zero1_param_allgather_B={gather:.0f};"
         f"buckets={len(layout.buckets)};fp8_elems={n_fp8};"
         f"sens_elems={n_sens};a2a_ops={n_a2a}",
         units="bytes", kind="model")
    if P > 1:
        assert ratio_bucket >= 3.0, \
            f"FP8 bucket path only {ratio_bucket:.2f}x below bf16 (< 3x)"

    # ---- streaming schedule: lowering + jaxpr interleave + exposed model -
    if overlap:
        from benchmarks.common import ici_model_us
        from repro.dist.grad_comm import stream_exposed_us
        from repro.roofline.analysis import PEAK_FLOPS_FP8

        dist_s = DistPlan(wire="fp8", schedule="stream")
        state_s = init_train_state(cfg, opt, jax.random.key(0), dist=dist_s)
        layout_s = build_layout(state_s["params"], dist_s)
        step_s = make_train_step(cfg, recipe, plan, opt, dist=dist_s,
                                 total_steps=100, warmup_steps=5)
        jx_s = str(jax.make_jaxpr(step_s)(state_s, batch))
        n_a2a_s = jx_s.count("all_to_all")
        with mesh:
            jax.jit(step_s).lower(state_s, batch)   # the "it lowers" gate
        interleaved = 0 <= jx_s.find("all_to_all") < jx_s.rfind("dot_general")
        posthoc_interleaved = 0 <= jaxpr.find("all_to_all") \
            < jaxpr.rfind("dot_general")
        if P > 1:
            assert n_a2a_s == len(layout_s.buckets), (n_a2a_s,
                                                      len(layout_s.buckets))
            assert interleaved, \
                "streaming: no bucket reduce-scatter before the last " \
                "backward GEMM in the jaxpr"
            assert not posthoc_interleaved, \
                "post-hoc baseline unexpectedly interleaved"

        # exposed-comm model: bucket i's wire time hides behind the NEXT
        # layer's backward GEMMs (greedy drain, reverse emission order);
        # per-layer backward ~= 4 flops/param/token on the local shard
        tok_local = data.global_batch * data.seq_len / P
        bucket_us = [ici_model_us(wire_grad_bytes(b.rows * TILE, P, "fp8"))
                     for b in layout_s.buckets]
        bwd_us = [4.0 * sum(s.size for s in b.slots) * tok_local
                  / PEAK_FLOPS_FP8 * 1e6 for b in layout_s.buckets]
        overlap_us = bwd_us[1:] + [0.0]
        exposed_stream = stream_exposed_us(bucket_us, overlap_us)
        exposed_posthoc = sum(bucket_us)
        emit(f"dp_comm_stream_p{P}_{arch}", exposed_stream,
             f"posthoc_exposed_us={exposed_posthoc:.1f};"
             f"stream_exposed_us={exposed_stream:.1f};"
             f"hidden_us={exposed_posthoc - exposed_stream:.1f};"
             f"buckets={len(layout_s.buckets)};a2a_ops={n_a2a_s};"
             f"jaxpr_interleaved={interleaved}",
             units="us", kind="model")
        assert exposed_stream <= exposed_posthoc + 1e-9

    if dry_run:
        extra = " + streaming schedule interleaves in the jaxpr" \
            if overlap else ""
        print(f"dp_comm_ab: dry-run OK (lowered fp8 wire on {P} devices; "
              f"bucket path {ratio_bucket:.2f}x fewer grad bytes than bf16 "
              f"all-reduce, {ratio_e2e:.2f}x end-to-end incl. bf16 "
              f"fallback{extra})")
        return

    # ---- CPU wall-clock A/B (functional check, not a fabric model) -------
    for wire in ("fp8", "f32"):
        d = DistPlan(wire=wire)
        st = init_train_state(cfg, opt, jax.random.key(0), dist=d)
        fn = jax.jit(make_train_step(cfg, recipe, plan, opt, dist=d,
                                     total_steps=100, warmup_steps=5))
        with mesh:
            us = time_fn(lambda s, b: fn(s, b)[1]["loss"], st, batch,
                         iters=steps, warmup=1)
        emit(f"dp_comm_ab_step_{wire}_p{P}", us, "cpu_wall_us_per_step",
             units="us", kind="measured")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower (not time) the wire; assert the byte model")
    ap.add_argument("--overlap", action="store_true",
                    help="also lower the streaming schedule and assert its "
                         "reduce-scatters interleave with backward GEMMs")
    args = ap.parse_args()

    # multi-device CPU mesh must be requested before jax initializes
    flag = "--xla_force_host_platform_device_count"
    if flag not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" {flag}={args.devices}")

    run(devices=args.devices, arch=args.arch, steps=args.steps,
        dry_run=args.dry_run, overlap=args.overlap)


if __name__ == "__main__":
    main()
