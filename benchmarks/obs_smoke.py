"""Observability smoke: exercise the whole repro.obs surface end to end.

Runs a short guarded training loop AND a continuous-batching serve trace,
both writing structured telemetry through JsonlSink, then:

  * renders the combined stream with `repro.obs.report` (the CLI reporter
    must understand every record kind the stack emits);
  * asserts the ZERO-HOST-SYNC structural gate on the instrumented train
    step — jaxpr + compiled HLO contain no callback / infeed / outfeed /
    send / recv ops, i.e. all device-side telemetry rides the loop's one
    existing per-step metrics fetch;
  * asserts the record inventory: one step record per train step with the
    per-site sat/flush matrix, a cast-ledger snapshot per traced program,
    a serve_tick stream, one request_done (with TTFT/TBT) per request, and
    a serve_summary matching the engine's aggregate counters.

  PYTHONPATH=src python benchmarks/obs_smoke.py                  # CI job
  PYTHONPATH=src python benchmarks/obs_smoke.py --out /tmp/obs   # keep files
"""
from __future__ import annotations

import argparse
import os
import re
import sys
import tempfile

_HOST_TRANSFER_TOKENS = ("callback", "infeed", "outfeed", "send", "recv")


def _host_transfer_counts(text: str):
    low = text.lower()
    return {t: len(re.findall(rf"\b{t}", low)) for t in _HOST_TRANSFER_TOKENS}


def run(train_steps: int = 5, requests: int = 20, out_dir=None):
    import jax
    import numpy as np

    try:
        import benchmarks.common  # noqa: F401  (path bootstrap only)
    except ModuleNotFoundError:      # invoked as `python benchmarks/...py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    from repro.compat import make_mesh
    from repro.configs import get_arch
    from repro.core import quant as quant_stats
    from repro.core.recipes import get_recipe
    from repro.data.pipeline import DataConfig
    from repro.models.lm import ParallelPlan, init_params
    from repro.obs.report import by_kind, load_records, render
    from repro.obs.sink import JsonlSink, Telemetry
    from repro.optim.adamw import AdamWConfig
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.scheduler import Request
    from repro.train.guards import GuardPlan, GuardPolicy
    from repro.train.loop import run as run_loop
    from repro.train.train_step import init_train_state, make_train_step

    if out_dir is None:
        out_dir = tempfile.mkdtemp(prefix="obs_smoke_")
    os.makedirs(out_dir, exist_ok=True)
    train_path = os.path.join(out_dir, "train.jsonl")
    serve_path = os.path.join(out_dir, "serve.jsonl")

    # -- guarded train loop with telemetry ---------------------------------
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=3e-3)
    recipe = get_recipe("fp8_flow")
    guard = GuardPlan()
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    raw = make_train_step(cfg, recipe, plan, opt, total_steps=100,
                          warmup_steps=5, guard=guard)
    state = init_train_state(cfg, opt, jax.random.key(0), guard=guard)

    tel = Telemetry(sinks=(JsonlSink(train_path),))
    with mesh:
        run_loop(jax.jit(raw), state, data, n_steps=train_steps,
                 log_every=1, guard_policy=GuardPolicy(), telemetry=tel)
    tel.emit_registry()
    tel.close()

    # -- zero-host-sync structural gate on the instrumented step -----------
    from repro.data.pipeline import make_batch
    batch = make_batch(data, 0)
    with mesh:
        jaxpr = str(jax.make_jaxpr(raw)(state, batch))
        hlo = jax.jit(raw).lower(state, batch).compile().as_text()
    for name, text in (("jaxpr", jaxpr), ("hlo", hlo)):
        counts = _host_transfer_counts(text)
        assert not any(counts.values()), (
            f"instrumented {name} contains host-transfer ops {counts} — "
            f"telemetry must ride the existing metrics fetch")
    assert "stage/" in hlo, "stage scopes missing from compiled HLO"
    print("[obs_smoke] zero-host-sync gate: jaxpr + HLO clean, "
          "stage scopes present")

    # -- serve trace with telemetry ----------------------------------------
    scfg = get_arch("qwen3_moe_235b").reduced()
    splan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    srecipe = get_recipe("fp8_flow")
    params = init_params(scfg, jax.random.key(0))
    ecfg = ServeConfig(max_batch=4, page_size=8, n_pages=64,
                       max_pages_per_req=6, token_budget=256,
                       prefill_buckets=(16,), fp8_kv=True, w8_weights=True)
    stel = Telemetry(sinks=(JsonlSink(serve_path),))
    eng = ServeEngine(scfg, srecipe, splan, params, ecfg, telemetry=stel)
    r = np.random.default_rng(0)
    reqs = [Request(prompt=list(r.integers(1, scfg.vocab,
                                           int(r.integers(4, 12)))),
                    max_new_tokens=4)
            for _ in range(requests)]
    results = eng.run(reqs, realtime=False)
    stats = results.stats
    stel.emit_registry()
    stel.close()
    assert len(results) == requests, "requests lost"
    assert stats["finished"] == requests

    # -- record inventory ---------------------------------------------------
    recs = load_records([train_path, serve_path])
    kinds = by_kind(recs)
    steps = kinds.get("step", [])
    assert len(steps) == train_steps, (len(steps), train_steps)
    for s in steps:
        assert {"device_ms", "fetch_ms", "loss"} <= set(s)
        assert set(s.get("quant_sites", {})) == set(quant_stats.STAT_SITES)
    assert len(kinds.get("cast_ledger", [])) >= 1
    assert len(kinds.get("request_done", [])) == requests
    assert all("ttft_ms" in d for d in kinds["request_done"])
    assert len(kinds.get("serve_tick", [])) == stats["ticks"]
    summ = kinds.get("serve_summary", [])
    assert len(summ) == 1 and summ[0]["finished"] == stats["finished"]
    print(f"[obs_smoke] record inventory: {len(steps)} steps, "
          f"{len(kinds['cast_ledger'])} cast ledgers, "
          f"{stats['ticks']} serve ticks, {requests} request_done")

    # -- the reporter renders the full stream -------------------------------
    n = render(recs)
    assert n == len(recs)
    print(f"obs_smoke: OK — {n} records rendered from "
          f"{os.path.basename(train_path)} + {os.path.basename(serve_path)}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--train-steps", type=int, default=5)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--out", default=None,
                    help="directory for the JSONL files (default: tmpdir)")
    args = ap.parse_args()
    run(train_steps=args.train_steps, requests=args.requests,
        out_dir=args.out)


if __name__ == "__main__":
    main()
