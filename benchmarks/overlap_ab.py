"""Overlapped vs synchronous EP dispatch A/B: exposed all-to-all time.

The synchronous ``moe_block`` exposes every dispatch/combine collective on
the MoE layer's critical path; ``moe_block_overlapped`` pipelines n_chunks
micro-chunks so a chunk's fused dispatch message flies while the previous
chunk's grouped FFN computes.  This bench verifies the overlapped path for
real (it LOWERS both blocks on a >=2-simulated-device CPU mesh and counts
the all-to-all ops in the jaxpr — 3 collectives/dispatch fused into 1) and
reports the v5e-modelled EXPOSED collective time per layer (no TPU fabric on
this container; wall-clock a2a overlap cannot be timed here).

  PYTHONPATH=src python benchmarks/overlap_ab.py --dry-run      # CI smoke
  PYTHONPATH=src python benchmarks/overlap_ab.py --devices 8 \
      --tokens 4096 --d-model 1024 --d-ff 512 --n-chunks 2 4

Exposed-time model (per layer, n chunks, per-chunk dispatch d, combine c,
grouped-FFN compute f):

  sync       n*(d + c)                 every byte on the critical path
  overlapped d + (n-1)*max(0, d+c-f) + c
             prologue + epilogue only, steady-state comm hides behind FFN

Strictly below sync for every n >= 2 (and equal at n=1 up to the fused
message's 2-launch saving, modelled via A2A_LAUNCH_US).
"""
from __future__ import annotations

import argparse
import os
import sys


A2A_LAUNCH_US = 6.0     # per-collective dispatch latency (DeepEP-class NIC)


def _count_a2a(fn, *args):
    """all-to-all ops in the closed jaxpr of fn(*args)."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    text = str(jaxpr)
    return text.count("all_to_all")


def run(devices: int = 2, tokens: int = 512, d_model: int = 256,
        d_ff: int = 128, n_experts: int = 4, top_k: int = 2,
        n_chunks=(2, 4), dry_run: bool = False, lower: bool = True):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    try:
        from benchmarks.common import emit, ici_model_us
    except ModuleNotFoundError:      # invoked as `python benchmarks/...py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        from benchmarks.common import emit, ici_model_us
    from repro.compat import make_mesh, shard_map
    from repro.core.fp8 import TILE
    from repro.core.moe import (MoEConfig, _round_up, moe_block,
                                moe_block_overlapped)
    from repro.core.recipes import get_recipe
    from repro.roofline.analysis import PEAK_FLOPS_FP8

    ndev = jax.device_count()
    if ndev < devices:
        print(f"overlap_ab: only {ndev} devices visible (wanted {devices}); "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count=N",
              file=sys.stderr)
        devices = ndev
    mesh = make_mesh((1, devices), ("data", "model"))
    EP = devices
    E = max(n_experts, EP)
    recipe = get_recipe("fp8_flow")
    cfg = MoEConfig(n_experts=E, top_k=top_k, d_model=d_model, d_ff=d_ff,
                    capacity_factor=1.25)
    T = tokens // devices               # local tokens per rank
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(tokens, d_model)), jnp.bfloat16)
    wr = jnp.asarray(r.normal(size=(d_model, E)) * 0.02, jnp.float32)
    w13 = jnp.asarray(r.normal(size=(E, d_model, 2 * d_ff)) * 0.05,
                      jnp.float32)
    w2 = jnp.asarray(r.normal(size=(E, d_ff, d_model)) * 0.05, jnp.float32)

    def sharded(block, **kw):
        def body(x, wr, w13, w2):
            y, _ = block(recipe, cfg, x, wr, w13, w2, **kw)
            return y
        return shard_map(body, mesh=mesh,
                         in_specs=(P(("data", "model"), None), P(None, None),
                                   P("model", None, None),
                                   P("model", None, None)),
                         out_specs=P(("data", "model"), None))

    # ---- real lowering check: the overlapped path must lower AND fuse the
    # per-chunk dispatch from 3 collectives into 1 ------------------------
    f_sync = sharded(moe_block)
    n_sync = _count_a2a(f_sync, x, wr, w13, w2)
    if lower:
        jax.jit(f_sync).lower(x, wr, w13, w2)

    results = []
    for n in n_chunks:
        f_ovl = sharded(moe_block_overlapped, n_chunks=n)
        n_ovl = _count_a2a(f_ovl, x, wr, w13, w2)
        if lower:
            jax.jit(f_ovl).lower(x, wr, w13, w2)   # the CI "it lowers" gate
        assert n_ovl == 2 * n, (n_ovl, n)          # 1 fused dispatch + 1
                                                   # combine per chunk
        results.append((n, n_ovl))
    assert n_sync == 5, n_sync                     # d, s, expert, p, combine

    # ---- v5e exposed-time model -----------------------------------------
    def exposed_us(n):
        Tc = T // n
        C_send = _round_up(max(int(Tc * top_k / EP * cfg.capacity_factor), 8),
                           8)
        R = EP * C_send
        C_exp = _round_up(max(R // (E // EP), 8), 128)
        # fused message bytes: e4m3 payload + f32 po2 scales + expert id + p
        disp_b = R * (d_model + 4 * d_model // TILE + 8)
        comb_b = R * d_model * 2                   # bf16 combine
        d_us = ici_model_us(disp_b) + A2A_LAUNCH_US
        c_us = ici_model_us(comb_b) + A2A_LAUNCH_US
        ffn_flops = (E // EP) * C_exp * (2 * d_model * 2 * d_ff
                                         + 2 * d_ff * d_model)
        f_us = ffn_flops / PEAK_FLOPS_FP8 * 1e6
        sync_d_us = (ici_model_us(R * n * d_model)
                     + ici_model_us(R * n * 4 * d_model // TILE)
                     + ici_model_us(R * n * 8) + 3 * A2A_LAUNCH_US)
        sync_us = sync_d_us + ici_model_us(R * n * d_model * 2) + A2A_LAUNCH_US
        ovl_us = d_us + (n - 1) * max(0.0, d_us + c_us - f_us) + c_us
        return sync_us, ovl_us

    for n, n_ovl in results:
        sync_us, ovl_us = exposed_us(n)
        if ovl_us >= sync_us:
            # physically possible at compute-poor shapes (the extra launch
            # latency of 2n collectives is not hidden when the per-chunk FFN
            # is shorter than the per-chunk comm) — a modelling result the
            # bench should SURFACE, but the acceptance gate (dry-run default
            # shapes) must hold strictly.
            msg = (f"n={n}: overlapped exposed {ovl_us:.1f}us >= sync "
                   f"{sync_us:.1f}us (per-chunk FFN too short to hide comm)")
            if dry_run:
                raise AssertionError(msg)
            print(f"overlap_ab: WARNING {msg}", file=sys.stderr)
        emit(f"overlap_ab_ep{devices}_T{tokens}_n{n}", ovl_us,
             f"sync_exposed_us={sync_us:.1f};overlap_exposed_us={ovl_us:.1f};"
             f"speedup={sync_us / ovl_us:.2f}x;"
             f"a2a_ops_sync={n_sync};a2a_ops_overlapped={n_ovl};"
             f"launches_per_dispatch=1(vs 3)",
             units="us", kind="model")
    if dry_run:
        print("overlap_ab: dry-run OK (lowered sync + overlapped on "
              f"{devices} devices; exposed-comm model strictly better)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--tokens", type=int, default=4096)
    ap.add_argument("--d-model", type=int, default=1024)
    ap.add_argument("--d-ff", type=int, default=2048)
    ap.add_argument("--experts", type=int, default=8)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--n-chunks", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes; lower (not time) the overlapped path")
    args = ap.parse_args()

    # multi-device CPU mesh must be requested before jax initializes
    flag = "--xla_force_host_platform_device_count"
    if flag not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" {flag}={args.devices}")

    if args.dry_run:
        run(devices=args.devices, tokens=256, d_model=256, d_ff=128,
            n_experts=max(args.experts, args.devices), top_k=2,
            n_chunks=[2], dry_run=True)
    else:
        run(devices=args.devices, tokens=args.tokens, d_model=args.d_model,
            d_ff=args.d_ff, n_experts=args.experts, top_k=args.top_k,
            n_chunks=args.n_chunks)


if __name__ == "__main__":
    main()
