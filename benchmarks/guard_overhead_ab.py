"""Guarded vs unguarded train-step A/B: the guardrails must be (near) free.

The whole design constraint of train/guards.py is that detection rides the
metrics fetch the loop ALREADY does every step — anomaly folding happens
in-jit on replica-uniform scalars, FP8 site stats are a 2-float max-merge
threaded through the existing carries, and the wire guard is a lax.cond on
a pmax'd predicate.  Nothing may add a host round-trip.

This bench builds the SAME tiny model twice (guard=None vs GuardPlan) and
checks exactly that:

  structural (the CI gate, --dry-run):
    * the guarded jaxpr + compiled HLO contain ZERO additional host
      transfer ops (callbacks / infeed / outfeed / host send-recv) over
      the unguarded build — detection is computed on device and fetched
      with the loss;
    * the unguarded jaxpr is free of guard artifacts (no uint32 anomaly
      fold, no quantize-site stat outputs) — guards off costs nothing.

  measured (full run):
    * median step wall-clock for both builds -> overhead %.

  PYTHONPATH=src python benchmarks/guard_overhead_ab.py --dry-run   # CI
  PYTHONPATH=src python benchmarks/guard_overhead_ab.py --steps 5
"""
from __future__ import annotations

import argparse
import os
import re
import sys

# ops that move data between host and device: any of these appearing in the
# guarded build but not the unguarded one means detection broke the
# zero-extra-syncs contract
_HOST_TRANSFER_TOKENS = ("callback", "infeed", "outfeed", "send", "recv")


def _host_transfer_counts(text: str):
    low = text.lower()
    return {t: len(re.findall(rf"\b{t}", low)) for t in _HOST_TRANSFER_TOKENS}


def run(arch: str = "qwen15_05b", steps: int = 5, dry_run: bool = False):
    import jax

    try:
        from benchmarks.common import emit, time_fn
    except ModuleNotFoundError:      # invoked as `python benchmarks/...py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        from benchmarks.common import emit, time_fn
    from repro.compat import make_mesh
    from repro.configs import get_arch
    from repro.core.recipes import get_recipe
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models.lm import ParallelPlan
    from repro.optim.adamw import AdamWConfig
    from repro.train.guards import GuardPlan
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_arch(arch).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=3e-3)
    recipe = get_recipe("fp8_flow")
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)

    builds = {}
    for name, guard in [("unguarded", None), ("guarded", GuardPlan())]:
        raw = make_train_step(cfg, recipe, plan, opt,
                              total_steps=1000, warmup_steps=5, guard=guard)
        state = init_train_state(cfg, opt, jax.random.key(0), guard=guard)
        batch = make_batch(data, 0)
        with mesh:
            jaxpr = str(jax.make_jaxpr(raw)(state, batch))
            lowered = jax.jit(raw).lower(state, batch)
            hlo = lowered.compile().as_text()
        builds[name] = dict(raw=raw, state=state, batch=batch,
                            jaxpr=jaxpr, hlo=hlo)

    # -- structural gate ----------------------------------------------------
    for text_key in ("jaxpr", "hlo"):
        cu = _host_transfer_counts(builds["unguarded"][text_key])
        cg = _host_transfer_counts(builds["guarded"][text_key])
        extra = {t: cg[t] - cu[t] for t in cu if cg[t] > cu[t]}
        assert not extra, (
            f"guarded {text_key} adds host transfer ops {extra} — the "
            f"guardrails must not introduce device->host syncs")
        print(f"[guard_ab] {text_key}: host-transfer ops guarded == "
              f"unguarded ({ {t: cg[t] for t in cg} })")

    ju = builds["unguarded"]["jaxpr"]
    assert "guard" not in ju and "u32" not in ju.split("let")[0], \
        "unguarded jaxpr carries guard artifacts"
    print("[guard_ab] unguarded jaxpr is guard-free")

    eq_u = ju.count("\n")
    eq_g = builds["guarded"]["jaxpr"].count("\n")
    print(f"[guard_ab] jaxpr lines: unguarded={eq_u} guarded={eq_g} "
          f"(+{eq_g - eq_u} for detection)")

    if dry_run:
        print("guard_overhead_ab: DRY-RUN OK — zero extra host transfers")
        return

    # -- measured overhead --------------------------------------------------
    times = {}
    with mesh:
        for name, b in builds.items():
            step = jax.jit(b["raw"])
            st = b["state"]

            def one(st=st, step=step, batch=b["batch"]):
                new_st, metrics = step(st, batch)
                return metrics["loss"]

            times[name] = time_fn(one, iters=steps, warmup=2)
            emit(f"train_step_{name}", times[name], f"arch={arch}",
                 units="us", kind="measured")
    ovh = (times["guarded"] / times["unguarded"] - 1.0) * 100.0
    emit("guard_overhead", times["guarded"] - times["unguarded"],
         f"overhead_pct={ovh:.2f}",
         units="us", kind="measured")
    print(f"[guard_ab] guard overhead: {ovh:+.2f}% wall-clock")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args()
    run(arch=args.arch, steps=args.steps, dry_run=args.dry_run)


if __name__ == "__main__":
    main()
