"""Fig. 3/4 reproduction: fused permute+padding vs separate kernels.

Separate = one gather pass (permute) + one pad/copy pass; fused = a single
pass writing the padded layout directly.  We compare compiled HLO bytes
(the TPU predictor) and CPU wall time of both jitted variants, forward
(permute+pad) and backward (unpermute+unpad = scatter into token order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bytes_of, emit, hbm_model_us, time_fn

CASES = [(8192, 2048, 10240), (24576, 2048, 28672), (32768, 7168, 36864)]


def run():
    for (t, d, n_out) in CASES:
        r = np.random.default_rng(0)
        x = jnp.asarray(r.normal(size=(t, d)).astype(np.float32)
                        ).astype(jnp.float8_e4m3fn)
        row_map = np.full(n_out, -1, np.int32)
        perm = r.permutation(t)
        row_map[:t] = perm
        row_map = jnp.asarray(row_map)

        def fused(x, row_map):
            valid = (row_map >= 0)[:, None]
            rows = jnp.take(x, jnp.maximum(row_map, 0), axis=0)
            return jnp.where(valid, rows, jnp.zeros((), x.dtype))

        def separate(x, row_map):
            # permute pass materializes the reordered tensor, THEN a second
            # pass writes it into the padded buffer (two HBM round trips)
            permuted = jnp.take(x, jnp.maximum(row_map[:t], 0), axis=0)
            permuted = permuted * jnp.ones((), x.dtype)   # force materialize
            out = jnp.zeros((n_out, d), x.dtype)
            return jax.lax.dynamic_update_slice(out, permuted, (0, 0))

        ff = jax.jit(fused)
        fs = jax.jit(separate)
        us_f = time_fn(ff, x, row_map)
        us_s = time_fn(fs, x, row_map)
        b_f = bytes_of(ff.lower(x, row_map).compile())
        b_s = bytes_of(fs.lower(x, row_map).compile())
        emit(f"fig3_permute_pad_fused_{t}x{d}", us_f,
             f"model_us={hbm_model_us(b_f):.1f}")
        emit(f"fig3_permute_pad_separate_{t}x{d}", us_s,
             f"model_us={hbm_model_us(b_s):.1f};"
             f"cpu_speedup={us_s / us_f:.2f}x;"
             f"tpu_model_speedup={b_s / b_f:.2f}x")

        # backward: unpermute+unpad (scatter-add into token order)
        y = jnp.asarray(r.normal(size=(n_out, d)).astype(np.float32)
                        ).astype(jnp.bfloat16)

        def fused_b(y, row_map):
            seg = jnp.where(row_map >= 0, row_map, t)
            return jax.ops.segment_sum(y.astype(jnp.float32), seg,
                                       num_segments=t + 1)[:t]

        def separate_b(y, row_map):
            trimmed = y[:t] * jnp.ones((), y.dtype)      # unpad pass
            seg = jnp.where(row_map[:t] >= 0, row_map[:t], t)
            return jax.ops.segment_sum(trimmed.astype(jnp.float32), seg,
                                       num_segments=t + 1)[:t]

        fb = jax.jit(fused_b)
        sb = jax.jit(separate_b)
        us_fb = time_fn(fb, y, row_map)
        us_sb = time_fn(sb, y, row_map)
        b_fb = bytes_of(fb.lower(y, row_map).compile())
        b_sb = bytes_of(sb.lower(y, row_map).compile())
        emit(f"fig4_unpermute_fused_{t}x{d}", us_fb,
             f"model_us={hbm_model_us(b_fb):.1f}")
        emit(f"fig4_unpermute_separate_{t}x{d}", us_sb,
             f"model_us={hbm_model_us(b_sb):.1f};"
             f"cpu_speedup={us_sb / us_fb:.2f}x;"
             f"tpu_model_speedup={b_sb / b_fb:.2f}x")


if __name__ == "__main__":
    run()
