"""Shared benchmark utilities: wall-clock timing of jitted callables on CPU
plus TPU-v5e cost MODELS derived from compiled HLO (this container has no
TPU; kernel-level tables report measured CPU latency ratios AND the
bytes-moved model that predicts the TPU ratio — see EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.roofline.analysis import HBM_BW, ICI_BW


def time_fn(fn, *args, iters=5, warmup=2):
    """Median wall-clock microseconds per call (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def hbm_model_us(nbytes: float) -> float:
    """Ideal TPU-v5e time for an HBM-bound op moving `nbytes`."""
    return nbytes / HBM_BW * 1e6


def ici_model_us(nbytes: float) -> float:
    return nbytes / ICI_BW * 1e6


def bytes_of(compiled) -> float:
    return float(compiled.cost_analysis().get("bytes accessed", 0.0))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
