"""Shared benchmark utilities: wall-clock timing of jitted callables on CPU
plus TPU-v5e cost MODELS derived from compiled HLO (this container has no
TPU; kernel-level tables report measured CPU latency ratios AND the
bytes-moved model that predicts the TPU ratio — see EXPERIMENTS.md).

Result emission is unified through ``emit``: every result prints the legacy
``name,value,derived`` CSV line AND (when REPRO_BENCH_JSONL names a file)
appends ONE structured 'bench' record per result — name, value, units, and
whether the number is a cost-model prediction or a measurement — which
`python -m repro.obs.report` renders alongside train/serve telemetry."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.roofline.analysis import HBM_BW, ICI_BW


def time_fn(fn, *args, iters=5, warmup=2):
    """Median wall-clock microseconds per call (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def hbm_model_us(nbytes: float) -> float:
    """Ideal TPU-v5e time for an HBM-bound op moving `nbytes`."""
    return nbytes / HBM_BW * 1e6


def ici_model_us(nbytes: float) -> float:
    return nbytes / ICI_BW * 1e6


def bytes_of(compiled) -> float:
    return float(compiled.cost_analysis().get("bytes accessed", 0.0))


def emit(name: str, us_per_call: float, derived: str = "", *,
         units: str = "us", kind: str = "measured"):
    """One benchmark result.  Positional args keep the legacy CSV contract
    (`name,value,derived`); `units` and `kind` ('measured' CPU wall clock vs
    'model' analytic/HLO-derived prediction) land in the JSONL record."""
    print(f"{name},{us_per_call:.1f},{derived}")
    path = os.environ.get("REPRO_BENCH_JSONL")
    if path:
        rec = {"t": time.time(), "kind": "bench", "name": name,
               "value": float(us_per_call), "units": units,
               "source": kind, "derived": derived}
        with open(path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
