"""Activation-residency A/B (train/memory.py MemoryPlan).

Measures, for each remat policy, the REAL residual set a decoder MoE
layer's backward keeps live — ``jax.ad_checkpoint``'s saved-residual
introspection, classified into fp8 payload / po2 scales / wide bf16 /
small — and checks the paper-memory acceptance gate:

  * ``fp8_resident`` keeps >= 3x fewer checkpointed-activation bytes per
    MoE layer than ``full`` (bf16 stage) remat;
  * residency invariant: under ``fp8_resident`` NO saved bf16/f32
    activation is wider than the residual stream (everything wide is
    e4m3 payload bits + po2 scales);
  * the analytic bytes model (memory.layer_saved_bytes_model — the README
    table) tracks the measurement.

Also measures the compile-time side of the ROADMAP follow-on ("unrolled vs
scan at real depth — checkpoint-of-pairs"): trace+lower wall time of the
scan driver vs the unrolled staged driver vs unrolled+pair at depth, and
counts remat sites in the jaxpr (pair must halve the unrolled count).

  PYTHONPATH=src python benchmarks/remat_mem_ab.py --dry-run     # CI smoke
  PYTHONPATH=src python benchmarks/remat_mem_ab.py --steps 20    # + parity
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

POLICIES = ("none", "full", "fp8_resident", "pair")


def run(arch: str = "qwen3_moe_235b", batch: int = 4, seq: int = 128,
        depth: int = 8, steps: int = 0, dry_run: bool = False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    try:
        from benchmarks.common import emit
    except ModuleNotFoundError:      # invoked as `python benchmarks/...py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        from benchmarks.common import emit
    from repro.configs import get_arch
    from repro.core.recipes import get_recipe
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models.lm import NO_PLAN, forward, init_params
    from repro.train.memory import (layer_saved_bytes_model,
                                    measure_layer_residuals)

    cfg = get_arch(arch).reduced()
    recipe = get_recipe("fp8_flow")
    plan = NO_PLAN
    T = batch * seq

    # ---- saved-residual bytes per MoE layer, per policy ------------------
    # (measure_layer_residuals is the SAME harness tests/test_remat.py
    # gates on — benchmark and test account the same jaxpr)
    act_bytes, wide_bf16 = {}, {}
    for pol in POLICIES:
        cls = measure_layer_residuals(cfg, recipe, pol, batch=batch, seq=seq)
        act_bytes[pol] = (cls["fp8"] + cls["scale"] + cls["wide_bf16"]
                          + cls["small"])
        wide_bf16[pol] = cls["wide_bf16"]
        model = layer_saved_bytes_model(cfg, T, pol)
        emit(f"remat_mem_{arch}_{pol}", float(act_bytes[pol]),
             f"saved_act_B={act_bytes[pol]};fp8_B={cls['fp8']};"
             f"scale_B={cls['scale']};wide_bf16_B={cls['wide_bf16']};"
             f"small_B={cls['small']};model_B={model:.0f}",
             units="bytes", kind="measured")

    ratio = act_bytes["full"] / max(act_bytes["fp8_resident"], 1)
    emit(f"remat_mem_ratio_{arch}", ratio,
         f"full_B={act_bytes['full']};fp8_resident_B="
         f"{act_bytes['fp8_resident']};gate=3.0x",
         units="ratio", kind="measured")
    assert ratio >= 3.0, \
        f"fp8_resident saves only {ratio:.2f}x fewer activation bytes " \
        f"than full bf16 remat (< 3x gate)"
    # residency invariant: nothing wide crosses the boundary in bf16
    assert wide_bf16["fp8_resident"] == 0, \
        f"fp8_resident saved {wide_bf16['fp8_resident']} wide bf16 bytes"
    # ordering sanity: pair <= fp8_resident <= full <= none
    assert act_bytes["pair"] <= act_bytes["fp8_resident"] \
        <= act_bytes["full"] <= act_bytes["none"], act_bytes

    # ---- compile-time: scan vs unrolled vs unrolled+pair at depth --------
    glen = len(cfg.pattern)
    d = depth // glen * glen or glen
    data = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    b = make_batch(data, 0)
    trace_us, remat_sites = {}, {}
    for name, staged, pol in (("scan", False, "full"),
                              ("unrolled", True, "full"),
                              ("pair", True, "pair")):
        c = dataclasses.replace(cfg, n_layers=d, n_dense_layers=0,
                                remat_policy=pol)
        p_d = init_params(c, jax.random.key(0))
        pl = dataclasses.replace(plan, stage_layers=staged)

        def loss(p, bb, _c=c, _pl=pl):
            return forward(_c, recipe, _pl, p, bb)[0]

        t0 = time.perf_counter()
        jx = str(jax.make_jaxpr(jax.value_and_grad(loss))(p_d, b))
        jax.jit(loss).lower(p_d, b)
        trace_us[name] = (time.perf_counter() - t0) * 1e6
        remat_sites[name] = jx.count("remat2[")
        emit(f"remat_compile_{name}_d{d}", trace_us[name],
             f"trace_lower_us={trace_us[name]:.0f};"
             f"remat_sites={remat_sites[name]}",
             units="us", kind="measured")
    # pair halves the unrolled trace sites (the ROADMAP follow-on's point)
    assert remat_sites["pair"] <= remat_sites["unrolled"] // 2 + 1, \
        remat_sites

    if dry_run:
        print(f"remat_mem_ab: dry-run OK ({arch}: fp8_resident keeps "
              f"{ratio:.2f}x fewer checkpointed-activation bytes/MoE layer "
              f"than full bf16 remat; 0 wide bf16 saves; pair "
              f"{remat_sites['pair']} vs unrolled {remat_sites['unrolled']} "
              f"remat sites at depth {d})")
        return

    # ---- optional: short training parity across policies -----------------
    if steps:
        losses = {}
        for pol in POLICIES:
            c = dataclasses.replace(cfg, remat_policy=pol)
            from repro.optim.adamw import AdamWConfig
            from repro.train.train_step import (init_train_state,
                                                make_train_step)
            opt = AdamWConfig(lr=1e-3)
            state = init_train_state(c, opt, jax.random.key(0))
            step = jax.jit(make_train_step(c, recipe, plan, opt,
                                           total_steps=steps,
                                           warmup_steps=2))
            ls = []
            for i in range(steps):
                state, m = step(state, make_batch(data, i))
                ls.append(float(m["loss"]))
            losses[pol] = np.array(ls)
            emit(f"remat_parity_{pol}", float(losses[pol][-1]),
                 f"loss_first={losses[pol][0]:.5f};"
                 f"loss_last={losses[pol][-1]:.5f}",
                 units="loss", kind="measured")
        ref = losses["none"]
        for pol in POLICIES:
            rel = np.max(np.abs(losses[pol] - ref) / np.abs(ref))
            assert rel < 1e-5, (pol, rel)
        print(f"remat_mem_ab: {steps}-step loss parity OK (<1e-5 rel)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_235b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--depth", type=int, default=8,
                    help="stack depth for the compile-time A/B")
    ap.add_argument("--steps", type=int, default=0,
                    help="if > 0, also run the N-step loss-parity check")
    ap.add_argument("--dry-run", action="store_true",
                    help="bytes model + compile-time only (CI smoke)")
    args = ap.parse_args()
    run(arch=args.arch, batch=args.batch, seq=args.seq, depth=args.depth,
        steps=args.steps, dry_run=args.dry_run)


if __name__ == "__main__":
    main()
