"""Masked vs padded grouped-GEMM expert pipeline A/B under routing skew.

The padded layout runs every capacity slot through the MXU (E * C rows per
grouped GEMM, regardless of how many tokens actually routed to each
expert).  The masked layout prefetches the per-expert live-row counts into
SMEM and skips whole M-tiles beyond ``masked_m[e]``, so modeled expert
FLOPs scale with sum_e round_up(m_e, BM) instead of E * C.  Dead-tile
outputs are the zeros/scale-1.0 bits the padded kernels emit for
zero-padded rows, so the two layouts are bitwise-interchangeable and the
A/B is pure throughput.

The second table is the fig5-style fused-epilogue A/B: fusing SwiGLU +
row-wise e4m3 re-quantize into GEMM-1's last K-step keeps the bf16 island
``h`` out of HBM entirely (the unfused pipeline writes h, re-reads it, and
writes the e4m3 payload; the fused epilogue writes only payload + scale).

Usage:
  PYTHONPATH=src python benchmarks/masked_moe_ab.py --dry-run    # CI smoke
  PYTHONPATH=src python benchmarks/masked_moe_ab.py              # timed

Acceptance gates (checked in BOTH modes):
  * at 4:1 hot/cold routing skew the masked layout models >= 1.5x fewer
    expert FLOPs than padded;
  * the fused epilogue removes the full bf16-h HBM round trip;
  * (dry-run) masked kernels are bitwise the padded kernels on a skewed
    dispatch buffer with an empty expert.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, hbm_model_us, time_fn
except ModuleNotFoundError:          # invoked as `python benchmarks/...py`
    sys.path.insert(0, os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import emit, hbm_model_us, time_fn
from repro.core.quant import quantize
from repro.core.fp8 import TILE
from repro.kernels import ops
from repro.kernels.grouped_gemm_fp8 import BM


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _skew_loads(kind: str, E: int, C: int) -> np.ndarray:
    """Per-expert live-row counts for each routing pattern (capacity C is
    sized to the hottest expert, as the dispatch plan does)."""
    if kind == "uniform":
        m = np.full(E, C)
    elif kind == "skew4":                    # 4:1 hot/cold, hot fills C
        m = np.full(E, C // 4)
        m[0] = C
    elif kind == "zero_expert":
        m = np.full(E, C)
        m[0] = 0
    elif kind == "all_to_one":
        m = np.zeros(E, dtype=np.int64)
        m[0] = C
    else:
        raise ValueError(kind)
    return m.astype(np.int64)


def modeled_tile_ratio(loads: np.ndarray, C: int) -> tuple[float, float, float]:
    """(padded M-rows, masked M-rows, padded/masked FLOPs ratio) for one
    grouped GEMM.  FLOPs are proportional to MXU-visited rows; the masked
    kernel visits round_up(m_e, BM) rows per expert, the padded one C."""
    E = len(loads)
    padded = float(E * _round_up(C, BM))
    masked = float(sum(_round_up(int(m), BM) for m in loads))
    return padded, max(masked, float(BM)), padded / max(masked, float(BM))


def fused_h_bytes(E: int, C: int, F: int) -> tuple[float, float]:
    """(unfused, fused) modeled HBM bytes for the GEMM-1 epilogue stage:
    unfused writes bf16 h (E,C,2F), re-reads it, writes e4m3 (E,C,F) +
    f32 scales; fused skips the h round trip entirely."""
    h = E * C * 2 * F * 2.0               # bf16 payload
    out = E * C * F * 1.0 + E * C * (F // TILE) * 4.0
    return h + h + out, out


def run(dry_run: bool = False):
    E, C, K, F = 8, 1024, 2048, 1024      # training-shape model
    for kind in ("uniform", "skew4", "zero_expert", "all_to_one"):
        loads = _skew_loads(kind, E, C)
        padded, masked, ratio = modeled_tile_ratio(loads, C)
        emit(f"masked_moe_flops_{kind}", 0.0,
             f"padded_rows={padded:.0f};masked_rows={masked:.0f};"
             f"modeled_flop_saving={ratio:.2f}x",
             units="rows", kind="model")
        if kind == "skew4":
            assert ratio >= 1.5, (
                f"masked layout must model >=1.5x FLOP saving at 4:1 skew, "
                f"got {ratio:.2f}x")
    unfused_b, fused_b = fused_h_bytes(E, C, F)
    assert unfused_b - fused_b == 2 * (E * C * 2 * F * 2.0), "h round trip"
    emit("masked_moe_fused_epilogue_hbm", 0.0,
         f"unfused_model_us={hbm_model_us(unfused_b):.1f};"
         f"fused_model_us={hbm_model_us(fused_b):.1f};"
         f"h_bytes_saved={unfused_b - fused_b:.0f};"
         f"tpu_model_speedup={unfused_b / fused_b:.2f}x",
         units="us", kind="model")

    # bitwise parity smoke on a real (interpret-mode) kernel invocation:
    # skewed counts incl. an empty expert, dead dispatch slots zeroed.
    Es, Cs, Ks, Ns = 2, 128, 128, 128
    r = np.random.default_rng(0)
    mm = jnp.asarray([0, 96], jnp.int32)
    x = r.normal(size=(Es, Cs, Ks)).astype(np.float32)
    x[np.arange(Cs)[None, :] >= np.asarray(mm)[:, None]] = 0.0
    qx = quantize(jnp.asarray(x), (1, 1, TILE), tag="bench")
    qw = quantize(jnp.asarray(
        r.normal(size=(Es, Ks, Ns)).astype(np.float32) * 0.05),
        (1, TILE, TILE), tag="bench")
    out_p = ops.grouped_gemm_fp8(qx, qw)
    out_m = ops.grouped_gemm_fp8_masked(qx, qw, mm)
    assert np.array_equal(np.asarray(out_m).view(np.uint16),
                          np.asarray(out_p).view(np.uint16)), \
        "masked kernel diverged from padded on zero-padded dispatch buffer"
    emit("masked_moe_parity_smoke", 0.0,
         f"bitwise_equal=True;E={Es};C={Cs};"
         f"masked_m={[int(v) for v in np.asarray(mm)]}",
         units="bool", kind="measured")
    if dry_run:
        print(f"masked_moe_ab: dry-run OK (4:1-skew modeled saving "
              f"{ratio_at('skew4', E, C):.2f}x >= 1.5x; parity smoke bitwise)")
        return

    # timed CPU A/B (interpret mode; the model above predicts the TPU ratio)
    Ct = 512
    mm_t = jnp.asarray(_skew_loads("skew4", Es, Ct), jnp.int32)
    xt = r.normal(size=(Es, Ct, Ks)).astype(np.float32)
    xt[np.arange(Ct)[None, :] >= np.asarray(mm_t)[:, None]] = 0.0
    qxt = quantize(jnp.asarray(xt), (1, 1, TILE), tag="bench")
    us_p = time_fn(lambda a: ops.grouped_gemm_fp8(a, qw), qxt,
                   iters=3, warmup=1)
    us_m = time_fn(lambda a: ops.grouped_gemm_fp8_masked(a, qw, mm_t), qxt,
                   iters=3, warmup=1)
    _, _, r_small = modeled_tile_ratio(np.asarray(mm_t), Ct)
    emit("masked_moe_gemm_skew4_cpu", us_m,
         f"padded_us={us_p:.1f};modeled_tpu_saving={r_small:.2f}x",
         units="us", kind="measured")


def ratio_at(kind: str, E: int, C: int) -> float:
    return modeled_tile_ratio(_skew_loads(kind, E, C), C)[2]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="models + bitwise parity smoke only (CI)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(dry_run=args.dry_run)
