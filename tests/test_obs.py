"""Unified telemetry layer (repro.obs): registry semantics, sink round
trips, the per-site FP8 stats matrix riding the existing carries, and the
ZERO-HOST-SYNC structural gate — observability must never add a device->
host transfer or an activation cast to the step program.

The gate mirrors benchmarks/guard_overhead_ab.py: count host-transfer op
tokens (callback/infeed/outfeed/send/recv) in the jaxpr and compiled HLO of
the fully instrumented step (named stage scopes + per-site stats + guard
bitmask) and require ZERO — all device telemetry rides the loop's one
existing per-step metrics fetch.
"""
import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import casts
from repro.core import quant as quant_stats
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig, make_batch
from repro.models.lm import ParallelPlan, forward, init_params
from repro.obs.metrics import Counter, Gauge, Histogram, Registry, po2_buckets
from repro.obs.report import by_kind, load_records, render
from repro.obs.sink import (JsonlSink, MemorySink, Telemetry, null_telemetry)
from repro.obs.trace import STAGES, annotate, stage_annotation
from repro.optim.adamw import AdamWConfig
from repro.train.guards import GuardPlan, GuardPolicy
from repro.train.loop import run as run_loop
from repro.train.train_step import init_train_state, make_train_step

_HOST_TRANSFER_TOKENS = ("callback", "infeed", "outfeed", "send", "recv")


def _host_transfer_counts(text: str):
    low = text.lower()
    return {t: len(re.findall(rf"\b{t}", low)) for t in _HOST_TRANSFER_TOKENS}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_po2_buckets_monotone():
    edges = po2_buckets(-3, 5)
    assert edges[0] == 2.0 ** -3 and edges[-1] == 2.0 ** 5
    assert all(b == 2 * a for a, b in zip(edges, edges[1:]))


def test_counter_monotonic():
    c = Counter("n")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_observe_and_quantile():
    h = Histogram("lat", po2_buckets(0, 6))    # edges 1..64
    for v in (0.5, 3.0, 3.5, 40.0, 1000.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(1047.0)
    assert h.mean == pytest.approx(1047.0 / 5)
    # p50 lands in the bucket holding the 3rd of 5 observations
    assert h.quantile(0.5) <= 8.0
    # overflow observations clamp to the top edge (conservative)
    assert h.quantile(1.0) == 64.0


def test_histogram_merge_is_countwise():
    edges = po2_buckets(0, 4)
    a, b = Histogram("x", edges), Histogram("x", edges)
    for v in (1.5, 3.0):
        a.observe(v)
    b.observe(12.0)
    a.merge(b)
    assert a.count == 3 and a.sum == pytest.approx(16.5)
    with pytest.raises(ValueError):
        a.merge(Histogram("x", po2_buckets(0, 5)))


def test_registry_get_or_create_and_labels():
    r = Registry()
    c1 = r.counter("ticks", labels={"phase": "train"})
    c2 = r.counter("ticks", labels={"phase": "train"})
    c3 = r.counter("ticks", labels={"phase": "serve"})
    assert c1 is c2 and c1 is not c3
    with pytest.raises(TypeError):
        r.gauge("ticks", labels={"phase": "train"})


def test_prometheus_exposition():
    r = Registry()
    r.counter("steps_total").inc(3)
    r.gauge("loss").set(2.5)
    h = r.histogram("span_ms", po2_buckets(0, 2))
    h.observe(1.5)
    h.observe(100.0)
    text = r.to_prometheus()
    assert "# TYPE steps_total counter" in text
    assert "steps_total 3" in text
    assert "loss 2.5" in text
    # cumulative buckets + the +Inf catch-all, then _sum/_count
    assert 'span_ms_bucket{le="+Inf"} 2' in text
    assert "span_ms_count 2" in text


# ---------------------------------------------------------------------------
# sinks + telemetry facade
# ---------------------------------------------------------------------------
def test_jsonl_sink_round_trip(tmp_path):
    path = tmp_path / "run.jsonl"
    tel = Telemetry(sinks=(JsonlSink(str(path)),))
    tel.event("guard", msg="[guard] step=4 event=skip", step=4,
              flags=int(np.uint32(3)))
    tel.step(0, {"loss": 1.25}, spans={"device": 10.0, "fetch": 1.0},
             extra={"quant_sites": {"q_entry_mlp": {"sat": 0.0,
                                                    "flush": 0.0}}})
    tel.close()
    recs = load_records([str(path)])
    kinds = by_kind(recs)
    assert len(kinds["guard"]) == 1
    assert kinds["guard"][0]["msg"] == "[guard] step=4 event=skip"
    assert kinds["guard"][0]["flags"] == 3         # numpy scalar -> int
    step = kinds["step"][0]
    assert step["loss"] == 1.25 and step["device_ms"] == 10.0
    assert step["quant_sites"]["q_entry_mlp"]["flush"] == 0.0


def test_memory_sink_ring_and_event_rendering():
    sink = MemorySink(capacity=3)
    lines = []
    tel = Telemetry(sinks=(sink,), log_fn=lines.append)
    for i in range(5):
        tel.event("tick", msg=f"line {i}", i=i)
    assert len(sink.records) == 3                  # bounded ring
    assert [r["i"] for r in sink.of_kind("tick")] == [2, 3, 4]
    assert lines == [f"line {i}" for i in range(5)]  # msg verbatim


def test_null_telemetry_still_logs():
    lines = []
    tel = null_telemetry(log_fn=lines.append)
    assert not tel.enabled
    tel.event("x", msg="human line")
    tel.counter("n").inc()
    assert lines == ["human line"]


def test_report_renders_mixed_stream(tmp_path):
    path = tmp_path / "mix.jsonl"
    with open(path, "w") as f:
        for rec in (
            {"t": 0.0, "kind": "step", "step": 0, "loss": 2.0,
             "device_ms": 9.0, "fetch_ms": 1.0, "total_ms": 10.5,
             "quant_sites": {"dp_wire": [0.0, 0.1]}},
            {"t": 1.0, "kind": "guard", "step": 0, "event": "skip",
             "flags": 1, "flag_names": "nonfinite_loss"},
            {"t": 2.0, "kind": "cast_ledger", "fn": "train_step",
             "activation_casts": 2, "fused_casts": 7, "total": 9,
             "by_tag": {"quantize:q_entry": 2}},
            {"t": 3.0, "kind": "serve_tick", "n_decode": 3,
             "kv_used_pages": 7},
            {"t": 4.0, "kind": "request_done", "rid": 0, "ttft_ms": 50.0,
             "tbt_ms_mean": 5.0, "n_tokens": 8},
            {"t": 5.0, "kind": "bench", "name": "fig1", "value": 3.0,
             "units": "us", "source": "measured", "derived": ""},
        ):
            f.write(json.dumps(rec) + "\n")
    out = []
    n = render(load_records([str(path)]), out=out.append)
    assert n == 6
    text = "\n".join(out)
    for needle in ("train: 1 steps", "host fetch", "guard events",
                   "cast-ledger", "serve:", "TTFT", "benchmark records"):
        assert needle in text, text


# ---------------------------------------------------------------------------
# per-site FP8 stats: the (N_SITES, 2) matrix rides the existing carries
# ---------------------------------------------------------------------------
def test_site_stats_shape_and_maxima():
    z = quant_stats.zero_stats()
    assert z.shape == (len(quant_stats.STAT_SITES), quant_stats.STATS_LEN)
    m = quant_stats.site_maxima(
        jnp.asarray([[0.1, 0.0], [0.3, 0.2], [0.0, 0.5]]))
    assert np.asarray(m).tolist() == [pytest.approx(0.3),
                                      pytest.approx(0.5)]


def _guarded_build(arch="qwen15_05b"):
    from tests.conftest import make_mesh11
    cfg = get_arch(arch).reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=3e-3)
    recipe = get_recipe("fp8_flow")
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    guard = GuardPlan()
    raw = make_train_step(cfg, recipe, plan, opt, total_steps=100,
                          warmup_steps=5, guard=guard)
    state = init_train_state(cfg, opt, jax.random.key(0), guard=guard)
    return mesh, raw, state, make_batch(data, 0)


def test_site_stats_ride_guarded_step_metrics():
    mesh, raw, state, batch = _guarded_build()
    with mesh:
        _, m = jax.jit(raw)(state, batch)
    sv = np.asarray(m["quant_site_stats"])
    assert sv.shape == (len(quant_stats.STAT_SITES), 2)
    # guard scalars are exactly the max over sites (behavior-preserving)
    assert float(m["quant_sat_frac"]) == sv[:, 0].max()
    assert float(m["quant_flush_frac"]) == sv[:, 1].max()


# ---------------------------------------------------------------------------
# THE structural gate: obs adds zero host syncs and zero casts
# ---------------------------------------------------------------------------
def test_instrumented_step_has_zero_host_transfers():
    mesh, raw, state, batch = _guarded_build()
    with mesh:
        jaxpr = str(jax.make_jaxpr(raw)(state, batch))
        hlo = jax.jit(raw).lower(state, batch).compile().as_text()
    for name, text in (("jaxpr", jaxpr), ("hlo", hlo)):
        counts = _host_transfer_counts(text)
        assert not any(counts.values()), (
            f"instrumented {name} contains host-transfer ops {counts} — "
            f"telemetry must ride the existing metrics fetch")
    # the stage scopes ARE in the compiled program's metadata (named, free)
    assert "stage/" in hlo and "remat/" in hlo


def test_guard_stats_do_not_change_cast_ledger():
    # the obs instrumentation (stage scopes + per-site stats collection,
    # armed by guard) must not add quantize/dequantize ops: the guarded and
    # unguarded step programs count the SAME activation casts.
    from tests.conftest import make_mesh11
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=3e-3)
    recipe = get_recipe("fp8_flow")
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    ledgers = {}
    with mesh:
        for name, guard in (("off", None), ("on", GuardPlan())):
            raw = make_train_step(cfg, recipe, plan, opt, total_steps=100,
                                  warmup_steps=5, guard=guard)
            state = init_train_state(cfg, opt, jax.random.key(0),
                                     guard=guard)
            with casts.ledger() as led:
                jax.eval_shape(raw, state, make_batch(data, 0))
            ledgers[name] = led
    assert ledgers["on"].by_tag() == ledgers["off"].by_tag()
    assert ledgers["on"].activation_casts() == \
        ledgers["off"].activation_casts()


def test_annotate_is_zero_ops():
    def f(x):
        with annotate("stage/attn"):
            y = x * 2
        return y

    def g(x):
        return x * 2

    x = jnp.ones((4,))
    assert str(jax.make_jaxpr(f)(x)) == str(jax.make_jaxpr(g)(x))
    assert [s for s in STAGES] == ["attn", "router", "dispatch", "expert",
                                   "combine"]
    with stage_annotation("attn"):
        pass


# ---------------------------------------------------------------------------
# the loop's honest dt split + typed events
# ---------------------------------------------------------------------------
def test_loop_emits_split_timing_and_step_records():
    cfg = get_arch("qwen15_05b").reduced()
    plan = ParallelPlan(mesh=None)
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe("fp8_flow")
    guard = GuardPlan()
    state = init_train_state(cfg, opt, jax.random.key(0), guard=guard)
    step = jax.jit(make_train_step(cfg, recipe, plan, opt, total_steps=3,
                                   warmup_steps=1, guard=guard))
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    sink = MemorySink()
    lines = []
    tel = Telemetry(sinks=(sink,))
    _, hist = run_loop(step, state, data, n_steps=2, log_every=1,
                       guard_policy=GuardPolicy(), telemetry=tel,
                       log_fn=lines.append)
    for h in hist:
        assert {"step", "loss", "dt", "device_ms", "fetch_ms"} <= set(h)
        # the split is honest: spans are inside the conflated dt
        assert (h["device_ms"] + h["fetch_ms"]) <= h["dt"] * 1e3 + 1.0
    steps = sink.of_kind("step")
    assert len(steps) == 2
    assert set(quant_stats.STAT_SITES) == set(steps[0]["quant_sites"])
    # per-recompile cast-ledger snapshot: exactly one distinct callable
    assert len(sink.of_kind("cast_ledger")) == 1
    # human progress lines unchanged in shape
    assert any(l.startswith("[loop] step=") for l in lines)
    # the per-step sample landed in the registry
    assert "train_loss" in tel.registry.snapshot()["gauges"]
