"""The activation-residency plan (train/memory.py MemoryPlan): policy
parity (rematerialization must be semantically invisible), the FP8
residency invariant of the paper's memory claim, the checkpoint-of-pairs
structure, and the single-owner rule (no jax.checkpoint outside memory.py).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 in CI (the
stream-schedule compose tests live in tests/test_dist.py)."""
import dataclasses
import os
import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import casts
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig, make_batch
from repro.models.lm import ParallelPlan, forward, init_params
from repro.optim.adamw import AdamWConfig
from repro.train.memory import (MemoryPlan, POLICIES,
                                layer_saved_bytes_model,
                                measure_layer_residuals)
from repro.train.train_step import init_train_state, make_train_step

PLAN = ParallelPlan(mesh=None, dp_axes=(), shard_map_mlp=False)


# ---------------------------------------------------------------------------
# The single-owner rule (the refactor's acceptance criterion).
# ---------------------------------------------------------------------------
def test_no_jax_checkpoint_outside_memory():
    """train/memory.py is the ONLY jax.checkpoint call site in the tree."""
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    hits = []
    for dirpath, _, files in os.walk(root):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            src = open(path).read()
            if re.search(r"jax\.checkpoint\(", src):
                hits.append(os.path.relpath(path, root))
    assert hits == [os.path.join("repro", "train", "memory.py")], hits


def test_plan_structure_and_aliases():
    assert MemoryPlan("pair").block_size == 2
    assert MemoryPlan("full").block_size == 1
    assert MemoryPlan("pair").layer_blocks(5) == ((0, 1), (2, 3), (4,))
    assert MemoryPlan("full").layer_blocks(3) == ((0,), (1,), (2,))
    assert MemoryPlan("pair").group_factor(4) == 2
    assert MemoryPlan("pair").group_factor(3) == 1
    # legacy bool spelling (config sweeps) still works, on plan AND config
    assert MemoryPlan(True).policy == "full"
    assert MemoryPlan(False).policy == "none"
    cfg = get_arch("qwen15_05b").reduced()
    assert dataclasses.replace(cfg, remat_policy=False).remat_policy == "none"
    assert cfg.remat is True        # legacy read alias
    with pytest.raises(ValueError, match="remat policy"):
        MemoryPlan("selective")
    # 'none' applies no wrapper at all
    f = lambda x: x
    assert MemoryPlan("none").wrap(f) is f


# ---------------------------------------------------------------------------
# Loss parity: rematerialization is semantically invisible.  The bf16 pins
# at the tagged stage boundaries (core/quant.py tag_saveable) make every
# policy evaluate the identical function, so this is near-bitwise.
# ---------------------------------------------------------------------------
def _train_policy(cfg, policy, n_steps, seed=0):
    c = dataclasses.replace(cfg, remat_policy=policy)
    recipe = get_recipe("fp8_flow")
    opt = AdamWConfig(lr=3e-3)
    state = init_train_state(c, opt, jax.random.key(seed))
    step = jax.jit(make_train_step(c, recipe, PLAN, opt, total_steps=400,
                                   warmup_steps=5))
    data = DataConfig(vocab=c.vocab, seq_len=32, global_batch=4)
    losses = []
    for i in range(n_steps):
        state, m = step(state, make_batch(data, i))
        losses.append(float(m["loss"]))
    return np.array(losses)


@pytest.mark.slow
def test_policy_loss_parity_20_steps():
    """The ISSUE gate: 20-step fp8_flow training, fp8_resident vs full vs
    none agree to < 1e-5 relative on a MoE arch (dense prologue + shared
    experts included)."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    ref = _train_policy(cfg, "none", 20)
    assert np.isfinite(ref).all()
    for pol in ("full", "fp8_resident"):
        ls = _train_policy(cfg, pol, 20)
        rel = np.max(np.abs(ls - ref) / np.abs(ref))
        assert rel < 1e-5, (pol, rel)


@pytest.mark.parametrize("policy", [p for p in POLICIES if p != "none"])
@pytest.mark.parametrize("stage_layers", [False, True])
def test_policy_grad_parity_both_drivers(policy, stage_layers):
    """One value_and_grad step under the scan AND the unrolled staged
    driver: every policy matches 'none' near-bitwise."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    recipe = get_recipe("fp8_flow")
    plan = dataclasses.replace(PLAN, stage_layers=stage_layers)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=2), 0)

    def run(pol):
        c = dataclasses.replace(cfg, remat_policy=pol)
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p, b, _c=c: forward(_c, recipe, plan, p, b)[0]))(
                params, batch)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        return float(loss), float(gn)

    l0, g0 = run("none")
    l1, g1 = run(policy)
    np.testing.assert_allclose(l1, l0, rtol=1e-6)
    np.testing.assert_allclose(g1, g0, rtol=1e-5)


# ---------------------------------------------------------------------------
# The residency invariant + the bytes ordering (the memory claim itself).
# ---------------------------------------------------------------------------
def _layer_residuals(cfg, policy, batch=4, seq=128):
    return measure_layer_residuals(cfg, get_recipe("fp8_flow"), policy,
                                   batch=batch, seq=seq)


def test_fp8_resident_saves_nothing_wide_in_bf16():
    """The jaxpr-level assertion: under fp8_resident, every saved MoE-layer
    activation wider than the residual stream is e4m3 payload bits (+ po2
    scales) — no bf16 stage activation crosses the boundary."""
    cfg = get_arch("qwen3_moe_235b").reduced()
    cls = _layer_residuals(cfg, "fp8_resident")
    assert cls["wide_bf16"] == 0, cls
    assert cls["fp8"] > 0, cls              # qx/qa payloads ARE saved
    # 'full' by contrast saves wide bf16 stage tensors, and >= 3x the bytes
    cls_full = _layer_residuals(cfg, "full")
    assert cls_full["wide_bf16"] > 0
    act = lambda c: c["fp8"] + c["scale"] + c["wide_bf16"] + c["small"]
    assert act(cls_full) >= 3.0 * act(cls), (cls_full, cls)


def test_bytes_model_tracks_measurement():
    """The analytic README-table model stays within 2x of the measured
    saved-residual bytes for the policies it models (padding effects are
    real; the model is the no-padding floor)."""
    cfg = get_arch("qwen3_moe_235b").reduced()
    T = 4 * 128
    for pol in ("full", "fp8_resident"):
        measured = _layer_residuals(cfg, pol)
        act = (measured["fp8"] + measured["scale"] + measured["wide_bf16"]
               + measured["small"])
        model = layer_saved_bytes_model(cfg, T, pol)
        assert model <= act <= 4.0 * model, (pol, model, act)


# ---------------------------------------------------------------------------
# Cast-count invariance: no policy adds an explicit Q/DQ site.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", list(POLICIES))
def test_cast_tags_invariant_across_policies(policy):
    """Fig.-2 accounting holds under every residency policy: the only
    explicit activation casts are the entry quantize + the backward island
    quantize, and no explicit dequantize ever materializes."""
    cfg = dataclasses.replace(get_arch("deepseek_v2_lite").reduced(),
                              remat_policy=policy)
    recipe = get_recipe("fp8_flow")
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.key(0))
    step = make_train_step(cfg, recipe, PLAN, opt, total_steps=10,
                           warmup_steps=2)
    batch = make_batch(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=2), 0)
    with casts.ledger() as led:
        jax.jit(step)(state, batch)
    tags = {t for (k, t) in led.by_tag()
            if k in ("quantize", "dequantize") and not t.startswith("q_w")}
    assert tags == {"q_entry", "q_bwd_island"}, led.summary()
    assert not [e for e in led.events if e.kind == "dequantize"], \
        led.summary()
