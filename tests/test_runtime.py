"""Training-runtime substrate: checkpoint/restart, fault tolerance (failure
detection, elastic shrink, straggler reassignment), data determinism, the
training loop end-to-end, and convergence parity (mini Fig. 6)."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpointing
from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig, make_batch
from repro.models.lm import ParallelPlan
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import (ElasticTrainer, HealthMonitor,
                                           reassign_shards, shrink_mesh)
from repro.train.loop import run as run_loop
from repro.train.train_step import init_train_state, make_train_step
from tests.conftest import make_mesh11


def _tiny_setup(recipe_name="fp8_flow", arch="qwen15_05b"):
    cfg = get_arch(arch).reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=3e-3)
    recipe = get_recipe(recipe_name)
    step = make_train_step(cfg, recipe, plan, opt, total_steps=200, warmup_steps=5)
    state = init_train_state(cfg, opt, jax.random.key(0))
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    return cfg, mesh, jax.jit(step), state, data


def test_data_pipeline_deterministic():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    b3 = make_batch(cfg, 8)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 512
    # targets are next tokens
    assert np.array_equal(np.asarray(b1["targets"][:, :-1]),
                          np.asarray(b1["tokens"][:, 1:]))


def test_checkpoint_save_restore_atomic(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    checkpointing.save(d, 10, tree)
    checkpointing.save(d, 20, tree)
    assert checkpointing.completed_steps(d) == [10, 20]
    # a partial (crashed) write is ignored
    os.makedirs(os.path.join(d, "step_30"))
    restored, step = checkpointing.restore(d, tree)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.zeros(4)}
    for s in [1, 2, 3, 4, 5]:
        checkpointing.save(d, s, tree, max_keep=2)
    assert checkpointing.completed_steps(d) == [4, 5]


def test_loop_restart_resumes(tmp_path):
    cfg, mesh, step, state, data = _tiny_setup()
    d = str(tmp_path)
    with mesh:
        state1, hist1 = run_loop(step, state, data, n_steps=6, ckpt_dir=d,
                                 ckpt_every=2, log_every=100,
                                 log_fn=lambda *a: None)
        # simulate a crash + restart from the same initial state
        state2, hist2 = run_loop(step, state, data, n_steps=8, ckpt_dir=d,
                                 ckpt_every=2, log_every=100,
                                 log_fn=lambda *a: None)
    assert hist2[0]["step"] > 0       # resumed, did not start from 0
    assert np.isfinite(hist2[-1]["loss"])


def test_health_monitor_failure_and_straggler():
    t = [0.0]
    now = lambda: t[0]
    mon = HealthMonitor([0, 1, 2, 3], timeout=10.0, straggler_factor=2.0,
                        now=now)
    for h in range(4):
        mon.beat(h, 1.0)
    t[0] = 5.0
    for h in range(3):
        mon.beat(h, 1.0)
    assert mon.failed_hosts() == []
    t[0] = 16.0
    for h in range(3):
        mon.beat(h, 1.0)
    assert mon.failed_hosts() == [3]
    # straggler: host 2 suddenly 3x slower
    for _ in range(8):
        mon.beat(2, 3.0)
    assert 2 in mon.stragglers()


def test_shrink_mesh_and_reassign():
    shape, axes = shrink_mesh((16, 16), ("data", "model"), 2)
    assert shape == (14, 16)
    extra = reassign_shards(8, [1, 5])
    owners = [h for hs in extra.values() for h in hs]
    assert sorted(owners) == [1, 5]
    with pytest.raises(RuntimeError):
        shrink_mesh((1, 16), ("data", "model"), 1)


def test_elastic_trainer_remesh_flow(tmp_path):
    """End-to-end: train, inject a failure, loop shrinks + restores."""
    cfg, mesh, step, state, data = _tiny_setup()
    d = str(tmp_path)
    t = [0.0]
    el = ElasticTrainer(n_data_shards=4, timeout=5.0,
                        now=lambda: t[0])
    events = []

    def injector(s, elastic):
        t[0] += 1.0
        for h in list(elastic.monitor.hosts):
            if h != 2 or s < 4:
                elastic.monitor.beat(h, 0.5)
        # host 2 stops beating at step >= 4 -> timeout at t+5

    def log(msg):
        events.append(msg)

    with mesh:
        t[0] = 0.0
        run_loop(step, state, data, n_steps=12, ckpt_dir=d, ckpt_every=3,
                 log_every=100, elastic=el, fail_injector=injector,
                 log_fn=log)
    assert el.generation >= 1
    assert el.n_data_shards == 3
    assert any("shrinking" in m for m in events)


def test_restore_with_new_shardings(tmp_path):
    """Elastic restart re-shards the checkpoint onto a different mesh."""
    d = str(tmp_path)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_mesh11()
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    checkpointing.save(d, 1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = checkpointing.restore(d, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]


@pytest.mark.slow
def test_convergence_parity_mini():
    """Mini Fig. 6: BF16 vs FP8-Flow on identical data for 60 steps — the
    loss curves must track (paper: 'nearly indistinguishable')."""
    losses = {}
    for name in ["bf16", "fp8_flow"]:
        cfg, mesh, step, state, data = _tiny_setup(name)
        with mesh:
            _, hist = run_loop(step, state, data, n_steps=60,
                               log_every=1000, log_fn=lambda *a: None)
        losses[name] = np.array([h["loss"] for h in hist])
    l_b, l_f = losses["bf16"], losses["fp8_flow"]
    # both models learn
    assert l_b[-10:].mean() < l_b[:5].mean() - 0.05
    assert l_f[-10:].mean() < l_f[:5].mean() - 0.05
    # and the curves track each other
    gap = np.abs(l_b[-10:].mean() - l_f[-10:].mean())
    assert gap < 0.15, f"convergence gap {gap}"
