"""Radix prefix cache over the paged FP8 KV pool: allocator refcount
properties, radix insert/match/split/evict invariants, the scheduler's
single release hook + cache-aware budget accounting, deterministic page
content (what makes sharing safe), router scoring, and the end-to-end
bitwise guarantee that generated tokens are identical cache-on vs
cache-off."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.models.lm import ParallelPlan, init_params, paged_prefill
from repro.serve.paged_kv import PageAllocator, init_paged_cache
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import Request, Scheduler
from tests.conftest import make_mesh11


# ---------------------------------------------------------------------------
# Allocator refcount properties (pure host).
# ---------------------------------------------------------------------------
def test_refcount_lifecycle_and_sharing():
    a = PageAllocator(n_pages=8, page_size=4)
    owner = a.alloc(3)
    assert all(a.refcount(p) == 1 for p in owner)
    a.incref(owner)                               # cache takes its reference
    assert all(a.refcount(p) == 2 for p in owner)
    assert a.shared_pages == 3
    freed = a.decref(owner)                       # owner request finishes
    assert freed == []                            # cache ref keeps them alive
    assert all(a.refcount(p) == 1 for p in owner)
    assert a.free_pages == 7 - 3                  # still resident
    freed = a.decref(owner)                       # cache evicts
    assert sorted(freed) == sorted(owner)
    assert a.free_pages == 7
    assert a.live_pages == 0


def test_refcount_never_negative_no_double_free():
    a = PageAllocator(n_pages=4, page_size=4)
    pages = a.alloc(2)
    a.decref(pages)
    with pytest.raises(ValueError):
        a.decref(pages)                           # double free
    with pytest.raises(ValueError):
        a.decref([3])                             # never-allocated page
    with pytest.raises(ValueError):
        a.incref([pages[0]])                      # resurrecting a dead page
    assert all(a.refcount(p) == 0 for p in pages)  # counts never go negative


def test_refcount_randomized_conservation():
    """Property: after any interleaving of alloc/incref/decref, free +
    live == n_pages - 1 and every refcount is >= 1 for live pages."""
    rng = np.random.default_rng(0)
    a = PageAllocator(n_pages=16, page_size=4)
    held = []                                     # one entry per reference
    for _ in range(400):
        op = rng.integers(0, 3)
        if op == 0:
            got = a.alloc(int(rng.integers(1, 4)))
            if got is not None:
                held.extend(got)
        elif op == 1 and held:
            p = held[int(rng.integers(len(held)))]
            a.incref([p])
            held.append(p)
        elif op == 2 and held:
            p = held.pop(int(rng.integers(len(held))))
            a.decref([p])
        assert a.free_pages + a.live_pages == 15
        for p in set(held):
            assert a.refcount(p) == held.count(p) >= 1


# ---------------------------------------------------------------------------
# Radix tree invariants (pure host).
# ---------------------------------------------------------------------------
def _fill(cache, alloc, tokens):
    """Simulate a finished request: alloc its pages, insert, release its
    own refs (the cache's refs keep cached pages alive)."""
    pages = alloc.alloc(alloc.pages_for(len(tokens)))
    cache.insert(tokens, pages, alloc)
    alloc.decref(pages)
    return pages


def test_radix_insert_match_split():
    ps = 4
    alloc = PageAllocator(n_pages=32, page_size=ps)
    cache = PrefixCache(page_size=ps)
    a = list(range(100, 112))                     # 3 full blocks
    pa = _fill(cache, alloc, a)
    cache.check_invariants(alloc)
    # full-prefix match is page-aligned and in page order
    assert cache.match_tokens(a + [1, 2]) == 12
    m = cache.lookup(a + [1, 2])
    assert m.pages == pa[:3] and m.tokens == 12 and not m.cow
    # partial-block tails never match
    assert cache.match_tokens(a[:6]) == 4
    # diverge after block 1 -> mid-edge split, shared prefix kept canonical
    b = a[:4] + list(range(200, 208))
    _fill(cache, alloc, b)
    cache.check_invariants(alloc)
    mb = cache.lookup(b + [9])
    assert mb.pages[0] == pa[0] and mb.tokens == 12
    ma = cache.lookup(a + [9])                    # original still fully cached
    assert ma.pages == pa[:3]
    # a third divergence off the same shared head
    c = a[:4] + list(range(300, 304))
    _fill(cache, alloc, c)
    cache.check_invariants(alloc)
    assert cache.lookup(c + [9]).pages[0] == pa[0]


def test_whole_prompt_hit_is_cow_capped():
    ps = 4
    alloc = PageAllocator(n_pages=16, page_size=ps)
    cache = PrefixCache(page_size=ps)
    a = list(range(8))
    _fill(cache, alloc, a)
    m = cache.lookup(list(a))                     # identical whole prompt
    assert m.cow and m.tokens == len(a) - 1       # last token recomputed
    assert len(m.pages) == 2                      # boundary page included
    # re-inserting an already-cached prompt is a no-op
    pages = alloc.alloc(2)
    assert cache.insert(a, pages, alloc) == 0
    alloc.decref(pages)
    cache.check_invariants(alloc)


def test_lru_eviction_prefers_cold_leaves_and_skips_pinned():
    ps = 4
    alloc = PageAllocator(n_pages=9, page_size=ps)   # 8 usable
    cache = PrefixCache(page_size=ps)
    cold = _fill(cache, alloc, list(range(0, 16)))      # 4 pages
    hot = _fill(cache, alloc, list(range(100, 116)))    # 4 pages
    assert alloc.free_pages == 0
    cache.lookup(list(range(100, 118)))           # touch hot's LRU clock
    got = cache.alloc_pages(alloc, 2)             # must evict to satisfy
    assert got is not None and len(got) == 2
    assert cache.match_tokens(list(range(100, 118))) == 16   # hot survives
    assert cache.match_tokens(list(range(0, 18))) < 16       # cold trimmed
    cache.check_invariants(alloc)
    alloc.decref(got)
    # pinned pages (a resident's incref) are never evicted
    alloc.incref(hot)                             # resident uses the prefix
    assert cache.alloc_pages(alloc, 8) is None    # only cold remnants evict
    assert cache.match_tokens(list(range(100, 118))) == 16
    cache.check_invariants(alloc)
    assert all(alloc.refcount(p) == 2 for p in hot)


def test_hit_stats_count_once_per_admission():
    ps = 4
    alloc = PageAllocator(n_pages=16, page_size=ps)
    cache = PrefixCache(page_size=ps)
    _fill(cache, alloc, list(range(8)))
    for _ in range(5):                            # blocked head re-lookups
        m = cache.lookup(list(range(8)) + [42])
    assert cache.n_lookups == cache.n_hits == 0   # lookup is stat-free
    cache.record_admitted(m)
    cache.record_admitted(None)                   # a miss admission
    s = cache.stats()
    assert s["prefix_lookups"] == 2 and s["prefix_hits"] == 1
    assert s["prefix_hit_tokens"] == 8


# ---------------------------------------------------------------------------
# Scheduler: release hook + cache-aware admission (pure host).
# ---------------------------------------------------------------------------
def test_release_hook_sees_every_release():
    released = []
    alloc = PageAllocator(n_pages=32, page_size=4)
    sched = Scheduler(max_batch=2, token_budget=64,
                      release_hook=lambda st, pages, a: (
                          released.append((st.req.rid, tuple(pages))),
                          a.decref(pages)))
    r1 = Request(prompt=[1] * 8, max_new_tokens=4)
    r2 = Request(prompt=[2] * 8, max_new_tokens=4)
    sched.submit(r1), sched.submit(r2)
    s1 = sched.try_admit(alloc, now=0.0)
    s2 = sched.try_admit(alloc, now=0.0)
    sched.evict_youngest(alloc, requester=s1)     # eviction path
    s1.generated.extend([0] * 4)
    sched.finish(s1.slot, alloc, now=1.0)         # finish path
    assert [rid for rid, _ in released] == [r2.rid, r1.rid]
    assert all(pages for _, pages in released)
    assert alloc.free_pages == 31                 # hook actually freed


def test_cache_aware_admission_discounts_budget_and_pins_shared():
    ps = 4
    alloc = PageAllocator(n_pages=32, page_size=ps)
    cache = PrefixCache(page_size=ps)
    prefix = list(range(500, 512))                # 12 tokens, 3 pages
    shared = _fill(cache, alloc, prefix)
    # budget fits ONLY with the cached 12 tokens discounted
    sched = Scheduler(max_batch=2, token_budget=10,
                      release_hook=lambda st, p, a: a.decref(p))
    req = Request(prompt=prefix + [1, 2], max_new_tokens=4)  # reserves 18
    sched.submit(req)
    st = sched.try_admit(alloc, now=0.0, prefix_cache=cache)
    assert st is not None, "cached tokens must not count against the budget"
    assert st.cached_tokens == 12 and st.prefill_pos == 12
    assert st.pages[:3] == shared and st.n_shared_pages == 3
    assert sched.reserved_tokens == 18 - 12
    assert all(alloc.refcount(p) == 2 for p in shared)   # cache + request
    sched.evict_youngest(alloc)                   # restart semantics
    assert all(alloc.refcount(p) == 1 for p in shared)   # request ref dropped
    assert cache.match_tokens(prefix + [0]) == 12        # cache unaffected


def test_admission_rollback_restores_shared_refs():
    ps = 4
    alloc = PageAllocator(n_pages=4, page_size=ps)       # 3 usable
    cache = PrefixCache(page_size=ps)
    prefix = list(range(8))                       # 2 pages cached
    shared = _fill(cache, alloc, prefix)
    alloc.incref(shared)                          # pretend a resident pins it
    assert alloc.free_pages == 1
    sched = Scheduler(max_batch=2, token_budget=64,
                      release_hook=lambda st, p, a: a.decref(p))
    # needs 2 fresh pages but only 1 exists and nothing is evictable
    sched.submit(Request(prompt=prefix + [1] * 6, max_new_tokens=2))
    assert sched.try_admit(alloc, now=0.0, prefix_cache=cache) is None
    assert all(alloc.refcount(p) == 2 for p in shared)   # incref rolled back
    assert len(sched.waiting) == 1                # head stays queued


# ---------------------------------------------------------------------------
# Deterministic page content: the property that makes sharing safe.
# ---------------------------------------------------------------------------
def test_fp8_pages_are_content_addressable():
    """The same tokens prefilled at the same positions produce BITWISE
    identical e4m3 payloads and po2 scales regardless of which physical
    pages they land in — so handing a request somebody else's pages is
    indistinguishable from its own prefill (paper Eq. 5-8 idempotence)."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    recipe = get_recipe("fp8_flow")
    ps, mp, P = 4, 4, 12
    pools = init_paged_cache(cfg, n_pages=16, page_size=ps, fp8_kv=True)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab, P).astype(np.int32)
    toks = np.zeros((1, 16), np.int32)
    toks[0, :P] = prompt

    def run_at(pools, pages):
        ptrow = np.zeros((mp,), np.int32)
        ptrow[:len(pages)] = pages
        with mesh:
            lg, pools = paged_prefill(cfg, recipe, plan, params, pools,
                                      jnp.asarray(ptrow), jnp.asarray(toks),
                                      jnp.int32(P))
        return lg, pools

    lg1, pools = run_at(pools, [1, 2, 3])
    lg2, pools = run_at(pools, [7, 9, 11])        # same prompt, other pages
    for stack in pools.values():
        for kv in ("k", "v"):
            data = np.asarray(stack[kv]["data"])
            scale = np.asarray(stack[kv]["scale"])
            np.testing.assert_array_equal(
                data[:, [1, 2, 3]].view(np.uint8),
                data[:, [7, 9, 11]].view(np.uint8))
            np.testing.assert_array_equal(scale[:, [1, 2, 3]],
                                          scale[:, [7, 9, 11]])
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))


# ---------------------------------------------------------------------------
# Router scoring (host-side; fake replicas).
# ---------------------------------------------------------------------------
class _FakeSched:
    def __init__(self):
        self.reserved_tokens = 0


class _FakeEngine:
    """Just enough surface for ReplicaRouter.route()."""
    def __init__(self, ps=4, n_pages=64, budget=256):
        from repro.serve.engine import ServeConfig
        self.ecfg = ServeConfig(page_size=ps, n_pages=n_pages,
                                token_budget=budget, prefix_cache=True)
        self.alloc = PageAllocator(n_pages, ps)
        self.prefix_cache = PrefixCache(ps)
        self.sched = _FakeSched()
        self.submitted = []

    def submit(self, req):
        self.submitted.append(req)


def test_router_prefers_prefix_overlap_then_load():
    from repro.serve.router import ReplicaRouter, RouterConfig
    e0, e1 = _FakeEngine(), _FakeEngine()
    prefix = list(range(700, 716))
    _fill(e1.prefix_cache, e1.alloc, prefix)      # replica 1 holds the prefix
    router = ReplicaRouter([e0, e1], RouterConfig())
    idx = router.route(Request(prompt=prefix + [1, 2], max_new_tokens=4))
    assert idx == 1                               # affinity wins
    # overlap loses to load once the replica is saturated
    e1.sched.reserved_tokens = e1.ecfg.token_budget
    for p in range(1, e1.ecfg.n_pages):           # pool fully occupied
        if e1.alloc.refcount(p) == 0:
            e1.alloc.alloc(1)
    heavy = ReplicaRouter([e0, e1], RouterConfig(w_prefix=0.2, w_load=2.0))
    assert heavy.route(Request(prompt=prefix + [3], max_new_tokens=4)) == 0
    # no-overlap traffic round-robins across equally loaded replicas
    rr = ReplicaRouter([_FakeEngine(), _FakeEngine()], RouterConfig())
    picks = {rr.route(Request(prompt=[9, 9, 9], max_new_tokens=2))
             for _ in range(4)}
    assert picks == {0, 1}
    assert sum(rr.route_counts) == 4


# ---------------------------------------------------------------------------
# End-to-end: bitwise-identical decode, cache on vs off.
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_bitwise_identical_cache_on_vs_off():
    """Same shared-prefix trace through two engines — with and without the
    radix cache.  Page-aligned chunk geometry (prefill_chunk == page_size)
    makes the hit path's chunk boundaries identical to the miss path's, so
    greedy decode must be BITWISE identical; the cache run must also
    actually hit (including a whole-prompt copy-on-write case) and return
    every page."""
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_arch("qwen3_moe_235b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    recipe = get_recipe("fp8_flow")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(11)
    prefix = list(rng.integers(1, cfg.vocab, 8))
    prompts = [prefix + list(rng.integers(1, cfg.vocab, k))
               for k in (3, 4, 2, 1)]
    prompts.append(list(prompts[0]))              # whole-prompt hit -> CoW
    prompts.append(prefix[:4] + [7, 8, 9])        # mid-edge divergence

    def run(cache_on):
        ecfg = ServeConfig(max_batch=3, page_size=4, n_pages=32,
                           max_pages_per_req=8, token_budget=128,
                           prefill_buckets=(16,), prefill_chunk=4,
                           fp8_kv=True, w8_weights=True,
                           prefix_cache=cache_on)
        eng = ServeEngine(cfg, recipe, plan, params, ecfg)
        reqs = [Request(prompt=list(p), max_new_tokens=4) for p in prompts]
        results = eng.run(reqs, realtime=False)
        return eng, [results[q.rid]["tokens"] for q in reqs]

    eng_off, toks_off = run(False)
    eng_on, toks_on = run(True)
    assert toks_on == toks_off                    # bitwise-identical decode
    s = eng_on.stats()
    assert s["prefix_hits"] >= 3 and s["prefix_hit_tokens"] >= 20
    assert s["prefix_lookups"] == len(prompts)
    eng_on.prefix_cache.check_invariants(eng_on.alloc)
    # cached pages are the only live ones; scheduler returned all its refs
    assert eng_on.alloc.live_pages == eng_on.prefix_cache.n_cached_pages
    assert all(eng_on.alloc.refcount(p) == 1
               for n in eng_on.prefix_cache._iter_nodes() for p in n.pages)
    assert eng_off.alloc.free_pages == 31         # no-cache path unchanged
