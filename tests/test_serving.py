"""Serving features: FP8 KV cache and W8-resident weights (§Perf cell 3)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.quant import QTensor
from repro.core.recipes import get_recipe
from repro.models.lm import ParallelPlan, decode_step, init_cache, init_params
from repro.serve.w8 import quantize_params_for_serving
from tests.conftest import make_mesh11


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3_moe_235b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    return cfg, mesh, plan, params


def test_fp8_kv_cache_halves_bytes_and_decodes(setup):
    cfg, mesh, plan, params = setup
    recipe = get_recipe("fp8_flow")
    B = 2
    c_bf = init_cache(cfg, B, 64)
    c_f8 = init_cache(cfg, B, 64, fp8_kv=True)
    bytes_bf = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(c_bf))
    bytes_f8 = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(c_f8))
    assert bytes_f8 < 0.6 * bytes_bf
    with mesh:
        lg, _ = decode_step(cfg, recipe, plan, params, c_f8,
                            jnp.ones((B, 1), jnp.int32), jnp.int32(2))
    assert bool(jnp.isfinite(lg).all())


def test_w8_resident_weights_decode_matches_bf16_weights(setup):
    cfg, mesh, plan, params = setup
    recipe = get_recipe("fp8_flow")
    qparams = quantize_params_for_serving(params)
    # expert weights became QTensors; everything else untouched
    assert isinstance(qparams["layers"]["we13"], QTensor)
    assert isinstance(qparams["layers"]["we2"], QTensor)
    assert not isinstance(qparams["layers"]["wq"], QTensor)
    # payload bytes halved (+ small scale overhead)
    w_bf = params["layers"]["we13"]
    w_q8 = qparams["layers"]["we13"]
    assert (w_q8.data.size * 1 + w_q8.scale.size * 4) < 0.6 * w_bf.size * 2

    B = 2
    toks = jnp.ones((B, 1), jnp.int32)
    with mesh:
        lg_bf, _ = decode_step(cfg, recipe, plan, params,
                               init_cache(cfg, B, 64), toks, jnp.int32(1))
        lg_w8, _ = decode_step(cfg, recipe, plan, qparams,
                               init_cache(cfg, B, 64), toks, jnp.int32(1))
    a = np.asarray(lg_bf, np.float32).ravel()
    b = np.asarray(lg_w8, np.float32).ravel()
    cos = a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30)
    # the training recipe quantizes the same weights per step, so W8-resident
    # decode is numerically near-identical to the on-the-fly path
    assert cos > 0.999, cos
