"""Overlapped EP dispatch pipeline + chunked prefill scheduling.

Covers the PR-2 tentpole: moe_block_overlapped forward/grad parity vs the
synchronous moe_block (fp8_flow / naive_fp8 / bf16), the unchanged Fig.-2
cast count (2 for fp8_flow at any n_chunks), the fused single-message
dispatch (2 collectives per chunk vs 5 for the synchronous block), the real
moe_block_decode drop fraction, chunked-prefill parity and scheduler
invariants (FCFS preserved; decode never starved more than one chunk), and
the unified serve_step sampling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import casts
from repro.core.moe import (DispatchPlan, MoEConfig, moe_block,
                            moe_block_decode, moe_block_decode_overlapped,
                            moe_block_overlapped)
from repro.core.recipes import get_recipe
from tests.conftest import make_mesh11


# ---------------------------------------------------------------------------
# moe_block_overlapped parity vs the synchronous block.
# ---------------------------------------------------------------------------
def _toy_moe(seed=1, T=256, D=256, F=128, E=4, topk=2, cf=4.0):
    """capacity_factor is ample so neither block drops (capacities are
    per-chunk in the overlapped block, so drop SETS could differ under
    overflow — parity is defined on the no-drop regime)."""
    cfg = MoEConfig(n_experts=E, top_k=topk, d_model=D, d_ff=F,
                    capacity_factor=cf)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(T, D)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    wr = jnp.asarray(r.normal(size=(D, E)).astype(np.float32) * 0.02)
    w13 = jnp.asarray(r.normal(size=(E, D, 2 * F)).astype(np.float32) * 0.05)
    w2 = jnp.asarray(r.normal(size=(E, F, D)).astype(np.float32) * 0.05)
    return cfg, (x, wr, w13, w2)


def _sharded_block(recipe, cfg, mesh, block, **kw):
    def body(x, wr, w13, w2):
        y, m = block(recipe, cfg, x, wr, w13, w2, **kw)
        return y, m["drop_frac"]

    return shard_map(body, mesh=mesh,
                     in_specs=(P(("data", "model"), None), P(None, None),
                               P("model", None, None), P("model", None, None)),
                     out_specs=(P(("data", "model"), None), P()))


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-30)


# naive_fp8's Wgrad layouts are rebuilt by dequantize->transpose->requantize
# with COLUMN tiles spanning the capacity dim, so chunking changes its
# quantization groups — the double-quantization error the paper identifies is
# genuinely chunk-sensitive; the casting-free recipes are not.
GRAD_RTOL = {"bf16": 1e-3, "fp8_flow": 2e-2, "naive_fp8": 1.5e-1}


@pytest.mark.parametrize("name", ["fp8_flow", "bf16", "naive_fp8"])
def test_overlap_forward_and_grad_parity(name):
    recipe = get_recipe(name)
    mesh = make_mesh11()
    cfg, args = _toy_moe()
    f_sync = _sharded_block(recipe, cfg, mesh, moe_block)
    f_ovl = _sharded_block(recipe, cfg, mesh, moe_block_overlapped,
                           n_chunks=2)
    y0, d0 = f_sync(*args)
    y1, d1 = f_ovl(*args)
    assert float(d0) == 0.0 and float(d1) == 0.0
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32), atol=2e-2)

    def loss(fn):
        return lambda *a: jnp.sum(fn(*a)[0].astype(jnp.float32) ** 2)

    g0 = jax.grad(loss(f_sync), argnums=(0, 2, 3))(*args)
    g1 = jax.grad(loss(f_ovl), argnums=(0, 2, 3))(*args)
    for a, b in zip(g0, g1):
        assert _rel_err(a, b) < GRAD_RTOL[name], (name, _rel_err(a, b))


def test_overlap_multidevice_parity(n_chunks=2):
    """Real 2-rank EP: dispatch/combine actually cross ranks.  (Deeper
    pipelines are exercised on the 1x1 mesh above — this compile is the
    expensive one, so one multi-device depth keeps CI within budget.)"""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    recipe = get_recipe("fp8_flow")
    mesh = make_mesh((1, 2), ("data", "model"))
    cfg, args = _toy_moe(T=256)
    f_sync = _sharded_block(recipe, cfg, mesh, moe_block)
    f_ovl = _sharded_block(recipe, cfg, mesh, moe_block_overlapped,
                           n_chunks=n_chunks)
    y0, d0 = f_sync(*args)
    y1, d1 = f_ovl(*args)
    assert float(d0) == 0.0 and float(d1) == 0.0
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32), atol=2e-2)

    def loss(fn):
        return lambda *a: jnp.sum(fn(*a)[0].astype(jnp.float32) ** 2)

    g0 = jax.grad(loss(f_sync), argnums=(0, 2, 3))(*args)
    g1 = jax.grad(loss(f_ovl), argnums=(0, 2, 3))(*args)
    for a, b in zip(g0, g1):
        assert _rel_err(a, b) < 2e-2


@pytest.mark.parametrize("n_chunks", [2, 4])
def test_overlap_cast_count_stays_two(n_chunks):
    """Chunk boundaries never re-quantize: ONE entry quantize over the whole
    block and ONE hoisted backward island quantize — the Fig.-2 count is 2
    at any pipeline depth, and no explicit dequantize ever materializes."""
    recipe = get_recipe("fp8_flow")
    mesh = make_mesh11()
    cfg, args = _toy_moe()
    f_ovl = _sharded_block(recipe, cfg, mesh, moe_block_overlapped,
                           n_chunks=n_chunks)
    with casts.ledger() as led:
        jax.grad(lambda *a: jnp.sum(f_ovl(*a)[0].astype(jnp.float32) ** 2),
                 argnums=(0, 2, 3))(*args)
    assert led.activation_casts() == 2, led.summary()
    assert not [e for e in led.events if e.kind == "dequantize"]


def test_overlap_fuses_dispatch_into_one_collective():
    """The synchronous block launches 5 forward all-to-alls (payload, scale,
    expert ids, probs, combine); the overlapped block packs payload+scales+
    metadata into ONE uint8 message per chunk: 2 per chunk total."""
    recipe = get_recipe("fp8_flow")
    mesh = make_mesh11()
    cfg, args = _toy_moe()

    def count_a2a(fn):
        return str(jax.make_jaxpr(fn)(*args)).count("all_to_all")

    assert count_a2a(_sharded_block(recipe, cfg, mesh, moe_block)) == 5
    for n in (1, 2, 4):
        f = _sharded_block(recipe, cfg, mesh, moe_block_overlapped,
                           n_chunks=n)
        assert count_a2a(f) == 2 * n


def test_dispatch_plan_chunking():
    assert DispatchPlan(n_chunks=4, min_chunk_tokens=64).chunks_for(256) == 4
    assert DispatchPlan(n_chunks=4, min_chunk_tokens=64).chunks_for(128) == 2
    assert DispatchPlan(n_chunks=4, min_chunk_tokens=64).chunks_for(63) == 1
    # clamps to a divisor of T
    assert DispatchPlan(n_chunks=3, min_chunk_tokens=1).chunks_for(256) == 2


# ---------------------------------------------------------------------------
# moe_block_decode: real drop fraction.
# ---------------------------------------------------------------------------
def test_moe_decode_reports_real_drop_frac():
    """All tokens route to expert 0 (uniform router => top_k tie-break picks
    index 0), overflowing C_dec: drop_frac must report the real dropped
    fraction, not 0.0."""
    recipe = get_recipe("bf16")
    mesh = make_mesh11()
    T, D, E = 64, 128, 4
    cfg = MoEConfig(n_experts=E, top_k=1, d_model=D, d_ff=128)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(T, D)),
                    jnp.bfloat16)
    wr = jnp.zeros((D, E), jnp.float32)
    w13 = jnp.ones((E, D, 256), jnp.bfloat16) * 0.01
    w2 = jnp.ones((E, 128, D), jnp.bfloat16) * 0.01

    def body(x, wr, w13, w2):
        y, m = moe_block_decode(recipe, cfg, x, wr, w13, w2)
        return m["drop_frac"]

    sm = shard_map(body, mesh=mesh,
                   in_specs=(P(None, None), P(None, None),
                             P("model", None, None), P("model", None, None)),
                   out_specs=P())
    # C_dec = round_up(2*64*1/4, 8) = 32 slots for expert 0; 64 assignments
    assert float(sm(x, wr, w13, w2)) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# moe_block_decode_overlapped: the prefetched (chunk-pipelined psum) decode
# path must match the synchronous psum path bitwise in the no-drop regime.
# ---------------------------------------------------------------------------
def _sharded_decode(recipe, cfg, mesh, block, **kw):
    """Decode-style sharding: tokens REPLICATED across the EP axis, experts
    sharded — the combine is a psum, not an all-to-all."""
    def body(x, wr, w13, w2):
        y, m = block(recipe, cfg, x, wr, w13, w2, **kw)
        # aux is rank-identical (full-batch router on replicated x); the
        # pmean proves the invariance to the replication checker and is
        # bitwise-neutral (sum of P equal po2-divisible terms)
        return y, jax.lax.pmean(m["aux_loss"], "model"), m["drop_frac"]

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, None), P(None, None),
                               P("model", None, None), P("model", None, None)),
                     out_specs=(P(None, None), P(), P()))


@pytest.mark.parametrize("name", ["fp8_flow", "bf16"])
@pytest.mark.parametrize("n_chunks", [2, 4])
def test_decode_overlap_parity(name, n_chunks):
    """Chunking the decode batch is exact: decode tokens never interact
    below the combine, the router runs over the WHOLE batch (aux identical
    at any depth), and the fp8 entry quantize happens once (row scales are
    row-local).  Bitwise parity vs the synchronous psum."""
    recipe = get_recipe(name)
    mesh = make_mesh11()
    cfg, args = _toy_moe(T=64, cf=4.0)
    y0, a0, d0 = _sharded_decode(recipe, cfg, mesh, moe_block_decode)(*args)
    y1, a1, d1 = _sharded_decode(recipe, cfg, mesh,
                                 moe_block_decode_overlapped,
                                 n_chunks=n_chunks)(*args)
    assert float(d0) == 0.0 and float(d1) == 0.0
    # per-token math is identical; the per-chunk C_dec changes the grouped
    # GEMM's padded shape, and XLA's shape-dependent tiling can wobble the
    # bf16 output by 1 ulp — tolerance pinned to that, far below fp8 error
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32), atol=1e-2)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a0))


def test_decode_overlap_parity_multidevice():
    """Real 2-rank EP: the combine psums actually cross ranks."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    recipe = get_recipe("fp8_flow")
    mesh = make_mesh((1, 2), ("data", "model"))
    cfg, args = _toy_moe(T=64, cf=4.0)
    y0, a0, d0 = _sharded_decode(recipe, cfg, mesh, moe_block_decode)(*args)
    y1, a1, d1 = _sharded_decode(recipe, cfg, mesh,
                                 moe_block_decode_overlapped,
                                 n_chunks=2)(*args)
    assert float(d0) == 0.0 and float(d1) == 0.0
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32), atol=1e-2)


def test_decode_overlap_pipelines_the_psum():
    """Depth n converts the single combine psum into an n-deep chain (+1
    for the drop-fraction scalar), with each chunk's dispatch/expert
    compute traced BETWEEN consecutive combine psums — the double-buffer
    window XLA's latency-hiding scheduler needs."""
    recipe = get_recipe("fp8_flow")
    mesh = make_mesh11()
    cfg, args = _toy_moe(T=64, cf=4.0)

    def jaxpr_of(block, **kw):
        return str(jax.make_jaxpr(
            lambda *a: _sharded_decode(recipe, cfg, mesh, block, **kw)(*a))(
            *args))

    jx_sync = jaxpr_of(moe_block_decode)
    # combine + drop_frac (+1: the harness's aux pmean lowers to a psum)
    assert jx_sync.count("psum") == 3
    for n in (2, 4):
        jx = jaxpr_of(moe_block_decode_overlapped, n_chunks=n)
        assert jx.count("psum") == n + 2, (n, jx.count("psum"))
        # grouped-FFN GEMMs appear between the first and last combine psum
        first, last = jx.find("psum"), jx.rfind("psum")
        assert jx.find("dot_general", first, last) != -1

    # tiny decode batches degrade to the synchronous depth
    assert DispatchPlan().decode_chunks_for(4) == 1
    assert DispatchPlan().decode_chunks_for(64) == 2
    assert DispatchPlan(decode_chunks=4).decode_chunks_for(64) == 4


# ---------------------------------------------------------------------------
# Chunked prefill: model-level parity + engine/scheduler invariants.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dense_setup():
    from repro.configs import get_arch
    from repro.models.lm import ParallelPlan, init_params
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    return cfg, mesh, plan, params


def _cos(a, b):
    a = np.asarray(a, np.float32).ravel()
    b = np.asarray(b, np.float32).ravel()
    return a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30)


def test_chunked_prefill_matches_monolithic(dense_setup):
    from repro.models.lm import paged_prefill
    from repro.serve.paged_kv import PageAllocator, init_paged_cache
    cfg, mesh, plan, params = dense_setup
    recipe = get_recipe("bf16")
    ps, mp = 8, 8
    prompt = list(np.random.default_rng(1).integers(1, cfg.vocab, 22))
    alloc = PageAllocator(32, ps)
    pages = alloc.alloc(alloc.pages_for(len(prompt)))
    ptrow = np.zeros((mp,), np.int32)
    ptrow[:len(pages)] = pages

    pools = init_paged_cache(cfg, 32, ps, fp8_kv=False)
    toks = np.zeros((1, 32), np.int32)
    toks[0, :len(prompt)] = prompt
    with mesh:
        lg_m, _ = paged_prefill(cfg, recipe, plan, params, pools,
                                jnp.asarray(ptrow), jnp.asarray(toks),
                                jnp.int32(len(prompt)))

    pools2 = init_paged_cache(cfg, 32, ps, fp8_kv=False)
    t1 = np.zeros((1, 16), np.int32)
    t1[0, :] = prompt[:16]
    t2 = np.zeros((1, 16), np.int32)
    t2[0, :len(prompt) - 16] = prompt[16:]
    with mesh:
        _, pools2 = paged_prefill(cfg, recipe, plan, params, pools2,
                                  jnp.asarray(ptrow), jnp.asarray(t1),
                                  jnp.int32(16))
        lg_c, _ = paged_prefill(cfg, recipe, plan, params, pools2,
                                jnp.asarray(ptrow), jnp.asarray(t2),
                                jnp.int32(len(prompt) - 16),
                                start=jnp.int32(16), history=True)
    assert _cos(lg_c[0, -1], lg_m[0, -1]) > 0.999
    assert int(np.argmax(np.asarray(lg_c[0, -1], np.float32))) == \
        int(np.argmax(np.asarray(lg_m[0, -1], np.float32)))


def _mk_engine(cfg, plan, params, **kw):
    from repro.core.recipes import get_recipe as _gr
    from repro.serve.engine import ServeConfig, ServeEngine
    ecfg = ServeConfig(max_batch=4, page_size=8, n_pages=64,
                       max_pages_per_req=8, token_budget=256,
                       prefill_buckets=(16,), fp8_kv=False, **kw)
    return ServeEngine(cfg, _gr("bf16"), plan, params, ecfg), ecfg


def test_engine_chunked_prefill_decode_not_starved(dense_setup):
    """While a long prompt prefills chunk-by-chunk, every already-resident
    request must decode one token per tick (decode is never starved by more
    than the one bounded chunk riding the tick)."""
    from repro.serve.scheduler import Request
    cfg, mesh, plan, params = dense_setup
    eng, ecfg = _mk_engine(cfg, plan, params, prefill_chunk=8)
    r = np.random.default_rng(0)
    short = Request(prompt=list(r.integers(1, cfg.vocab, 4)),
                    max_new_tokens=12)
    long_ = Request(prompt=list(r.integers(1, cfg.vocab, 33)),
                    max_new_tokens=2)
    results = {}
    eng.submit(short)
    assert eng.tick(0.0, results)           # admit + prefill `short`
    st_short = eng.sched.active[0]
    assert st_short.prefilled and len(st_short.generated) == 1
    eng.submit(long_)
    n_chunks = -(-33 // 8)                  # 5 chunks
    for i in range(n_chunks):
        before = len(st_short.generated)
        assert eng.tick(0.0, results)
        st_long = eng.sched.mid_prefill()
        if i < n_chunks - 1:
            assert st_long is not None and st_long.req is long_
            assert st_long.prefill_pos == (i + 1) * 8
            assert not st_long.prefilled   # first token only after last chunk
        else:
            assert eng.sched.mid_prefill() is None
        # the resident decoded exactly one token on EVERY prefill tick
        assert len(st_short.generated) == before + 1
    # long request sampled its first token on the final chunk's tick
    long_st = [s for s in eng.sched.active.values() if s.req is long_]
    assert long_st and len(long_st[0].generated) == 1


def test_engine_chunked_prefill_fcfs_and_completion(dense_setup):
    """Chunked prefill preserves FCFS admission order end-to-end and every
    request completes (prompts longer than the largest bucket included)."""
    from repro.serve.scheduler import Request
    cfg, mesh, plan, params = dense_setup
    eng, _ = _mk_engine(cfg, plan, params, prefill_chunk=16)
    r = np.random.default_rng(2)
    reqs = [Request(prompt=list(r.integers(1, cfg.vocab, n)),
                    max_new_tokens=3)
            for n in (40, 9, 25, 5)]        # 40 > largest bucket (16)
    results = eng.run(reqs, realtime=False)
    assert len(results) == len(reqs)
    for req in reqs:
        assert len(results[req.rid]["tokens"]) == req.max_new_tokens
    # FCFS: first-token order == submission order
    first = sorted(results.items(), key=lambda kv: kv[1]["first_token"])
    assert [rid for rid, _ in first] == [req.rid for req in reqs]
    assert eng.alloc.free_pages == 63       # every page returned


def test_engine_rejects_long_prompt_without_chunking(dense_setup):
    from repro.serve.scheduler import Request
    cfg, mesh, plan, params = dense_setup
    eng, _ = _mk_engine(cfg, plan, params)            # prefill_chunk=None
    with pytest.raises(ValueError, match="prefill_chunk"):
        eng.submit(Request(prompt=[1] * 40, max_new_tokens=2))
    from repro.serve.engine import ServeConfig, ServeEngine
    with pytest.raises(ValueError, match="prefill_chunk"):
        _mk_engine(cfg, plan, params, prefill_chunk=32)   # > largest bucket


# ---------------------------------------------------------------------------
# serve_step unified with the engine's sampling.
# ---------------------------------------------------------------------------
def test_serve_step_unified_sampling_and_per_request_pos(dense_setup):
    from repro.models.lm import init_cache
    from repro.serve.engine import sample_tokens
    from repro.serve.serve_step import make_serve_step
    cfg, mesh, plan, params = dense_setup
    B = 2
    toks = jnp.asarray(np.random.default_rng(3).integers(
        1, cfg.vocab, (B, 1)), jnp.int32)

    step = make_serve_step(cfg, recipe := get_recipe("bf16"), plan)
    with mesh:
        # greedy default == engine greedy lane; per-request pos vector honored
        nt_vec, _ = step(params, init_cache(cfg, B, 32), toks,
                         jnp.asarray([2, 2], jnp.int32))
        nt_scl, _ = step(params, init_cache(cfg, B, 32), toks, jnp.int32(2))
    assert nt_vec.shape == (B, 1)
    np.testing.assert_array_equal(np.asarray(nt_vec), np.asarray(nt_scl))

    # stochastic lane routes through engine.sample_tokens (same key => same
    # tokens), greedy rows (temp<=0) stay deterministic
    step_k = make_serve_step(cfg, recipe, plan, top_k=8)
    temps = jnp.asarray([0.0, 1.5], jnp.float32)
    key = jax.random.key(7)
    with mesh:
        from repro.models.lm import decode_step
        lg, _ = decode_step(cfg, recipe, plan, params,
                            init_cache(cfg, B, 32), toks, jnp.int32(2))
        want = sample_tokens(lg[:, -1, :], key, temps, 8)
        got, _ = step_k(params, init_cache(cfg, B, 32), toks, jnp.int32(2),
                        temps=temps, key=key)
    np.testing.assert_array_equal(np.asarray(got[:, 0]), np.asarray(want))
    assert int(got[0, 0]) == int(nt_scl[0, 0])      # greedy row unchanged
