"""Properties of the paper's core numerics (§3.1): po2 scales, idempotence,
double quantization error, scaling-aware transpose exactness.

Hypothesis (when installed) drives the shapes/value-distributions; each
property is the formal statement of an equation in the paper:
  Eq. 5-8  : requantization at the same layout is value-idempotent
  Eq. 9    : naive re-layout with 'linear' scales has nonzero error
  Alg. 1   : the direct transpose is exact up to subnormal underflow

Without hypothesis the same properties run over a fixed seeded grid
(`SEEDED_CASES`) so the invariants are always exercised.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.fp8 import BLOCK, TILE, is_po2
from repro.core.quant import (_dequantize_nocount, quantize_rowwise)
from repro.core.transpose import (double_quant_error, transpose_direct,
                                  transpose_naive)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed; seeded fallback "
    "tests below cover the same properties")


def _rand_x(seed, rows, cols, spread=2.0):
    r = np.random.default_rng(seed)
    return jnp.asarray((r.normal(size=(rows, cols))
                        * np.exp(r.normal(size=(rows, cols)) * spread)
                        ).astype(np.float32))


SHAPE_POOL = [(128, 128), (256, 128), (128, 384), (256, 256)]
# fixed (seed, shape, spread) grid for the no-hypothesis fallback
SEEDED_CASES = [(s * 7919 + 13, SHAPE_POOL[s % len(SHAPE_POOL)],
                 0.5 + 0.7 * (s % 4)) for s in range(6)]


# ---------------------------------------------------------------------------
# Property implementations (shared between hypothesis and seeded drivers).
# ---------------------------------------------------------------------------
def check_scales_are_po2(seed, shape, spread):
    q = quantize_rowwise(_rand_x(seed, *shape, spread))
    assert bool(is_po2(q.scale).all())


def check_value_idempotence(seed, shape):
    """Eq. 5-8: D(Q(D(Q(x)))) == D(Q(x)) exactly (po2 scales)."""
    x = _rand_x(seed, *shape)
    d1 = _dequantize_nocount(quantize_rowwise(x))
    d2 = _dequantize_nocount(quantize_rowwise(d1))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))


def check_double_quant_error_po2_vs_linear(seed, shape):
    """Eq. 1/9: linear scales accumulate double-quantization error; po2
    scales shrink it by orders of magnitude (only subnormal flushes left)."""
    x = _rand_x(seed, *shape)
    e_lin = float(jnp.mean(jnp.abs(double_quant_error(x, "linear"))))
    e_po2 = float(jnp.mean(jnp.abs(double_quant_error(x, "po2"))))
    assert e_po2 <= e_lin
    if e_lin > 1e-6:
        assert e_po2 < 0.05 * e_lin


def check_direct_transpose_exact_up_to_underflow(seed, shape, spread):
    """Algorithm 1: dequant(T_direct(q)) equals dequant(q)^T except where the
    re-based encoding underflows; those errors are bounded by half a
    subnormal ulp at the block scale (s_max * 2^-10)."""
    q = quantize_rowwise(_rand_x(seed, *shape, spread))
    qt = transpose_direct(q)
    a = np.asarray(_dequantize_nocount(qt, jnp.float32))
    b = np.asarray(_dequantize_nocount(q, jnp.float32)).T
    diff = np.abs(a - b)
    s_up = np.repeat(np.asarray(qt.scale), TILE, axis=-1)
    assert (diff <= s_up * 2.0 ** -10 + 1e-30).all()
    # mismatching entries must be small values (underflow candidates)
    mism = diff > 0
    if mism.any():
        assert (np.abs(b)[mism] < s_up[mism] * 2.0 ** -6).all()


def check_direct_transpose_involution_values(seed):
    """T(T(q)) dequantizes to dequant(q) up to (already-flushed) underflow."""
    q = quantize_rowwise(_rand_x(seed, 128, 128))
    qtt = transpose_direct(transpose_direct(q))
    a = np.asarray(_dequantize_nocount(qtt, jnp.float32))
    b = np.asarray(_dequantize_nocount(q, jnp.float32))
    s_up = np.repeat(np.asarray(qtt.scale), TILE, axis=-1)
    assert (np.abs(a - b) <= s_up * 2.0 ** -9).all()


# ---------------------------------------------------------------------------
# Hypothesis drivers (richer distributions; skipped when not installed).
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    shapes = st.sampled_from(SHAPE_POOL)

    @needs_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), shape=shapes,
           spread=st.floats(0.1, 3.0))
    def test_scales_are_po2(seed, shape, spread):
        check_scales_are_po2(seed, shape, spread)

    @needs_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), shape=shapes)
    def test_value_idempotence(seed, shape):
        check_value_idempotence(seed, shape)

    @needs_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), shape=shapes)
    def test_double_quant_error_po2_vs_linear(seed, shape):
        check_double_quant_error_po2_vs_linear(seed, shape)

    @needs_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), shape=shapes,
           spread=st.floats(0.1, 3.0))
    def test_direct_transpose_exact_up_to_underflow(seed, shape, spread):
        check_direct_transpose_exact_up_to_underflow(seed, shape, spread)

    @needs_hypothesis
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_direct_transpose_involution_values(seed):
        check_direct_transpose_involution_values(seed)


# ---------------------------------------------------------------------------
# Seeded fallback drivers — always run, so the core invariants are exercised
# on environments without hypothesis (e.g. the minimal CI image).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,shape,spread", SEEDED_CASES)
def test_seeded_scales_are_po2(seed, shape, spread):
    check_scales_are_po2(seed, shape, spread)


@pytest.mark.parametrize("seed,shape,spread", SEEDED_CASES)
def test_seeded_value_idempotence(seed, shape, spread):
    check_value_idempotence(seed, shape)


@pytest.mark.parametrize("seed,shape,spread", SEEDED_CASES)
def test_seeded_double_quant_error_po2_vs_linear(seed, shape, spread):
    check_double_quant_error_po2_vs_linear(seed, shape)


@pytest.mark.parametrize("seed,shape,spread", SEEDED_CASES)
def test_seeded_direct_transpose_exact(seed, shape, spread):
    check_direct_transpose_exact_up_to_underflow(seed, shape, spread)


@pytest.mark.parametrize("seed", [c[0] for c in SEEDED_CASES])
def test_seeded_direct_transpose_involution(seed):
    check_direct_transpose_involution_values(seed)


# ---------------------------------------------------------------------------
# Deterministic end-to-end checks (never needed hypothesis).
# ---------------------------------------------------------------------------
def test_direct_adds_no_relayout_error():
    """The end-to-end claim, measured as ADDED error of the re-layout step
    (the first quantization's error is the recipe's baseline either way):

      direct transpose on po2 scales : ~0 added error (underflow only)
      dequant->transpose->requant on linear scales : large added error

    Note the documented trade-off: po2 (UE8M0-style) scales have a larger
    BASE quantization error than linear scales (ceil-to-power-of-two wastes
    up to half the fp8 range) — the paper accepts this for exact re-layout;
    convergence parity is validated separately (Fig. 6 reproduction)."""
    x = _rand_x(7, 256, 256, 2.5)
    q_lin = quantize_rowwise(x, scale_mode="linear")
    q_po2 = quantize_rowwise(x, scale_mode="po2")
    ref = np.asarray(x).T

    naive = _dequantize_nocount(transpose_naive(q_lin, "linear"), jnp.float32)
    direct = _dequantize_nocount(transpose_direct(q_po2), jnp.float32)
    base_po2 = np.abs(np.asarray(
        _dequantize_nocount(q_po2, jnp.float32)).T - ref).mean()
    base_lin = np.abs(np.asarray(
        _dequantize_nocount(q_lin, jnp.float32)).T - ref).mean()
    added_direct = np.abs(np.asarray(direct) - ref).mean() - base_po2
    added_naive = np.abs(np.asarray(naive) - ref).mean() - base_lin
    assert added_direct < 0.3 * added_naive
    assert added_direct <= 0.05 * base_po2 + 1e-9


def test_transpose_rejects_bad_tiles():
    x = _rand_x(0, 128, 128)
    q = quantize_rowwise(x)
    bad = type(q)(data=q.data[:100], scale=q.scale[:100], tile=q.tile)
    with pytest.raises(ValueError):
        transpose_direct(bad)
