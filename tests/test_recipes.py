"""Recipe-level numerics: all FP8 recipes track the BF16 gradients (cosine
similarity), fp8_flow is not worse than naive_fp8, and the FP8 cotangent of
the dispatch path round-trips exactly through permute/all-to-all."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.linear import expert_ffn, quantize_entry
from repro.core.quant import QTensor, quantize_rowwise, _dequantize_nocount
from repro.core.recipes import get_recipe


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30))


def _setup(seed=0, E=2, C=128, K=256, F=128):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(E, C, K)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    w13 = jnp.asarray(r.normal(size=(E, K, 2 * F)).astype(np.float32) * 0.05)
    w2 = jnp.asarray(r.normal(size=(E, F, K)).astype(np.float32) * 0.05)
    return x, w13, w2


def _grads(name, x, w13, w2, act="swiglu"):
    recipe = get_recipe(name)

    def L(x, w13, w2):
        xi = quantize_entry(recipe, x) if name in ("fp8_flow",) else x
        y = expert_ffn(recipe, act, (), (), xi, w13, w2)
        return jnp.sum(jnp.square(y.astype(jnp.float32)))

    return jax.grad(L, argnums=(0, 1, 2))(x, w13, w2)


@pytest.mark.parametrize("act", ["swiglu", "geglu", "gelu", "relu"])
def test_recipe_grads_track_bf16(act):
    x, w13, w2 = _setup()
    if act in ("gelu", "relu"):
        w13 = w13[:, :, :128]
    gb = _grads("bf16", x, w13, w2, act)
    for name in ["blockwise", "naive_fp8", "fp8_flow"]:
        g = _grads(name, x, w13, w2, act)
        cosines = [_cos(a, b) for a, b in zip(g, gb)]
        assert min(cosines) > 0.97, (name, act, cosines)


def test_flow_not_worse_than_naive():
    """fp8_flow (2 casts, direct transpose) must match or beat naive_fp8
    (12 casts, double-quantization) in gradient fidelity vs BF16."""
    votes = 0
    trials = 5
    for seed in range(trials):
        x, w13, w2 = _setup(seed)
        gb = _grads("bf16", x, w13, w2)
        gf = _grads("fp8_flow", x, w13, w2)
        gn = _grads("naive_fp8", x, w13, w2)
        cf = min(_cos(a, b) for a, b in zip(gf, gb))
        cn = min(_cos(a, b) for a, b in zip(gn, gb))
        votes += int(cf >= cn - 0.005)
    assert votes >= trials - 1, f"flow worse than naive in {trials-votes} runs"


def test_fp8_cotangent_roundtrip_through_permute():
    """permute_q routes FP8 cotangents via inverse maps with zero loss."""
    from repro.core.moe import permute_q
    recipe = get_recipe("fp8_flow")
    r = np.random.default_rng(2)
    T, D = 64, 256
    x = jnp.asarray(r.normal(size=(T, D)).astype(np.float32))
    q = quantize_rowwise(x)
    perm = r.permutation(T)
    row_map = jnp.asarray(perm.astype(np.int32))
    inv = np.empty(T, np.int32)
    inv[perm] = np.arange(T)
    inv_map = jnp.asarray(inv)

    def f(data, scale):
        qq = QTensor(data, scale, (1, 128))
        out = permute_q(recipe, qq, row_map, inv_map)
        return jnp.sum(_dequantize_nocount(out, jnp.float32) ** 2)

    g_data = jax.grad(lambda d: f(d, q.scale))(q.data)
    # gradient exists, is fp8-typed, and matches the permuted structure
    assert g_data.dtype == q.data.dtype
    assert g_data.shape == q.data.shape


def test_save_h_matches_recompute():
    """AC=sel (recompute h) vs AC=off (save h) produce identical grads."""
    x, w13, w2 = _setup(3)
    r1 = get_recipe("fp8_flow", save_h=False)
    r2 = get_recipe("fp8_flow", save_h=True)

    def L(recipe):
        def fn(x, w13, w2):
            xi = quantize_entry(recipe, x)
            y = expert_ffn(recipe, "swiglu", (), (), xi, w13, w2)
            return jnp.sum(jnp.square(y.astype(jnp.float32)))
        return jax.grad(fn, argnums=(0, 1, 2))(x, w13, w2)

    for a, b in zip(L(r1), L(r2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_grad_compression_roundtrip():
    from repro.runtime.compression import compress_decompress
    r = np.random.default_rng(4)
    g = jnp.asarray(r.normal(size=(1000,)).astype(np.float32) * 1e-3)
    g2 = compress_decompress(g)
    assert _cos(g, g2) > 0.999
    rel = np.abs(np.asarray(g2) - np.asarray(g)) / (np.abs(np.asarray(g)) + 1e-9)
    assert np.median(rel) < 0.1
