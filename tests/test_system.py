"""End-to-end behaviour tests for the paper's system (multi-device):
the EP dispatch conserves tokens, recipes agree across the full MoE block,
decode-EP agrees with train-mode routing, and the FP8 dispatch payload is
actually 1-byte on the wire (HLO inspection)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core.moe import MoEConfig, moe_block, _dispatch_plan, _expert_plan
from repro.core.recipes import get_recipe
from tests.conftest import make_mesh11


def test_dispatch_plan_conserves_assignments():
    r = np.random.default_rng(0)
    T, k, EP, E_loc, C = 64, 2, 4, 2, 64
    ids = jnp.asarray(r.integers(0, EP * E_loc, (T, k)).astype(np.int32))
    row_map, slot_e, slot_a, drop = _dispatch_plan(ids, k, EP, E_loc, C)
    row_map = np.asarray(row_map)
    valid = row_map >= 0
    # ample capacity -> nothing dropped; every assignment has a slot
    assert float(drop) == 0.0
    assert valid.sum() == T * k
    # each token appears exactly k times
    counts = np.bincount(row_map[valid], minlength=T)
    assert (counts == k).all()
    # slots are grouped by destination rank and carry the right local expert
    se = np.asarray(slot_e)
    sa = np.asarray(slot_a)
    flat = np.asarray(ids).reshape(-1)
    for s in np.nonzero(valid)[0]:
        dest = s // C
        assert flat[sa[s]] // E_loc == dest
        assert flat[sa[s]] % E_loc == se[s]


def test_expert_plan_inverse_consistency():
    r = np.random.default_rng(1)
    R, E_loc, C = 128, 4, 48
    recv_e = jnp.asarray(
        np.where(r.random(R) < 0.1, -1,
                 r.integers(0, E_loc, R)).astype(np.int32))
    row_map, ret_map = _expert_plan(recv_e, E_loc, C)
    rm, im = np.asarray(row_map), np.asarray(ret_map)
    for slot, src in enumerate(rm):
        if src >= 0:
            assert im[src] == slot
    for src, slot in enumerate(im):
        if slot >= 0:
            assert rm[slot] == src


def test_moe_block_output_is_weighted_expert_mix():
    """bf16 recipe on a 1x1 mesh: replace experts with identity-scaled
    weights and check the combine reproduces sum_k p_k * f_e(x)."""
    mesh = make_mesh11()
    E, D, F, k, T = 2, 256, 128, 1, 128
    cfg = MoEConfig(n_experts=E, top_k=k, d_model=D, d_ff=F,
                    capacity_factor=4.0)
    recipe = get_recipe("bf16")
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(T, D)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    wr = jnp.asarray(r.normal(size=(D, E)).astype(np.float32))
    w13 = jnp.asarray(r.normal(size=(E, D, 2 * F)).astype(np.float32) * 0.05)
    w2 = jnp.asarray(r.normal(size=(E, F, D)).astype(np.float32) * 0.05)

    def body(x, wr, w13, w2):
        y, m = moe_block(recipe, cfg, x, wr, w13, w2)
        return y

    sm = shard_map(body, mesh=mesh,
                   in_specs=(P(("data", "model"), None), P(None, None),
                             P("model", None, None), P("model", None, None)),
                   out_specs=P(("data", "model"), None))
    with mesh:
        y = sm(x, wr, w13, w2)

    # reference: route every token to its argmax expert with p=1 (top-1,
    # renormalized)
    logits = np.asarray(x, np.float32) @ np.asarray(wr)
    e_star = logits.argmax(-1)
    from repro.core.linear import _swiglu
    xf = np.asarray(x, np.float32)
    ref = np.zeros((T, D), np.float32)
    for e in range(E):
        sel = e_star == e
        h = xf[sel] @ np.asarray(w13[e])
        a = np.asarray(_swiglu(jnp.asarray(h)), np.float32)
        ref[sel] = a @ np.asarray(w2[e])
    got = np.asarray(y, np.float32)
    cos = (ref.ravel() @ got.ravel()) / (
        np.linalg.norm(ref) * np.linalg.norm(got) + 1e-30)
    assert cos > 0.99, cos


def test_fp8_dispatch_payload_is_one_byte():
    """HLO check: the fp8_flow dispatch all-to-all moves f8e4m3fn payloads;
    bf16 recipe moves bf16 — the wire-format claim of the paper."""
    mesh = make_mesh11()
    E, D, F, k, T = 2, 256, 128, 2, 128
    cfg = MoEConfig(n_experts=E, top_k=k, d_model=D, d_ff=F)
    wr_s, w13_s, w2_s = (P(None, None), P("model", None, None),
                         P("model", None, None))

    def lowered_text(recipe_name):
        recipe = get_recipe(recipe_name)

        def body(x, wr, w13, w2):
            y, _ = moe_block(recipe, cfg, x, wr, w13, w2)
            return y

        sm = shard_map(body, mesh=mesh,
                       in_specs=(P(("data", "model"), None), wr_s, w13_s,
                                 w2_s),
                       out_specs=P(("data", "model"), None))
        args = [jax.ShapeDtypeStruct((T, D), jnp.bfloat16),
                jax.ShapeDtypeStruct((D, E), jnp.float32),
                jax.ShapeDtypeStruct((E, D, 2 * F), jnp.float32),
                jax.ShapeDtypeStruct((E, F, D), jnp.float32)]
        with mesh:
            return jax.jit(sm).lower(*args).as_text()

    flow = lowered_text("fp8_flow").lower()
    assert "f8e4m3" in flow
    bf = lowered_text("bf16").lower()
    assert "f8e4m3" not in bf
