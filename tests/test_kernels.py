"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and value distributions."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.fp8 import TILE
from repro.core.quant import QTensor, quantize, _dequantize_nocount
from repro.kernels import ops, ref


def _bits(a):
    return np.asarray(a).view(np.uint8)


def _x(seed, *shape, spread=1.5):
    r = np.random.default_rng(seed)
    return jnp.asarray((r.normal(size=shape)
                        * np.exp(r.normal(size=shape) * spread)
                        ).astype(np.float32))


@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (384, 256)])
@pytest.mark.parametrize("seed", [0, 1])
def test_quantize_kernel(shape, seed):
    x = _x(seed, *shape)
    q = ops.quantize_rowwise(x)
    dr, sr = ref.quantize_rowwise_ref(x)
    assert np.array_equal(_bits(q.data), _bits(dr))
    assert np.array_equal(np.asarray(q.scale), np.asarray(sr))


@pytest.mark.parametrize("shape", [(128, 128), (256, 128), (128, 256),
                                   (384, 384)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fp8_transpose_kernel_bit_exact(shape, seed):
    """The integer exponent-rebase kernel must match the po2-multiply oracle
    BIT FOR BIT (including RNE shifts into the subnormal range)."""
    x = _x(seed, *shape, spread=2.5)
    q = ops.quantize_rowwise(x)
    qt = ops.fp8_transpose(q)
    dr, sr = ref.fp8_transpose_ref(q.data, q.scale)
    assert np.array_equal(_bits(qt.data), _bits(dr))
    assert np.array_equal(np.asarray(qt.scale), np.asarray(sr))


def test_fp8_transpose_subnormal_edge():
    """Force large scale spread within a block so re-basing shifts values
    deep into (and past) the subnormal range."""
    r = np.random.default_rng(3)
    x = r.normal(size=(128, 128)).astype(np.float32)
    x[::2] *= 2.0 ** 12    # alternate rows huge -> s_max >> s of small rows
    x[1::2] *= 2.0 ** -10
    q = ops.quantize_rowwise(jnp.asarray(x))
    qt = ops.fp8_transpose(q)
    dr, sr = ref.fp8_transpose_ref(q.data, q.scale)
    assert np.array_equal(_bits(qt.data), _bits(dr))


@pytest.mark.parametrize("m,f", [(128, 128), (256, 256), (128, 384)])
def test_fused_swiglu_quant_kernel(m, f):
    h = _x(11, m, 2 * f, spread=0.5).astype(jnp.bfloat16)
    q = ops.fused_swiglu_quant(h)
    dr, sr = ref.fused_swiglu_quant_ref(h)
    assert np.array_equal(_bits(q.data), _bits(dr))
    assert np.array_equal(np.asarray(q.scale), np.asarray(sr))


@pytest.mark.parametrize("e,c,k,n", [(2, 128, 128, 128), (4, 128, 256, 128),
                                     (1, 256, 384, 256)])
def test_grouped_gemm_kernel(e, c, k, n):
    x = _x(5, e, c, k, spread=0.5)
    w = _x(6, e, k, n, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    out_k = ops.grouped_gemm_fp8(qx, qw)
    out_r = ref.grouped_gemm_fp8_ref(qx.data, qx.scale, qw.data, qw.scale)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)
    # against the dequantized ground truth (same math, unordered sum)
    gt = np.einsum("eck,ekn->ecn",
                   np.asarray(_dequantize_nocount(qx, jnp.float32)),
                   np.asarray(_dequantize_nocount(qw, jnp.float32)))
    rel = np.abs(np.asarray(out_k, np.float32) - gt) / (np.abs(gt) + 1e-2)
    assert rel.mean() < 2e-2


@pytest.mark.parametrize("e,m,n,c", [(2, 128, 128, 128), (1, 256, 128, 256)])
def test_grouped_gemm_nt_kernel(e, m, n, c):
    a = _x(7, e, m, c, spread=0.5)
    b = _x(8, e, n, c, spread=0.5) * 0.1
    qa = quantize(a, (1, 1, TILE), tag="t")
    qb = quantize(b, (1, 1, TILE), tag="t")
    out_k = ops.grouped_gemm_nt_fp8(qa, qb)
    out_r = ref.grouped_gemm_nt_fp8_ref(qa.data, qa.scale, qb.data, qb.scale)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-2, atol=2e-2)


def test_grouped_gemm_quant_out_kernel():
    e, c, k, n = 2, 128, 256, 128
    x = _x(9, e, c, k, spread=0.5)
    w = _x(10, e, k, n, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    q_k = ops.grouped_gemm_fp8_quant_out(qx, qw)
    dr, sr = ref.grouped_gemm_fp8_quant_out_ref(qx.data, qx.scale,
                                                qw.data, qw.scale)
    assert np.array_equal(_bits(q_k.data), _bits(dr))
    assert np.array_equal(np.asarray(q_k.scale), np.asarray(sr))


@pytest.mark.parametrize("t,d,n_out", [(32, 256, 48), (64, 128, 64),
                                       (16, 128, 40)])
def test_fused_permute_pad_kernel(t, d, n_out):
    r = np.random.default_rng(12)
    x = jnp.asarray(r.normal(size=(t, d))).astype(jnp.float8_e4m3fn)
    sc = jnp.asarray(np.exp2(r.integers(-8, 8, (t, d // TILE))
                             ).astype(np.float32))
    row_map = np.full(n_out, -1, np.int32)
    perm = r.permutation(t)[:min(t, n_out)]
    row_map[:len(perm)] = perm
    row_map = jnp.asarray(row_map)
    q = QTensor(data=x, scale=sc, tile=(1, TILE))
    out = ops.fused_permute_pad(q, row_map, n_out)
    xr, sr = ref.fused_permute_pad_ref(x, sc, row_map, n_out)
    assert np.array_equal(_bits(out.data), _bits(xr))
    assert np.array_equal(np.asarray(out.scale), np.asarray(sr))


def test_xla_path_matches_pallas_path():
    """linear.py's XLA fallbacks must agree with the Pallas kernels (the
    dry-run lowers the XLA path; TPU runs the kernels)."""
    from repro.core.linear import _ggemm, _ggemm_nt, _t_direct
    from repro.core.recipes import get_recipe
    r_x = get_recipe("fp8_flow", use_pallas=False)
    r_p = get_recipe("fp8_flow", use_pallas=True)
    x = _x(13, 2, 128, 256, spread=0.5)
    w = _x(14, 2, 256, 128, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    np.testing.assert_allclose(
        np.asarray(_ggemm(r_x, qx, qw), np.float32),
        np.asarray(_ggemm(r_p, qx, qw), np.float32), rtol=2e-2, atol=2e-2)
    ta, tb = _t_direct(r_x, qx), _t_direct(r_p, qx)
    assert np.array_equal(_bits(ta.data), _bits(tb.data))
