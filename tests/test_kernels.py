"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and value distributions."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.fp8 import TILE
from repro.core.quant import QTensor, quantize, _dequantize_nocount
from repro.kernels import ops, ref


def _bits(a):
    return np.asarray(a).view(np.uint8)


def _x(seed, *shape, spread=1.5):
    r = np.random.default_rng(seed)
    return jnp.asarray((r.normal(size=shape)
                        * np.exp(r.normal(size=shape) * spread)
                        ).astype(np.float32))


@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (384, 256)])
@pytest.mark.parametrize("seed", [0, 1])
def test_quantize_kernel(shape, seed):
    x = _x(seed, *shape)
    q = ops.quantize_rowwise(x)
    dr, sr = ref.quantize_rowwise_ref(x)
    assert np.array_equal(_bits(q.data), _bits(dr))
    assert np.array_equal(np.asarray(q.scale), np.asarray(sr))


@pytest.mark.parametrize("shape", [(128, 128), (256, 128), (128, 256),
                                   (384, 384)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fp8_transpose_kernel_bit_exact(shape, seed):
    """The integer exponent-rebase kernel must match the po2-multiply oracle
    BIT FOR BIT (including RNE shifts into the subnormal range)."""
    x = _x(seed, *shape, spread=2.5)
    q = ops.quantize_rowwise(x)
    qt = ops.fp8_transpose(q)
    dr, sr = ref.fp8_transpose_ref(q.data, q.scale)
    assert np.array_equal(_bits(qt.data), _bits(dr))
    assert np.array_equal(np.asarray(qt.scale), np.asarray(sr))


def test_fp8_transpose_subnormal_edge():
    """Force large scale spread within a block so re-basing shifts values
    deep into (and past) the subnormal range."""
    r = np.random.default_rng(3)
    x = r.normal(size=(128, 128)).astype(np.float32)
    x[::2] *= 2.0 ** 12    # alternate rows huge -> s_max >> s of small rows
    x[1::2] *= 2.0 ** -10
    q = ops.quantize_rowwise(jnp.asarray(x))
    qt = ops.fp8_transpose(q)
    dr, sr = ref.fp8_transpose_ref(q.data, q.scale)
    assert np.array_equal(_bits(qt.data), _bits(dr))


@pytest.mark.parametrize("m,f", [(128, 128), (256, 256), (128, 384)])
def test_fused_swiglu_quant_kernel(m, f):
    h = _x(11, m, 2 * f, spread=0.5).astype(jnp.bfloat16)
    q = ops.fused_swiglu_quant(h)
    dr, sr = ref.fused_swiglu_quant_ref(h)
    assert np.array_equal(_bits(q.data), _bits(dr))
    assert np.array_equal(np.asarray(q.scale), np.asarray(sr))


@pytest.mark.parametrize("e,c,k,n", [(2, 128, 128, 128), (4, 128, 256, 128),
                                     (1, 256, 384, 256)])
def test_grouped_gemm_kernel(e, c, k, n):
    x = _x(5, e, c, k, spread=0.5)
    w = _x(6, e, k, n, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    out_k = ops.grouped_gemm_fp8(qx, qw)
    out_r = ref.grouped_gemm_fp8_ref(qx.data, qx.scale, qw.data, qw.scale)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)
    # against the dequantized ground truth (same math, unordered sum)
    gt = np.einsum("eck,ekn->ecn",
                   np.asarray(_dequantize_nocount(qx, jnp.float32)),
                   np.asarray(_dequantize_nocount(qw, jnp.float32)))
    rel = np.abs(np.asarray(out_k, np.float32) - gt) / (np.abs(gt) + 1e-2)
    assert rel.mean() < 2e-2


@pytest.mark.parametrize("e,m,n,c", [(2, 128, 128, 128), (1, 256, 128, 256)])
def test_grouped_gemm_nt_kernel(e, m, n, c):
    a = _x(7, e, m, c, spread=0.5)
    b = _x(8, e, n, c, spread=0.5) * 0.1
    qa = quantize(a, (1, 1, TILE), tag="t")
    qb = quantize(b, (1, 1, TILE), tag="t")
    out_k = ops.grouped_gemm_nt_fp8(qa, qb)
    out_r = ref.grouped_gemm_nt_fp8_ref(qa.data, qa.scale, qb.data, qb.scale)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-2, atol=2e-2)


def test_grouped_gemm_quant_out_kernel():
    e, c, k, n = 2, 128, 256, 128
    x = _x(9, e, c, k, spread=0.5)
    w = _x(10, e, k, n, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    q_k = ops.grouped_gemm_fp8_quant_out(qx, qw)
    dr, sr = ref.grouped_gemm_fp8_quant_out_ref(qx.data, qx.scale,
                                                qw.data, qw.scale)
    assert np.array_equal(_bits(q_k.data), _bits(dr))
    assert np.array_equal(np.asarray(q_k.scale), np.asarray(sr))


@pytest.mark.parametrize("t,d,n_out", [(32, 256, 48), (64, 128, 64),
                                       (16, 128, 40)])
def test_fused_permute_pad_kernel(t, d, n_out):
    r = np.random.default_rng(12)
    x = jnp.asarray(r.normal(size=(t, d))).astype(jnp.float8_e4m3fn)
    sc = jnp.asarray(np.exp2(r.integers(-8, 8, (t, d // TILE))
                             ).astype(np.float32))
    row_map = np.full(n_out, -1, np.int32)
    perm = r.permutation(t)[:min(t, n_out)]
    row_map[:len(perm)] = perm
    row_map = jnp.asarray(row_map)
    q = QTensor(data=x, scale=sc, tile=(1, TILE))
    out = ops.fused_permute_pad(q, row_map, n_out)
    xr, sr = ref.fused_permute_pad_ref(x, sc, row_map, n_out)
    assert np.array_equal(_bits(out.data), _bits(xr))
    assert np.array_equal(np.asarray(out.scale), np.asarray(sr))


# ---------------------------------------------------------------------------
# Masked grouped-GEMM layout: skewed-routing parity vs the padded kernels,
# tile-granular oracle semantics, alignment padding, and metadata contracts.
# ---------------------------------------------------------------------------
def _skew(kind, E, C, seed=0):
    """Per-expert live-row counts for the routing-skew patterns."""
    r = np.random.default_rng(seed)
    mm = {"zero_expert": [0] + [C] * (E - 1),
          "all_to_one": [C] + [0] * (E - 1),
          "random": list(r.integers(0, C + 1, E))}[kind]
    return jnp.asarray(np.asarray(mm, np.int32))


def _zero_dead_rows(q, mm):
    """Zero payload rows beyond each expert's count (scale -> 1.0) — the
    dispatch-layout invariant the masked kernels rely on for bitwise parity."""
    E, C = q.data.shape[:2]
    live = jnp.asarray(np.arange(C)[None, :] < np.asarray(mm)[:, None])
    data = jnp.where(live[..., None], q.data.astype(jnp.float32),
                     0.0).astype(q.data.dtype)
    scale = jnp.where(live[..., None], q.scale, 1.0)
    return QTensor(data, scale, q.tile)


def _gg_operands(seed=21, E=3, C=256, K=256, N=128):
    x = _x(seed, E, C, K, spread=0.5)
    w = _x(seed + 1, E, K, N, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    return qx, qw


@pytest.mark.parametrize("skew", ["zero_expert", "all_to_one", "random"])
def test_masked_vs_padded_bitwise(skew):
    """Masked kernels must be BITWISE the padded kernels on the zero-padded
    dispatch layout, under every routing skew (incl. empty experts)."""
    qx, qw = _gg_operands()
    mm = _skew(skew, *qx.data.shape[:2])
    qx = _zero_dead_rows(qx, mm)
    out_m = ops.grouped_gemm_fp8_masked(qx, qw, mm)
    out_p = ops.grouped_gemm_fp8(qx, qw)
    assert np.array_equal(np.asarray(out_m).view(np.uint16),
                          np.asarray(out_p).view(np.uint16))
    q_m = ops.grouped_gemm_fp8_masked_quant_out(qx, qw, mm)
    q_p = ops.grouped_gemm_fp8_quant_out(qx, qw)
    assert np.array_equal(_bits(q_m.data), _bits(q_p.data))
    assert np.array_equal(np.asarray(q_m.scale), np.asarray(q_p.scale))


@pytest.mark.parametrize("skew", ["zero_expert", "all_to_one", "random"])
def test_masked_nt_vs_padded_bitwise(skew):
    """NT (Wgrad) form: masked contraction-tile skip is bitwise-invisible
    when dead token columns are zero."""
    E, M, N, C = 2, 128, 128, 256
    a = _x(23, E, M, C, spread=0.5)
    b = _x(24, E, N, C, spread=0.5) * 0.1
    mm = _skew(skew, E, C, seed=1)
    live = jnp.asarray(np.arange(C)[None, None, :] < np.asarray(mm)[:, None, None])
    qa = quantize(jnp.where(live, a, 0.0), (1, 1, TILE), tag="t")
    qb = quantize(jnp.where(live, b, 0.0), (1, 1, TILE), tag="t")
    out_m = ops.grouped_gemm_nt_fp8_masked(qa, qb, mm)
    out_p = ops.grouped_gemm_nt_fp8(qa, qb)
    assert np.array_equal(np.asarray(out_m).view(np.uint32),
                          np.asarray(out_p).view(np.uint32))


@pytest.mark.parametrize("skew", ["zero_expert", "all_to_one", "random"])
def test_masked_swiglu_epilogue_vs_unfused_pair(skew):
    """The fused SwiGLU+quant GEMM-1 epilogue must be bitwise the unfused
    pipeline (grouped GEMM -> bf16 h -> fused_swiglu_quant kernel)."""
    E, C, K, F = 2, 256, 256, 128
    x = _x(25, E, C, K, spread=0.5)
    w13 = _x(26, E, K, 2 * F, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw13 = quantize(w13, (1, TILE, TILE), tag="t")
    mm = _skew(skew, E, C, seed=2)
    qx = _zero_dead_rows(qx, mm)
    q_f = ops.grouped_gemm_swiglu_quant_masked(qx, qw13, mm)
    h = ops.grouped_gemm_fp8(qx, qw13)                       # bf16 island
    q_u = ops.fused_swiglu_quant(h.reshape(E * C, 2 * F))
    assert np.array_equal(_bits(q_f.data), _bits(q_u.data.reshape(E, C, F)))
    assert np.array_equal(np.asarray(q_f.scale),
                          np.asarray(q_u.scale.reshape(E, C, F // TILE)))


def test_masked_oracles_tile_granular():
    """Tile-granular mask semantics: with NONZERO payload beyond masked_m,
    dead tiles zero out but partial tiles compute whole — the masked oracles
    encode exactly the kernel behavior."""
    from repro.kernels.grouped_gemm_fp8 import (
        masked_grouped_gemm_fp8_pallas, masked_grouped_gemm_swiglu_quant_pallas)
    from repro.kernels.grouped_gemm_nt_fp8 import masked_grouped_gemm_nt_fp8_pallas
    E, C, K, N = 2, 256, 256, 128
    qx, qw = _gg_operands(seed=31, E=E, C=C, K=K, N=N)
    mm = jnp.asarray([37, 200], jnp.int32)      # mid-tile counts, garbage beyond
    out_m = masked_grouped_gemm_fp8_pallas(qx.data, qx.scale, qw.data,
                                           qw.scale, mm)
    out_r = ref.masked_grouped_gemm_fp8_ref(qx.data, qx.scale, qw.data,
                                            qw.scale, mm)
    assert np.array_equal(np.asarray(out_m).view(np.uint16),
                          np.asarray(out_r).view(np.uint16))
    d_m, s_m = masked_grouped_gemm_fp8_pallas(qx.data, qx.scale, qw.data,
                                              qw.scale, mm, quant_out=True)
    d_r, s_r = ref.masked_grouped_gemm_fp8_quant_out_ref(
        qx.data, qx.scale, qw.data, qw.scale, mm)
    assert np.array_equal(_bits(d_m), _bits(d_r))
    assert np.array_equal(np.asarray(s_m), np.asarray(s_r))

    w13 = _x(33, E, K, 2 * N, spread=0.3) * 0.05
    qw13 = quantize(w13, (1, TILE, TILE), tag="t")
    d_f, s_f = masked_grouped_gemm_swiglu_quant_pallas(
        qx.data, qx.scale, qw13.data, qw13.scale, mm)
    d_fr, s_fr = ref.masked_grouped_gemm_swiglu_quant_ref(
        qx.data, qx.scale, qw13.data, qw13.scale, mm)
    assert np.array_equal(_bits(d_f), _bits(d_fr))
    assert np.array_equal(np.asarray(s_f), np.asarray(s_fr))

    qa = quantize(_x(34, E, 128, C, spread=0.5), (1, 1, TILE), tag="t")
    qb = quantize(_x(35, E, 128, C, spread=0.5) * 0.1, (1, 1, TILE), tag="t")
    nt_m = masked_grouped_gemm_nt_fp8_pallas(qa.data, qa.scale, qb.data,
                                             qb.scale, mm)
    nt_r = ref.masked_grouped_gemm_nt_fp8_ref(qa.data, qa.scale, qb.data,
                                              qb.scale, mm)
    assert np.array_equal(np.asarray(nt_m).view(np.uint32),
                          np.asarray(nt_r).view(np.uint32))


def test_capacity_pad_to_block():
    """Regression for the decode-capacity crash: MoE rounds decode capacity
    to 8 but the Pallas grouped GEMMs need 128-row tiles — the ops wrappers
    must pad the capacity axis (payload 0 / scale 1.0) and slice back."""
    qx, qw = _gg_operands(seed=41, E=2, C=8, K=256, N=128)
    out = ops.grouped_gemm_fp8(qx, qw)
    out_r = ref.grouped_gemm_fp8_ref(qx.data, qx.scale, qw.data, qw.scale)
    assert out.shape == (2, 8, 128)
    assert np.array_equal(np.asarray(out).view(np.uint16),
                          np.asarray(out_r).view(np.uint16))
    q_o = ops.grouped_gemm_fp8_quant_out(qx, qw)
    d_r, s_r = ref.grouped_gemm_fp8_quant_out_ref(qx.data, qx.scale,
                                                  qw.data, qw.scale)
    assert np.array_equal(_bits(q_o.data), _bits(d_r))
    mm = jnp.asarray([3, 8], jnp.int32)
    out_m = ops.grouped_gemm_fp8_masked(_zero_dead_rows(qx, mm), qw, mm)
    out_p = ops.grouped_gemm_fp8(_zero_dead_rows(qx, mm), qw)
    assert np.array_equal(np.asarray(out_m).view(np.uint16),
                          np.asarray(out_p).view(np.uint16))


def test_rowwise_wrappers_pad_short_m():
    """quantize_rowwise / fused_swiglu_quant accept M not divisible by the
    128-row kernel block (decode batches)."""
    x = _x(43, 40, 256)
    q = ops.quantize_rowwise(x)
    dr, sr = ref.quantize_rowwise_ref(x)
    assert q.data.shape == (40, 256)
    assert np.array_equal(_bits(q.data), _bits(dr))
    assert np.array_equal(np.asarray(q.scale), np.asarray(sr))
    h = _x(44, 40, 256, spread=0.5).astype(jnp.bfloat16)
    qs = ops.fused_swiglu_quant(h)
    dsr, ssr = ref.fused_swiglu_quant_ref(h)
    assert np.array_equal(_bits(qs.data), _bits(dsr))
    assert np.array_equal(np.asarray(qs.scale), np.asarray(ssr))


def test_quant_out_tiling_asserts_at_trace_time(monkeypatch):
    """The quantizing epilogues expose one scale per (row, BN-tile) as
    (1, TILE) row metadata — valid ONLY while BN == TILE.  A diverged block
    config must fail loudly at trace time, not corrupt scale shapes."""
    import repro.kernels.grouped_gemm_fp8 as gg
    gg._assert_quant_out_tiling()                     # current config: fine
    monkeypatch.setattr(gg, "BN", 2 * TILE)
    with pytest.raises(AssertionError, match="BN == TILE"):
        gg._assert_quant_out_tiling()


def test_ops_wrappers_tile_convention():
    """Every QTensor-producing wrapper follows the normative tile-metadata
    convention: len(tile) == data.ndim, row-tiled = leading 1s + TILE."""
    from repro.core.quant import row_tile
    qx, qw = _gg_operands(seed=45, E=2, C=128, K=256, N=128)
    mm = jnp.asarray([64, 128], jnp.int32)
    w13 = _x(46, 2, 256, 256, spread=0.3) * 0.05
    qw13 = quantize(w13, (1, TILE, TILE), tag="t")
    outs = [
        ops.quantize_rowwise(_x(47, 128, 256)),
        ops.fp8_transpose(ops.quantize_rowwise(_x(48, 128, 256))),
        ops.fused_swiglu_quant(_x(49, 128, 256).astype(jnp.bfloat16)),
        ops.grouped_gemm_fp8_quant_out(qx, qw),
        ops.grouped_gemm_fp8_masked_quant_out(qx, qw, mm),
        ops.grouped_gemm_swiglu_quant_masked(qx, qw13, mm),
    ]
    for q in outs:
        assert len(q.tile) == q.data.ndim, (q.tile, q.data.shape)
        assert q.tile == row_tile(q.data.ndim), (q.tile, q.data.shape)
        assert all(s * t == n for s, t, n in
                   zip(q.scale.shape, q.tile, q.data.shape)), \
            (q.scale.shape, q.tile, q.data.shape)


def test_expert_ffn_masked_matches_padded_fwd_and_grads():
    """End-to-end recipe check: expert_ffn with masked_m (masked kernels on
    every fwd/bwd grouped GEMM) is bitwise the padded path on the dispatch
    layout, outputs AND weight gradients, under skewed routing."""
    from repro.core.linear import expert_ffn
    from repro.core.recipes import get_recipe
    E, C, K, F = 2, 128, 128, 128
    mm = jnp.asarray([48, 128], jnp.int32)
    x = _x(51, E, C, K, spread=0.5)
    qx = _zero_dead_rows(quantize(x, (1, 1, TILE), tag="t"), mm)
    w13 = _x(52, E, K, 2 * F, spread=0.3) * 0.05
    w2 = _x(53, E, F, K, spread=0.3) * 0.05
    # cotangents on dead slots are zero in the real block (p_exp weighting);
    # replicate that with a live-row mask inside the loss
    live = jnp.asarray((np.arange(C)[None, :] < np.asarray(mm)[:, None])
                       ).astype(jnp.float32)[..., None]

    def loss(recipe, masked_m):
        def L(w13, w2):
            y = expert_ffn(recipe, "swiglu", (), (), qx, w13, w2, masked_m)
            return jnp.sum((y.astype(jnp.float32) * live) ** 2)
        return jax.value_and_grad(L, argnums=(0, 1))(w13, w2)

    r_pad = get_recipe("fp8_flow", use_pallas=True)
    r_msk = get_recipe("fp8_flow", use_pallas=True, masked_experts=True)
    y_p, (g13_p, g2_p) = loss(r_pad, None)
    y_m, (g13_m, g2_m) = loss(r_msk, mm)
    assert np.array_equal(np.asarray(y_p), np.asarray(y_m))
    assert np.array_equal(np.asarray(g13_p), np.asarray(g13_m))
    assert np.array_equal(np.asarray(g2_p), np.asarray(g2_m))


def test_xla_path_matches_pallas_path():
    """linear.py's XLA fallbacks must agree with the Pallas kernels (the
    dry-run lowers the XLA path; TPU runs the kernels)."""
    from repro.core.linear import _ggemm, _ggemm_nt, _t_direct
    from repro.core.recipes import get_recipe
    r_x = get_recipe("fp8_flow", use_pallas=False)
    r_p = get_recipe("fp8_flow", use_pallas=True)
    x = _x(13, 2, 128, 256, spread=0.5)
    w = _x(14, 2, 256, 128, spread=0.3) * 0.05
    qx = quantize(x, (1, 1, TILE), tag="t")
    qw = quantize(w, (1, TILE, TILE), tag="t")
    np.testing.assert_allclose(
        np.asarray(_ggemm(r_x, qx, qw), np.float32),
        np.asarray(_ggemm(r_p, qx, qw), np.float32), rtol=2e-2, atol=2e-2)
    ta, tb = _t_direct(r_x, qx), _t_direct(r_p, qx)
    assert np.array_equal(_bits(ta.data), _bits(tb.data))
