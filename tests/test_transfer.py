"""Casting-free KV migration (serve/transfer.py) and the disaggregated
fleet's host-side protocol: bit-codec parity for po2 exponents, wire
header round-trip, pack->unpack->scatter bitwise identity on fp8 AND bf16
pools, the structural zero-requantization assert (with a quantizer as the
negative control), scheduler park/adopt/release semantics, the router's
saturated-fleet drain-progress guard, and the end-to-end bitwise guarantee
that a 1-prefill + 1-decode fleet generates the same tokens as a
single-tier engine while re-sharing migrated pages on the receiver."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.dist.scale_sync import (exp_i8_to_scale, exp_i8_to_scale_bits,
                                   scale_to_exp_i8, scale_to_exp_i8_bits)
from repro.serve.paged_kv import PageAllocator
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.transfer import (KVTransferCodec, TransferMeta,
                                  check_casting_free)
from tests.conftest import make_mesh11


# ---------------------------------------------------------------------------
# Exponent bit codec == frexp/ldexp codec, for every legal exponent.
# ---------------------------------------------------------------------------
def test_exponent_bit_codec_matches_frexp_everywhere():
    """The migration wire uses the shift-and-bias spelling so its jaxpr has
    zero float ops; it must be VALUE-IDENTICAL to the frexp/ldexp codec of
    the DP gradient wire over the full po2 range |e| <= 126."""
    exps = jnp.arange(-126, 127, dtype=jnp.int8)
    scales = exp_i8_to_scale(exps)                 # exact ldexp reference
    assert (scale_to_exp_i8_bits(scales) == exps).all()
    assert (scale_to_exp_i8(scales) == exps).all()
    back = exp_i8_to_scale_bits(exps)
    # bit-for-bit, not just value-equal
    assert (jax.lax.bitcast_convert_type(back, jnp.uint32)
            == jax.lax.bitcast_convert_type(scales, jnp.uint32)).all()


# ---------------------------------------------------------------------------
# Wire header round-trip.
# ---------------------------------------------------------------------------
def test_transfer_meta_roundtrip():
    # the wire carries f32 bits, so start from an f32-exact temperature
    meta = TransferMeta(rid=42, n_pages=3, page_size=4, bytes_per_page=1040,
                        pos=11, max_new_tokens=9,
                        temperature=float(np.float32(0.7)),
                        prompt=(5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15),
                        generated=(99,))
    msg = meta.to_bytes()
    got, off = TransferMeta.from_bytes(msg)
    assert got == meta                      # incl. temperature: raw f32 bits
    assert off == len(msg)                  # header consumes exactly itself
    assert np.float32(got.temperature) == np.float32(0.7)

    empty = TransferMeta(rid=0, n_pages=0, page_size=4, bytes_per_page=8,
                         pos=2, max_new_tokens=1, temperature=0.0,
                         prompt=(1, 2), generated=())
    got2, _ = TransferMeta.from_bytes(empty.to_bytes())
    assert got2 == empty

    bad = msg.copy()
    bad[0] ^= 0xFF                          # corrupt the magic
    with pytest.raises(ValueError, match="magic"):
        TransferMeta.from_bytes(bad)


# ---------------------------------------------------------------------------
# Codec pack -> unpack -> scatter bitwise identity on synthetic pools.
# ---------------------------------------------------------------------------
def _mk_pools(rng, n_pages=8, L=2, ps=4, KV=2, hd=6, fp8=True):
    """Two-stack pools pytree with the paged_kv layout.  fp8 pools get RAW
    random payload bytes — including 0x7F/0xFF NaN encodings — because the
    wire must move bytes verbatim, and po2 scales; bf16 pools have no
    scale plane."""
    def one():
        shape = (L, n_pages, ps, KV, hd)
        if fp8:
            raw = jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))
            data = jax.lax.bitcast_convert_type(raw, jnp.float8_e4m3fn)
            scale = exp_i8_to_scale(jnp.asarray(
                rng.integers(-30, 31, (L, n_pages, ps, KV, 1),
                             dtype=np.int8)))
            return {"data": data, "scale": scale}
        data = jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)
        return {"data": data}
    return {"attn": {"k": one(), "v": one()},
            "alt": {"k": one(), "v": one()}}


@pytest.mark.parametrize("fp8", [True, False], ids=["fp8", "bf16"])
def test_codec_roundtrip_bitwise(fp8):
    rng = np.random.default_rng(0 if fp8 else 1)
    pools = _mk_pools(rng, fp8=fp8)
    codec = KVTransferCodec(pools)
    itemsize = 1 if fp8 else 2
    per_page = 2 * 2 * (2 * 4 * 2) * (6 * itemsize + (1 if fp8 else 0))
    assert codec.bytes_per_page == per_page
    assert codec.bytes_for(3) == 4 * per_page       # bucket-padded
    assert codec.bytes_for(0) == 0

    meta = TransferMeta(rid=7, n_pages=3, page_size=4,
                        bytes_per_page=codec.bytes_per_page, pos=12,
                        max_new_tokens=4, temperature=0.0,
                        prompt=tuple(range(12)), generated=(3,))
    src_ids = [2, 5, 1]
    msg = codec.pack(pools, src_ids, meta)
    got, payload = codec.unpack(msg)
    assert got == meta and len(payload) == codec.bytes_for(3)

    # scatter into a zeroed clone at DIFFERENT page ids, gather back
    blank = jax.tree.map(jnp.zeros_like, pools)
    dst_ids = [6, 3, 7]
    blank = codec.scatter(blank, payload, dst_ids)
    for s, d in zip(src_ids, dst_ids):
        a = np.asarray(codec._gather(pools, codec._pad_ids([s])))
        b = np.asarray(codec._gather(blank, codec._pad_ids([d])))
        assert (a == b).all(), f"page {s}->{d} not bit-identical"

    # geometry fingerprint: a mismatched fleet refuses the message
    other = KVTransferCodec(_mk_pools(rng, hd=4, fp8=fp8))
    with pytest.raises(ValueError, match="geometry"):
        other.unpack(msg)


def test_fp8_nan_payload_survives_migration():
    """Every e4m3 NaN encoding (0x7F/0xFF) must cross the wire verbatim —
    a value-level copy would canonicalize them; a bitcast cannot."""
    rng = np.random.default_rng(2)
    pools = _mk_pools(rng, fp8=True)
    raw = np.asarray(jax.lax.bitcast_convert_type(
        pools["attn"]["k"]["data"], jnp.uint8))
    assert ((raw == 0x7F) | (raw == 0xFF)).any()    # NaNs are in the deck
    codec = KVTransferCodec(pools)
    meta = TransferMeta(rid=0, n_pages=2, page_size=4,
                        bytes_per_page=codec.bytes_per_page, pos=8,
                        max_new_tokens=1, temperature=0.0,
                        prompt=tuple(range(8)), generated=())
    _, payload = codec.unpack(codec.pack(pools, [1, 2], meta))
    blank = codec.scatter(jax.tree.map(jnp.zeros_like, pools), payload,
                          [1, 2])
    ids = codec._pad_ids([1, 2])
    assert (np.asarray(codec._gather(pools, ids))
            == np.asarray(codec._gather(blank, ids))).all()


# ---------------------------------------------------------------------------
# The structural zero-requantization proof.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fp8", [True, False], ids=["fp8", "bf16"])
def test_codec_is_casting_free(fp8):
    pools = _mk_pools(np.random.default_rng(3), fp8=fp8)
    KVTransferCodec(pools).assert_casting_free(pools, n=3)


def test_casting_free_rejects_a_quantizer():
    """Negative control: a textbook quantize (amax -> scale -> divide ->
    convert) must FAIL the checker — otherwise the assert proves nothing."""
    def quantize(x):
        s = jnp.max(jnp.abs(x)) / 448.0
        return (x / s).astype(jnp.float8_e4m3fn)
    j = jax.make_jaxpr(quantize)(jnp.ones((8,), jnp.float32))
    with pytest.raises(AssertionError, match="casting-free"):
        check_casting_free(j.jaxpr)

    def dequantize(q, s):
        return q.astype(jnp.float32) * s
    j2 = jax.make_jaxpr(dequantize)(
        jnp.ones((8,), jnp.float8_e4m3fn), jnp.float32(2.0))
    with pytest.raises(AssertionError, match="casting-free"):
        check_casting_free(j2.jaxpr)


# ---------------------------------------------------------------------------
# Scheduler park / adopt / release semantics (pure host).
# ---------------------------------------------------------------------------
def _admit(sched, alloc, n_prompt=4, max_new=2, now=0.0):
    sched.submit(Request(prompt=list(range(n_prompt)), max_new_tokens=max_new))
    st = sched.try_admit(alloc, now)
    assert st is not None
    return st


def test_parked_requests_are_never_eviction_victims():
    alloc = PageAllocator(n_pages=16, page_size=4)
    sched = Scheduler(max_batch=3, token_budget=100)
    a = _admit(sched, alloc)
    b = _admit(sched, alloc)                # youngest
    b.parked = True                         # in the handoff queue
    victim = sched.evict_youngest(alloc)
    assert victim is a                      # youngest LIVE, not the parked b
    assert b.slot in sched.active and sched.active[b.slot].parked
    b.parked = False
    assert sched.evict_youngest(alloc) is b
    assert sched.evict_youngest(alloc) is None   # nothing live remains


def test_adopt_installs_into_free_slot_and_guards_full_batch():
    alloc = PageAllocator(n_pages=16, page_size=4)
    sched = Scheduler(max_batch=2, token_budget=100)
    a = _admit(sched, alloc)
    _admit(sched, alloc)
    migrant = RequestState(req=Request(prompt=[1, 2, 3], max_new_tokens=2),
                           slot=-1, pages=alloc.alloc(1), admit_seq=-1,
                           admit_time=0.0, prefilled=True, prefill_pos=3,
                           parked=True)
    with pytest.raises(RuntimeError, match="free slot"):
        sched.adopt(migrant)                # batch is full
    sched.finish(a.slot, alloc, now=1.0)
    sched.adopt(migrant)
    assert migrant.slot in sched.active and not migrant.parked
    assert migrant.admit_seq > a.admit_seq  # joins at the back of seniority
    assert sched.n_adopted == 1


def test_donor_release_goes_through_the_release_hook():
    """release_parked must exit through the SAME funnel as finish/evict so a
    prefix cache sees the decref (cached pages stay shareable)."""
    seen = []
    alloc = PageAllocator(n_pages=16, page_size=4)
    sched = Scheduler(max_batch=2, token_budget=100,
                      release_hook=lambda st, pages, a: (
                          seen.append(list(pages)), a.free(pages)))
    st = _admit(sched, alloc)
    held = list(st.pages)
    st.parked = True
    sched.release(st, alloc)                # the receiver-ack path
    assert seen == [held]
    assert st.pages == [] and st.slot not in sched.active
    assert alloc.live_pages == 0
    # the freed slot is immediately adoptable
    sched.adopt(RequestState(req=Request(prompt=[1], max_new_tokens=1),
                             slot=-1, pages=[], admit_seq=-1, admit_time=0.0))
    assert sched.n_active == 1


# ---------------------------------------------------------------------------
# Router guard: a saturated fleet that progresses ONLY via the drain must
# not trip the deadlock detector (the satellite-1 regression).
# ---------------------------------------------------------------------------
class _ParkedEngine:
    """Every tick returns False (at budget, no admissible head) but the
    engine is NOT idle: `work` stands in for parked requests that only the
    router's drain (migration) can retire."""
    def __init__(self, work):
        self.work = work
        self.sched = self

    def idle(self):
        return self.work == 0

    def tick(self, now, results):
        return False

    def stats(self):
        return {}


class _MigratingRouter:
    """ReplicaRouter whose drain retires one unit of parked work per cycle
    — the shape of DisaggRouter._drain without devices."""
    def __new__(cls, engines):
        from repro.serve.router import ReplicaRouter

        class _R(ReplicaRouter):
            def _drain(self, now, results):
                for e in self.engines:
                    if e.work:
                        e.work -= 1
                        return True
                return False
        return _R(engines)


def test_saturated_fleet_progresses_via_drain():
    # > 1000 units of drain-only work per engine: if drain progress did not
    # reset the idle counter, the deadlock guard would fire long before the
    # handoff queues empty
    engines = [_ParkedEngine(work=1200), _ParkedEngine(work=1200)]
    router = _MigratingRouter(engines)
    router.run([], realtime=False)
    assert all(e.work == 0 for e in engines)


def test_genuinely_stuck_fleet_still_raises():
    from repro.serve.router import ReplicaRouter
    router = ReplicaRouter([_ParkedEngine(work=1)])   # base drain: no-op
    with pytest.raises(RuntimeError, match="deadlock"):
        router.run([], realtime=False)


# ---------------------------------------------------------------------------
# End-to-end: 1-prefill + 1-decode fleet == single-tier engine, bitwise.
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_disagg_fleet_bitwise_identical_and_reshares_pages():
    """Shared-prefix trace through (a) one mixed engine and (b) a
    DisaggRouter fleet.  Greedy decode must be BITWISE identical — the
    migration is a pure bitcast, so there is nothing to drift — every
    request must migrate, the receiver must dedupe repeated prefixes
    against pages it already adopted, migrated pages must be bit-equal on
    both tiers, and neither tier may leak pages."""
    from repro.configs import get_arch
    from repro.core.recipes import get_recipe
    from repro.models.lm import ParallelPlan, init_params
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.router import DisaggRouter

    cfg = get_arch("qwen15_05b").reduced()
    plan = ParallelPlan(mesh=make_mesh11(), dp_axes=("data",))
    recipe = get_recipe("fp8_flow")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    prefix = list(rng.integers(1, cfg.vocab, 8))     # two full pages
    prompts = [prefix + list(rng.integers(1, cfg.vocab, k))
               for k in (3, 4, 2, 1, 5)]
    kw = dict(max_batch=3, page_size=4, n_pages=32, max_pages_per_req=8,
              token_budget=128, prefill_buckets=(16,), prefill_chunk=4,
              fp8_kv=True, w8_weights=True, prefix_cache=True, seed=0)

    def reqs():
        return [Request(prompt=list(p), max_new_tokens=4) for p in prompts]

    single = ServeEngine(cfg, recipe, plan, params, ServeConfig(**kw))
    r1 = reqs()
    res1 = single.run(r1, realtime=False)
    toks1 = [res1[q.rid]["tokens"] for q in r1]

    pe = ServeEngine(cfg, recipe, plan, params,
                     ServeConfig(role="prefill", **kw))
    de = ServeEngine(cfg, recipe, plan, params,
                     ServeConfig(role="decode", **kw))
    router = DisaggRouter([pe], [de])
    r2 = reqs()
    res2 = router.run(r2, realtime=False)
    toks2 = [res2[q.rid]["tokens"] for q in r2]
    assert toks1 == toks2

    d = router.stats()["disagg"]
    assert d["migrations"] == len(prompts)
    # the shared prefix ships once; later migrations re-share it on the
    # receiver (radix identity travels with the pages)
    assert d["deduped_pages"] > 0
    # migrated pages bit-equal donor vs receiver (payload + exponents),
    # gathered one page at a time (bucket padding drags in scratch garbage)
    compared = 0
    for q in r2:
        dp = pe.prefix_cache.match_pages(q.prompt)
        rp = de.prefix_cache.match_pages(q.prompt)
        for s, t in zip(dp, rp):
            a = np.asarray(pe.codec._gather(pe.pools, pe.codec._pad_ids([s])))
            b = np.asarray(de.codec._gather(de.pools, de.codec._pad_ids([t])))
            assert (a == b).all()
            compared += 1
    assert compared > 0
    # no leaks: both tiers idle, every live page is cache-held
    for eng in (pe, de, single):
        assert eng.sched.idle()
        assert eng.alloc.live_pages == eng.prefix_cache.n_cached_pages
        eng.prefix_cache.check_invariants(eng.alloc)
