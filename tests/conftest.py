"""Shared fixtures.  NOTE: no XLA device-count override here — smoke tests
and benches see the real single CPU device; only dryrun/sweep force 512."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_mesh11():
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
