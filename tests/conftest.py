"""Shared fixtures.  NOTE: no XLA device-count override here — smoke tests
and benches see the real single CPU device; only dryrun/sweep force 512."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_mesh11():
    from repro.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))
