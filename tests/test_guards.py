"""Numerics guardrails: anomaly detection bits, the host-side recovery
ladder, checkpoint integrity, and the deterministic fault-injection matrix
(every fault class detected within one step and recovered).

The heavyweight tests run the REAL training loop (train/loop.py) around a
tiny model, with faults scheduled by runtime/fault_injection.FaultPlan —
numeric faults are baked into per-spec jit traces (FaultStepper), disk
faults corrupt checkpoint shards, host faults flip the HealthMonitor.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpointing
from repro.configs import get_arch
from repro.core import casts
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig, make_batch
from repro.dist import DistPlan
from repro.models.lm import ParallelPlan
from repro.optim.adamw import AdamWConfig
from repro.runtime import fault_injection as fi
from repro.runtime.fault_tolerance import ElasticTrainer
from repro.train import guards
from repro.train.guards import (FP8_FLUSH, FP8_SAT, GNORM_SPIKE, HARD_FLAGS,
                                NONFINITE_GRAD, NONFINITE_LOSS, WIRE_SCALE,
                                GuardGiveUp, GuardPlan, GuardPolicy)
from repro.train.loop import _restore_latest_valid, run as run_loop
from repro.train.train_step import init_train_state, make_train_step
from tests.conftest import make_mesh11


def _build(recipe_name="fp8_flow", guard=None, dist=None, seq=32, batch=2):
    """Tiny model + UN-jitted step (so FaultPlan.wrap can own the jit)."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=3e-3)
    recipe = get_recipe(recipe_name)
    raw = make_train_step(cfg, recipe, plan, opt, dist=dist,
                          total_steps=200, warmup_steps=5, guard=guard)
    state = init_train_state(cfg, opt, jax.random.key(0), dist=dist,
                             guard=guard)
    data = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    return cfg, mesh, raw, state, data


# ---------------------------------------------------------------------------
# In-jit detection: guards.evaluate unit behaviour.
# ---------------------------------------------------------------------------
def test_flag_names():
    assert guards.flag_names(0) == "none"
    assert guards.flag_names(NONFINITE_LOSS | WIRE_SCALE) == \
        "nonfinite_loss|wire_scale"


def test_evaluate_nonfinite_and_spike_bits():
    plan = GuardPlan(spike_factor=4.0, spike_warmup=2)
    g = guards.init_guard_state()
    # healthy steps seed then decay the EMA; steps counter advances
    for _ in range(3):
        flags, g, _ = guards.evaluate(plan, g, loss=jnp.float32(2.0),
                                      gnorm=jnp.float32(1.0))
        assert int(flags) == 0
    assert int(g["steps"]) == 3
    assert float(g["gnorm_ema"]) == pytest.approx(1.0)
    # NaN loss / inf grad set the hard bits and FREEZE the EMA
    flags, g2, _ = guards.evaluate(plan, g, loss=jnp.float32(np.nan),
                                   gnorm=jnp.float32(np.inf))
    assert int(flags) & NONFINITE_LOSS
    assert int(flags) & NONFINITE_GRAD
    assert float(g2["gnorm_ema"]) == float(g["gnorm_ema"])
    assert int(g2["steps"]) == int(g["steps"])
    # a 10x grad-norm jump post-warmup is a spike; EMA again frozen
    flags, g3, gm = guards.evaluate(plan, g, loss=jnp.float32(2.0),
                                    gnorm=jnp.float32(10.0))
    assert int(flags) == GNORM_SPIKE
    assert float(g3["gnorm_ema"]) == float(g["gnorm_ema"])
    assert int(gm["guard_flags"]) == GNORM_SPIKE
    # before warmup the same jump is NOT a spike (EMA still learning)
    fresh = guards.init_guard_state()
    flags, fresh, _ = guards.evaluate(plan, fresh, loss=jnp.float32(2.0),
                                      gnorm=jnp.float32(1.0))
    flags, _, _ = guards.evaluate(plan, fresh, loss=jnp.float32(2.0),
                                  gnorm=jnp.float32(10.0))
    assert int(flags) == 0


def test_evaluate_fp8_and_wire_bits():
    plan = GuardPlan(sat_frac_limit=0.05, flush_frac_limit=0.5)
    g = guards.init_guard_state()
    flags, _, _ = guards.evaluate(plan, g, loss=jnp.float32(1.0),
                                  gnorm=jnp.float32(1.0),
                                  sat_frac=jnp.float32(0.2),
                                  flush_frac=jnp.float32(0.9),
                                  wire_bad=jnp.bool_(True))
    assert int(flags) == FP8_SAT | FP8_FLUSH | WIRE_SCALE
    # soft bits are not in the hard set — the policy keeps the update
    assert int(flags) & HARD_FLAGS == 0


# ---------------------------------------------------------------------------
# Host-side recovery ladder (pure python — no jax).
# ---------------------------------------------------------------------------
def test_policy_ladder_skip_then_rollback_then_demote():
    pol = GuardPolicy(rollback_after=3, demote_after=5, demote_steps=4,
                      give_up_after=50)
    log = lambda *a: None
    # strikes 1-2: skip only
    for s in (10, 11):
        v = pol.observe(s, NONFINITE_LOSS, log)
        assert v.skip and not v.rollback and not v.demote
    # strike 3: rollback (checkpoint available)
    v = pol.observe(12, NONFINITE_LOSS, log, can_rollback=True)
    assert v.skip and v.rollback
    # strike 4 without a checkpoint: skip again, no rollback
    v = pol.observe(13, NONFINITE_LOSS, log, can_rollback=False)
    assert v.skip and not v.rollback
    # strike 5: demote for demote_steps
    v = pol.observe(14, NONFINITE_LOSS, log)
    assert v.demote and pol.demoted(15) and pol.demoted(18)
    assert not pol.demoted(19)
    # clean step at the window end fires the repromote event
    pol.observe(19, 0, log)
    names = [e["event"] for e in pol.events]
    assert names == ["skip", "skip", "rollback", "skip", "demote",
                     "recovered", "repromote"]


def test_policy_soft_flags_keep_update():
    pol = GuardPolicy()
    v = pol.observe(5, WIRE_SCALE | FP8_SAT, lambda *a: None)
    assert not v.skip and not v.rollback and not v.demote
    assert pol.events[-1]["event"] == "soft_anomaly"
    assert pol.consecutive == 0


def test_policy_give_up():
    pol = GuardPolicy(give_up_after=3)
    log = lambda *a: None
    pol.observe(1, NONFINITE_LOSS, log, can_rollback=False)
    pol.observe(2, NONFINITE_LOSS, log, can_rollback=False)
    with pytest.raises(GuardGiveUp):
        pol.observe(3, NONFINITE_LOSS, log, can_rollback=False)
    assert pol.events[-1]["event"] == "give_up"


# ---------------------------------------------------------------------------
# Checkpoint integrity: corruption detected, rollback walks past it.
# ---------------------------------------------------------------------------
def _tiny_tree():
    return {"a": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": {"c": jnp.ones((4, 4), jnp.bfloat16)}}


def test_restore_detects_corrupt_payload(tmp_path):
    d = str(tmp_path)
    tree = _tiny_tree()
    checkpointing.save(d, 1, tree)
    checkpointing.save(d, 2, tree)
    fi.corrupt_checkpoint_shard(d, 2)
    with pytest.raises(checkpointing.CheckpointCorruptError):
        checkpointing.restore(d, tree, step=2)
    # the loop's rollback helper walks past the poisoned step
    msgs = []
    res = _restore_latest_valid(d, tree, None, msgs.append)
    assert res is not None
    _, step = res
    assert step == 1
    assert any("failed integrity check" in m for m in msgs)


def test_restore_detects_truncated_shard(tmp_path):
    d = str(tmp_path)
    checkpointing.save(d, 1, _tiny_tree())
    fi.truncate_checkpoint_shard(d, 1)
    with pytest.raises(checkpointing.CheckpointCorruptError):
        checkpointing.restore(d, _tiny_tree(), step=1)


def test_restore_requires_complete_marker(tmp_path):
    d = str(tmp_path)
    checkpointing.save(d, 1, _tiny_tree())
    os.remove(os.path.join(d, "step_1", ".COMPLETE"))
    with pytest.raises(checkpointing.CheckpointCorruptError):
        checkpointing.restore(d, _tiny_tree(), step=1)


def test_async_save_failure_reraises(tmp_path):
    # ckpt_dir collides with an existing FILE: the background write fails
    # and the exception must surface at join(), not vanish in the thread
    bad = os.path.join(str(tmp_path), "not_a_dir")
    with open(bad, "w") as f:
        f.write("x")
    handle = checkpointing.save(bad, 1, _tiny_tree(), async_=True)
    with pytest.raises(Exception):
        handle.join()


# ---------------------------------------------------------------------------
# Guards off => the step is unchanged (jaxpr + cast ledger).
# ---------------------------------------------------------------------------
def test_unguarded_step_is_unchanged():
    cfg, mesh, raw, state, data = _build(guard=None)
    batch = make_batch(data, 0)
    assert "guard" not in state
    with mesh:
        j_plain = str(jax.make_jaxpr(raw)(state, batch))
        # unarmed fault hooks contribute zero ops: tracing through the
        # FaultStepper's clean path and under activate(None) is identical
        with fi.activate(None):
            j_hooked = str(jax.make_jaxpr(raw)(state, batch))
        stepper = fi.FaultPlan().wrap(raw)
        j_stepper = str(jax.make_jaxpr(stepper._raw)(state, batch))
        _, metrics = jax.jit(raw)(state, batch)
    assert j_plain == j_hooked == j_stepper
    assert not any(k.startswith(("guard_", "quant_")) for k in metrics)


def test_guard_leaves_cast_ledger_unchanged():
    """The 2-cast fp8_flow ledger must be IDENTICAL with guards armed —
    stats collection reuses quantized values (or recomputes outside the
    ledgered quantize), never adds a counted activation cast."""
    _, mesh, raw_u, state_u, data = _build(guard=None)
    _, _, raw_g, state_g, _ = _build(guard=GuardPlan())
    batch = make_batch(data, 0)
    with mesh, casts.ledger() as led_u:
        jax.jit(raw_u)(state_u, batch)
    with mesh, casts.ledger() as led_g:
        _, metrics = jax.jit(raw_g)(state_g, batch)
    assert led_u.by_tag() == led_g.by_tag()
    assert led_u.activation_casts() == led_g.activation_casts()
    # and the guarded build actually reports health
    assert "guard_flags" in metrics
    assert int(metrics["guard_flags"]) == 0


# ---------------------------------------------------------------------------
# The fault-injection matrix: detection within ONE step + recovery.
# ---------------------------------------------------------------------------
def test_fault_matrix_detect_and_recover_with_parity():
    """Parity harness under injection: a NaN'd activation (step 3), a
    bit-flipped wire payload (step 7) and a poisoned bucket scale
    (step 11) against the quantized ZeRO-1 wire.  Each fault flags ON the
    faulted step; the NaN is skipped, the wire faults recover in-step via
    the bf16-bucket fallback; the run finishes green and tracks a clean
    bf16 baseline (30 steps — past the steep early descent, where one
    legitimately skipped update no longer dominates the loss gap)."""
    dist = DistPlan(axis="data", schedule="stream")
    guard = GuardPlan()
    _, mesh, raw, state, data = _build(guard=guard, dist=dist)
    plan_f = fi.FaultPlan((fi.Fault("nan_activation", 3, "q_entry"),
                           fi.Fault("payload_bitflip", 7),
                           fi.Fault("wire_scale", 11)))
    stepper = plan_f.wrap(raw)
    pol = GuardPolicy()
    with mesh:
        _, hist = run_loop(stepper, state, data, n_steps=30,
                           guard_policy=pol, fault_plan=plan_f,
                           log_every=1000, log_fn=lambda *a: None)
    by_step = {e["step"]: e for e in pol.events}
    # NaN activation: hard nonfinite bits, caught on the faulted step
    assert 3 in by_step and by_step[3]["event"] == "skip"
    assert by_step[3]["flags"] & (NONFINITE_LOSS | NONFINITE_GRAD)
    # wire faults: WIRE_SCALE flagged on the faulted step, update kept
    for s in (7, 11):
        assert s in by_step and by_step[s]["event"] == "soft_anomaly"
        assert by_step[s]["flags"] & WIRE_SCALE
    # in-step recovery: every non-skipped loss is finite
    losses = np.array([h["loss"] for h in hist])
    steps = np.array([h["step"] for h in hist])
    assert np.isfinite(losses[steps != 3]).all()
    # parity vs a clean bf16 run on identical data (mini Fig. 6 shape)
    _, mesh_b, raw_b, state_b, _ = _build("bf16")
    with mesh_b:
        _, hist_b = run_loop(jax.jit(raw_b), state_b, data, n_steps=30,
                             log_every=1000, log_fn=lambda *a: None)
    l_b = np.array([h["loss"] for h in hist_b])
    l_f = losses[np.isfinite(losses)]
    assert l_b[-8:].mean() < l_b[:3].mean() - 0.05   # baseline learns
    assert l_f[-8:].mean() < l_f[:3].mean() - 0.05   # injected run learns
    gap = abs(l_b[-8:].mean() - l_f[-8:].mean())
    assert gap < 0.2, f"parity gap {gap} under injection"


def test_recovery_ladder_rollback_demote_repromote(tmp_path):
    """A persistent fp8-path fault (NaN at every step 4..9) climbs the full
    ladder: skip, rollback (which replays INTO the fault), demote to the
    bf16 fallback step (curing it — bf16 has no quantize sites), and
    repromote after the window."""
    guard = GuardPlan()
    cfg, mesh, raw, state, data = _build(guard=guard)
    _, _, raw_bf16, _, _ = _build("bf16", guard=guard)
    plan_f = fi.FaultPlan(tuple(
        fi.Fault("nan_activation", s, "q_entry") for s in range(4, 10)))
    stepper = plan_f.wrap(raw)
    pol = GuardPolicy(rollback_after=3, demote_after=5, demote_steps=6,
                      give_up_after=50)
    with mesh:
        _, hist = run_loop(stepper, state, data, n_steps=13,
                           ckpt_dir=str(tmp_path), ckpt_every=3,
                           guard_policy=pol, fault_plan=plan_f,
                           fallback_step=jax.jit(raw_bf16),
                           log_every=1000, log_fn=lambda *a: None)
    names = [e["event"] for e in pol.events]
    for expected in ("skip", "rollback", "demote", "recovered", "repromote"):
        assert expected in names, f"missing {expected} in {names}"
    # ladder order: first skip < first rollback < demote < repromote
    assert names.index("skip") < names.index("rollback") < \
        names.index("demote") < names.index("repromote")
    # the run finished green past the fault window
    assert hist[-1]["step"] == 12
    assert np.isfinite(hist[-1]["loss"])
    assert not pol.demoted(13)


def test_give_up_without_checkpoint():
    """No checkpoint + persistent NaN: skip-only ladder exhausts the
    anomaly budget and the loop raises instead of spinning forever."""
    guard = GuardPlan()
    _, mesh, raw, state, data = _build(guard=guard)
    plan_f = fi.FaultPlan(tuple(
        fi.Fault("nan_activation", s, "q_entry") for s in range(1, 6)))
    pol = GuardPolicy(give_up_after=3)
    with mesh, pytest.raises(GuardGiveUp):
        run_loop(plan_f.wrap(raw), state, data, n_steps=10,
                 guard_policy=pol, fault_plan=plan_f,
                 log_every=1000, log_fn=lambda *a: None)


def test_disk_fault_restart_rolls_past_corrupt(tmp_path):
    """A checkpoint shard corrupted mid-run (valid npz, wrong bytes) is
    caught by the restore fingerprint check on restart, and the loop falls
    back to the previous complete step instead of loading garbage."""
    d = str(tmp_path)
    _, mesh, raw, state, data = _build()
    step = jax.jit(raw)
    plan_f = fi.FaultPlan((fi.Fault("ckpt_corrupt", 5),))
    with mesh:
        run_loop(step, state, data, n_steps=6, ckpt_dir=d, ckpt_every=2,
                 fault_plan=plan_f, log_every=1000, log_fn=lambda *a: None)
        # saves landed at steps 2 and 4; the fault poisoned step_4
        msgs = []
        _, hist2 = run_loop(step, state, data, n_steps=8, ckpt_dir=d,
                            ckpt_every=100, log_every=1000,
                            log_fn=msgs.append)
    assert any("step_4 failed integrity check" in m for m in msgs)
    assert hist2[0]["step"] == 3          # resumed from step 2, not 4
    assert np.isfinite(hist2[-1]["loss"])


def test_host_failure_remesh_rewinds_step(tmp_path):
    """A scheduled host failure triggers the elastic re-mesh path; the
    loop restores the last checkpoint AND rewinds `step`, so the optimizer
    steps between checkpoint and failure are replayed (visible as
    duplicated step ids in the history)."""
    d = str(tmp_path)
    _, mesh, raw, state, data = _build()
    elastic = ElasticTrainer(n_data_shards=4, timeout=3600.0)
    plan_f = fi.FaultPlan((fi.Fault("host_failure", 4, "2"),))

    def beats(step, el):
        for h in list(el.monitor.hosts):
            el.monitor.beat(h, 0.1)

    with mesh:
        _, hist = run_loop(jax.jit(raw), state, data, n_steps=8,
                           ckpt_dir=d, ckpt_every=2, elastic=elastic,
                           fail_injector=beats, fault_plan=plan_f,
                           log_every=1000, log_fn=lambda *a: None)
    assert elastic.generation == 1
    assert elastic.n_data_shards == 3
    steps = [h["step"] for h in hist]
    # steps 3 and 4 ran twice: once before the failure, once replayed
    assert steps.count(4) == 2 and steps.count(3) == 2
    assert sorted(set(steps)) == list(range(8))
    assert np.isfinite(hist[-1]["loss"])
