"""The FP8-native DP wire + ZeRO-1 state (repro.dist): scale agreement,
quantized-reduction parity, FP8 optimizer-state checkpoint round-trip
(including restore onto a different DP mesh size), training parity of the
FP8 wire vs the f32 wire, and the Fig.-2 cast-count invariance.

Multi-replica tests size the mesh to jax.device_count(): run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (CI does) for real
cross-replica coverage; on one device they degenerate to the P=1 wire."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpointing
from repro.compat import make_mesh, shard_map
from repro.configs import get_arch
from repro.core import casts
from repro.core.fp8 import TILE, is_po2
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig, make_batch
from repro.dist import DistPlan, StatePolicy, build_layout
from repro.dist import grad_comm, opt_state, scale_sync
from repro.dist.plan import bucket_flat, bucket_scatter
from repro.launch.sharding import dist_state_specs
from repro.models.lm import ParallelPlan
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def _dp_mesh(n=None):
    n = n or max(d for d in range(1, jax.device_count() + 1)
                 if jax.device_count() % d == 0)
    return make_mesh((n, 1), ("data", "model")), n


# ---------------------------------------------------------------------------
# Codecs + layout
# ---------------------------------------------------------------------------
def test_exp_i8_codec_exact():
    exps = jnp.arange(-120, 121, dtype=jnp.int8)
    scales = scale_sync.exp_i8_to_scale(exps)
    assert bool(jnp.all(is_po2(scales)))
    back = scale_sync.scale_to_exp_i8(scales)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(exps))


def test_pack_unpack_roundtrip(rng):
    pay = jnp.asarray(rng.normal(size=(16, TILE)), jnp.float8_e4m3fn)
    exp = jnp.asarray(rng.integers(-50, 50, (16, 1)), jnp.int8)
    msg = grad_comm.pack_bucket(pay, exp)
    assert msg.dtype == jnp.uint8 and msg.shape == (16, TILE + 1)
    p2, e2 = grad_comm.unpack_bucket(msg)
    np.testing.assert_array_equal(np.asarray(p2).view(np.uint8),
                                  np.asarray(pay).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(exp))


def test_layout_partitions_every_leaf():
    cfg = get_arch("qwen15_05b").reduced()
    from repro.models.lm import init_params
    params = init_params(cfg, jax.random.key(0))
    plan = DistPlan()
    layout = build_layout(params, plan)
    n_leaves = len(jax.tree.leaves(params))
    slot_idx = [s.index for b in layout.buckets for s in b.slots]
    sens_idx = [i for i, _ in layout.sensitive]
    assert sorted(slot_idx + sens_idx) == list(range(n_leaves))
    assert layout.n_leaves == n_leaves
    # embeddings + norms + biases fall back; big 2D+ weights ride FP8
    sens_names = {p.split(".")[-1] for _, p in layout.sensitive}
    assert "embed" in sens_names
    assert all(n.endswith("_s") or n in ("embed", "bq", "bk", "bv")
               for n in sens_names), sens_names
    for b in layout.buckets:
        assert b.rows % plan.shard_multiple == 0
        offs = [(s.offset_rows, s.offset_rows + s.rows) for s in b.slots]
        for (a0, a1), (b0, _) in zip(offs, offs[1:]):
            assert a1 == b0          # contiguous, non-overlapping


def test_bucket_flat_scatter_roundtrip(rng):
    leaves = [jnp.asarray(rng.normal(size=(4, 100)), jnp.bfloat16),
              jnp.asarray(rng.normal(size=(257,)), jnp.float32),
              jnp.asarray(rng.normal(size=(8, 64)), jnp.bfloat16)]
    from repro.dist.plan import Bucket, LeafSlot
    slots, off = [], 0
    for i, l in enumerate(leaves):
        rows = -(-l.size // TILE)
        slots.append(LeafSlot(index=i, path=f"l{i}", offset_rows=off,
                              rows=rows, size=l.size))
        off += rows
    b = Bucket(rows=off + 3, slots=tuple(slots))   # uneven tail pad
    flat = bucket_flat(b, leaves)
    assert flat.shape == (b.rows, TILE) and flat.dtype == jnp.float32
    out = bucket_scatter(b, flat, leaves)
    for i, l in enumerate(leaves):
        np.testing.assert_array_equal(np.asarray(out[i], np.float32),
                                      np.asarray(l, np.float32))
        assert out[i].dtype == l.dtype


# ---------------------------------------------------------------------------
# Scale agreement + reduction (property tests on the real mesh)
# ---------------------------------------------------------------------------
def test_scale_agreement_identical_buckets(rng):
    """All replicas must produce identically-SCALED buckets — and with
    identical input grads, bit-identical quantized buckets."""
    mesh, n = _dp_mesh()
    rows = 8 * n

    def quant(g):
        pay, exp = grad_comm.quantize_bucket(g[0], "data")
        return pay[None], exp[None]

    f = shard_map(quant, mesh=mesh,
                  in_specs=P("data", None, None),
                  out_specs=(P("data", None, None), P("data", None, None)))
    # different grads per replica -> exponents still agree everywhere
    g_diff = jnp.asarray(rng.normal(size=(n, rows, TILE)) *
                         (10.0 ** rng.integers(-3, 3, (n, 1, 1))),
                         jnp.float32)
    pay, exp = f(g_diff)
    exp = np.asarray(exp)
    assert (exp == exp[:1]).all(), "per-replica scales disagree"
    # scales are the agreed (pmax) po2 of the global amax
    amax = np.abs(np.asarray(g_diff)).max(axis=0).max(-1, keepdims=True)
    want = np.asarray(scale_sync.scale_to_exp_i8(
        jnp.asarray(np.exp2(np.ceil(np.log2(amax / 448.0))))))
    np.testing.assert_array_equal(exp[0], want)
    # identical grads -> identical quantized payload bits
    g_same = jnp.broadcast_to(g_diff[:1], g_diff.shape)
    pay, _ = f(g_same)
    pay = np.asarray(pay).view(np.uint8)
    assert (pay == pay[:1]).all()


@pytest.mark.parametrize("wire", ["fp8", "bf16", "f32"])
def test_reduce_scatter_matches_mean(rng, wire):
    mesh, n = _dp_mesh()
    rows = 8 * n
    g = jnp.asarray(rng.normal(size=(n, rows, TILE)), jnp.float32)

    def red(gl):
        return grad_comm.reduce_scatter_bucket(gl[0], "data", n, wire)

    f = shard_map(red, mesh=mesh, in_specs=P("data", None, None),
                  out_specs=P("data", None))
    got = np.asarray(f(g))                       # (rows, TILE) re-stitched
    want = np.asarray(g).mean(axis=0)
    if wire == "f32":
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    else:
        # one quantization per replica, exact sum: error bounded by the
        # e4m3/bf16 resolution at the (agreed) row amax
        res = 2 ** -3 if wire == "fp8" else 2 ** -8
        amax = np.abs(np.asarray(g)).max(axis=0).max(-1, keepdims=True)
        tol = res * amax * 1.01
        assert (np.abs(got - want) <= tol).all(), \
            np.max(np.abs(got - want) / amax)


# ---------------------------------------------------------------------------
# FP8-split optimizer state: policy encode/decode + AdamW integration
# ---------------------------------------------------------------------------
def test_state_encode_decode_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(3, 200)) * 7.3, jnp.float32)
    for kind, res in [("e4m3", 2 ** -3), ("f16", 2 ** -10)]:
        enc = opt_state.encode(kind, x)
        assert bool(jnp.all(is_po2(enc.scale)))
        dec = opt_state.decode(enc, x.shape, x.size)
        err = np.abs(np.asarray(dec - x))
        amax = np.abs(np.asarray(x)).max()
        assert err.max() <= res * amax * 1.01


def test_adamw_state_policy_dtypes_and_parity(rng):
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.bfloat16),
              "norm": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape) * 1e-2, p.dtype),
        params)
    base = AdamWConfig(lr=1e-2)
    pol = AdamWConfig(lr=1e-2, state_policy=StatePolicy(min_size=1024))
    s0 = adamw.init_state(base, params)
    s1 = adamw.init_state(pol, params)
    # policy state: QTensor e4m3 m / bf16 v / f16 master for the big leaf,
    # classic f32 for the small one
    assert s1["m"]["w"].data.dtype == jnp.float8_e4m3fn
    assert s1["v"]["w"].dtype == jnp.bfloat16
    assert s1["master"]["w"].data.dtype == jnp.float16
    assert s1["m"]["norm"].dtype == jnp.float32
    p0, n0, _ = adamw.apply_updates(base, params, grads, s0)
    p1, n1, _ = adamw.apply_updates(pol, params, grads, s1)
    # the exempt leaf updates identically; the policy leaf within fp8 error
    np.testing.assert_allclose(np.asarray(p1["norm"]), np.asarray(p0["norm"]),
                               rtol=1e-6)
    d = np.abs(np.asarray(p1["w"], np.float32) - np.asarray(p0["w"],
                                                            np.float32))
    assert d.max() < 1e-2 * 0.3          # lr * bounded moment error
    assert n1["m"]["w"].data.dtype == jnp.float8_e4m3fn


def test_state_bytes_model():
    pol = StatePolicy()
    assert opt_state.state_bytes_model(1, pol) < 5.2
    assert opt_state.state_bytes_model(
        1, StatePolicy(m="f32", v="f32", master="f32")) == 12.0


def test_wire_bytes_model_3x():
    n = 10 * 2 ** 20
    fp8 = grad_comm.wire_grad_bytes(n, 8, "fp8")
    bf16_ar = grad_comm.wire_grad_bytes(n, 8, "bf16", mode="none")
    assert bf16_ar / fp8 >= 3.0


# ---------------------------------------------------------------------------
# Checkpoint round-trip: e4m3 moments + po2 scales are bitwise, and restore
# onto a DIFFERENT DP mesh size re-shards the ZeRO-1 flat state.
# ---------------------------------------------------------------------------
def test_fp8_opt_state_checkpoint_bitwise(tmp_path, rng):
    cfg = get_arch("qwen15_05b").reduced()
    opt = AdamWConfig(lr=1e-3)
    dist = DistPlan()
    state = init_train_state(cfg, opt, jax.random.key(1), dist=dist)
    # make the moments non-trivial so the bit check means something
    st = state["opt"]["flat"][0]
    g = jnp.asarray(rng.normal(size=st["v"].shape), jnp.float32)
    state["opt"]["flat"][0]["m"] = opt_state.encode("e4m3", g)
    d = str(tmp_path)
    checkpointing.save(d, 3, state)
    restored, step = checkpointing.restore(d, state)
    assert step == 3

    def bits(x):
        return np.ascontiguousarray(np.asarray(x)).reshape(-1).view(np.uint8)

    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype, (a.dtype, b.dtype)
        np.testing.assert_array_equal(bits(a), bits(b))

    # restore onto a different DP mesh: values bitwise identical, ZeRO-1
    # flat rows (payload AND scales) sharded over the new data axis
    mesh2, n2 = _dp_mesh()
    sh = {"params": jax.tree.map(lambda _: None, state["params"]),
          "opt": dist_state_specs(mesh2, state["opt"])}
    resharded, _ = checkpointing.restore(d, state, shardings=sh)
    m2 = resharded["opt"]["flat"][0]["m"]
    np.testing.assert_array_equal(bits(m2.data),
                                  bits(state["opt"]["flat"][0]["m"].data))
    np.testing.assert_array_equal(np.asarray(m2.scale),
                                  np.asarray(state["opt"]["flat"][0]
                                             ["m"].scale))
    want = dist_state_specs(mesh2, state["opt"])["flat"][0]["m"]
    assert m2.data.sharding == want.data
    assert m2.scale.sharding == want.scale


# ---------------------------------------------------------------------------
# End-to-end: FP8-reduced vs f32-reduced training parity (the ISSUE gate)
# and the Fig.-2 cast-count invariance under the new wire.
# ---------------------------------------------------------------------------
def _train(cfg, mesh, dist, n_steps, lr=3e-3, seed=0):
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=lr)
    recipe = get_recipe("fp8_flow")
    state = init_train_state(cfg, opt, jax.random.key(seed), dist=dist)
    step = jax.jit(make_train_step(cfg, recipe, plan, opt, dist=dist,
                                   total_steps=400, warmup_steps=5))
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    losses = []
    with mesh:
        for i in range(n_steps):
            state, m = step(state, make_batch(data, i))
            losses.append(float(m["loss"]))
    return np.array(losses), state


def test_fp8_vs_f32_wire_training_parity():
    """20 steps on qwen15_05b: FP8-reduced loss within 1% of f32-reduced."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh, _ = _dp_mesh()
    l_fp8, _ = _train(cfg, mesh, DistPlan(wire="fp8"), 20)
    l_f32, _ = _train(cfg, mesh, DistPlan(
        wire="f32", policy=StatePolicy(m="f32", v="f32", master="f32")), 20)
    assert np.isfinite(l_fp8).all() and np.isfinite(l_f32).all()
    # both learn
    assert l_fp8[-5:].mean() < l_fp8[:3].mean() - 0.1
    rel = abs(l_fp8[-5:].mean() - l_f32[-5:].mean()) / l_f32[-5:].mean()
    assert rel < 0.01, f"fp8 vs f32 wire diverged: {rel:.4f}"
    # per-step tracking, not just the endpoint
    assert np.max(np.abs(l_fp8 - l_f32) / np.abs(l_f32)) < 0.05


def test_cast_count_unchanged_with_wire():
    """The DP wire must not add explicit casts: fp8_flow stays at 2 per FFN
    (entry quantize fwd + island quantize bwd); all wire quantizes are
    fused-kind ('dp_wire'/'opt_state' tags)."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh, _ = _dp_mesh(1)
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe("fp8_flow")
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    batch = make_batch(data, 0)

    counts, tagsets = {}, {}
    for name, dist in [("legacy", None), ("fp8_wire", DistPlan())]:
        state = init_train_state(cfg, opt, jax.random.key(0), dist=dist)
        step = make_train_step(cfg, recipe, plan, opt, dist=dist,
                               total_steps=10, warmup_steps=2)
        # jit: the ledger records at trace time, and eager shard_map cannot
        # evaluate the remat'd layer scan on this jax version
        with mesh, casts.ledger() as led:
            jax.jit(step)(state, batch)
        counts[name] = led.activation_casts()
        tags = led.by_tag()
        tagsets[name] = {t for (k, t) in tags
                         if k in ("quantize", "dequantize")
                         and not t.startswith("q_w")}
        if dist is not None:
            # the wire + opt-state quantizes exist but are FUSED kind
            assert ("fused_quantize", "dp_wire") in tags, tags
            assert ("fused_quantize", "opt_state") in tags, tags
    # zero additional explicit casts, and the fp8_flow dataflow stays the
    # paper's 2-per-FFN: entry quantize (fwd) + island quantize (bwd)
    assert counts["fp8_wire"] == counts["legacy"], counts
    assert tagsets["fp8_wire"] == tagsets["legacy"] \
        == {"q_entry", "q_bwd_island"}, tagsets


def test_moe_arch_through_fp8_wire():
    """The wire's replica-local forward takes the new EP=1 local MoE path
    (core/moe.py ep_axis=None identity collectives + shared-expert add):
    a MoE arch with shared experts must train end-to-end."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    assert cfg.moe and cfg.n_shared_experts
    mesh, _ = _dp_mesh()
    losses, state = _train(cfg, mesh, DistPlan(wire="fp8"), 3)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 0.1
    # expert weights rode the FP8 bucket wire (not the bf16 fallback)
    layout = build_layout(state["params"], DistPlan())
    bucket_names = {s.path.split(".")[-1]
                    for b in layout.buckets for s in b.slots}
    assert {"we13", "we2"} <= bucket_names
    sens_names = {p.split(".")[-1] for _, p in layout.sensitive}
    assert "w_router" in sens_names


def test_dist_rejects_model_parallel_mesh():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh((1, 2), ("data", "model"))
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    with pytest.raises(ValueError, match="model-parallel"):
        make_train_step(cfg, get_recipe("fp8_flow"), plan, AdamWConfig(),
                        dist=DistPlan())


# ---------------------------------------------------------------------------
# Streaming wire (schedule='stream'): layer-aligned reverse-order buckets,
# parity vs the post-hoc wire, the in-backward issue order, and the fast
# clear errors when a configuration cannot stream.
# ---------------------------------------------------------------------------
def test_layered_layout_partitions_and_reverse_orders():
    """Layered buckets cover every leaf per layer, never span a layer
    boundary, and are emitted in the staged backward's order: main stack
    last-layer-first, then the dense prologue last-first."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    from repro.models.lm import init_params
    params = init_params(cfg, jax.random.key(0))
    plan = DistPlan(schedule="stream")
    layout = build_layout(params, plan)
    leaves = jax.tree.leaves(params)
    slot_idx = {s.index for b in layout.buckets for s in b.slots}
    sens_idx = {i for i, _ in layout.sensitive}
    assert slot_idx | sens_idx == set(range(len(leaves)))
    assert not (slot_idx & sens_idx)
    # every bucket belongs to exactly one (stack, layer)
    for b in layout.buckets:
        assert b.stack is not None and b.layer is not None
        assert all(s.layer == b.layer for s in b.slots)
        assert b.rows % plan.shard_multiple == 0
    # reverse emission order: 'layers' L-1..0 before 'dense_layers' nd-1..0
    keys = [(b.stack, b.layer) for b in layout.buckets]
    main = [l for s, l in keys if s == "layers"]
    dense = [l for s, l in keys if s == "dense_layers"]
    assert main == sorted(main, reverse=True) and main[0] == max(main)
    assert dense == sorted(dense, reverse=True)
    assert keys.index(("layers", main[-1])) < keys.index(
        ("dense_layers", dense[0]))
    # each stacked eligible leaf appears once per layer
    from collections import Counter
    per = Counter(s.index for b in layout.buckets for s in b.slots)
    n_main = cfg.n_layers - cfg.n_dense_layers
    for i, n in per.items():
        path = [s.path for b in layout.buckets for s in b.slots
                if s.index == i][0]
        want = n_main if path.startswith("layers.") else cfg.n_dense_layers
        assert n == want, (path, n, want)


def test_layered_bucket_flat_scatter_roundtrip():
    """Per-layer slots slice the stacked leaf; scatter + restack is exact."""
    cfg = get_arch("qwen15_05b").reduced()
    from repro.models.lm import init_params
    params = init_params(cfg, jax.random.key(0))
    layout = build_layout(params, DistPlan(schedule="stream"))
    leaves = jax.tree.leaves(params)
    stacked = {}
    for b in layout.buckets:
        flat = bucket_flat(b, leaves)
        assert flat.shape == (b.rows, TILE)
        for key, piece in bucket_scatter(b, flat, leaves).items():
            assert isinstance(key, tuple)
            stacked.setdefault(key[0], {})[key[1]] = piece
    for i, pieces in stacked.items():
        re = jnp.stack([pieces[l] for l in range(leaves[i].shape[0])])
        assert re.dtype == leaves[i].dtype
        np.testing.assert_array_equal(np.asarray(re, np.float32),
                                      np.asarray(leaves[i], np.float32))


def test_streaming_matches_posthoc_wire():
    """Reverse-order-bucket parity: schedule='stream' vs schedule='posthoc'
    over the SAME layered layout — identical buckets and quantization
    groups, only the issue order differs, so the loss curves and updated
    params must agree to reduction-order noise."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh, _ = _dp_mesh()
    l_s, st_s = _train(cfg, mesh, DistPlan(wire="fp8", schedule="stream"), 5)
    l_p, st_p = _train(cfg, mesh, DistPlan(wire="fp8", layered=True), 5)
    assert np.isfinite(l_s).all()
    np.testing.assert_allclose(l_s, l_p, rtol=1e-3)
    for a, b in zip(jax.tree.leaves(st_s["params"]),
                    jax.tree.leaves(st_p["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_streaming_moe_arch_trains():
    """Streaming through a MoE arch with a dense prologue + shared experts
    (per-layer buckets over we13/we2/ws13/ws2, dense stack streamed after
    the main stack)."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    mesh, _ = _dp_mesh()
    losses, state = _train(cfg, mesh, DistPlan(wire="fp8",
                                               schedule="stream"), 3)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 0.1
    layout = build_layout(state["params"], DistPlan(schedule="stream"))
    bucket_names = {s.path.split(".")[-1]
                    for b in layout.buckets for s in b.slots}
    assert {"we13", "we2", "ws13", "ws2"} <= bucket_names


def test_streaming_fp8_vs_f32_training_parity():
    """The acceptance gate: 20 steps, fp8-STREAMING loss curve within 1% of
    the f32 post-hoc wire (same tolerance the PR-3 wire holds)."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh, _ = _dp_mesh()
    l_fp8, _ = _train(cfg, mesh, DistPlan(wire="fp8", schedule="stream"), 20)
    l_f32, _ = _train(cfg, mesh, DistPlan(
        wire="f32", policy=StatePolicy(m="f32", v="f32", master="f32")), 20)
    assert np.isfinite(l_fp8).all() and np.isfinite(l_f32).all()
    assert l_fp8[-5:].mean() < l_fp8[:3].mean() - 0.1
    rel = abs(l_fp8[-5:].mean() - l_f32[-5:].mean()) / l_f32[-5:].mean()
    assert rel < 0.01, f"fp8 streaming vs f32 wire diverged: {rel:.4f}"
    assert np.max(np.abs(l_fp8 - l_f32) / np.abs(l_f32)) < 0.05


def test_streaming_jaxpr_issues_rs_inside_backward():
    """The structural check: in the streaming step's jaxpr, at least one
    bucket reduce-scatter (all_to_all) is issued BEFORE the last backward
    GEMM; the post-hoc step issues every one after."""
    if jax.device_count() < 2:
        pytest.skip("P=1 elides the collective "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    cfg = get_arch("qwen15_05b").reduced()
    mesh, n = _dp_mesh()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe("fp8_flow")
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=max(n, 2))
    batch = make_batch(data, 0)

    def jaxpr_of(dist):
        state = init_train_state(cfg, opt, jax.random.key(0), dist=dist)
        step = make_train_step(cfg, recipe, plan, opt, dist=dist,
                               total_steps=10, warmup_steps=2)
        return str(jax.make_jaxpr(step)(state, batch))

    jx_s = jaxpr_of(DistPlan(wire="fp8", schedule="stream"))
    jx_p = jaxpr_of(DistPlan(wire="fp8", layered=True))
    assert jx_s.count("all_to_all") == jx_p.count("all_to_all") > 0
    assert jx_s.find("all_to_all") < jx_s.rfind("dot_general"), \
        "streaming wire: no reduce-scatter before the last backward GEMM"
    assert jx_p.find("all_to_all") > jx_p.rfind("dot_general"), \
        "post-hoc wire unexpectedly interleaved (baseline drifted)"


def test_streaming_fast_clear_errors():
    cfg = get_arch("qwen15_05b").reduced()
    mesh, _ = _dp_mesh(1)
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    # schedule='stream' forces layer-aligned buckets
    with pytest.raises(ValueError, match="layer-aligned"):
        DistPlan(schedule="stream", layered=False)
    # encoder-decoder archs keep the post-hoc wire
    enc = get_arch("seamless_m4t_v2").reduced()
    with pytest.raises(ValueError, match="decoder-only"):
        make_train_step(enc, get_recipe("fp8_flow"), plan, AdamWConfig(),
                        dist=DistPlan(schedule="stream"))
    # the launcher-facing probe reports a reason instead of raising; grad
    # accumulation no longer blocks streaming (local accumulation + one
    # wire pass on the last microbatch)
    from repro.dist import streaming_fallback_reason
    assert streaming_fallback_reason(enc) is not None
    assert streaming_fallback_reason(cfg) is None
    assert streaming_fallback_reason(cfg, grad_accum=4) is None


def test_layered_sensitive_leaves_carry_stack_tags():
    """Satellite: GradLayout.sensitive gains layer (stack) tags — stacked
    sensitive leaves (norm scales, per-layer routers) are marked so the
    streaming backward can issue each layer's bf16 psum with its bucket;
    the ends (embed / final norm / head) stay untagged (post-hoc)."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    from repro.models.lm import init_params
    params = init_params(cfg, jax.random.key(0))
    layout = build_layout(params, DistPlan(schedule="stream"))
    by_name = {s.path.split(".")[-1]: s for s in layout.sensitive}
    assert by_name["w_router"].stack == "layers"
    assert by_name["ln1_s"].stack in ("layers", "dense_layers")
    assert by_name["embed"].stack is None
    assert by_name["final_norm_s"].stack is None
    # the flat (non-layered) layout carries no tags
    flat_layout = build_layout(params, DistPlan())
    assert all(s.stack is None for s in flat_layout.sensitive)
    # legacy 2-tuple iteration still works
    for i, p in layout.sensitive:
        assert isinstance(i, int) and isinstance(p, str)


def _train_accum(cfg, mesh, dist, n_steps, grad_accum, lr=3e-3, seed=0):
    """_train with a leading microbatch axis on every batch."""
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=lr)
    recipe = get_recipe("fp8_flow")
    state = init_train_state(cfg, opt, jax.random.key(seed), dist=dist)
    step = jax.jit(make_train_step(cfg, recipe, plan, opt, dist=dist,
                                   grad_accum=grad_accum, total_steps=400,
                                   warmup_steps=5))
    # per-MICROBATCH rows must divide the DP axis
    data = DataConfig(vocab=cfg.vocab, seq_len=64,
                      global_batch=grad_accum * jax.device_count())
    losses = []
    with mesh:
        for i in range(n_steps):
            b = make_batch(data, i)
            if grad_accum > 1:
                b = jax.tree.map(lambda a: a.reshape(
                    grad_accum, a.shape[0] // grad_accum, *a.shape[1:]), b)
            state, m = step(state, b)
            losses.append(float(m["loss"]))
    return np.array(losses), state


def test_stream_grad_accum_matches_posthoc():
    """Satellite: grad-accum streaming — microbatch grads accumulate
    locally, ONE quantize + reduce-scatter per bucket on the last
    microbatch.  Must match the post-hoc wire over the same layered layout
    (identical buckets and quantization groups) to reduction-order noise,
    and the single-microbatch stream result."""
    cfg = get_arch("qwen15_05b").reduced()
    mesh, _ = _dp_mesh()
    l_s, st_s = _train_accum(cfg, mesh, DistPlan(wire="fp8",
                                                 schedule="stream"), 5, 2)
    l_p, st_p = _train_accum(cfg, mesh, DistPlan(wire="fp8", layered=True),
                             5, 2)
    assert np.isfinite(l_s).all()
    np.testing.assert_allclose(l_s, l_p, rtol=1e-3)
    for a, b in zip(jax.tree.leaves(st_s["params"]),
                    jax.tree.leaves(st_p["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_stream_grad_accum_single_wire_pass_jaxpr():
    """With grad_accum=2 the streaming step still issues exactly ONE fused
    reduce-scatter per bucket (not one per microbatch), and it stays
    interleaved with backward GEMMs."""
    if jax.device_count() < 2:
        pytest.skip("P=1 elides the collective")
    cfg = get_arch("qwen15_05b").reduced()
    mesh, n = _dp_mesh()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe("fp8_flow")
    dist = DistPlan(wire="fp8", schedule="stream")
    state = init_train_state(cfg, opt, jax.random.key(0), dist=dist)
    layout = build_layout(state["params"], dist)
    step = make_train_step(cfg, recipe, plan, opt, dist=dist, grad_accum=2,
                           total_steps=10, warmup_steps=2)
    data = DataConfig(vocab=cfg.vocab, seq_len=32,
                      global_batch=2 * max(n, 2))
    b = jax.tree.map(lambda a: a.reshape(2, a.shape[0] // 2, *a.shape[1:]),
                     make_batch(data, 0))
    jx = str(jax.make_jaxpr(step)(state, b))
    assert jx.count("all_to_all") == len(layout.buckets), \
        (jx.count("all_to_all"), len(layout.buckets))
    assert jx.find("all_to_all") < jx.rfind("dot_general"), \
        "accumulated streaming wire not interleaved with the backward"


@pytest.mark.parametrize("policy", ["fp8_resident", "pair"])
def test_stream_composes_with_remat_policy(policy):
    """Satellite compose test: the streaming wire under each MemoryPlan
    policy — per-block vjp granularity changes ('pair' streams two-layer
    blocks) but the math must match the post-hoc wire at the loss-curve
    level."""
    import dataclasses as dc
    cfg = dc.replace(get_arch("qwen15_05b").reduced(), remat_policy=policy)
    mesh, _ = _dp_mesh()
    l_s, _ = _train(cfg, mesh, DistPlan(wire="fp8", schedule="stream"), 5)
    l_p, _ = _train(cfg, mesh, DistPlan(wire="fp8", layered=True), 5)
    assert np.isfinite(l_s).all()
    np.testing.assert_allclose(l_s, l_p, rtol=1e-3)


def test_staged_forward_matches_scan():
    """ParallelPlan.stage_layers runs the decoder through the unrolled
    staged program (_run_stack_unrolled, two-layer carry window) — same
    function as the monolithic scan."""
    from repro.data.pipeline import make_batch as mk
    from repro.models.lm import forward
    cfg = get_arch("deepseek_v2_lite").reduced()
    from repro.models.lm import init_params
    params = init_params(cfg, jax.random.key(0))
    plan_scan = ParallelPlan(mesh=None, dp_axes=(), shard_map_mlp=False)
    plan_staged = ParallelPlan(mesh=None, dp_axes=(), shard_map_mlp=False,
                               stage_layers=True)
    batch = mk(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2), 0)
    recipe = get_recipe("fp8_flow")
    l0, m0 = jax.jit(lambda p, b: forward(cfg, recipe, plan_scan, p, b))(
        params, batch)
    l1, m1 = jax.jit(lambda p, b: forward(cfg, recipe, plan_staged, p, b))(
        params, batch)
    # same math, different fusion groups (scan body vs unrolled layers):
    # bf16 forward rounding differs at ~1e-4 relative
    np.testing.assert_allclose(float(l1), float(l0), rtol=1e-3)
    np.testing.assert_allclose(float(m1["aux_loss"]), float(m0["aux_loss"]),
                               rtol=1e-3, atol=1e-6)


def test_grad_accum_keeps_forward_metrics():
    """_local_grads used to return {} for grad_accum > 1 — forward metrics
    must now be accumulated and averaged like the loss."""
    cfg = get_arch("deepseek_v2_lite").reduced()
    plan = ParallelPlan(mesh=None, dp_axes=(), shard_map_mlp=False)
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.key(0))
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    flat = make_batch(data, 0)
    micro = jax.tree.map(lambda a: a.reshape(2, 2, *a.shape[1:]), flat)
    step = jax.jit(make_train_step(cfg, get_recipe("fp8_flow"), plan, opt,
                                   grad_accum=2, total_steps=10,
                                   warmup_steps=2))
    _, metrics = step(state, micro)
    assert "aux_loss" in metrics, metrics.keys()
    assert np.isfinite(float(metrics["aux_loss"]))
    assert np.isfinite(float(metrics["loss"]))
