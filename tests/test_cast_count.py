"""The paper's headline accounting (Fig. 2): explicit Q/DQ casts per MoE
forward+backward — 12 (naive drop-in FP8) -> 2 (FP8-Flow-MoE)."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import casts
from repro.core.linear import expert_ffn, quantize_entry
from repro.core.moe import MoEConfig, moe_block
from repro.core.recipes import get_recipe
from tests.conftest import make_mesh11

EXPECTED_FFN = {"bf16": 0, "blockwise": 8, "naive_fp8": 10, "fp8_flow": 1}
EXPECTED_MOE = {"bf16": 0, "blockwise": 8, "naive_fp8": 12, "fp8_flow": 2}


def _ffn_loss(recipe):
    r = np.random.default_rng(0)
    E, C, K, F = 2, 128, 256, 128
    x = jnp.asarray(r.normal(size=(E, C, K)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    w13 = jnp.asarray(r.normal(size=(E, K, 2 * F)).astype(np.float32) * 0.05)
    w2 = jnp.asarray(r.normal(size=(E, F, K)).astype(np.float32) * 0.05)

    def L(x, w13, w2):
        xi = quantize_entry(recipe, x) if recipe.name == "fp8_flow" else x
        y = expert_ffn(recipe, "swiglu", (), (), xi, w13, w2)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    return L, (x, w13, w2)


@pytest.mark.parametrize("name", list(EXPECTED_FFN))
def test_ffn_cast_count(name):
    recipe = get_recipe(name)
    L, args = _ffn_loss(recipe)
    with casts.ledger() as led:
        jax.grad(L, argnums=(0, 1, 2))(*args)
    n = led.activation_casts()
    # fp8_flow counts the entry quantize here too (no dispatch boundary)
    expected = EXPECTED_FFN[name] + (1 if name == "fp8_flow" else 0)
    assert n == expected, led.summary()


@pytest.mark.parametrize("name", list(EXPECTED_MOE))
def test_moe_block_cast_count(name):
    """Full MoE block (router+dispatch+experts+combine) on a 1x1 mesh."""
    recipe = get_recipe(name)
    mesh = make_mesh11()
    E, D, F, topk, T = 4, 256, 128, 2, 256
    cfg = MoEConfig(n_experts=E, top_k=topk, d_model=D, d_ff=F)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(T, D)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    wr = jnp.asarray(r.normal(size=(D, E)).astype(np.float32) * 0.02)
    w13 = jnp.asarray(r.normal(size=(E, D, 2 * F)).astype(np.float32) * 0.05)
    w2 = jnp.asarray(r.normal(size=(E, F, D)).astype(np.float32) * 0.05)

    def body(x, wr, w13, w2):
        y, m = moe_block(recipe, cfg, x, wr, w13, w2)
        return jax.lax.psum(jnp.sum(y.astype(jnp.float32) ** 2),
                            ("data", "model"))

    sm = shard_map(body, mesh=mesh,
                   in_specs=(P(("data", "model"), None), P(None, None),
                             P("model", None, None), P("model", None, None)),
                   out_specs=P())
    with casts.ledger() as led:
        jax.grad(lambda *a: jnp.sum(sm(*a)), argnums=(0, 1, 2, 3))(
            x, wr, w13, w2)
    assert led.activation_casts() == EXPECTED_MOE[name], led.summary()


def test_masked_fused_epilogue_keeps_two_casts():
    """Masked expert kernels + the fused SwiGLU-in-GEMM-1 epilogue must not
    change the Fig.-2 accounting: still 2 explicit casts (entry + bwd
    island), swiglu_quant stays FUSED kind, and the tag set is identical to
    the unfused fp8_flow FFN."""
    def run(recipe, masked_m):
        r = np.random.default_rng(0)
        E, C, K, F = 2, 128, 256, 128
        x = jnp.asarray(r.normal(size=(E, C, K)).astype(np.float32)
                        ).astype(jnp.bfloat16)
        if masked_m is not None:   # dead dispatch slots carry zeros
            live = jnp.asarray(np.arange(C)[None, :]
                               < np.asarray(masked_m)[:, None])
            x = jnp.where(live[..., None], x, 0)
        w13 = jnp.asarray(r.normal(size=(E, K, 2 * F)).astype(np.float32)
                          * 0.05)
        w2 = jnp.asarray(r.normal(size=(E, F, K)).astype(np.float32) * 0.05)

        def L(x, w13, w2):
            xi = quantize_entry(recipe, x)
            y = expert_ffn(recipe, "swiglu", (), (), xi, w13, w2, masked_m)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        with casts.ledger() as led:
            jax.grad(L, argnums=(0, 1, 2))(x, w13, w2)
        return led

    base = run(get_recipe("fp8_flow", use_pallas=True), None)
    fused = run(get_recipe("fp8_flow", use_pallas=True, masked_experts=True,
                           swiglu_epilogue=True),
                jnp.asarray([64, 128], jnp.int32))
    assert fused.activation_casts() == 2, fused.summary()
    assert fused.activation_casts() == base.activation_casts()
    # same tag set; swiglu_quant present in BOTH, always fused kind
    def tags(led):
        return {(e.kind, e.tag) for e in led.events
                if not e.tag.startswith("q_w")}
    assert tags(fused) == tags(base), (tags(fused), tags(base))
    assert ("fused_quantize", "swiglu_quant") in tags(fused)
    assert not [e for e in fused.events if e.kind == "dequantize"]


def test_flow_has_zero_dequantize_ops():
    """fp8_flow's explicit casts are both QUANTIZE ops — no dequantize ever
    materializes (the casting-free property)."""
    recipe = get_recipe("fp8_flow")
    L, args = _ffn_loss(recipe)
    with casts.ledger() as led:
        jax.grad(L, argnums=(0, 1, 2))(*args)
    explicit_dq = [e for e in led.events if e.kind == "dequantize"]
    assert not explicit_dq


def test_naive_has_double_quant_sites():
    """naive_fp8 must contain the dequantize->requantize pairs the paper
    identifies as the double-quantization-error sites."""
    recipe = get_recipe("naive_fp8")
    L, args = _ffn_loss(recipe)
    with casts.ledger() as led:
        jax.grad(L, argnums=(0, 1, 2))(*args)
    tags = [e.tag for e in led.events if e.kind == "dequantize"]
    assert "dq_transpose" in tags


def test_staged_streaming_backward_stays_two_casts():
    """The staged per-layer backward (DistPlan schedule='stream') keeps
    fp8_flow's Fig.-2 dataflow: per layer, ONE entry quantize (counted once
    more by the remat recompute trace) and ONE backward island quantize —
    no new explicit cast sites, no explicit dequantize; every wire/state
    quantize stays fused-kind."""
    from repro.compat import make_mesh
    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig, make_batch
    from repro.dist import DistPlan
    from repro.models.lm import ParallelPlan
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe("fp8_flow")
    dist = DistPlan(wire="fp8", schedule="stream")
    state = init_train_state(cfg, opt, jax.random.key(0), dist=dist)
    step = make_train_step(cfg, recipe, plan, opt, dist=dist,
                           total_steps=10, warmup_steps=2)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    with mesh, casts.ledger() as led:
        jax.jit(step)(state, make_batch(data, 0))
    by = led.by_tag()
    # the unrolled program has n_layers trace sites: one island quantize per
    # layer backward, one entry quantize per layer forward + one per remat
    # recompute — nothing else on the activation path
    assert by.get(("quantize", "q_bwd_island"), 0) == cfg.n_layers, by
    expected_entry = cfg.n_layers * (2 if cfg.remat else 1)
    assert by.get(("quantize", "q_entry"), 0) == expected_entry, by
    tags = {t for (k, t) in by
            if k in ("quantize", "dequantize") and not t.startswith("q_w")}
    assert tags == {"q_entry", "q_bwd_island"}, by
    assert not [e for e in led.events if e.kind == "dequantize"]
    # the wire + optimizer-state quantizes exist but are FUSED kind
    assert ("fused_quantize", "dp_wire") in by
    assert ("fused_quantize", "opt_state") in by


@pytest.mark.parametrize("policy", ["none", "full", "fp8_resident", "pair"])
def test_streamed_casts_under_every_remat_policy(policy):
    """MemoryPlan extension of the invariant above: the activation-
    residency policy changes WHAT is saved, never the cast structure —
    per layer, ONE backward island quantize under every policy, one entry
    quantize per forward trace (plus one per remat retrace when a policy
    checkpoints), and no new explicit cast tags."""
    import dataclasses
    from repro.compat import make_mesh
    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig, make_batch
    from repro.dist import DistPlan
    from repro.models.lm import ParallelPlan
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(get_arch("qwen15_05b").reduced(),
                              remat_policy=policy)
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe("fp8_flow")
    dist = DistPlan(wire="fp8", schedule="stream")
    state = init_train_state(cfg, opt, jax.random.key(0), dist=dist)
    step = make_train_step(cfg, recipe, plan, opt, dist=dist,
                           total_steps=10, warmup_steps=2)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    with mesh, casts.ledger() as led:
        jax.jit(step)(state, make_batch(data, 0))
    by = led.by_tag()
    assert by.get(("quantize", "q_bwd_island"), 0) == cfg.n_layers, by
    expected_entry = cfg.n_layers * (1 if policy == "none" else 2)
    assert by.get(("quantize", "q_entry"), 0) == expected_entry, by
    tags = {t for (k, t) in by
            if k in ("quantize", "dequantize") and not t.startswith("q_w")}
    assert tags == {"q_entry", "q_bwd_island"}, by
    assert not [e for e in led.events if e.kind == "dequantize"]
