"""Continuous-batching serving engine: scheduler admission/eviction
invariants, paged-KV allocator correctness, FP8-paged-KV decode parity vs
BF16 pages, prefill-then-decode parity vs the one-shot forward path, and an
end-to-end engine run with real admission + eviction."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.models.lm import (ParallelPlan, forward, init_params,
                             paged_decode_step, paged_prefill)
from repro.serve.paged_kv import (PageAllocator, SCRATCH_PAGE,
                                  init_paged_cache, pool_nbytes)
from repro.serve.scheduler import Request, Scheduler
from tests.conftest import make_mesh11


# ---------------------------------------------------------------------------
# Paged-KV allocator (pure host).
# ---------------------------------------------------------------------------
def test_allocator_alloc_free_reuse():
    a = PageAllocator(n_pages=8, page_size=4)
    assert a.free_pages == 7                      # page 0 reserved
    p1 = a.alloc(3)
    p2 = a.alloc(4)
    assert p1 is not None and p2 is not None
    assert a.free_pages == 0
    assert a.alloc(1) is None                     # exhausted: None, no raise
    assert SCRATCH_PAGE not in p1 + p2            # scratch never handed out
    assert len(set(p1 + p2)) == 7                 # all distinct
    a.free(p1)
    assert a.free_pages == 3
    with pytest.raises(ValueError):
        a.free(p1)                                # double free detected
    p3 = a.alloc(3)
    assert sorted(p3) == sorted(p1)               # freed pages are reused
    assert a.pages_for(1) == 1 and a.pages_for(4) == 1 and a.pages_for(5) == 2


# ---------------------------------------------------------------------------
# Scheduler invariants (pure host; no model).
# ---------------------------------------------------------------------------
def test_scheduler_fcfs_budget_and_no_starvation():
    rng = np.random.default_rng(0)
    alloc = PageAllocator(n_pages=64, page_size=4)
    sched = Scheduler(max_batch=4, token_budget=96)
    reqs = [Request(prompt=[1] * int(rng.integers(4, 12)),
                    max_new_tokens=int(rng.integers(2, 10)))
            for _ in range(16)]
    for r in reqs:
        sched.submit(r)
    submit_order = [r.rid for r in reqs]
    admit_order, finished = [], []
    for tick in range(500):
        if sched.idle():
            break
        st = sched.try_admit(alloc, now=float(tick))
        if st is not None:
            st.prefilled = True
            st.generated.append(0)
            admit_order.append(st.req.rid)
        # budget invariant holds at every tick
        assert sched.reserved_tokens <= sched.token_budget
        assert sched.n_active <= sched.max_batch
        # simulate one decode token for every resident request
        for slot in list(sched.active):
            s = sched.active[slot]
            s.generated.append(0)
            if s.done(eos_id=None):
                finished.append(s.req.rid)
                sched.finish(slot, alloc, now=float(tick))
    assert sched.idle()                           # no request starves
    assert sorted(finished) == sorted(submit_order)
    assert admit_order == submit_order            # strict FCFS admission
    assert alloc.free_pages == 63                 # every page returned


def test_scheduler_head_of_line_blocks_and_eviction_requeues_front():
    alloc = PageAllocator(n_pages=16, page_size=4)
    sched = Scheduler(max_batch=4, token_budget=40)
    big = Request(prompt=[1] * 16, max_new_tokens=20)    # reserves 36
    small = Request(prompt=[1] * 4, max_new_tokens=2)    # reserves 6
    sched.submit(big)
    sched.submit(small)
    st_big = sched.try_admit(alloc, 0.0)
    assert st_big is not None and st_big.req.rid == big.rid
    # head-of-line: `small` fits neither budget (36+6>40) -> nothing admitted
    assert sched.try_admit(alloc, 0.0) is None
    # evicting under pressure requeues the victim at the FRONT of the line
    st_big.prefilled = True
    st_big.generated.append(0)
    ev = sched.evict_youngest(alloc)
    assert ev is st_big and not ev.generated and not ev.prefilled
    assert sched.waiting[0] is big and sched.waiting[1] is small
    assert sched.n_evictions == 1
    assert alloc.free_pages == 15


# ---------------------------------------------------------------------------
# Model-level parity (dense arch keeps compiles cheap).
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_arch("qwen15_05b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    return cfg, mesh, plan, params


def _cos(a, b):
    a = np.asarray(a, np.float32).ravel()
    b = np.asarray(b, np.float32).ravel()
    return a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30)


def _prefill_one(cfg, plan, params, pools, prompt, ps, mp, recipe, mesh):
    alloc = PageAllocator(pools["main_attn"]["k"]["data"].shape[1], ps)
    pages = alloc.alloc(alloc.pages_for(len(prompt)))
    ptrow = np.zeros((mp,), np.int32)
    ptrow[:len(pages)] = pages
    bucket = 16
    toks = np.zeros((1, bucket), np.int32)
    toks[0, :len(prompt)] = prompt
    with mesh:
        lg, pools = paged_prefill(cfg, recipe, plan, params, pools,
                                  jnp.asarray(ptrow), jnp.asarray(toks),
                                  jnp.int32(len(prompt)))
    return lg, pools, pages, ptrow, alloc


def test_prefill_then_decode_matches_one_shot_forward(dense_setup):
    """Per-request parity: bucketed paged prefill reproduces the one-shot
    forward logits at the prompt's last position, and each paged decode step
    tracks the teacher-forced forward on the growing sequence."""
    cfg, mesh, plan, params = dense_setup
    recipe = get_recipe("bf16")
    ps, mp = 8, 8
    pools = init_paged_cache(cfg, 32, ps, fp8_kv=False)   # exact bf16 pages
    prompt = list(np.random.default_rng(1).integers(1, cfg.vocab, 7))
    lg, pools, pages, ptrow, alloc = _prefill_one(
        cfg, plan, params, pools, prompt, ps, mp, recipe, mesh)
    with mesh:
        ref, _ = forward(cfg, recipe, plan, params,
                         {"tokens": jnp.asarray([prompt], jnp.int32)},
                         compute_loss=False)
    assert _cos(lg[0, -1], ref[0, -1]) > 0.999

    B = 2                                   # slot 1 stays inactive/garbage
    pt = np.zeros((B, mp), np.int32)
    pt[0, :len(pages)] = pages
    seq = list(prompt)
    cur = int(np.argmax(np.asarray(lg[0, -1], np.float32)))
    for t in range(3):
        pos_w = len(prompt) + t
        if pos_w // ps + 1 > len(pages):
            pages += alloc.alloc(1)
            pt[0, :len(pages)] = pages
        pos = np.zeros((B,), np.int32)
        pos[0] = pos_w
        act = np.zeros((B,), bool)
        act[0] = True
        tk = np.zeros((B, 1), np.int32)
        tk[0, 0] = cur
        with mesh:
            dlg, pools = paged_decode_step(
                cfg, recipe, plan, params, pools, jnp.asarray(pt),
                jnp.asarray(tk), jnp.asarray(pos), jnp.asarray(act))
        seq.append(cur)
        with mesh:
            rlg, _ = forward(cfg, recipe, plan, params,
                             {"tokens": jnp.asarray([seq], jnp.int32)},
                             compute_loss=False)
        assert _cos(dlg[0, -1], rlg[0, -1]) > 0.999
        assert int(np.argmax(np.asarray(dlg[0, -1], np.float32))) == \
            int(np.argmax(np.asarray(rlg[0, -1], np.float32)))
        cur = int(np.argmax(np.asarray(dlg[0, -1], np.float32)))


def test_fp8_paged_kv_parity_and_bytes(dense_setup):
    """FP8 pages (e4m3 payload + per-row po2 scales) decode within tolerance
    of BF16 pages and hold ~half the bytes."""
    cfg, mesh, plan, params = dense_setup
    recipe = get_recipe("bf16")
    ps, mp = 8, 8
    prompt = list(np.random.default_rng(2).integers(1, cfg.vocab, 9))
    logits = {}
    pools_by_kind = {}
    for fp8 in (False, True):
        pools = init_paged_cache(cfg, 32, ps, fp8_kv=fp8)
        pools_by_kind[fp8] = pools
        lg, pools, pages, ptrow, _ = _prefill_one(
            cfg, plan, params, pools, prompt, ps, mp, recipe, mesh)
        pt = np.zeros((1, mp), np.int32)
        pt[0, :len(pages)] = pages
        cur = int(np.argmax(np.asarray(lg[0, -1], np.float32)))
        with mesh:
            dlg, _ = paged_decode_step(
                cfg, recipe, plan, params, pools, jnp.asarray(pt),
                jnp.asarray([[cur]], jnp.int32),
                jnp.asarray([len(prompt)], jnp.int32),
                jnp.asarray([True]))
        logits[fp8] = dlg
    assert _cos(logits[True], logits[False]) > 0.99
    assert pool_nbytes(pools_by_kind[True]) < \
        0.6 * pool_nbytes(pools_by_kind[False])


def test_decode_step_accepts_per_request_pos_vector(dense_setup):
    """The dense-cache decode path: a (B,) pos vector with equal entries
    reproduces the scalar shared-pos path exactly."""
    from repro.models.lm import decode_step, init_cache
    cfg, mesh, plan, params = dense_setup
    recipe = get_recipe("bf16")
    B = 2
    toks = jnp.asarray(np.random.default_rng(3).integers(1, cfg.vocab,
                                                         (B, 1)), jnp.int32)
    with mesh:
        lg_s, _ = decode_step(cfg, recipe, plan, params,
                              init_cache(cfg, B, 32), toks, jnp.int32(2))
        lg_v, _ = decode_step(cfg, recipe, plan, params,
                              init_cache(cfg, B, 32), toks,
                              jnp.asarray([2, 2], jnp.int32))
    assert np.allclose(np.asarray(lg_s, np.float32),
                       np.asarray(lg_v, np.float32), atol=2e-2)


# ---------------------------------------------------------------------------
# End-to-end engine run (MoE arch: W8-resident weights + FP8 paged KV).
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_end_to_end_with_admission_and_eviction():
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_arch("qwen3_moe_235b").reduced()
    mesh = make_mesh11()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    recipe = get_recipe("fp8_flow")
    params = init_params(cfg, jax.random.key(0))
    # pool deliberately small: 3 concurrent requests cannot all fit their
    # full lengths, so page-pressure eviction must fire and recover
    ecfg = ServeConfig(max_batch=3, page_size=4, n_pages=7,
                       max_pages_per_req=5, token_budget=64,
                       prefill_buckets=(16,), fp8_kv=True, w8_weights=True)
    eng = ServeEngine(cfg, recipe, plan, params, ecfg)
    r = np.random.default_rng(4)
    reqs = [Request(prompt=list(r.integers(1, cfg.vocab,
                                           int(r.integers(4, 9)))),
                    max_new_tokens=int(r.integers(6, 11)))
            for _ in range(8)]
    results = eng.run(reqs, realtime=False)
    assert len(results) == len(reqs)              # nobody starves
    assert eng.max_concurrent <= ecfg.max_batch < len(reqs)
    assert eng.sched.n_evictions >= 1             # pressure path exercised
    # per-request eviction counts survive re-admission into the results
    assert sum(v["n_evictions"] for v in results.values()) == \
        eng.sched.n_evictions
    for req in reqs:
        assert len(results[req.rid]["tokens"]) == req.max_new_tokens
    # every page came back to the free list
    assert eng.alloc.free_pages == ecfg.n_pages - 1
