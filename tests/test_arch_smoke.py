"""Per-architecture smoke tests: a REDUCED config of each assigned family
runs one forward and one train step on CPU, asserting output shapes and
finite values (the full configs are exercised only via the dry-run)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core.recipes import get_recipe
from repro.models.lm import (ParallelPlan, decode_step, forward, init_cache,
                             init_params)
from tests.conftest import make_mesh11


def _batch(cfg, B=2, S=64):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "targets": jnp.ones((B, S), jnp.int32),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend != "none":
        batch["prefix"] = jnp.full((B, cfg.frontend_len, cfg.d_model), 0.01,
                                   jnp.bfloat16)
    if cfg.encdec:
        batch["enc_input"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def mesh():
    return make_mesh11()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, mesh):
    cfg = get_arch(arch).reduced()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    recipe = get_recipe("fp8_flow")
    with mesh:
        loss, metrics = jax.jit(
            lambda p, b: forward(cfg, recipe, plan, p, b))(
                params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ["qwen3_moe_235b", "mamba2_27b",
                                  "gemma2_9b", "seamless_m4t_v2",
                                  "hymba_15b", "grok1_314b"])
def test_train_step_smoke(arch, mesh):
    """One full optimizer step on the reduced config."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_arch(arch).reduced()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    recipe = get_recipe("fp8_flow")
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.key(0))
    step = make_train_step(cfg, recipe, plan, opt, warmup_steps=2)
    with mesh:
        state2, metrics = jax.jit(step)(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved somewhere in the tree (bf16 resolution means
    # tiny decay-only deltas can round away on individual leaves)
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3_moe_235b", "gemma3_4b",
                                  "mamba2_27b", "hymba_15b",
                                  "seamless_m4t_v2", "llava_next_34b"])
def test_decode_smoke(arch, mesh):
    cfg = get_arch(arch).reduced()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    recipe = get_recipe("fp8_flow")
    B = 2
    cache = init_cache(cfg, B, 128)
    with mesh:
        logits, cache2 = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, recipe, plan, p, c, t, pos)
        )(params, cache, jnp.ones((B, 1), jnp.int32), jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())


def test_prefill_matches_decode(mesh):
    """Decoding token-by-token must match the prefill forward logits —
    validates cache correctness (qwen-family reduced config)."""
    cfg = get_arch("qwen15_05b").reduced()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    params = init_params(cfg, jax.random.key(1))
    recipe = get_recipe("bf16")
    B, S = 1, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    with mesh:
        logits_all, _ = forward(cfg, recipe, plan, params, batch,
                                compute_loss=False)
        cache = init_cache(cfg, B, 32)
        outs = []
        for t in range(S):
            lg, cache = decode_step(cfg, recipe, plan, params, cache,
                                    toks[:, t:t + 1], jnp.int32(t))
            outs.append(lg[:, 0])
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    ref = np.asarray(logits_all)
    np.testing.assert_allclose(dec, ref, rtol=0.1, atol=0.15)


def test_ssd_chunked_matches_sequential():
    """Mamba2 SSD chunked algorithm vs the naive sequential recurrence."""
    from repro.models.ssm import ssd_chunked
    r = np.random.default_rng(0)
    b, S, H, P, N = 2, 64, 4, 8, 16
    x = jnp.asarray(r.normal(size=(b, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(r.normal(size=(b, S, H))).astype(np.float32) * 0.5)
    A = jnp.asarray(-np.abs(r.normal(size=(H,))).astype(np.float32))
    B_ = jnp.asarray(r.normal(size=(b, S, N)).astype(np.float32))
    C_ = jnp.asarray(r.normal(size=(b, S, N)).astype(np.float32))

    y_chunked, state_c = ssd_chunked(x, dt, A, B_, C_, chunk=16)

    # sequential reference
    h = np.zeros((b, H, P, N), np.float64)
    ys = []
    for t in range(S):
        a_t = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])  # (b,H)
        dBx = np.einsum("bn,bh,bhp->bhpn", np.asarray(B_[:, t]),
                        np.asarray(dt[:, t]), np.asarray(x[:, t]))
        h = h * a_t[..., None, None] + dBx
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(C_[:, t]), h))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), y_ref, rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(state_c), h, rtol=2e-3, atol=2e-3)
