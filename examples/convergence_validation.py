"""Fig. 6 reproduction driver: BF16 vs FP8-Flow-MoE vs naive-FP8 loss curves
on identical data (DeepSeek-V2-Lite family, reduced scale).

Run:  PYTHONPATH=src:. REPRO_CONV_STEPS=120 python examples/convergence_validation.py
Writes experiments/convergence.csv + prints final-loss gaps.
"""
from benchmarks import fig6_convergence

if __name__ == "__main__":
    fig6_convergence.run()
