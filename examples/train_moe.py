"""End-to-end driver: train a ~100M-parameter MoE (DeepSeek-V2-Lite family,
reduced) for a few hundred steps with the FP8-Flow recipe — checkpointing,
restart, LR schedule, metrics included.

Run:  PYTHONPATH=src python examples/train_moe.py [--steps 300] [--recipe fp8_flow]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_test_mesh
from repro.models.lm import ParallelPlan
from repro.optim.adamw import AdamWConfig
from repro.train.loop import run as run_loop
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--recipe", default="fp8_flow")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    # ~100M-param MoE: v2-lite family, widened reduced config
    cfg = dataclasses.replace(
        get_arch("deepseek_v2_lite").reduced(),
        n_layers=6, d_model=768, n_heads=12, head_dim=64, d_ff=2048,
        n_experts=16, top_k=2, d_ff_expert=768, n_shared_experts=1,
        n_dense_layers=1, vocab=16384)
    print(f"model: {cfg.n_params()/1e6:.1f}M params "
          f"({cfg.active_params()/1e6:.1f}M active), recipe={args.recipe}")

    mesh = make_test_mesh()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    opt = AdamWConfig(lr=1e-3)
    recipe = get_recipe(args.recipe)
    step = jax.jit(make_train_step(cfg, recipe, plan, opt,
                                   total_steps=args.steps, warmup_steps=20))
    state = init_train_state(cfg, opt, jax.random.key(0))
    data = DataConfig(vocab=cfg.vocab, seq_len=256, global_batch=8)
    with mesh:
        state, hist = run_loop(step, state, data, n_steps=args.steps,
                               ckpt_dir=args.ckpt_dir, ckpt_every=100,
                               log_every=20)
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
