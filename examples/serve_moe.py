"""Serving example: batched autoregressive decoding with a KV cache
(optionally FP8-compressed) against a reduced MoE model.

Run:  PYTHONPATH=src python examples/serve_moe.py [--fp8-kv]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.launch.mesh import make_test_mesh
from repro.models.lm import ParallelPlan, init_cache, init_params
from repro.serve.serve_step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fp8-kv", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch("qwen3_moe_235b").reduced()
    mesh = make_test_mesh()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    recipe = get_recipe("fp8_flow")
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, args.batch, 128, fp8_kv=args.fp8_kv)
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"KV cache: {cache_bytes/2**20:.1f} MiB "
          f"({'fp8' if args.fp8_kv else 'bf16'})")

    step = jax.jit(make_serve_step(cfg, recipe, plan))
    toks = jnp.ones((args.batch, 1), jnp.int32)
    out = []
    with mesh:
        t0 = time.perf_counter()
        for t in range(args.tokens):
            toks, cache = step(params, cache, toks, jnp.int32(t))
            out.append(jax.device_get(toks)[:, 0])
        dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x {args.batch} reqs "
          f"in {dt:.2f}s; first request ids: "
          f"{[int(o[0]) for o in out[:8]]}...")


if __name__ == "__main__":
    main()
