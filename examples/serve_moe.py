"""Serving example: continuous batching over a reduced MoE model — paged
FP8 KV cache, W8-resident expert weights, FCFS scheduling with a token
budget, interleaved prefill/decode in one jitted step.

Run:  PYTHONPATH=src python examples/serve_moe.py [--bf16-kv] [--temperature 0.8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.recipes import get_recipe
from repro.launch.mesh import make_test_mesh
from repro.models.lm import ParallelPlan, init_params
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bf16-kv", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch("qwen3_moe_235b").reduced()
    mesh = make_test_mesh()
    plan = ParallelPlan(mesh=mesh, dp_axes=("data",))
    recipe = get_recipe("fp8_flow")
    params = init_params(cfg, jax.random.key(0))

    ecfg = ServeConfig(max_batch=4, page_size=8, n_pages=64,
                      max_pages_per_req=8, token_budget=256,
                      prefill_buckets=(16, 32), fp8_kv=not args.bf16_kv,
                      w8_weights=True, top_k=8)
    engine = ServeEngine(cfg, recipe, plan, params, ecfg)
    print(f"paged KV pool: {engine.kv_bytes()/2**20:.1f} MiB "
          f"({'fp8+po2-scales' if ecfg.fp8_kv else 'bf16'}), "
          f"{ecfg.max_batch} slots, {ecfg.n_pages} pages x "
          f"{ecfg.page_size} tokens")

    r = np.random.default_rng(0)
    reqs = [Request(prompt=list(r.integers(1, cfg.vocab,
                                           int(r.integers(3, 15)))),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.run(reqs, realtime=False)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v["tokens"]) for v in results.values())
    print(f"served {len(results)} requests ({n_tok} tokens) in {dt:.2f}s; "
          f"max concurrent batch {engine.max_concurrent}")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid]['tokens']}")


if __name__ == "__main__":
    main()
