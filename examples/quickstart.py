"""Quickstart: the paper's core objects in 30 lines.

  1. quantize a tensor with po2 scales (Eq. 2),
  2. re-layout it with the scaling-aware DIRECT transpose (Algorithm 1) and
     verify zero double-quantization error,
  3. run one FP8-Flow expert FFN fwd+bwd and print the cast ledger (Fig. 2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import casts
from repro.core.linear import expert_ffn, quantize_entry
from repro.core.quant import quantize_rowwise, _dequantize_nocount
from repro.core.recipes import get_recipe
from repro.core.transpose import transpose_direct

r = np.random.default_rng(0)
x = jnp.asarray(r.normal(size=(256, 512)).astype(np.float32))

# 1. po2 quantization
q = quantize_rowwise(x)
print(f"quantized {x.shape} -> e4m3 payload + {q.scale.shape} po2 scales")

# 2. casting-free re-layout
qt = transpose_direct(q)
err = np.abs(np.asarray(_dequantize_nocount(qt, jnp.float32))
             - np.asarray(_dequantize_nocount(q, jnp.float32)).T).max()
print(f"direct transpose max |error| vs exact relayout: {err:.2e}")

# 3. FP8-Flow expert FFN: 2 explicit casts per fwd+bwd
recipe = get_recipe("fp8_flow")
E, C, K, F = 2, 128, 512, 256
xe = jnp.asarray(r.normal(size=(E, C, K)).astype(np.float32)).astype(jnp.bfloat16)
w13 = jnp.asarray(r.normal(size=(E, K, 2 * F)).astype(np.float32) * 0.05)
w2 = jnp.asarray(r.normal(size=(E, F, K)).astype(np.float32) * 0.05)

def loss(xe, w13, w2):
    y = expert_ffn(recipe, "swiglu", (), (), quantize_entry(recipe, xe),
                   w13, w2)
    return jnp.sum(y.astype(jnp.float32) ** 2)

with casts.ledger() as led:
    grads = jax.grad(loss, argnums=(0, 1, 2))(xe, w13, w2)
print(f"explicit casts in fwd+bwd: {led.activation_casts()} "
      f"(fused: {led.fused_casts()})")
print(led.summary())
